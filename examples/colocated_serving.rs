//! Co-located multi-application serving (paper §7.3) at simulation speed.
//!
//! Runs QA + RG + CG sharing 4 Llama3-8B instances under excessive load and
//! compares Parrot (FCFS+RR), Ayo (Topo+RR) and Kairos (priority + time-slot
//! packing) on program-level token latency.
//!
//! Run: `cargo run --release --example colocated_serving`

// Examples time real runs; clippy's disallowed-methods (wall-clock) check
// only guards library code.
#![allow(clippy::disallowed_methods)]

use kairos::server::sim::{run_system, SimConfig};
use kairos::stats::rng::Rng;
use kairos::workload::{TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    println!("== Kairos co-located serving (QA + RG + CG, 4x A40/Llama3-8B sim) ==\n");
    let cfg = SimConfig::default();
    let rate = 5.0; // excessive-load operating point
    let n_tasks = 3000;

    let mut rows = Vec::new();
    for (name, sched, disp) in [
        ("Parrot (FCFS + RR)", "parrot", "rr"),
        ("Ayo    (Topo + RR)", "ayo", "rr"),
        ("Kairos (priority + packing)", "kairos", "kairos"),
    ] {
        let arrivals = TraceGen::default().generate(
            &WorkloadMix::colocated(),
            rate,
            n_tasks,
            &mut Rng::new(42),
        );
        let t0 = std::time::Instant::now();
        let res = run_system(cfg, sched, disp, arrivals);
        let s = &res.summary;
        println!(
            "{name:<30} avg {:.4}  P90 {:.4}  P95 {:.4}  P99 {:.4}  (qr {:.0}%, {} wf, {:.2}s wall)",
            s.avg_token_latency,
            s.p90_token_latency,
            s.p95_token_latency,
            s.p99_token_latency,
            s.mean_queue_ratio * 100.0,
            s.n_workflows,
            t0.elapsed().as_secs_f64(),
        );
        rows.push((name, s.avg_token_latency, s.p99_token_latency));
    }

    let parrot = rows[0].1;
    let ayo = rows[1].1;
    let kairos = rows[2].1;
    println!(
        "\nKairos avg reduction: {:.1}% vs Parrot, {:.1}% vs Ayo",
        (1.0 - kairos / parrot) * 100.0,
        (1.0 - kairos / ayo) * 100.0
    );
    println!("(paper §7.3: −45.1%..−72.8% vs Parrot, −6.1%..−37.9% vs Ayo)");
    anyhow::ensure!(kairos < parrot, "Kairos must beat Parrot under load");
    println!("\ncolocated_serving OK");
    Ok(())
}
