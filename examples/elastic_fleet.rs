//! Elastic fleet under a load burst and moving co-tenant pressure.
//!
//! The public-cloud regime the paper targets: load is bursty and the KV
//! budget each instance really has moves with its co-tenants. A fixed
//! 2-instance fleet takes a 10x overload burst on the chin; the elastic
//! fleet grows on the burst (queue-depth + queuing-ratio thresholds with
//! hysteresis), serves the same trace at a fraction of the latency, then
//! drains the extra instances back out once the calm tail arrives —
//! with every in-flight request of a retiring instance running to
//! completion (zero drops). A `PressureTrace` squeezes the original two
//! instances to 60% of their KV budget mid-run, so the memory-aware
//! time-slot dispatcher packs against budgets that change underneath it.
//!
//! Run: `cargo run --release --example elastic_fleet`

use kairos::server::autoscale::AutoscaleConfig;
use kairos::server::coordinator::{FleetSpec, PROVISIONING};
use kairos::server::pressure::PressureTrace;
use kairos::server::sim::{run_fleet, FleetConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{ArrivalEvent, TraceGen, WorkloadMix};

/// An overload burst followed by a calm tail.
fn burst_then_calm(seed: u64) -> Vec<ArrivalEvent> {
    let gen = TraceGen::default();
    let mut rng = Rng::new(seed);
    let mut arrivals = gen.generate(&WorkloadMix::colocated(), 14.0, 320, &mut rng);
    let burst_end = arrivals.last().map(|a| a.at).unwrap_or(0.0);
    for mut a in gen.generate(&WorkloadMix::colocated(), 0.8, 80, &mut rng) {
        a.at += burst_end;
        arrivals.push(a);
    }
    arrivals
}

fn main() -> anyhow::Result<()> {
    let fleet = FleetSpec::parse("2*llama3-8b@0.12").map_err(anyhow::Error::msg)?;
    // The original two instances lose 40% of their KV budget to co-tenants
    // between t=20s and t=80s; autoscaled instances are unpressured.
    let pressure = PressureTrace::parse("0:20=0.6,80=1.0;1:20=0.6,80=1.0")
        .map_err(anyhow::Error::msg)?;
    let mut auto = AutoscaleConfig::for_template(fleet.instances[0]);
    auto.min_instances = fleet.len();
    auto.max_instances = 6;
    auto.up_after = 1;
    auto.down_after = 2;
    auto.cooldown = 5.0;
    let floor = auto.min_instances;

    println!("== elastic vs fixed fleet under a 14 req/s burst + co-tenant pressure ==\n");
    let mut t = Table::new(&[
        "fleet", "avg s/tok", "P99 s/tok", "queue%", "dropped", "grows", "retires",
        "active@end",
    ]);
    for (label, autoscale) in [("fixed 2x", None), ("elastic 2..6", Some(auto))] {
        let elastic = autoscale.is_some();
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.autoscale = autoscale;
        cfg.pressure = Some(pressure.clone());
        let res = run_fleet(cfg, "kairos", "kairos", burst_then_calm(11));
        let (grows, retires) = res.scale_counts();
        let s = &res.summary;
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            res.dropped_requests.to_string(),
            grows.to_string(),
            retires.to_string(),
            res.final_active_instances.to_string(),
        ]);
        if elastic {
            println!("elastic scale events:");
            for ev in &res.scale_log {
                if ev.instance == PROVISIONING {
                    println!("  t={:7.2}s  (booting)   {:?}", ev.at, ev.kind);
                } else {
                    println!("  t={:7.2}s  instance {}  {:?}", ev.at, ev.instance, ev.kind);
                }
            }
            println!();
            // The acceptance contract of the elastic fleet:
            assert!(grows >= 1, "burst must grow the fleet");
            assert!(retires >= 1, "calm tail must drain it back down");
            assert_eq!(res.dropped_requests, 0, "draining dropped in-flight work");
            assert_eq!(
                res.final_active_instances, floor,
                "fleet must return to its floor"
            );
        }
    }
    t.print();
    println!("\nelastic_fleet OK");
    Ok(())
}
