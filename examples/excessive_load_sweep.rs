//! Load-sweep example: how the three systems degrade as the shared LLM
//! moves from idle to excessive load (paper §1's motivating regime).
//!
//! Sweeps the request rate, reports queueing ratio and avg token latency
//! per system — the crossover structure (all equal when idle, Kairos
//! pulling ahead as queueing grows) is the paper's core story.
//!
//! Run: `cargo run --release --example excessive_load_sweep`

use kairos::server::sim::{run_system, SimConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    println!("== load sweep: idle -> excessive (co-located workload, 4 instances) ==\n");
    let cfg = SimConfig::default();
    let mut t = Table::new(&[
        "rate (req/s)", "queue ratio", "Parrot avg", "Ayo avg", "Kairos avg",
        "Kairos vs Parrot",
    ]);
    for rate in [0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0] {
        let mut lat = std::collections::HashMap::new();
        let mut qr = 0.0;
        for (sys, sched, disp) in
            [("parrot", "parrot", "rr"), ("ayo", "ayo", "rr"), ("kairos", "kairos", "kairos")]
        {
            let arrivals = TraceGen::default().generate(
                &WorkloadMix::colocated(),
                rate,
                1200,
                &mut Rng::new(7),
            );
            let res = run_system(cfg, sched, disp, arrivals);
            if sys == "parrot" {
                qr = res.summary.mean_queue_ratio;
            }
            lat.insert(sys, res.summary.avg_token_latency);
        }
        let (p, a, k) = (lat["parrot"], lat["ayo"], lat["kairos"]);
        t.row(vec![
            format!("{rate:.1}"),
            format!("{:.0}%", qr * 100.0),
            format!("{p:.4}"),
            format!("{a:.4}"),
            format!("{k:.4}"),
            format!("{:+.1}%", (k - p) / p * 100.0),
        ]);
    }
    t.print();
    println!("\nexcessive_load_sweep OK");
    Ok(())
}
