//! Heterogeneous-fleet sweep: mixed per-instance KV budgets (and models)
//! behind one coordinator.
//!
//! Public-cloud co-tenancy is uneven: two of the four instances here keep
//! their usual 12% KV share while the other two are squeezed to 4% and a
//! half-width batch — the regime where a fleet-wide capacity constant lies
//! to the dispatcher. Every dispatcher runs over the *same* runtime
//! (`server::coordinator`), packing against each instance's real budget;
//! the memory-aware policies should hold the latency line where the blind
//! ones collapse into preemption storms.
//!
//! Run: `cargo run --release --example hetero_fleet`

use kairos::server::coordinator::FleetSpec;
use kairos::server::sim::{run_fleet, FleetConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    let fleets = [
        ("uniform 4×12%", "4*llama3-8b@0.12"),
        ("uneven 2×12% + 2×4%:128", "2*llama3-8b@0.12,2*llama3-8b@0.04:128"),
        ("mixed models 8B + 13B", "2*llama3-8b@0.12,2*llama2-13b@0.12"),
    ];
    for (label, spec) in fleets {
        let fleet = FleetSpec::parse(spec).map_err(anyhow::Error::msg)?;
        println!("== {label} ==");
        let mut t = Table::new(&[
            "dispatcher", "avg s/tok", "P99 s/tok", "queue%", "preempt%", "dropped",
        ]);
        for disp in ["rr", "least", "oracle", "kairos"] {
            let arrivals = TraceGen::default().generate(
                &WorkloadMix::colocated(),
                5.0,
                500,
                &mut Rng::new(11),
            );
            let res = run_fleet(FleetConfig::from(fleet.clone()), "kairos", disp, arrivals);
            let s = &res.summary;
            t.row(vec![
                res.dispatcher_name.to_string(),
                format!("{:.4}", s.avg_token_latency),
                format!("{:.4}", s.p99_token_latency),
                format!("{:.1}%", s.mean_queue_ratio * 100.0),
                format!("{:.1}%", s.preemption_rate * 100.0),
                res.dropped_requests.to_string(),
            ]);
        }
        t.print();
        println!();
    }
    println!("hetero_fleet OK");
    Ok(())
}
