//! Learned agent→family routing escaping a wrong static pin.
//!
//! A mixed fleet serves two Llama3-8B instances next to two Llama2-13B
//! co-tenants (slower per step, ~6x denser KV). The operator's affinity
//! spec pins *everything* to the 13B family — a plausible but wrong
//! guess. Under the static `pinned` policy the 8B half of the fleet
//! idles while the 13B group queues; under the `learned` policy the
//! router's deterministic exploration samples both families, the
//! per-(agent, family) latency profiles converge, and traffic migrates to
//! the measured-faster 8B group — pins are priors, not fate. `Any`
//! requests (none here, every agent is pinned) would meanwhile be
//! balanced to the least-pressured group.
//!
//! Run: `cargo run --release --example learned_routing`

use kairos::orchestrator::affinity::AffinitySpec;
use kairos::orchestrator::router::RoutePolicy;
use kairos::server::coordinator::FleetSpec;
use kairos::server::sim::{run_fleet, FleetConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,2*llama2-13b@0.12")
        .map_err(anyhow::Error::msg)?;
    let affinity = AffinitySpec::parse("*=llama2-13b").map_err(anyhow::Error::msg)?;
    let mut t = Table::new(&[
        "routing", "avg s/tok", "P99 s/tok", "mean e2e s", "8B dispatches", "13B dispatches",
    ]);
    let mut e2e = Vec::new();
    for (label, route) in [
        ("pinned (all 13B)", RoutePolicy::Pinned),
        ("learned", RoutePolicy::learned_default()),
    ] {
        let arrivals = TraceGen::default().generate(
            &WorkloadMix::colocated(),
            3.0,
            300,
            &mut Rng::new(17),
        );
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.affinity = Some(affinity.clone());
        cfg.route = Some(route);
        let res = run_fleet(cfg, "kairos", "kairos", arrivals);
        let s = &res.summary;
        let mean_e2e = res.mean_request_e2e();
        e2e.push(mean_e2e);
        let to_8b = res.group_log.iter().filter(|g| g.instance < 2).count();
        let to_13b = res.group_log.iter().filter(|g| g.instance >= 2).count();
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{mean_e2e:.3}"),
            to_8b.to_string(),
            to_13b.to_string(),
        ]);
        assert_eq!(res.cross_model_dispatches(), 0, "{label}: cross-model dispatch");
    }
    t.print();
    println!(
        "\nlearned mean E2E {:.3}s vs pinned {:.3}s ({}x)",
        e2e[1],
        e2e[0],
        (e2e[0] / e2e[1].max(1e-9)).round()
    );
    assert!(
        e2e[1] < e2e[0],
        "learned routing must beat the wrong static pin: {} !< {}",
        e2e[1],
        e2e[0]
    );
    println!("learned_routing OK");
    Ok(())
}
