//! Mixed-model fleet with model-affine serving groups.
//!
//! A public-cloud fleet rarely serves one model: here three Llama3-8B
//! instances share the coordinator with one Llama2-13B co-tenant whose
//! denser KV leaves it an order of magnitude smaller in tokens and ~1.7x
//! slower per step. Unsharded (everything `Any`), a load-blind dispatcher
//! sends every 4th request to the slow instance and its engine queue
//! balloons. With agent→model-class affinity, the central queue shards
//! into per-family serving groups: pinned requests only ever dispatch to
//! their own family (zero cross-model dispatches, by construction), a
//! blocked group stalls only itself, and the time-slot packer prices each
//! instance with its own cost model.
//!
//! Run: `cargo run --release --example mixed_model_fleet`

use kairos::orchestrator::affinity::AffinitySpec;
use kairos::server::coordinator::FleetSpec;
use kairos::server::sim::{run_fleet, FleetConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    let fleet = FleetSpec::parse("3*llama3-8b@0.12,llama2-13b@0.12")
        .map_err(anyhow::Error::msg)?;
    let affinities = [
        ("unsharded (all Any)", None),
        ("pin all to 8B group", Some("*=llama3-8b")),
        (
            "code agents on 13B",
            Some("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b"),
        ),
    ];
    for disp in ["rr", "kairos"] {
        println!("== dispatcher {disp} over {} instances ==", fleet.len());
        let mut t = Table::new(&[
            "affinity", "avg s/tok", "P99 s/tok", "mean queue s", "cross-model", "dropped",
        ]);
        let mut baseline_queue = None;
        for (label, aff) in affinities {
            let arrivals = TraceGen::default().generate(
                &WorkloadMix::colocated(),
                1.5,
                300,
                &mut Rng::new(11),
            );
            let mut cfg = FleetConfig::from(fleet.clone());
            cfg.affinity = aff
                .map(AffinitySpec::parse)
                .transpose()
                .map_err(anyhow::Error::msg)?;
            let res = run_fleet(cfg, "kairos", disp, arrivals);
            let s = &res.summary;
            let queue_delay = res.mean_queue_delay();
            t.row(vec![
                label.to_string(),
                format!("{:.4}", s.avg_token_latency),
                format!("{:.4}", s.p99_token_latency),
                format!("{queue_delay:.3}"),
                res.cross_model_dispatches().to_string(),
                res.dropped_requests.to_string(),
            ]);
            match baseline_queue {
                None => baseline_queue = Some(queue_delay),
                Some(b) => {
                    if queue_delay < b {
                        println!(
                            "  {label}: mean queuing delay {queue_delay:.3}s \
                             < unsharded {b:.3}s"
                        );
                    }
                }
            }
            assert_eq!(res.cross_model_dispatches(), 0, "{label}: cross-model dispatch");
        }
        t.print();
        println!();
    }
    println!("mixed_model_fleet OK");
    Ok(())
}
