//! Multi-agent QA over the developer API (paper Listing 1) + automated
//! workflow analysis (paper §4.2).
//!
//! Builds the Question-Answer application with the BaseAgent/Workflow API,
//! runs tasks through the Kafka-like bus with transparent identifier
//! propagation, then shows what the orchestrator learned: the reconstructed
//! call graph (branch structure), remaining depths, and — for a synthetic
//! complex workflow — the sweep-line parallel/sequential classification of
//! Fig. 11.
//!
//! Run: `cargo run --release --example multi_agent_qa`

use std::sync::{Arc, Mutex};

use kairos::agents::api::{AgentOutput, BaseAgent, LlmClient, Workflow};
use kairos::bus::Broker;
use kairos::orchestrator::graph::{EdgeKind, ExecRecord};
use kairos::orchestrator::Orchestrator;

/// A toy LLM: answers instantly with canned text (the real-PJRT path is
/// exercised by the quickstart; this example is about orchestration).
struct ToyLlm {
    clock: Mutex<f64>,
}

impl LlmClient for ToyLlm {
    fn generate(&self, agent: &str, prompt: &str) -> (String, f64, f64) {
        let mut t = self.clock.lock().unwrap();
        let start = *t;
        // Different agents take different time — the latency diversity the
        // scheduler exploits.
        let dur = match agent {
            "Router" => 0.05,
            "MathAgent" => 0.8,
            _ => 1.9,
        };
        *t += dur;
        (format!("[{agent}] answer to: {prompt}"), start, *t)
    }
}

struct Router;
impl BaseAgent for Router {
    fn name(&self) -> &str {
        "Router"
    }
    fn run_impl(&mut self, input: &str, llm: &dyn LlmClient) -> AgentOutput {
        let (out, _, _) = llm.generate("Router", input);
        let next = if input.contains("compute") || input.contains('*') {
            "MathAgent"
        } else {
            "HumanitiesAgent"
        };
        AgentOutput { payload: out, next_agent: Some(next.into()) }
    }
}

struct Expert(&'static str);
impl BaseAgent for Expert {
    fn name(&self) -> &str {
        self.0
    }
    fn run_impl(&mut self, input: &str, llm: &dyn LlmClient) -> AgentOutput {
        let (out, _, _) = llm.generate(self.0, input);
        AgentOutput { payload: out, next_agent: None }
    }
}

fn main() -> anyhow::Result<()> {
    println!("== Kairos multi-agent QA: developer API + workflow analysis ==\n");
    let orch = Arc::new(Mutex::new(Orchestrator::new()));
    let mut wf = Workflow::new(Broker::new(), orch.clone());
    wf.add_agent(Box::new(Router));
    wf.add_agent(Box::new(Expert("MathAgent")));
    wf.add_agent(Box::new(Expert("HumanitiesAgent")));

    let llm = ToyLlm { clock: Mutex::new(0.0) };
    let tasks = [
        "compute 17 * 23",
        "who was Napoleon?",
        "compute the integral of x^2",
        "what caused World War 1?",
        "compute 5!",
    ];
    for task in tasks {
        let (answer, msg_id) = wf.run_task("Router", task, &llm)?;
        println!("task {msg_id}: {task:?}\n  -> {answer}");
    }

    // What did the orchestrator learn?
    let o = orch.lock().unwrap();
    let router = o.registry.get("Router").unwrap();
    println!("\n== learned workflow structure ==");
    for (&(up, down), stats) in o.graph.edges() {
        println!(
            "  {} -> {}  ({:?}, observed {}x)",
            o.registry.name(up),
            o.registry.name(down),
            stats.kind,
            stats.count
        );
    }
    println!("  remaining depth(Router) = {}", o.graph.remaining_depth(router));
    for name in ["Router", "MathAgent", "HumanitiesAgent"] {
        let id = o.registry.get(name).unwrap();
        if let Some(p) = o.profiler.exec_profile(id) {
            println!(
                "  exec profile {name:<17} n={} mean={:.2}s",
                p.len(),
                p.mean().unwrap_or(0.0)
            );
        }
    }
    drop(o);

    // Fig 11: parallel vs sequential fan-out disambiguation by sweep line.
    println!("\n== Fig 11: complex fan-out classification ==");
    let mut orch2 = Orchestrator::new();
    let a = orch2.registry.intern("A");
    let b = orch2.registry.intern("B");
    let c = orch2.registry.intern("C");
    let d = orch2.registry.intern("D");
    // msg 1: A fans out to B, C, D in parallel (overlapping spans).
    for (agent, up, s, e) in
        [(a, None, 0.0, 1.0), (b, Some(a), 1.0, 3.0), (c, Some(a), 1.2, 2.5), (d, Some(a), 1.1, 4.0)]
    {
        orch2.record_execution(ExecRecord { msg_id: 1, agent, upstream: up, start: s, end: e });
    }
    // msg 2: E calls F, G, H sequentially (disjoint spans) — a different
    // application whose structure must be learned independently.
    let e_ = orch2.registry.intern("E");
    let f_ = orch2.registry.intern("F");
    let g_ = orch2.registry.intern("G");
    let h_ = orch2.registry.intern("H");
    for (agent, up, s, e) in [
        (e_, None, 10.0, 11.0),
        (f_, Some(e_), 11.0, 12.0),
        (g_, Some(e_), 12.5, 13.5),
        (h_, Some(e_), 14.0, 15.0),
    ] {
        orch2.record_execution(ExecRecord { msg_id: 2, agent, upstream: up, start: s, end: e });
    }
    for (&(up, down), stats) in orch2.graph.edges() {
        println!(
            "  {} -> {}  classified {:?}",
            orch2.registry.name(up),
            orch2.registry.name(down),
            stats.kind
        );
    }
    let kinds: Vec<EdgeKind> =
        orch2.graph.edges().map(|(_, s)| s.kind).collect();
    assert!(kinds.iter().all(|k| *k != EdgeKind::Simple), "fan-out classified");
    println!("\nmulti_agent_qa OK");
    Ok(())
}
