//! Profiling driver for the perf pass: one heavy co-located run.

// Examples time real runs; clippy's disallowed-methods (wall-clock) check
// only guards library code.
#![allow(clippy::disallowed_methods)]

fn main() {
    use kairos::server::sim::*; use kairos::workload::*; use kairos::stats::rng::Rng;
    let cfg = SimConfig::default();
    let arrivals = TraceGen::default().generate(&WorkloadMix::colocated(), 5.0, 8000, &mut Rng::new(13));
    let t0 = std::time::Instant::now();
    let res = run_system(cfg, "kairos", "kairos", arrivals);
    println!("events={} wall={:?} ev/s={:.0}", res.events_processed, t0.elapsed(),
        res.events_processed as f64 / t0.elapsed().as_secs_f64());
}
