//! Quickstart: the END-TO-END validation driver (DESIGN.md §7).
//!
//! Loads the real AOT-compiled tiny LM through PJRT (no python anywhere on
//! the request path), serves batched multi-agent requests through the same
//! queue → scheduler → dispatcher → continuous-batching engine stack the
//! simulations use, and reports latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::Path;

use kairos::dispatch::RoundRobin;
use kairos::lb::policies::Fcfs;
use kairos::server::real::{RealServer, ServeRequest};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("tiny_manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    println!("== Kairos quickstart: real PJRT serving ==\n");
    let mut server = RealServer::new(
        artifacts,
        "tiny",
        2, // two engine instances behind one load balancer
        Box::new(Fcfs),
        Box::new(RoundRobin::new()),
    )?;

    // A small multi-agent-flavoured batch: routers, experts, writers.
    let prompts = [
        ("Router", "Route this: what is 17 * 23?", 4),
        ("MathAgent", "Solve step by step: 17 * 23 =", 16),
        ("HumanitiesAgent", "Describe the causes of World War 1.", 20),
        ("Router", "Route this: who was Napoleon?", 4),
        ("ResearchAgent", "Collect material on LLM serving.", 16),
        ("WriterAgent", "Write a report from the materials.", 20),
        ("Engineer", "Implement quicksort in rust.", 18),
        ("QAEngineer", "Review the code for bugs.", 12),
    ];
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .map(|(agent, prompt, max_tokens)| ServeRequest {
            agent: agent.to_string(),
            prompt: prompt.to_string(),
            max_tokens: *max_tokens,
        })
        .collect();

    let (responses, stats) = server.serve(reqs)?;

    println!("{:<18} {:>5} {:>9} {:>9}  completion", "agent", "tok", "queue(s)", "e2e(s)");
    println!("{}", "-".repeat(78));
    for r in &responses {
        println!(
            "{:<18} {:>5} {:>9.4} {:>9.4}  {:?}",
            r.agent,
            r.output_tokens,
            r.queue_seconds,
            r.e2e_seconds,
            &r.completion[..r.completion.len().min(24)]
        );
    }
    println!("\n== summary ==");
    println!("requests served     : {}", stats.n_requests);
    println!("tokens generated    : {}", stats.total_tokens);
    println!("wall time           : {:.3} s", stats.wall_seconds);
    println!("throughput          : {:.1} tok/s", stats.tokens_per_second);
    println!("mean e2e latency    : {:.4} s", stats.mean_e2e);
    println!("p90 e2e latency     : {:.4} s", stats.p90_e2e);
    println!("PJRT compute time   : {:.3} s", stats.compute_seconds);
    assert_eq!(stats.n_requests, prompts.len(), "every request must complete");
    println!("\nquickstart OK — all layers (Pallas→JAX→HLO→PJRT→rust engine) composed.");
    Ok(())
}
