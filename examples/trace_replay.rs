//! Record a run, transform the trace, replay the scenarios.
//!
//! One mixed-model run is recorded through the coordinator's trace log
//! and written to JSONL. The reloaded artifact then becomes a family of
//! scenarios through the deterministic transforms: the original replay
//! (which must reproduce the recorded run's dispatch log exactly — the
//! record→replay contract), a 2x rate-scaled overload, a clipped window,
//! and a spliced double-length trace. Every scenario replays the SAME
//! recorded workload, so the latency differences are the scenario, not
//! sampling noise.
//!
//! Run: `cargo run --release --example trace_replay`

use kairos::server::coordinator::FleetSpec;
use kairos::server::sim::{run_fleet, FleetConfig};
use kairos::stats::rng::Rng;
use kairos::util::table::Table;
use kairos::workload::{Trace, TraceGen, WorkloadMix};

fn main() -> anyhow::Result<()> {
    let fleet = FleetSpec::parse("2*llama3-8b@0.12").map_err(anyhow::Error::msg)?;

    // Record: run the generator's workload once and capture the trace.
    let arrivals = TraceGen::default().generate(
        &WorkloadMix::colocated(),
        4.0,
        300,
        &mut Rng::new(23),
    );
    let res = run_fleet(FleetConfig::from(fleet.clone()), "kairos", "kairos", arrivals);
    let recorded = Trace::from_records(res.trace_log);
    let path = std::env::temp_dir().join("kairos_example_trace.jsonl");
    recorded.save(&path).map_err(anyhow::Error::msg)?;
    println!(
        "recorded {} tasks spanning {:.1}s -> {}\n",
        recorded.len(),
        recorded.span(),
        path.display()
    );

    // Replay: reload the artifact and derive the scenario family.
    let base = Trace::load(&path).map_err(anyhow::Error::msg)?;
    std::fs::remove_file(&path).ok();
    let scenarios = [
        ("replay (identical)", base.clone()),
        ("rate x2 (overload)", base.scale_rate(2.0).map_err(anyhow::Error::msg)?),
        ("first half (clip)", base.clip(0.0, base.span() / 2.0).map_err(anyhow::Error::msg)?),
        ("spliced x2 (marathon)", base.splice(&base)),
    ];

    let mut t = Table::new(&[
        "scenario", "tasks", "req/s", "avg s/tok", "queue%", "dropped",
    ]);
    for (label, trace) in &scenarios {
        let r = run_fleet(
            FleetConfig::from(fleet.clone()),
            "kairos",
            "kairos",
            trace.arrivals(),
        );
        if *label == "replay (identical)" {
            assert_eq!(
                r.dispatch_log, res.dispatch_log,
                "record→replay must reproduce the original dispatch log"
            );
        }
        t.row(vec![
            label.to_string(),
            trace.len().to_string(),
            format!("{:.2}", trace.mean_rate()),
            format!("{:.4}", r.summary.avg_token_latency),
            format!("{:.1}%", r.summary.mean_queue_ratio * 100.0),
            r.dropped_requests.to_string(),
        ]);
    }
    t.print();
    println!("\nreplay reproduced the recorded dispatch log exactly.");
    Ok(())
}
