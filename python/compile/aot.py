"""AOT lowering: JAX entry points -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model config ``<name>``:
  artifacts/<name>_prefill.hlo.txt   (tokens[B,S], seq_lens[B], kv) -> tuple
  artifacts/<name>_decode.hlo.txt    (tokens[B],   seq_lens[B], kv) -> tuple
  artifacts/<name>_manifest.json     static shapes the rust side validates

Both entry points return ``(logits, next_token, kv_cache)`` lowered with
``return_tuple=True``; the rust side unwraps the 3-tuple.

Usage: ``python -m compile.aot --out ../artifacts [--models tiny,micro]``
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked model weights ARE large constants; the
    # default printer elides them as `{...}` which the rust-side text parser
    # would silently zero-fill.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_entry_points(cfg: ModelConfig):
    """Lower prefill and decode for ``cfg``; returns (prefill_txt, decode_txt).

    Weights are created here and closed over, so they are constants in the
    emitted HLO (donated-arg style weight threading would force the rust side
    to carry ~1MB literals per call instead).
    """
    weights = model.init_weights(cfg)

    prefill_fn = functools.partial(model.prefill, cfg, weights)
    decode_fn = functools.partial(model.decode_step, cfg, weights)

    tokens2d = jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq), jnp.int32)
    tokens1d = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    lens = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, 2, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim),
        jnp.float32,
    )

    prefill_txt = to_hlo_text(jax.jit(prefill_fn).lower(tokens2d, lens, kv))
    decode_txt = to_hlo_text(jax.jit(decode_fn).lower(tokens1d, lens, kv))
    return prefill_txt, decode_txt


def manifest_for(cfg: ModelConfig) -> dict:
    """Static metadata the rust runtime validates against at load time."""
    return {
        "name": cfg.name,
        "vocab_size": cfg.vocab_size,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "batch": cfg.batch,
        "seed": cfg.seed,
        "kv_cache_shape": [
            cfg.n_layers, 2, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim,
        ],
        "outputs": ["logits", "next_token", "kv_cache"],
        "prefill_hlo": f"{cfg.name}_prefill.hlo.txt",
        "decode_hlo": f"{cfg.name}_decode.hlo.txt",
    }


def golden_for(cfg: ModelConfig, steps: int = 6) -> dict:
    """Reference greedy generation the rust runtime must reproduce exactly.

    A fixed prompt per batch row is prefilled and decoded ``steps`` times in
    python; the rust integration test replays the same calls through PJRT
    and compares token-for-token.
    """
    import jax.numpy as jnp

    weights = model.init_weights(cfg)
    prompts = [
        [(7 * i + 3 * b) % cfg.vocab_size for i in range(2 + b)]
        for b in range(cfg.batch)
    ]
    tokens = jnp.zeros((cfg.batch, cfg.max_seq), jnp.int32)
    lens = []
    for b, p in enumerate(prompts):
        tokens = tokens.at[b, : len(p)].set(jnp.array(p, jnp.int32))
        lens.append(len(p))
    seq_lens = jnp.array(lens, jnp.int32)
    cache = model.empty_cache(cfg)
    logits, nxt, cache = model.prefill(cfg, weights, tokens, seq_lens, cache)
    generated = [[int(t)] for t in nxt]
    cur_lens = seq_lens
    cur = nxt
    for _ in range(steps - 1):
        _, cur, cache = model.decode_step(cfg, weights, cur, cur_lens, cache)
        cur_lens = cur_lens + 1
        for b in range(cfg.batch):
            generated[b].append(int(cur[b]))
    return {"prompts": prompts, "steps": steps, "generated": generated}


def build(out_dir: str, names) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name in names:
        cfg = CONFIGS[name]
        prefill_txt, decode_txt = lower_entry_points(cfg)
        paths = {
            f"{cfg.name}_prefill.hlo.txt": prefill_txt,
            f"{cfg.name}_decode.hlo.txt": decode_txt,
            f"{cfg.name}_manifest.json": json.dumps(manifest_for(cfg), indent=2),
            f"{cfg.name}_golden.json": json.dumps(golden_for(cfg)),
        }
        for fname, text in paths.items():
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--models", default="tiny,micro", help="comma-separated config names"
    )
    args = parser.parse_args()
    build(args.out, [n for n in args.models.split(",") if n])


if __name__ == "__main__":
    main()
