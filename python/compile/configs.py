"""Model configurations for the tiny served LM.

The rust coordinator serves AOT-compiled variants of this model through PJRT.
Shapes are static (PJRT executables are monomorphic): one (batch, max_seq)
pair per artifact set. ``tiny`` is the default end-to-end model; ``micro`` is
an even smaller variant used by fast tests.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static hyper-parameters of the served decoder-only LM."""

    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    d_ff: int
    max_seq: int  # KV-cache capacity (prompt + generated tokens)
    batch: int  # static engine batch width
    seed: int = 0  # PRNG seed the weights are derived from

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 K+V bytes per token across all layers (one sequence)."""
        return 2 * 4 * self.n_layers * self.n_heads * self.head_dim

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = 4 * d * self.n_heads * self.head_dim + 3 * d * f + 2 * d
        return v * d + self.max_seq * d + self.n_layers * per_layer + d + d * v


TINY = ModelConfig(
    name="tiny",
    vocab_size=256,
    d_model=64,
    n_layers=2,
    n_heads=4,
    head_dim=16,
    d_ff=256,
    max_seq=64,
    batch=4,
    seed=0,
)

MICRO = ModelConfig(
    name="micro",
    vocab_size=64,
    d_model=32,
    n_layers=1,
    n_heads=2,
    head_dim=16,
    d_ff=64,
    max_seq=16,
    batch=2,
    seed=1,
)

CONFIGS = {c.name: c for c in (TINY, MICRO)}
