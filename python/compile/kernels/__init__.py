"""Layer-1 Pallas kernels for the Kairos tiny served model.

Two fused kernels cover the decode hot path of the served LM:

- :mod:`attention` -- single-step decode attention over an explicit KV cache
  with per-sequence length masking (the vLLM hot spot the paper serves).
- :mod:`swiglu` -- fused SwiGLU feed-forward for the decode step.

Both are authored for TPU (VMEM tiling via BlockSpec, MXU-shaped matmuls) but
executed with ``interpret=True`` on this CPU-only image; numerics are verified
against the pure-jnp oracles in :mod:`ref` by pytest.
"""
