"""Fused decode-attention Pallas kernel.

Single-token decode: each sequence in the batch attends from one query token
over its KV cache prefix (``seq_lens[b]`` valid positions), producing the
attention output for that token. This is the per-step hot spot of a
continuous-batching LLM engine (what vLLM's paged-attention kernel does on
CUDA).

TPU adaptation (DESIGN.md #Hardware-Adaptation): instead of a CUDA
threadblock per sequence with shared-memory staging, the grid iterates
(batch,) and the BlockSpec stages each sequence's full KV prefix into VMEM;
masking is an in-register iota-vs-length compare; the QK^T and PV contractions
are jnp.dot's that land on the MXU when compiled for TPU. On this image the
kernel always runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls), so the lowered HLO is plain ops executable by the rust PJRT
CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Softmax numerics: subtract the row max before exp. Masked positions get
# this large negative bias so they contribute ~0 after exp.
_NEG_INF = -1e30


def _decode_attention_kernel(seq_len_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    """Kernel body for one batch element.

    Block shapes (leading batch dim squeezed via ``None`` in the BlockSpec):
      seq_len_ref: (1,)      int32   -- valid KV prefix length for this seq
      q_ref:       (H, D)    float   -- query for the current token
      k_ref:       (S, H, D) float   -- key cache (padded to max len S)
      v_ref:       (S, H, D) float   -- value cache
      o_ref:       (H, D)    float   -- attention output
    """
    q = q_ref[...].astype(jnp.float32)  # (H, D)
    k = k_ref[...].astype(jnp.float32)  # (S, H, D)
    v = v_ref[...].astype(jnp.float32)  # (S, H, D)
    seq_len = seq_len_ref[0]

    # scores[h, s] = scale * <q[h, :], k[s, h, :]>
    scores = jnp.einsum("hd,shd->hs", q, k) * scale  # (H, S)

    # Mask out positions >= seq_len (padding / not-yet-written cache slots).
    positions = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)  # (H, S)
    mask = positions < seq_len
    scores = jnp.where(mask, scores, _NEG_INF)

    # Numerically stable softmax over the key axis.
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=1, keepdims=True)
    # seq_len >= 1 always holds for live sequences, but guard anyway.
    p = p / jnp.maximum(denom, 1e-30)

    # out[h, d] = sum_s p[h, s] * v[s, h, d]
    out = jnp.einsum("hs,shd->hd", p, v)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, seq_lens, *, interpret=True):
    """Single-step decode attention over a padded KV cache.

    Args:
      q:        (B, H, D)     queries for the token being decoded.
      k_cache:  (B, S, H, D)  key cache; rows >= seq_lens[b] are padding.
      v_cache:  (B, S, H, D)  value cache.
      seq_lens: (B,) int32    number of valid cache rows per sequence
                              (includes the current token's K/V, already
                              written by the caller).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      (B, H, D) attention outputs, same dtype as ``q``.
    """
    batch, num_heads, head_dim = q.shape
    _, max_len, kh, kd = k_cache.shape
    assert (kh, kd) == (num_heads, head_dim), "KV cache head shape mismatch"
    assert v_cache.shape == k_cache.shape, "K and V cache shapes must match"
    assert seq_lens.shape == (batch,), "seq_lens must be (B,)"
    scale = 1.0 / (head_dim**0.5)

    kernel = functools.partial(_decode_attention_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),  # per-seq length
            pl.BlockSpec((None, num_heads, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, max_len, num_heads, head_dim), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((None, max_len, num_heads, head_dim), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, num_heads, head_dim), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, num_heads, head_dim), q.dtype),
        interpret=interpret,
    )(seq_lens, q, k_cache, v_cache)
