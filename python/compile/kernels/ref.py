"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the pytest suite compares the kernels against.
They deliberately avoid Pallas and any fused tricks: plain masked softmax
attention and a three-matmul SwiGLU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, seq_lens):
    """Reference single-step decode attention.

    Args mirror :func:`kernels.attention.decode_attention`.
    """
    batch, num_heads, head_dim = q.shape
    _, max_len, _, _ = k_cache.shape
    scale = 1.0 / (head_dim**0.5)

    qf = q.astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    scores = jnp.einsum("bhd,bshd->bhs", qf, kf) * scale  # (B, H, S)
    positions = jnp.arange(max_len, dtype=jnp.int32)[None, None, :]
    mask = positions < seq_lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask, probs, 0.0)  # handle all-masked rows -> NaN guard
    out = jnp.einsum("bhs,bshd->bhd", probs, vf)
    return out.astype(q.dtype)


def swiglu_ffn_ref(x, w_gate, w_up, w_down):
    """Reference SwiGLU FFN: ``silu(x @ w_gate) * (x @ w_up) @ w_down``."""
    xf = x.astype(jnp.float32)
    gate = xf @ w_gate.astype(jnp.float32)
    up = xf @ w_up.astype(jnp.float32)
    hidden = jax.nn.silu(gate) * up
    out = hidden @ w_down.astype(jnp.float32)
    return out.astype(x.dtype)


def causal_attention_ref(q, k, v, seq_lens):
    """Reference full (prefill) causal attention with padding mask.

    Args:
      q, k, v:  (B, S, H, D)
      seq_lens: (B,) valid token counts; positions >= seq_lens are padding.

    Returns:
      (B, S, H, D); rows at padded positions are zeros.
    """
    batch, max_len, num_heads, head_dim = q.shape
    scale = 1.0 / (head_dim**0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    qpos = jnp.arange(max_len)[None, None, :, None]
    kpos = jnp.arange(max_len)[None, None, None, :]
    causal = kpos <= qpos
    valid = kpos < seq_lens[:, None, None, None]
    mask = causal & valid
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask, probs, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    row_valid = (jnp.arange(max_len)[None, :] < seq_lens[:, None])[:, :, None, None]
    return jnp.where(row_valid, out, 0.0).astype(q.dtype)
