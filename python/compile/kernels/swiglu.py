"""Fused SwiGLU feed-forward Pallas kernel for the decode step.

Computes ``down( silu(x @ W_gate) * (x @ W_up) )`` in one kernel so the two
projection results never round-trip through HBM. On TPU the three matmuls are
MXU-shaped contractions over (D, F) / (F, D) tiles staged into VMEM by the
BlockSpec; here it runs under ``interpret=True``.

The decode step has a single token per sequence, so the activation block is
(B, D) -- small enough to keep entirely in VMEM alongside one (D, F) weight
tile; the grid is therefore trivial (single program) for the tiny model, but
the kernel is written to block over the FFN dimension so larger F would still
fit VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One grid step: a block of the FFN dimension.

    Block shapes (F blocked into chunks of Fb):
      x_ref:  (B, D)   activations (whole batch; decode step = 1 tok/seq)
      wg_ref: (D, Fb)  gate projection tile
      wu_ref: (D, Fb)  up projection tile
      wd_ref: (Fb, D)  down projection tile
      o_ref:  (B, D)   output; the block mapping is constant across the
                       grid, so it stays resident in VMEM and doubles as the
                       accumulator across F blocks.
    """
    fb = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)
    gate = x @ wg_ref[...].astype(jnp.float32)  # (B, Fb) -> MXU
    up = x @ wu_ref[...].astype(jnp.float32)  # (B, Fb) -> MXU
    hidden = jax.nn.silu(gate) * up
    partial = hidden @ wd_ref[...].astype(jnp.float32)  # (B, D) -> MXU

    @pl.when(fb == 0)
    def _init():
        o_ref[...] = partial.astype(o_ref.dtype)

    @pl.when(fb != 0)
    def _accum():
        o_ref[...] += partial.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def swiglu_ffn(x, w_gate, w_up, w_down, *, block_f=None, interpret=True):
    """Fused SwiGLU FFN: ``silu(x @ w_gate) * (x @ w_up) @ w_down``.

    Args:
      x:      (B, D)  input activations.
      w_gate: (D, F)  gate projection.
      w_up:   (D, F)  up projection.
      w_down: (F, D)  down projection.
      block_f: FFN-dimension block size (defaults to min(F, 128)); must
               divide F.
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      (B, D) output, same dtype as ``x``.
    """
    batch, d_model = x.shape
    d_in, d_ff = w_gate.shape
    assert d_in == d_model, "w_gate shape mismatch"
    assert w_up.shape == (d_model, d_ff), "w_up shape mismatch"
    assert w_down.shape == (d_ff, d_model), "w_down shape mismatch"
    if block_f is None:
        block_f = min(d_ff, 128)
    assert d_ff % block_f == 0, "block_f must divide the FFN dimension"
    n_blocks = d_ff // block_f

    return pl.pallas_call(
        _swiglu_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((batch, d_model), lambda f: (0, 0)),
            pl.BlockSpec((d_model, block_f), lambda f: (0, f)),
            pl.BlockSpec((d_model, block_f), lambda f: (0, f)),
            pl.BlockSpec((block_f, d_model), lambda f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((batch, d_model), lambda f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_model), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
