"""Layer-2: the tiny Llama-style decoder LM served by the rust coordinator.

Two jittable entry points over an explicit, caller-owned KV cache:

- :func:`prefill` -- run the (padded) prompt through the model, write the
  prompt's K/V into the cache, return next-token logits per sequence.
- :func:`decode_step` -- run ONE token per sequence, append its K/V to the
  cache, return logits. The attention and FFN of this hot path go through the
  Layer-1 Pallas kernels.

Design notes:
- Weights are derived from a PRNG seed and **closed over** at lowering time,
  so they appear as constants in the AOT HLO and the rust binary needs no
  weight files.
- Shapes are static; sequences shorter than ``max_seq`` are padded and
  masked via ``seq_lens``.
- Positional encoding is a learned embedding (simpler than RoPE and
  irrelevant to the serving experiments).
- All caches are functional: entry points return the updated cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import decode_attention
from .kernels.ref import causal_attention_ref
from .kernels.swiglu import swiglu_ffn


def init_weights(cfg: ModelConfig):
    """Deterministic weight pytree from ``cfg.seed``."""
    key = jax.random.PRNGKey(cfg.seed)
    d, h, hd, f, v = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff, cfg.vocab_size

    def dense(key, shape, scale=None):
        if scale is None:
            scale = 1.0 / (shape[0] ** 0.5)
        return jax.random.normal(key, shape, jnp.float32) * scale

    n_keys = 3 + 8 * cfg.n_layers
    keys = iter(jax.random.split(key, n_keys))
    weights = {
        "tok_emb": dense(next(keys), (v, d), scale=0.02),
        "pos_emb": dense(next(keys), (cfg.max_seq, d), scale=0.02),
        "layers": [],
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": dense(next(keys), (d, v)),
    }
    for _ in range(cfg.n_layers):
        weights["layers"].append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": dense(next(keys), (d, h * hd)),
                "wk": dense(next(keys), (d, h * hd)),
                "wv": dense(next(keys), (d, h * hd)),
                "wo": dense(next(keys), (h * hd, d)),
                "ffn_norm": jnp.ones((d,), jnp.float32),
                "w_gate": dense(next(keys), (d, f)),
                "w_up": dense(next(keys), (d, f)),
                "w_down": dense(next(keys), (f, d)),
            }
        )
    return weights


def rms_norm(x, gamma, eps=1e-5):
    """RMSNorm over the trailing feature axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def empty_cache(cfg: ModelConfig):
    """Fresh zeroed KV cache: (layers, 2, B, S, H, D) as one array."""
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim),
        jnp.float32,
    )


def prefill(cfg: ModelConfig, weights, tokens, seq_lens, kv_cache):
    """Process padded prompts; returns (logits, next_token, new_cache).

    Args:
      tokens:   (B, S) int32 prompt tokens, padded with anything.
      seq_lens: (B,) int32 valid prompt lengths (>= 1 for live rows).
      kv_cache: (L, 2, B, S, H, D) cache to (re)write.

    Returns:
      logits:     (B, V) logits for the token after each prompt.
      next_token: (B,) int32 greedy argmax.
      kv_cache:   updated cache with prompt K/V written at [0, seq_len).
    """
    b, s = tokens.shape
    assert (b, s) == (cfg.batch, cfg.max_seq)
    h, hd = cfg.n_heads, cfg.head_dim

    pos = jnp.arange(s)
    x = weights["tok_emb"][tokens] + weights["pos_emb"][None, pos]

    for li, layer in enumerate(weights["layers"]):
        xn = rms_norm(x, layer["attn_norm"])
        q = (xn @ layer["wq"]).reshape(b, s, h, hd)
        k = (xn @ layer["wk"]).reshape(b, s, h, hd)
        v = (xn @ layer["wv"]).reshape(b, s, h, hd)
        kv_cache = kv_cache.at[li, 0].set(k)
        kv_cache = kv_cache.at[li, 1].set(v)
        attn = causal_attention_ref(q, k, v, seq_lens)
        x = x + attn.reshape(b, s, h * hd) @ layer["wo"]
        xn = rms_norm(x, layer["ffn_norm"])
        hidden = jax.nn.silu(xn @ layer["w_gate"]) * (xn @ layer["w_up"])
        x = x + hidden @ layer["w_down"]

    x = rms_norm(x, weights["final_norm"])
    # Gather the hidden state at the last valid position of each sequence.
    last = jnp.clip(seq_lens - 1, 0, s - 1)
    x_last = x[jnp.arange(b), last]  # (B, D)
    logits = x_last @ weights["lm_head"]
    return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), kv_cache


def decode_step(cfg: ModelConfig, weights, tokens, seq_lens, kv_cache):
    """Decode ONE token per sequence through the Pallas hot path.

    Args:
      tokens:   (B,) int32 current input token per sequence.
      seq_lens: (B,) int32 number of cache rows already valid (i.e. the
                position this token will be written to).
      kv_cache: (L, 2, B, S, H, D).

    Returns:
      logits:     (B, V)
      next_token: (B,) int32 greedy argmax.
      kv_cache:   cache with this token's K/V appended at ``seq_lens``.
    """
    b = tokens.shape[0]
    assert b == cfg.batch
    h, hd = cfg.n_heads, cfg.head_dim

    pos = jnp.clip(seq_lens, 0, cfg.max_seq - 1)
    x = weights["tok_emb"][tokens] + weights["pos_emb"][pos]  # (B, D)

    rows = jnp.arange(b)
    for li, layer in enumerate(weights["layers"]):
        xn = rms_norm(x, layer["attn_norm"])
        q = (xn @ layer["wq"]).reshape(b, h, hd)
        k = (xn @ layer["wk"]).reshape(b, h, hd)
        v = (xn @ layer["wv"]).reshape(b, h, hd)
        kv_cache = kv_cache.at[li, 0, rows, pos].set(k)
        kv_cache = kv_cache.at[li, 1, rows, pos].set(v)
        # Attend over the prefix INCLUDING the token just written.
        attn = decode_attention(q, kv_cache[li, 0], kv_cache[li, 1], seq_lens + 1)
        x = x + attn.reshape(b, h * hd) @ layer["wo"]
        xn = rms_norm(x, layer["ffn_norm"])
        x = x + swiglu_ffn(xn, layer["w_gate"], layer["w_up"], layer["w_down"])

    x = rms_norm(x, weights["final_norm"])
    logits = x @ weights["lm_head"]
    return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), kv_cache


def full_forward_logits(cfg: ModelConfig, weights, tokens, seq_lens):
    """Oracle: next-token logits at EVERY position via one full forward.

    Used by tests to check prefill+decode consistency. Returns (B, S, V).
    """
    b, s = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    pos = jnp.arange(s)
    x = weights["tok_emb"][tokens] + weights["pos_emb"][None, pos]
    for layer in weights["layers"]:
        xn = rms_norm(x, layer["attn_norm"])
        q = (xn @ layer["wq"]).reshape(b, s, h, hd)
        k = (xn @ layer["wk"]).reshape(b, s, h, hd)
        v = (xn @ layer["wv"]).reshape(b, s, h, hd)
        attn = causal_attention_ref(q, k, v, seq_lens)
        x = x + attn.reshape(b, s, h * hd) @ layer["wo"]
        xn = rms_norm(x, layer["ffn_norm"])
        hidden = jax.nn.silu(xn @ layer["w_gate"]) * (xn @ layer["w_up"])
        x = x + hidden @ layer["w_down"]
    x = rms_norm(x, weights["final_norm"])
    return x @ weights["lm_head"]
