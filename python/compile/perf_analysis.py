"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

Pallas interpret=True gives CPU-numpy timings that say nothing about TPU
performance, so the L1 analysis is *structural*: per-kernel VMEM footprint
and MXU utilization estimates from the BlockSpecs, plus an HLO op census of
the lowered L2 module (fusion/redundancy check).

Usage: ``python -m compile.perf_analysis``
"""

from __future__ import annotations

import collections
import re

from . import aot
from .configs import CONFIGS

MXU_DIM = 128  # TPU systolic array is 128x128
VMEM_BYTES = 16 * 2**20  # ~16 MiB per TensorCore


def attention_kernel_stats(cfg):
    """Decode-attention kernel: one grid step = one sequence."""
    h, d, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    f32 = 4
    vmem = (
        h * d * f32  # q block
        + 2 * s * h * d * f32  # k + v blocks
        + h * d * f32  # out block
        + 2 * h * s * f32  # scores + probs intermediates
    )
    # MXU work per step: QK^T (h*d*s MACs) + PV (h*s*d MACs); the
    # contraction dims (d=16, s<=64) underfill the 128x128 array -> ratio.
    util = min(d / MXU_DIM, 1.0) * min(h / 8.0, 1.0)
    return vmem, util


def swiglu_kernel_stats(cfg, block_f=128):
    b, dm, f = cfg.batch, cfg.d_model, cfg.d_ff
    block_f = min(block_f, f)
    f32 = 4
    vmem = (
        b * dm * f32  # x block
        + 2 * dm * block_f * f32  # gate + up tiles
        + block_f * dm * f32  # down tile
        + b * dm * f32  # out/acc
        + 2 * b * block_f * f32  # gate/up intermediates
    )
    # Matmul shapes (b x dm) @ (dm x block_f): contraction dm=64 of 128.
    util = min(dm / MXU_DIM, 1.0) * min(block_f / MXU_DIM, 1.0)
    return vmem, util


def hlo_census(text: str) -> dict:
    ops = collections.Counter()
    for m in re.finditer(r"=\s+\w+\[[^\]]*\]\{?[^}]*\}?\s+([a-z-]+)\(", text):
        ops[m.group(1)] += 1
    return dict(ops)


def main() -> None:
    for name in ("tiny",):
        cfg = CONFIGS[name]
        print(f"== {name}: L1 kernel structure ==")
        vmem, util = attention_kernel_stats(cfg)
        print(
            f"decode-attention: VMEM/block {vmem/1024:.1f} KiB "
            f"({vmem/VMEM_BYTES*100:.2f}% of VMEM), MXU fill ~{util*100:.0f}%"
        )
        for bf in (64, 128, 256):
            vmem, util = swiglu_kernel_stats(cfg, bf)
            print(
                f"swiglu block_f={bf:<4}: VMEM/block {vmem/1024:.1f} KiB "
                f"({vmem/VMEM_BYTES*100:.2f}%), MXU fill ~{util*100:.0f}%"
            )

        print(f"\n== {name}: L2 HLO census ==")
        prefill_txt, decode_txt = aot.lower_entry_points(cfg)
        for kind, text in (("prefill", prefill_txt), ("decode", decode_txt)):
            ops = hlo_census(text)
            total = sum(ops.values())
            top = sorted(ops.items(), key=lambda kv: -kv[1])[:8]
            print(f"{kind}: {total} ops; top: {top}")
            fused = ops.get("fusion", 0)
            print(f"  fusions: {fused}; custom-calls: {ops.get('custom-call', 0)} (must be 0)")


if __name__ == "__main__":
    main()
