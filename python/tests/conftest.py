"""Shared fixtures for the python test suite."""

import jax
import pytest

from compile import model
from compile.configs import CONFIGS


@pytest.fixture(scope="session")
def tiny_cfg():
    return CONFIGS["tiny"]


@pytest.fixture(scope="session")
def micro_cfg():
    return CONFIGS["micro"]


@pytest.fixture(scope="session")
def tiny_weights(tiny_cfg):
    return model.init_weights(tiny_cfg)


@pytest.fixture(scope="session")
def micro_weights(micro_cfg):
    return model.init_weights(micro_cfg)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(1234)
