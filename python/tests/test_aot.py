"""AOT lowering: artifacts are well-formed HLO text with the right interface."""

import json
import os
import re

import pytest

from compile import aot
from compile.configs import CONFIGS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def micro_texts():
    return aot.lower_entry_points(CONFIGS["micro"])


def test_entry_has_three_params_and_tuple_root(micro_texts):
    cfg = CONFIGS["micro"]
    for text, tok_shape in zip(micro_texts, [f"s32[{cfg.batch},{cfg.max_seq}]", f"s32[{cfg.batch}]"]):
        entry = text[text.index("ENTRY") :]
        params = re.findall(r"parameter\(\d+\)", entry)
        assert len(params) == 3, "expects (tokens, seq_lens, kv_cache)"
        assert tok_shape in entry
        assert "ROOT" in entry and "tuple(" in entry


def test_no_elided_constants(micro_texts):
    for text in micro_texts:
        assert "{...}" not in text


def test_no_custom_calls(micro_texts):
    """interpret=True must lower Pallas to plain HLO (no Mosaic custom-call)."""
    for text in micro_texts:
        assert "custom-call" not in text, "CPU PJRT cannot run Mosaic custom-calls"


def test_manifest_round_trip(tmp_path):
    aot_dir = str(tmp_path)
    cfg = CONFIGS["micro"]
    manifest = aot.manifest_for(cfg)
    path = os.path.join(aot_dir, "m.json")
    with open(path, "w") as f:
        json.dump(manifest, f)
    with open(path) as f:
        back = json.load(f)
    assert back["batch"] == cfg.batch
    assert back["kv_cache_shape"] == [
        cfg.n_layers, 2, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim,
    ]
    assert back["outputs"] == ["logits", "next_token", "kv_cache"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "tiny_manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_consistent():
    for name in ("tiny", "micro"):
        with open(os.path.join(ART, f"{name}_manifest.json")) as f:
            m = json.load(f)
        cfg = CONFIGS[name]
        assert m["batch"] == cfg.batch and m["max_seq"] == cfg.max_seq
        for kind in ("prefill", "decode"):
            p = os.path.join(ART, m[f"{kind}_hlo"])
            assert os.path.exists(p)
            with open(p) as f:
                text = f.read()
            assert "ENTRY" in text and "{...}" not in text
