"""L1 correctness: Pallas decode-attention kernel vs pure-jnp oracle.

Parametrized sweeps over shapes, dtypes, seeds and sequence-length patterns
stand in for hypothesis (not installed on this image).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import decode_attention
from compile.kernels.ref import decode_attention_ref

SHAPES = [
    # (batch, heads, head_dim, max_len)
    (1, 1, 8, 4),
    (2, 2, 16, 16),
    (4, 4, 16, 64),
    (3, 5, 32, 33),  # deliberately non-power-of-two
    (8, 2, 64, 128),
]

DTYPES = [jnp.float32, jnp.bfloat16]


def make_inputs(key, batch, heads, head_dim, max_len, dtype, len_pattern):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (batch, heads, head_dim), dtype)
    k = jax.random.normal(ks[1], (batch, max_len, heads, head_dim), dtype)
    v = jax.random.normal(ks[2], (batch, max_len, heads, head_dim), dtype)
    if len_pattern == "ones":
        lens = jnp.ones((batch,), jnp.int32)
    elif len_pattern == "full":
        lens = jnp.full((batch,), max_len, jnp.int32)
    elif len_pattern == "random":
        lens = jax.random.randint(ks[3], (batch,), 1, max_len + 1).astype(jnp.int32)
    elif len_pattern == "mixed":
        base = [1, max_len, max(1, max_len // 2), max(1, max_len // 3)]
        lens = jnp.array([base[i % 4] for i in range(batch)], jnp.int32)
    else:
        raise ValueError(len_pattern)
    return q, k, v, lens


def tolerances(dtype):
    return (2e-2, 2e-2) if dtype == jnp.bfloat16 else (1e-5, 1e-5)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("len_pattern", ["ones", "full", "random", "mixed"])
def test_kernel_matches_ref(key, shape, dtype, len_pattern):
    q, k, v, lens = make_inputs(key, *shape, dtype, len_pattern)
    got = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    rtol, atol = tolerances(dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=atol
    )


@pytest.mark.parametrize("seed", range(8))
def test_kernel_matches_ref_seed_sweep(seed):
    key = jax.random.PRNGKey(seed)
    q, k, v, lens = make_inputs(key, 4, 4, 16, 32, jnp.float32, "random")
    got = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_output_shape_and_dtype(key):
    q, k, v, lens = make_inputs(key, 4, 4, 16, 32, jnp.float32, "random")
    out = decode_attention(q, k, v, lens)
    assert out.shape == q.shape
    assert out.dtype == q.dtype


def test_len_one_attends_only_first_position(key):
    """With seq_len == 1 the output must equal v[:, 0] exactly."""
    q, k, v, _ = make_inputs(key, 4, 4, 16, 32, jnp.float32, "random")
    lens = jnp.ones((4,), jnp.int32)
    out = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, 0]), rtol=1e-6, atol=1e-6)


def test_padding_is_ignored(key):
    """Garbage beyond seq_len must not change the result."""
    q, k, v, lens = make_inputs(key, 4, 4, 16, 32, jnp.float32, "mixed")
    out1 = decode_attention(q, k, v, lens)
    mask = (jnp.arange(32)[None, :, None, None] < lens[:, None, None, None])
    k2 = jnp.where(mask, k, 1e6)
    v2 = jnp.where(mask, v, -1e6)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_softmax_convexity(key):
    """Attention output lies in the convex hull of the valid V rows."""
    q, k, v, lens = make_inputs(key, 4, 4, 16, 32, jnp.float32, "random")
    out = np.asarray(decode_attention(q, k, v, lens))
    vn = np.asarray(v)
    ln = np.asarray(lens)
    for b in range(4):
        valid = vn[b, : ln[b]]  # (s, h, d)
        lo = valid.min(axis=0) - 1e-5
        hi = valid.max(axis=0) + 1e-5
        assert (out[b] >= lo).all() and (out[b] <= hi).all()


def test_scale_invariance_of_uniform_keys(key):
    """If all valid keys are identical, output is the mean of valid values."""
    batch, heads, hd, s = 2, 3, 8, 16
    q = jax.random.normal(key, (batch, heads, hd), jnp.float32)
    k = jnp.ones((batch, s, heads, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (batch, s, heads, hd), jnp.float32)
    lens = jnp.array([4, 16], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lens))
    for b, l in enumerate([4, 16]):
        want = np.asarray(v)[b, :l].mean(axis=0)
        np.testing.assert_allclose(out[b], want, rtol=1e-5, atol=1e-5)


def test_deterministic(key):
    q, k, v, lens = make_inputs(key, 4, 4, 16, 32, jnp.float32, "random")
    a = decode_attention(q, k, v, lens)
    b = decode_attention(q, k, v, lens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
