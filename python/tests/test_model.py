"""L2 correctness: prefill/decode consistency against the full-forward oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def random_prompts(key, cfg, min_len=2):
    ks = jax.random.split(key, 2)
    tokens = jax.random.randint(
        ks[0], (cfg.batch, cfg.max_seq), 0, cfg.vocab_size
    ).astype(jnp.int32)
    lens = jax.random.randint(
        ks[1], (cfg.batch,), min_len, cfg.max_seq // 2
    ).astype(jnp.int32)
    return tokens, lens


class TestPrefill:
    def test_shapes(self, micro_cfg, micro_weights, key):
        tokens, lens = random_prompts(key, micro_cfg)
        cache = model.empty_cache(micro_cfg)
        logits, nxt, cache = model.prefill(micro_cfg, micro_weights, tokens, lens, cache)
        assert logits.shape == (micro_cfg.batch, micro_cfg.vocab_size)
        assert nxt.shape == (micro_cfg.batch,)
        assert cache.shape == (
            micro_cfg.n_layers, 2, micro_cfg.batch, micro_cfg.max_seq,
            micro_cfg.n_heads, micro_cfg.head_dim,
        )

    def test_matches_full_forward(self, tiny_cfg, tiny_weights, key):
        tokens, lens = random_prompts(key, tiny_cfg)
        cache = model.empty_cache(tiny_cfg)
        logits, _, _ = model.prefill(tiny_cfg, tiny_weights, tokens, lens, cache)
        oracle = model.full_forward_logits(tiny_cfg, tiny_weights, tokens, lens)
        want = np.asarray(oracle)[np.arange(tiny_cfg.batch), np.asarray(lens) - 1]
        np.testing.assert_allclose(np.asarray(logits), want, rtol=1e-4, atol=1e-4)

    def test_padding_independence(self, micro_cfg, micro_weights, key):
        """Tokens beyond seq_len must not affect the logits."""
        tokens, lens = random_prompts(key, micro_cfg)
        cache = model.empty_cache(micro_cfg)
        l1, _, _ = model.prefill(micro_cfg, micro_weights, tokens, lens, cache)
        pad_mask = jnp.arange(micro_cfg.max_seq)[None, :] >= lens[:, None]
        tokens2 = jnp.where(pad_mask, (tokens + 7) % micro_cfg.vocab_size, tokens)
        l2, _, _ = model.prefill(micro_cfg, micro_weights, tokens2, lens, cache)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)

    def test_greedy_token_is_argmax(self, micro_cfg, micro_weights, key):
        tokens, lens = random_prompts(key, micro_cfg)
        cache = model.empty_cache(micro_cfg)
        logits, nxt, _ = model.prefill(micro_cfg, micro_weights, tokens, lens, cache)
        np.testing.assert_array_equal(
            np.asarray(nxt), np.argmax(np.asarray(logits), axis=-1)
        )


class TestDecodeStep:
    def test_decode_after_prefill_matches_oracle(self, tiny_cfg, tiny_weights, key):
        """THE core L2 invariant: prefill(prompt) then decode(next tokens)
        reproduces the logits a full forward pass over the whole sequence
        would produce at every step."""
        cfg, weights = tiny_cfg, tiny_weights
        tokens, lens = random_prompts(key, cfg)
        cache = model.empty_cache(cfg)
        logits, _, cache = model.prefill(cfg, weights, tokens, lens, cache)

        n_steps = 4
        cur_lens = lens
        cur_tokens = tokens
        for _ in range(n_steps):
            step_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # Extend the oracle's token matrix at position cur_lens.
            cur_tokens = cur_tokens.at[jnp.arange(cfg.batch), cur_lens].set(step_tok)
            logits, _, cache = model.decode_step(
                cfg, weights, step_tok, cur_lens, cache
            )
            cur_lens = cur_lens + 1
            oracle = model.full_forward_logits(cfg, weights, cur_tokens, cur_lens)
            want = np.asarray(oracle)[np.arange(cfg.batch), np.asarray(cur_lens) - 1]
            np.testing.assert_allclose(
                np.asarray(logits), want, rtol=2e-3, atol=2e-3
            )

    def test_cache_rows_untouched_beyond_position(self, micro_cfg, micro_weights, key):
        cfg, weights = micro_cfg, micro_weights
        tokens, lens = random_prompts(key, cfg)
        cache = model.empty_cache(cfg)
        _, nxt, cache = model.prefill(cfg, weights, tokens, lens, cache)
        _, _, cache2 = model.decode_step(cfg, weights, nxt, lens, cache)
        c1, c2 = np.asarray(cache), np.asarray(cache2)
        for b in range(cfg.batch):
            pos = int(np.asarray(lens)[b])
            # rows strictly beyond the written position are unchanged
            if pos + 1 < cfg.max_seq:
                np.testing.assert_array_equal(
                    c1[:, :, b, pos + 1 :], c2[:, :, b, pos + 1 :]
                )

    def test_deterministic(self, micro_cfg, micro_weights, key):
        cfg, weights = micro_cfg, micro_weights
        tokens, lens = random_prompts(key, cfg)
        cache = model.empty_cache(cfg)
        _, nxt, cache = model.prefill(cfg, weights, tokens, lens, cache)
        l1, _, _ = model.decode_step(cfg, weights, nxt, lens, cache)
        l2, _, _ = model.decode_step(cfg, weights, nxt, lens, cache)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


class TestWeights:
    def test_deterministic_from_seed(self, tiny_cfg):
        w1 = model.init_weights(tiny_cfg)
        w2 = model.init_weights(tiny_cfg)
        np.testing.assert_array_equal(np.asarray(w1["tok_emb"]), np.asarray(w2["tok_emb"]))
        np.testing.assert_array_equal(
            np.asarray(w1["layers"][0]["wq"]), np.asarray(w2["layers"][0]["wq"])
        )

    def test_layer_count(self, tiny_cfg):
        w = model.init_weights(tiny_cfg)
        assert len(w["layers"]) == tiny_cfg.n_layers

    @pytest.mark.parametrize("name", ["tiny", "micro"])
    def test_kv_bytes_per_token(self, name):
        from compile.configs import CONFIGS

        cfg = CONFIGS[name]
        assert cfg.kv_bytes_per_token == 2 * 4 * cfg.n_layers * cfg.n_heads * cfg.head_dim
