"""L1 correctness: fused SwiGLU Pallas kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import swiglu_ffn_ref
from compile.kernels.swiglu import swiglu_ffn

SHAPES = [
    # (batch, d_model, d_ff)
    (1, 16, 32),
    (2, 32, 64),
    (4, 64, 256),
    (8, 64, 512),
    (3, 48, 96),  # non-power-of-two
]


def make_inputs(key, batch, d_model, d_ff, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d_model**0.5)
    x = jax.random.normal(ks[0], (batch, d_model), dtype)
    wg = jax.random.normal(ks[1], (d_model, d_ff), dtype) * scale
    wu = jax.random.normal(ks[2], (d_model, d_ff), dtype) * scale
    wd = jax.random.normal(ks[3], (d_ff, d_model), dtype) * (1.0 / d_ff**0.5)
    return x, wg, wu, wd


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_matches_ref(key, shape):
    x, wg, wu, wd = make_inputs(key, *shape)
    got = swiglu_ffn(x, wg, wu, wd)
    want = swiglu_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block_f", [16, 32, 64, 128, 256])
def test_blocking_invariance(key, block_f):
    """Result must not depend on the FFN block size."""
    x, wg, wu, wd = make_inputs(key, 4, 64, 256)
    got = swiglu_ffn(x, wg, wu, wd, block_f=block_f)
    want = swiglu_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(6))
def test_seed_sweep(seed):
    x, wg, wu, wd = make_inputs(jax.random.PRNGKey(seed), 4, 64, 256)
    got = swiglu_ffn(x, wg, wu, wd)
    want = swiglu_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_bf16(key):
    x, wg, wu, wd = make_inputs(key, 4, 64, 256, dtype=jnp.bfloat16)
    got = swiglu_ffn(x, wg, wu, wd)
    want = swiglu_ffn_ref(x, wg, wu, wd)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_zero_input_gives_zero(key):
    _, wg, wu, wd = make_inputs(key, 4, 64, 256)
    x = jnp.zeros((4, 64), jnp.float32)
    out = np.asarray(swiglu_ffn(x, wg, wu, wd))
    np.testing.assert_allclose(out, np.zeros_like(out), atol=1e-7)


def test_invalid_block_raises(key):
    x, wg, wu, wd = make_inputs(key, 4, 64, 256)
    with pytest.raises(AssertionError):
        swiglu_ffn(x, wg, wu, wd, block_f=100)  # does not divide 256
