//! End-to-end figure benches: one reduced run per paper experiment family,
//! timing the full simulation pipeline and printing the headline
//! comparison (who wins, by what factor) — the `cargo bench` counterpart
//! of `kairos figures`.
//!
//! Run: `cargo bench`.

// Benches measure real elapsed time by definition (lint rule D1 exempts
// bench targets; this allow covers clippy's disallowed-methods check).
#![allow(clippy::disallowed_methods)]

mod common;

use common::bench;
use kairos::agents::apps::App;
use kairos::engine::cost_model::ModelKind;
use kairos::server::sim::{run_system, SimConfig};
use kairos::stats::rng::Rng;
use kairos::workload::{TraceGen, WorkloadMix};

fn trace(mix: &WorkloadMix, rate: f64, n: usize, seed: u64) -> Vec<kairos::workload::ArrivalEvent> {
    TraceGen::default().generate(mix, rate, n, &mut Rng::new(seed))
}

fn headline(tag: &str, cfg: SimConfig, mix: &WorkloadMix, rate: f64, n: usize) {
    let mut lat = vec![];
    for (sched, disp) in [("parrot", "rr"), ("ayo", "rr"), ("kairos", "kairos")] {
        let res = run_system(cfg, sched, disp, trace(mix, rate, n, 11));
        lat.push((sched, res.summary.avg_token_latency));
    }
    println!(
        "  {tag}: parrot {:.4}  ayo {:.4}  kairos {:.4}  (kairos vs parrot {:+.1}%)",
        lat[0].1,
        lat[1].1,
        lat[2].1,
        (lat[2].1 - lat[0].1) / lat[0].1 * 100.0
    );
}

fn main() {
    println!("== end-to-end (reduced figure runs) ==");

    // Fig 14 family: single application.
    bench("fig14_reduced/QA_GM_3systems", 3, || {
        headline(
            "fig14 QA/G+M",
            SimConfig::default(),
            &WorkloadMix::single(App::Qa, "G+M"),
            10.0,
            600,
        );
    });

    // Fig 15 family: co-located.
    bench("fig15_reduced/colocated_3systems", 3, || {
        headline("fig15 co-located", SimConfig::default(), &WorkloadMix::colocated(), 5.0, 600);
    });

    // Fig 17 family: 13B.
    bench("fig17_reduced/colocated_13B", 3, || {
        headline(
            "fig17 co-located 13B",
            SimConfig { model: ModelKind::Llama2_13B, ..Default::default() },
            &WorkloadMix::colocated(),
            3.0,
            400,
        );
    });

    // Raw simulator throughput (events/s) — the perf-pass tracking number.
    let cfg = SimConfig::default();
    let arrivals = trace(&WorkloadMix::colocated(), 5.0, 2000, 13);
    let t0 = std::time::Instant::now();
    let res = run_system(cfg, "kairos", "kairos", arrivals);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nsim_throughput: {} events in {:.3}s = {:.0} events/s ({:.0} sim-s/wall-s)",
        res.events_processed,
        dt,
        res.events_processed as f64 / dt,
        res.sim_duration / dt
    );
}
