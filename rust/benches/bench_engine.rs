//! Engine-substrate benches: continuous-batching step throughput and block
//! manager operations — the L3 hot loop under every end-to-end figure.
//!
//! Run: `cargo bench`.

mod common;

use common::{bench, black_box};
use kairos::engine::core::{EngineConfig, EngineCore, SimBackend};
use kairos::engine::cost_model::{CostModel, ModelClass, ModelKind};
use kairos::engine::request::Request;
use kairos::orchestrator::ids::AgentId;

fn mk_req(id: u64, prompt: u32, output: u32) -> Request {
    Request {
        id,
        msg_id: id,
        agent: AgentId((id % 8) as u32),
        session: id,
        model_class: ModelClass::Any,
        upstream: None,
        prompt_tokens: prompt,
        true_output_tokens: output,
        true_remaining_latency: 1.0,
        remaining_stages: 1,
        app_start: 0.0,
        stage_arrival: id as f64 * 1e-3,
    }
}

fn engine(max_batch: usize) -> EngineCore<SimBackend> {
    let cost = CostModel::new(ModelKind::Llama3_8B);
    let mut cfg = EngineConfig::for_model(ModelKind::Llama3_8B, 16);
    cfg.max_batch = max_batch;
    EngineCore::new(0, cfg, SimBackend::new(cost))
}

fn main() {
    println!("== engine substrate ==");
    for batch in [8usize, 64, 256] {
        let mut e = engine(batch);
        for i in 0..batch as u64 {
            e.submit(mk_req(i, 256, 1_000_000), 0.0); // never finish
        }
        let mut now = 0.0;
        e.step(now); // admit everyone
        bench(&format!("engine_step/decode_batch={batch}"), 2000, || {
            now += 0.01;
            black_box(e.step(now).n_decode);
        });
    }

    // Full request lifecycle: submit → prefill → decode → complete.
    bench("engine_lifecycle/req=32x(128p,64o)", 50, || {
        let mut e = engine(64);
        for i in 0..32 {
            e.submit(mk_req(i, 128, 64), 0.0);
        }
        let mut now = 0.0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-6);
        }
        black_box(now);
    });

    // Preemption-pressure lifecycle (small pool forces recompute).
    bench("engine_lifecycle/preemption_pressure", 50, || {
        let cost = CostModel::new(ModelKind::Llama3_8B);
        let cfg = EngineConfig {
            model: ModelKind::Llama3_8B,
            block_size: 16,
            total_blocks: 64,
            max_batch: 32,
            max_prefill_tokens: 4096,
            prefix_cache_blocks: 0,
        };
        let mut e = EngineCore::new(0, cfg, SimBackend::new(cost));
        for i in 0..16 {
            e.submit(mk_req(i, 64, 96), 0.0);
        }
        let mut now = 0.0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-6);
        }
        black_box(e.preemptions);
    });
}
