//! §7.7 overhead benches: queue scheduling pick, time-slot packing
//! decision, and MDS priority update vs agent count.
//!
//! Paper reference points: sort ≈ 3.6 ms, packing ≈ 4.1 ms, MDS 0.1 s @ 10
//! agents → 4.3 s @ 5000 agents (python). Run: `cargo bench`.

mod common;

use common::{bench, black_box};
use kairos::figures::overhead::{mds_time, packing_time, pump_time, sort_time};

fn main() {
    println!("== §7.7 overheads ==");
    for n in [100usize, 1_000, 10_000, 100_000] {
        bench(&format!("scheduler_pick/queue={n}"), 20, || {
            black_box(sort_time(n, 1));
        });
    }
    for inst in [4usize, 8, 16] {
        bench(&format!("timeslot_packing/instances={inst}"), 20, || {
            black_box(packing_time(inst, 200, 2));
        });
    }
    // Coordinator pump: full schedule+dispatch of a backlog. The status
    // snapshot is a reusable buffer (no per-pump Vec allocation), so cost
    // should scale with decisions, not instances × pumps.
    for n in [1_000usize, 10_000] {
        bench(&format!("coordinator_pump/backlog={n}"), 10, || {
            black_box(pump_time(4, n, 3));
        });
    }
    // MDS scaling: report the measured update time directly (one-shot per
    // size; the inner computation is the measurement).
    println!("\nMDS priority update (agents -> seconds):");
    for n in [10usize, 100, 500, 1000, 5000] {
        let dt = mds_time(n, 64, 3);
        println!("mds_update/agents={n:<6} {dt:.4} s");
    }
}
