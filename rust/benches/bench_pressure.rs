//! Group-pressure depth reads: the old per-call `group_len` walks vs the
//! epoch-keyed single-pass snapshot (`ShardedQueue::for_each_group_depth`
//! gated on `ShardedQueue::epoch`).
//!
//! The learned router reads every serving group's queued depth on every
//! routed submission. The legacy path re-walked the shard list once per
//! group per read; the snapshot path folds all shards in one pass and
//! reuses the result verbatim while the queue epoch is unchanged. Run:
//! `cargo bench --bench bench_pressure`.

mod common;

use common::{bench, black_box};
use kairos::engine::cost_model::{ModelClass, ModelKind};
use kairos::engine::request::Request;
use kairos::engine::SimBackend;
use kairos::lb::{Fcfs, ShardKey, ShardedQueue};
use kairos::orchestrator::ids::AgentId;
use kairos::orchestrator::router::RoutePolicy;
use kairos::orchestrator::AffinitySpec;
use kairos::server::coordinator::{Coordinator, FleetSpec};
use kairos::server::sim::make_dispatcher_tuned;

/// The two experiment model families — one serving group each.
const GROUPS: [ModelKind; 2] = [ModelKind::Llama3_8B, ModelKind::Llama2_13B];

/// Pressure reads folded into one bench iteration (one read per routed
/// submission in the coordinator, so this stands in for a burst of 1024
/// arrivals against an otherwise-idle queue).
const READS: usize = 1024;

fn req(i: u64) -> Request {
    Request {
        id: i,
        msg_id: i,
        agent: AgentId((i % 16) as u32),
        session: i,
        model_class: ModelClass::Any,
        upstream: None,
        prompt_tokens: 64,
        true_output_tokens: 8,
        true_remaining_latency: 0.0,
        remaining_stages: 1,
        app_start: 0.0,
        stage_arrival: i as f64 * 1e-3,
    }
}

/// A queue spread over every group shard kind the router produces: the
/// pinned class shard and the routed-`Any` shard of both families.
fn filled_queue(n: usize) -> ShardedQueue {
    let policy = Fcfs;
    let mut q = ShardedQueue::new();
    for i in 0..n {
        let key = match i % 4 {
            0 => ShardKey::Class(ModelClass::Model(ModelKind::Llama3_8B)),
            1 => ShardKey::AnyIn(ModelKind::Llama3_8B),
            2 => ShardKey::Class(ModelClass::Model(ModelKind::Llama2_13B)),
            _ => ShardKey::AnyIn(ModelKind::Llama2_13B),
        };
        q.push_routed(req(i as u64), key, &policy);
    }
    q
}

/// A live coordinator whose learned router reads group pressure on every
/// external submission (the end-to-end path the snapshot serves).
fn coordinator(legacy: bool) -> Coordinator<SimBackend> {
    let fleet =
        FleetSpec::parse("6*llama3-8b@0.12,6*llama2-13b@0.12").expect("fleet spec");
    let dispatcher = make_dispatcher_tuned("kairos", &fleet, None, None);
    let mut c = Coordinator::sim(fleet, Box::new(Fcfs), dispatcher);
    c.set_affinity(&AffinitySpec::parse("Pinned=llama2-13b").expect("affinity"));
    c.set_route_policy(RoutePolicy::learned_default());
    c.set_legacy_hot_path(legacy);
    c
}

fn main() {
    println!("== group-pressure depth reads ==");
    for n in [1_000usize, 10_000] {
        let q = filled_queue(n);

        // Legacy: one shard-list walk per group per read.
        bench(&format!("group_len_walks/queue={n}/reads={READS}"), 20, || {
            let mut total = 0usize;
            for _ in 0..READS {
                for m in GROUPS {
                    total += q.group_len(m);
                }
            }
            black_box(total);
        });

        // Snapshot, epoch ignored: one full shard pass per read (the cost
        // of a read that always finds the snapshot stale).
        bench(&format!("snapshot_pass/queue={n}/reads={READS}"), 20, || {
            let mut scratch = [0usize; GROUPS.len()];
            for _ in 0..READS {
                scratch = [0; GROUPS.len()];
                q.for_each_group_depth(|m, d| {
                    if let Some(i) = GROUPS.iter().position(|g| *g == m) {
                        scratch[i] += d;
                    }
                });
                black_box(&scratch);
            }
            black_box(scratch);
        });

        // Epoch-gated snapshot: the steady state — the queue is unchanged
        // between reads, so all but the first read reuse the scratch.
        bench(&format!("epoch_gated/queue={n}/reads={READS}"), 20, || {
            let mut scratch = [0usize; GROUPS.len()];
            let mut seen = None;
            for _ in 0..READS {
                let epoch = q.epoch();
                if seen != Some(epoch) {
                    scratch = [0; GROUPS.len()];
                    q.for_each_group_depth(|m, d| {
                        if let Some(i) = GROUPS.iter().position(|g| *g == m) {
                            scratch[i] += d;
                        }
                    });
                    seen = Some(epoch);
                }
                black_box(&scratch);
            }
            black_box(scratch);
        });
    }

    // End to end: routed submissions under the learned policy, which takes
    // a full pressure read (instance skeleton + queue depths) per call.
    // `legacy` rescans every instance and walks shards per group; `cached`
    // clones the instance skeleton and patches epoch-keyed depths in.
    println!("\n== learned-router submissions (pressure read per call) ==");
    for (label, legacy) in [("legacy", true), ("cached", false)] {
        let mut c = coordinator(legacy);
        let mut i = 0u64;
        bench(&format!("submit_burst/{label}/batch=256"), 20, || {
            for _ in 0..256 {
                let agent = if i % 3 == 0 { "Pinned" } else { "Free" };
                black_box(c.submit_external(agent, 64, 8, i as f64 * 1e-3));
                i += 1;
            }
        });
    }
}
