//! Statistics-substrate benches: Wasserstein-1, ECDF construction, MDS —
//! the math inside every Kairos priority refresh.
//!
//! Run: `cargo bench`.

mod common;

use common::{bench, black_box};
use kairos::stats::dist::{Dist, LogNormal};
use kairos::stats::ecdf::{wasserstein1, Ecdf};
use kairos::stats::mds::{mds_1d, SymMatrix};
use kairos::stats::rng::Rng;

fn samples(n: usize, seed: u64) -> Vec<f64> {
    let d = LogNormal::from_mean_cv(5.0, 0.7);
    let mut rng = Rng::new(seed);
    (0..n).map(|_| d.sample(&mut rng)).collect()
}

fn main() {
    println!("== stats substrate ==");
    for n in [100usize, 1_000, 10_000] {
        let xs = samples(n, 1);
        bench(&format!("ecdf_build/n={n}"), 200, || {
            black_box(Ecdf::new(xs.clone()));
        });
        let a = Ecdf::new(samples(n, 2));
        let b = Ecdf::new(samples(n, 3));
        bench(&format!("wasserstein1/n={n}"), 200, || {
            black_box(wasserstein1(&a, &b));
        });
    }
    for n in [10usize, 50, 200] {
        let mut m = SymMatrix::zeros(n);
        let mut rng = Rng::new(4);
        for i in 0..n {
            for j in (i + 1)..n {
                m.set(i, j, rng.f64() * 10.0);
            }
        }
        bench(&format!("mds_1d/agents={n}"), 50, || {
            black_box(mds_1d(&m));
        });
    }
}
