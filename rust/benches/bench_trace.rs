//! Trace-subsystem throughput: JSONL serialize, parse, and materialize.
//!
//! The trace file is the artifact every sweep arm replays, so parse +
//! materialize sit on the startup path of every run. Run: `cargo bench`.

mod common;

use common::{bench, black_box};
use kairos::workload::{GenSource, Trace, TraceGen, TraceSource, WorkloadMix};

fn main() {
    println!("== trace subsystem (JSONL parse + materialize) ==");
    for n in [1_000usize, 10_000] {
        let trace = GenSource {
            gen: TraceGen::default(),
            mix: WorkloadMix::colocated(),
            rate: 8.0,
            n,
            seed: 42,
        }
        .materialize()
        .expect("generated trace");
        let jsonl = trace.to_jsonl();
        println!(
            "trace n={n}: {} stages, {} JSONL bytes",
            trace.records.iter().map(|r| r.stages.len()).sum::<usize>(),
            jsonl.len()
        );
        bench(&format!("trace_serialize/n={n}"), 10, || {
            black_box(trace.to_jsonl());
        });
        bench(&format!("trace_parse/n={n}"), 10, || {
            black_box(Trace::from_jsonl(&jsonl).expect("parse"));
        });
        bench(&format!("trace_materialize/n={n}"), 10, || {
            black_box(trace.arrivals());
        });
        bench(&format!("trace_scale_rate/n={n}"), 10, || {
            black_box(trace.scale_rate(2.0).expect("scale"));
        });
    }
}
