//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `bench(name, iters, f)` warms up, times `iters` runs, and prints
//! mean / p50 / p95 per-iteration latency in a fixed format the perf pass
//! and EXPERIMENTS.md grep for.

// Benches measure real elapsed time by definition; the determinism lint
// (rule D1) and clippy's disallowed-methods both exempt this path.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters: usize,
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        p50_ns: p50,
        p95_ns: p95,
        iters,
    };
    println!(
        "bench {:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters
    );
    r
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Keep a value alive / defeat optimizer.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
