//! The developer-facing workflow API (paper Listing 1), rust edition.
//!
//! Mirrors the python API the paper shows: subclass `BaseAgent`, override
//! `_run_impl`, register agents in a `Workflow`. Here an agent is anything
//! implementing [`BaseAgent`]; [`Workflow`] wires agents to bus topics and
//! [`Workflow::run_task`] drives one task through the chain, transparently
//! propagating the system identifiers (msg_id, upstream, timestamps) in
//! message headers so the orchestrator can reconstruct the workflow —
//! exactly the "almost transparent to developers" contract of §4.1.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::bus::{Broker, Message};
use crate::orchestrator::graph::ExecRecord;
use crate::orchestrator::Orchestrator;
use crate::Time;

/// What an agent returns: its output payload and the next agent to invoke
/// (None terminates the workflow).
pub struct AgentOutput {
    pub payload: String,
    pub next_agent: Option<String>,
}

/// An LLM client the agents call — the `self.generate(prompt)` of
/// Listing 1. Implementations: the real PJRT server or a test stub.
pub trait LlmClient: Send + Sync {
    /// Generate a completion; returns (text, exec_start, exec_end).
    fn generate(&self, agent: &str, prompt: &str) -> (String, Time, Time);
}

/// The BaseAgent contract (Listing 1's `_run_impl`).
pub trait BaseAgent: Send {
    fn name(&self) -> &str;
    /// Consume the upstream payload, call the LLM, pick the next agent.
    fn run_impl(&mut self, input: &str, llm: &dyn LlmClient) -> AgentOutput;
}

/// A workflow: agents registered by name, connected via bus topics
/// `agent.<name>`, with identifier propagation and orchestrator feedback.
pub struct Workflow {
    broker: Broker,
    agents: HashMap<String, Box<dyn BaseAgent>>,
    orchestrator: Arc<Mutex<Orchestrator>>,
    next_msg_id: u64,
}

impl Workflow {
    pub fn new(broker: Broker, orchestrator: Arc<Mutex<Orchestrator>>) -> Workflow {
        Workflow { broker, agents: HashMap::new(), orchestrator, next_msg_id: 1 }
    }

    /// `workflow.add_agent(...)` of Listing 1.
    pub fn add_agent(&mut self, agent: Box<dyn BaseAgent>) {
        let topic = format!("agent.{}", agent.name());
        self.broker.create_topic(&topic, 1);
        self.agents.insert(agent.name().to_string(), agent);
    }

    pub fn agent_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.agents.keys().cloned().collect();
        v.sort();
        v
    }

    /// Drive one user task through the workflow starting at `entry`.
    /// Returns the final payload and the msg_id assigned to the task.
    ///
    /// Identifier propagation: each hop publishes a message to the next
    /// agent's topic carrying `msg_id` and `upstream` headers; execution
    /// timestamps are reported to the orchestrator after every stage.
    pub fn run_task(
        &mut self,
        entry: &str,
        task: &str,
        llm: &dyn LlmClient,
    ) -> crate::Result<(String, u64)> {
        let msg_id = self.next_msg_id;
        self.next_msg_id += 1;

        // Wrap the client so the workflow observes each stage's execution
        // span without the agent having to report it (transparency, §4.1).
        struct SpanRecorder<'a> {
            inner: &'a dyn LlmClient,
            last: Mutex<(Time, Time)>,
        }
        impl LlmClient for SpanRecorder<'_> {
            fn generate(&self, agent: &str, prompt: &str) -> (String, Time, Time) {
                let (text, s, e) = self.inner.generate(agent, prompt);
                *self.last.lock().unwrap() = (s, e);
                (text, s, e)
            }
        }
        let recorder = SpanRecorder { inner: llm, last: Mutex::new((0.0, 0.0)) };

        let mut current = entry.to_string();
        let mut payload = task.to_string();
        let mut upstream: Option<String> = None;
        let mut last_end: Time = 0.0;
        let mut hops = 0usize;

        loop {
            anyhow::ensure!(hops < 64, "workflow exceeded 64 hops (cycle?)");
            hops += 1;
            // Deliver through the bus (headers carry the identifiers).
            let topic = format!("agent.{current}");
            let mut msg = Message::new(format!("{msg_id}"), payload.clone())
                .header("msg_id", format!("{msg_id}"))
                .header("agent", current.clone());
            if let Some(up) = &upstream {
                msg = msg.header("upstream", up.clone());
            }
            self.broker.publish(&topic, msg)?;
            let delivered = self
                .broker
                .try_poll(&topic, "workflow")?
                .expect("just published");

            let agent = self
                .agents
                .get_mut(&current)
                .ok_or_else(|| anyhow::anyhow!("no agent {current:?}"))?;
            let out = agent.run_impl(&delivered.payload, &recorder);

            // Report execution to the orchestrator (identifiers + spans).
            {
                let (mut start, mut end) = *recorder.last.lock().unwrap();
                if end <= last_end {
                    // Stage spans must be monotone even for stub clients.
                    start = last_end;
                    end = last_end + 1e-3;
                }
                last_end = end;
                let mut orch = self.orchestrator.lock().unwrap();
                let agent_id = orch.registry.intern(&current);
                let upstream_id =
                    upstream.as_ref().map(|u| orch.registry.intern(u));
                orch.record_execution(ExecRecord {
                    msg_id,
                    agent: agent_id,
                    upstream: upstream_id,
                    start,
                    end,
                });
            }

            upstream = Some(current.clone());
            payload = out.payload;
            match out.next_agent {
                Some(next) => current = next,
                None => break,
            }
        }
        self.orchestrator
            .lock()
            .unwrap()
            .record_workflow_done(msg_id, last_end);
        Ok((payload, msg_id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct StubLlm;
    impl LlmClient for StubLlm {
        fn generate(&self, _agent: &str, prompt: &str) -> (String, Time, Time) {
            (format!("echo:{prompt}"), 0.0, 0.1)
        }
    }

    struct Router;
    impl BaseAgent for Router {
        fn name(&self) -> &str {
            "Router"
        }
        fn run_impl(&mut self, input: &str, llm: &dyn LlmClient) -> AgentOutput {
            let (out, _, _) = llm.generate("Router", input);
            let next = if input.contains("17 * 23") { "MathAgent" } else { "HumanitiesAgent" };
            AgentOutput { payload: out, next_agent: Some(next.to_string()) }
        }
    }

    struct Expert(&'static str);
    impl BaseAgent for Expert {
        fn name(&self) -> &str {
            self.0
        }
        fn run_impl(&mut self, input: &str, llm: &dyn LlmClient) -> AgentOutput {
            let (out, _, _) = llm.generate(self.0, input);
            AgentOutput { payload: out, next_agent: None }
        }
    }

    fn workflow() -> Workflow {
        let orch = Arc::new(Mutex::new(Orchestrator::new()));
        let mut w = Workflow::new(Broker::new(), orch);
        w.add_agent(Box::new(Router));
        w.add_agent(Box::new(Expert("MathAgent")));
        w.add_agent(Box::new(Expert("HumanitiesAgent")));
        w
    }

    #[test]
    fn routes_math_questions_to_math_agent() {
        let mut w = workflow();
        let (out, _) = w.run_task("Router", "what is 17 * 23?", &StubLlm).unwrap();
        assert!(out.starts_with("echo:"));
    }

    #[test]
    fn orchestrator_learns_the_workflow() {
        let orch = Arc::new(Mutex::new(Orchestrator::new()));
        let mut w = Workflow::new(Broker::new(), orch.clone());
        w.add_agent(Box::new(Router));
        w.add_agent(Box::new(Expert("MathAgent")));
        w.add_agent(Box::new(Expert("HumanitiesAgent")));
        w.run_task("Router", "what is 17 * 23?", &StubLlm).unwrap();
        w.run_task("Router", "who was Napoleon?", &StubLlm).unwrap();
        let o = orch.lock().unwrap();
        let router = o.registry.get("Router").unwrap();
        let math = o.registry.get("MathAgent").unwrap();
        let hum = o.registry.get("HumanitiesAgent").unwrap();
        assert!(o.graph.edge(router, math).is_some());
        assert!(o.graph.edge(router, hum).is_some());
        assert_eq!(o.graph.remaining_depth(router), 2);
    }

    #[test]
    fn agent_names_listed() {
        let w = workflow();
        assert_eq!(w.agent_names(), vec!["HumanitiesAgent", "MathAgent", "Router"]);
    }

    #[test]
    fn missing_agent_errors() {
        let mut w = workflow();
        assert!(w.run_task("Nope", "task", &StubLlm).is_err());
    }
}
