//! The three benchmark applications (paper Fig. 2) as workflow generators.
//!
//! A user task is instantiated into a [`WorkflowPlan`] — the resolved
//! sequence of agent stages with sampled prompt/output lengths. Dynamic
//! structure (QA's branch, CG's feedback loop) is resolved by sampling at
//! instantiation; the serving system never sees the plan, only the requests
//! as they arrive stage by stage (the orchestrator must *learn* the
//! structure, §4.2).

use super::datasets::{cg_dataset, qa_dataset, rg_dataset, DatasetProfile};
use crate::stats::rng::Rng;

/// The three benchmark applications, plus the external-request marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum App {
    /// Question Answer — dynamic branching (Router → Math | Humanities).
    Qa,
    /// Report Generate — sequential (Research → Writer).
    Rg,
    /// Code Generate — dynamic feedback (PM → Arch → PjM → Eng → QA ⟲ Eng).
    Cg,
    /// A free-standing external request recorded off the serving frontend
    /// (`Coordinator::submit_external`): a synthetic single-stage "app" so
    /// externals ride the same trace schema as workflows. Never sampled by
    /// the workload generators — [`App::all`] stays the three benchmark
    /// apps.
    Ext,
}

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::Qa => "QA",
            App::Rg => "RG",
            App::Cg => "CG",
            App::Ext => "EXT",
        }
    }

    /// Parse an app name as written by [`App::name`] (CLI filters, trace
    /// files).
    pub fn parse(s: &str) -> Result<App, String> {
        match s {
            "QA" | "qa" => Ok(App::Qa),
            "RG" | "rg" => Ok(App::Rg),
            "CG" | "cg" => Ok(App::Cg),
            "EXT" | "ext" => Ok(App::Ext),
            other => Err(format!("unknown app {other:?} (QA|RG|CG|EXT)")),
        }
    }

    /// Dataset profile by paper dataset name.
    pub fn dataset(&self, name: &str) -> DatasetProfile {
        match self {
            App::Qa => qa_dataset(name),
            App::Rg => rg_dataset(name),
            App::Cg => cg_dataset(name),
            // Externals are recorded pre-resolved, never instantiated from
            // a dataset profile.
            App::Ext => panic!("EXT records are pre-resolved; no dataset profiles"),
        }
    }

    pub fn datasets(&self) -> [&'static str; 3] {
        match self {
            App::Qa => ["G+M", "M+W", "S+S"],
            App::Rg => ["TQ", "NCD", "NQ"],
            App::Cg => ["HE", "MBPP", "APPS"],
            App::Ext => ["external", "external", "external"],
        }
    }

    pub fn all() -> [App; 3] {
        [App::Qa, App::Rg, App::Cg]
    }
}

/// One resolved stage of a workflow instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedStage {
    pub agent: &'static str,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
}

/// A fully resolved workflow instance (linear stage sequence: the paper's
/// three apps branch/loop but never fan out in parallel, Fig. 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowPlan {
    pub app: App,
    pub dataset: &'static str,
    pub stages: Vec<PlannedStage>,
}

impl WorkflowPlan {
    /// Sample one user task of `app` over `dataset`.
    pub fn sample(app: App, dataset: &'static str, rng: &mut Rng) -> WorkflowPlan {
        let ds = app.dataset(dataset);
        let mut stages = Vec::new();
        let stage = |ds: &DatasetProfile, agent: &'static str, rng: &mut Rng| {
            let p = ds.agent(agent);
            PlannedStage {
                agent,
                prompt_tokens: p.sample_prompt(rng),
                output_tokens: p.sample_output(rng),
            }
        };
        match app {
            App::Qa => {
                stages.push(stage(&ds, "Router", rng));
                if rng.chance(ds.math_ratio) {
                    stages.push(stage(&ds, "MathAgent", rng));
                } else {
                    stages.push(stage(&ds, "HumanitiesAgent", rng));
                }
            }
            App::Rg => {
                stages.push(stage(&ds, "ResearchAgent", rng));
                stages.push(stage(&ds, "WriterAgent", rng));
            }
            App::Cg => {
                stages.push(stage(&ds, "ProductManager", rng));
                stages.push(stage(&ds, "Architect", rng));
                stages.push(stage(&ds, "ProjectManager", rng));
                stages.push(stage(&ds, "Engineer", rng));
                stages.push(stage(&ds, "QAEngineer", rng));
                // Dynamic feedback: failed evaluation feeds back to the
                // engineer (bounded retries keep plans finite).
                let mut retries = 0;
                while retries < 3 && rng.chance(ds.feedback_ratio) {
                    stages.push(stage(&ds, "Engineer", rng));
                    stages.push(stage(&ds, "QAEngineer", rng));
                    retries += 1;
                }
            }
            // `app.dataset()` above already panicked for EXT.
            App::Ext => unreachable!("EXT records are never sampled"),
        }
        WorkflowPlan { app, dataset: ds.name, stages }
    }

    /// Total generated tokens across all stages (the denominator of
    /// program-level token latency).
    pub fn total_output_tokens(&self) -> u64 {
        self.stages.iter().map(|s| s.output_tokens as u64).sum()
    }

    /// Stages remaining including stage `i`, as the STATIC workflow
    /// topology sees it (Ayo's signal): the agent's depth in the app's
    /// call graph. Dynamic feedback iterations (CG) do not deepen it —
    /// Ayo cannot know how many loop iterations a task will take.
    pub fn remaining_stages(&self, i: usize) -> u32 {
        static_depth(self.app, self.stages[i].agent)
    }

    /// True resolved stages remaining including stage `i` (ground truth;
    /// Oracle/analysis only).
    pub fn true_remaining_stages(&self, i: usize) -> u32 {
        (self.stages.len() - i) as u32
    }
}

/// Static topology depth of an agent within its application workflow
/// (longest downstream path including the agent's own stage).
pub fn static_depth(app: App, agent: &str) -> u32 {
    match (app, agent) {
        (App::Qa, "Router") => 2,
        (App::Qa, _) => 1,
        (App::Rg, "ResearchAgent") => 2,
        (App::Rg, _) => 1,
        (App::Cg, "ProductManager") => 5,
        (App::Cg, "Architect") => 4,
        (App::Cg, "ProjectManager") => 3,
        (App::Cg, "Engineer") => 2,
        (App::Cg, _) => 1,
        // External requests are single free-standing stages.
        (App::Ext, _) => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qa_is_two_stage_branch() {
        let mut rng = Rng::new(1);
        let mut math = 0;
        let mut hum = 0;
        for _ in 0..1000 {
            let p = WorkflowPlan::sample(App::Qa, "G+M", &mut rng);
            assert_eq!(p.stages.len(), 2);
            assert_eq!(p.stages[0].agent, "Router");
            match p.stages[1].agent {
                "MathAgent" => math += 1,
                "HumanitiesAgent" => hum += 1,
                other => panic!("unexpected {other}"),
            }
        }
        let ratio = math as f64 / (math + hum) as f64;
        assert!((ratio - 0.5).abs() < 0.05, "branch ratio {ratio}");
    }

    #[test]
    fn rg_is_fixed_sequence() {
        let mut rng = Rng::new(2);
        let p = WorkflowPlan::sample(App::Rg, "TQ", &mut rng);
        let agents: Vec<&str> = p.stages.iter().map(|s| s.agent).collect();
        assert_eq!(agents, vec!["ResearchAgent", "WriterAgent"]);
    }

    #[test]
    fn cg_has_feedback_loops_sometimes() {
        let mut rng = Rng::new(3);
        let mut base = 0;
        let mut looped = 0;
        for _ in 0..500 {
            let p = WorkflowPlan::sample(App::Cg, "HE", &mut rng);
            assert!(p.stages.len() >= 5);
            assert_eq!(p.stages[3].agent, "Engineer");
            assert_eq!(p.stages[4].agent, "QAEngineer");
            assert!((p.stages.len() - 5) % 2 == 0, "loops add Eng+QA pairs");
            if p.stages.len() == 5 {
                base += 1;
            } else {
                looped += 1;
            }
        }
        assert!(base > 0 && looped > 0, "both outcomes occur");
        let loop_rate = looped as f64 / 500.0;
        assert!((loop_rate - 0.3).abs() < 0.08, "loop rate {loop_rate}");
    }

    #[test]
    fn true_remaining_stages_counts_down() {
        let mut rng = Rng::new(4);
        let p = WorkflowPlan::sample(App::Cg, "HE", &mut rng);
        assert_eq!(p.true_remaining_stages(0) as usize, p.stages.len());
        assert_eq!(p.true_remaining_stages(p.stages.len() - 1), 1);
    }

    #[test]
    fn static_depth_ignores_feedback_loops() {
        let mut rng = Rng::new(11);
        // Find a plan with a feedback loop (> 5 stages).
        let p = loop {
            let p = WorkflowPlan::sample(App::Cg, "APPS", &mut rng);
            if p.stages.len() > 5 {
                break p;
            }
        };
        // The looped Engineer stage still reports static depth 2.
        let loop_eng_idx = 5;
        assert_eq!(p.stages[loop_eng_idx].agent, "Engineer");
        assert_eq!(p.remaining_stages(loop_eng_idx), 2);
        // QA depths.
        assert_eq!(static_depth(App::Qa, "Router"), 2);
        assert_eq!(static_depth(App::Qa, "MathAgent"), 1);
    }

    #[test]
    fn total_output_positive() {
        let mut rng = Rng::new(5);
        for app in App::all() {
            let ds = app.datasets()[0];
            let p = WorkflowPlan::sample(app, ds, &mut rng);
            assert!(p.total_output_tokens() > 0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p1 = WorkflowPlan::sample(App::Cg, "APPS", &mut Rng::new(9));
        let p2 = WorkflowPlan::sample(App::Cg, "APPS", &mut Rng::new(9));
        assert_eq!(p1.stages.len(), p2.stages.len());
        for (a, b) in p1.stages.iter().zip(&p2.stages) {
            assert_eq!(a.agent, b.agent);
            assert_eq!(a.output_tokens, b.output_tokens);
        }
    }
}
