//! Synthetic dataset models (substitution for GSM8K/MMLU/… — DESIGN.md §3).
//!
//! The scheduler and dispatcher only ever observe token *counts*; these
//! models reproduce the paper's measured per-agent output-length structure
//! (Fig. 3: heavy-tailed, LogNormal-like; Fig. 5: stable per-agent means
//! across dataset groups; §2.1: up to ~25× Router-vs-expert latency gap;
//! §7.2: SocialIQA shrinks HumanitiesAgent outputs, weakening QA gains on
//! S+S).

use crate::stats::dist::{Dist, LogNormal};
use crate::stats::rng::Rng;

/// Per-agent prompt/output token-length model.
#[derive(Debug, Clone)]
pub struct AgentProfile {
    pub agent: &'static str,
    pub prompt: LogNormal,
    pub output: LogNormal,
}

impl AgentProfile {
    fn new(agent: &'static str, prompt_mean: f64, output_mean: f64, cv: f64) -> Self {
        AgentProfile {
            agent,
            prompt: LogNormal::from_mean_cv(prompt_mean, 0.35),
            output: LogNormal::from_mean_cv(output_mean, cv),
        }
    }

    pub fn sample_prompt(&self, rng: &mut Rng) -> u32 {
        (self.prompt.sample(rng).round() as u32).clamp(8, 4096)
    }

    pub fn sample_output(&self, rng: &mut Rng) -> u32 {
        (self.output.sample(rng).round() as u32).clamp(2, 4096)
    }
}

/// One (application, dataset) pairing with its agent roster.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub agents: Vec<AgentProfile>,
    /// QA only: probability the router sends the task to the math expert.
    pub math_ratio: f64,
    /// CG only: probability a QA evaluation fails and feeds back.
    pub feedback_ratio: f64,
}

impl DatasetProfile {
    pub fn agent(&self, name: &str) -> &AgentProfile {
        self.agents
            .iter()
            .find(|a| a.agent == name)
            .unwrap_or_else(|| panic!("no agent {name:?} in dataset {}", self.name))
    }
}

/// QA datasets: G+M (GSM8K+MMLU), M+W (MathQA+WorldHistoryQA),
/// S+S (SVAMP+SocialIQA).
pub fn qa_dataset(name: &str) -> DatasetProfile {
    // Router: short routing decision (~15 tok — the 25x gap vs experts).
    let router = |out: f64| AgentProfile::new("Router", 180.0, out, 0.45);
    match name {
        "G+M" => DatasetProfile {
            name: "G+M",
            agents: vec![
                router(15.0),
                AgentProfile::new("MathAgent", 210.0, 280.0, 0.75),
                AgentProfile::new("HumanitiesAgent", 240.0, 380.0, 0.65),
            ],
            math_ratio: 0.5,
            feedback_ratio: 0.0,
        },
        "M+W" => DatasetProfile {
            name: "M+W",
            agents: vec![
                router(14.0),
                AgentProfile::new("MathAgent", 200.0, 235.0, 0.8),
                AgentProfile::new("HumanitiesAgent", 230.0, 350.0, 0.6),
            ],
            math_ratio: 0.5,
            feedback_ratio: 0.0,
        },
        // SocialIQA: social-science questions get SHORT humanities answers,
        // compressing the inter-agent gap (paper §7.2 nuance).
        "S+S" => DatasetProfile {
            name: "S+S",
            agents: vec![
                router(15.0),
                AgentProfile::new("MathAgent", 190.0, 225.0, 0.7),
                AgentProfile::new("HumanitiesAgent", 210.0, 250.0, 0.55),
            ],
            math_ratio: 0.5,
            feedback_ratio: 0.0,
        },
        other => panic!("unknown QA dataset {other:?}"),
    }
}

/// RG datasets: TQ (TruthfulQA), NCD (News Category), NQ (Natural Questions).
pub fn rg_dataset(name: &str) -> DatasetProfile {
    let mk = |name: &'static str, research: f64, writer: f64| DatasetProfile {
        name,
        agents: vec![
            AgentProfile::new("ResearchAgent", 260.0, research, 0.55),
            AgentProfile::new("WriterAgent", 420.0, writer, 0.5),
        ],
        math_ratio: 0.0,
        feedback_ratio: 0.0,
    };
    match name {
        "TQ" => mk("TQ", 450.0, 620.0),
        "NCD" => mk("NCD", 380.0, 560.0),
        "NQ" => mk("NQ", 420.0, 600.0),
        other => panic!("unknown RG dataset {other:?}"),
    }
}

/// CG datasets: HE (HumanEval), MBPP, APPS.
pub fn cg_dataset(name: &str) -> DatasetProfile {
    let mk = |name: &'static str, scale: f64, feedback: f64| DatasetProfile {
        name,
        agents: vec![
            AgentProfile::new("ProductManager", 280.0, 350.0 * scale, 0.5),
            AgentProfile::new("Architect", 340.0, 420.0 * scale, 0.5),
            AgentProfile::new("ProjectManager", 300.0, 300.0 * scale, 0.45),
            AgentProfile::new("Engineer", 420.0, 550.0 * scale, 0.6),
            AgentProfile::new("QAEngineer", 380.0, 260.0 * scale, 0.55),
        ],
        math_ratio: 0.0,
        feedback_ratio: feedback,
    };
    match name {
        "HE" => mk("HE", 1.0, 0.3),
        "MBPP" => mk("MBPP", 0.85, 0.25),
        "APPS" => mk("APPS", 1.25, 0.4),
        other => panic!("unknown CG dataset {other:?}"),
    }
}

/// Paper dataset groups (Fig. 5/6): Group 1 = {G+M, TQ, HE},
/// Group 2 = {M+W, NCD, MBPP}, Group 3 = {S+S, NQ, APPS}.
pub fn group_datasets(group: usize) -> (&'static str, &'static str, &'static str) {
    match group {
        1 => ("G+M", "TQ", "HE"),
        2 => ("M+W", "NCD", "MBPP"),
        3 => ("S+S", "NQ", "APPS"),
        other => panic!("unknown group {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_output(p: &AgentProfile, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| p.sample_output(&mut rng) as f64).sum::<f64>() / n as f64
    }

    #[test]
    fn router_vs_expert_gap_is_large() {
        // Paper §1: latency variance up to 25.1x between Router and experts.
        let ds = qa_dataset("G+M");
        let r = mean_output(ds.agent("Router"), 5000, 1);
        let h = mean_output(ds.agent("HumanitiesAgent"), 5000, 2);
        assert!(h / r > 15.0, "gap {h}/{r}");
    }

    #[test]
    fn ss_dataset_compresses_gap() {
        // §7.2: S+S humanities outputs shorter => smaller inter-agent diff.
        let gm = qa_dataset("G+M");
        let ss = qa_dataset("S+S");
        let gap_gm = gm.agent("HumanitiesAgent").output.mean()
            - gm.agent("MathAgent").output.mean();
        let gap_ss = ss.agent("HumanitiesAgent").output.mean()
            - ss.agent("MathAgent").output.mean();
        assert!(gap_ss < gap_gm * 0.5, "gap_ss={gap_ss} gap_gm={gap_gm}");
    }

    #[test]
    fn agent_means_stable_across_groups() {
        // Fig. 5: each agent's behaviour is consistent across datasets.
        for app_datasets in [["G+M", "M+W", "S+S"]] {
            let means: Vec<f64> = app_datasets
                .iter()
                .map(|d| qa_dataset(d).agent("Router").output.mean())
                .collect();
            let max = means.iter().cloned().fold(f64::MIN, f64::max);
            let min = means.iter().cloned().fold(f64::MAX, f64::min);
            assert!(max / min < 1.3, "router stable: {means:?}");
        }
    }

    #[test]
    fn samples_positive_and_bounded() {
        let mut rng = Rng::new(3);
        for ds in ["HE", "MBPP", "APPS"] {
            let d = cg_dataset(ds);
            for a in &d.agents {
                for _ in 0..200 {
                    let p = a.sample_prompt(&mut rng);
                    let o = a.sample_output(&mut rng);
                    assert!((8..=4096).contains(&p));
                    assert!((2..=4096).contains(&o));
                }
            }
        }
    }

    #[test]
    fn rosters_match_paper() {
        assert_eq!(qa_dataset("G+M").agents.len(), 3);
        assert_eq!(rg_dataset("TQ").agents.len(), 2);
        assert_eq!(cg_dataset("HE").agents.len(), 5);
    }

    #[test]
    #[should_panic]
    fn unknown_dataset_panics() {
        qa_dataset("nope");
    }
}
