//! Multi-agent applications and their dataset models (paper §2.1).
//!
//! * [`datasets`] — synthetic per-(app, dataset, agent) prompt/output-length
//!   models fit to the paper's Fig. 3/5 shapes (DESIGN.md §3 substitution).
//! * [`apps`] — the three benchmark applications: Question Answer (dynamic
//!   branching), Report Generate (sequential), Code Generate (dynamic
//!   feedback), instantiated as sampled [`apps::WorkflowPlan`]s.
//! * [`api`] — the Listing-1-style developer API (BaseAgent / Workflow)
//!   used by the real-mode server over the message bus.

pub mod api;
pub mod apps;
pub mod datasets;

pub use apps::{App, PlannedStage, WorkflowPlan};
pub use datasets::{AgentProfile, DatasetProfile};
