//! The `kairos bench` harness: seeded million-request speed runs with
//! machine-readable results.
//!
//! Four benchmarks, each run as an in-binary A/B over a pair of arms (one
//! commit, one binary, two arms — no cross-build noise):
//!
//! * **pump** — a tight submit→pump→drain loop of free-standing external
//!   requests through one [`Coordinator`], timing only the submission and
//!   dispatch half (`hot_seconds`); engine stepping is driven but untimed.
//! * **e2e** — a full [`run_fleet`] simulation over a generated workflow
//!   trace, timing the whole discrete-event run.
//! * **pack** — a packing-heavy [`run_fleet`] trace (large mixed fleet,
//!   learned demand on) through the time-slot packer with only the
//!   dispatcher's scoring arm differing
//!   ([`Coordinator::set_legacy_scoring`]): naive linear peak scans vs.
//!   the max-tree fast paths. Both arms run the optimized coordinator hot
//!   path, so the delta isolates candidate scoring.
//! * **cache** — a session-heavy [`run_fleet`] trace (round-robin session
//!   keys, so each conversation's stages share a growing prefix) with the
//!   per-instance prefix cache enabled in BOTH arms; only placement
//!   differs: the cache-blind `kairos` packer vs. the session-sticky
//!   `cache-affine` CHWBL dispatcher. The delta isolates how much of the
//!   cache's reuse potential placement converts into hits, saved prefill
//!   tokens and end-to-end latency.
//! * **par** — the packing-heavy pump stream through the time-slot packer
//!   at 1..=`--threads` pump workers
//!   ([`Coordinator::set_pump_threads`]): the score-in-parallel /
//!   commit-in-order dispatch round vs. the sequential reference arm.
//!   Every worker count must produce the bit-identical dispatch and group
//!   logs (asserted, `equal_logs`); the curve reports wall time, conflict
//!   and re-score counts per thread count.
//!
//! The **baseline** arm runs [`Coordinator::set_legacy_hot_path`] `(true)`
//! with unbounded logs and exact (vector-backed) metrics: the pre-index
//! linear candidate scans, per-call group-pressure rebuilds and unbatched
//! refreshes. The **optimized** arm runs the incremental family index,
//! bounded [`LogConfig`] ring buffers and lean streaming metrics. Both arms
//! replay the identical seeded submission stream and must make identical
//! dispatch decisions (asserted) — the A/B measures speed and memory, never
//! behavior.
//!
//! Results go to `BENCH_pump.json` / `BENCH_e2e.json` / `BENCH_pack.json` /
//! `BENCH_cache.json` / `BENCH_par.json`
//! (schema documented in the README). Decision counts, drop counts and log-state bytes are
//! seed-deterministic; wall-clock fields vary by host and carry a
//! `provenance` block saying where they were measured. `--quick` shrinks
//! the run for CI smoke (~seconds); the full run serves a million pump
//! requests.

// The bench harness times real execution (that is its whole point), so the
// determinism lint (rule D1) exempts `bench/` and clippy's
// disallowed-methods check is switched off module-wide.
#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;
use std::time::Instant;

use crate::dispatch::RoundRobin;
use crate::lb::policies::Fcfs;
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::router::RoutePolicy;
use crate::server::coordinator::{Coordinator, FleetSpec, LogConfig};
use crate::server::sim::{run_fleet, CacheTuning, FleetConfig, SimResult};
use crate::stats::rng::Rng;
use crate::util::Json;
use crate::workload::{TraceGen, WorkloadMix};

/// CLI-facing knobs of one `kairos bench` invocation.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Shrink both benchmarks to CI-smoke size (~seconds end to end).
    pub quick: bool,
    /// Seed for the submission streams (decision counts are functions of
    /// the seed alone).
    pub seed: u64,
    /// Directory receiving `BENCH_pump.json`, `BENCH_e2e.json`,
    /// `BENCH_pack.json`, `BENCH_cache.json` and `BENCH_par.json`.
    pub out_dir: PathBuf,
    /// Top of the parallel-pump scaling curve (`--threads`): the par
    /// stage runs worker counts 1, 2, 4, … up to this value.
    pub threads: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { quick: false, seed: 42, out_dir: PathBuf::from("."), threads: 4 }
    }
}

/// Measured numbers of one arm of the pump microbench.
#[derive(Debug, Clone, Copy)]
struct PumpArm {
    /// Submission + pump time only (the measured hot path).
    hot_seconds: f64,
    /// Whole arm including the untimed engine drain.
    wall_seconds: f64,
    dispatched_total: u64,
    dropped: u64,
    peak_log_bytes: usize,
}

/// One pre-generated external request of the pump stream (shared verbatim
/// by both arms, so their decision streams are comparable bit for bit).
#[derive(Debug, Clone, Copy)]
struct PumpReq {
    agent: &'static str,
    prompt_tokens: u32,
    output_tokens: u32,
}

fn pump_stream(n: usize, seed: u64) -> Vec<PumpReq> {
    const AGENTS: [&str; 4] = ["Pinned8", "Pinned13", "FreeA", "FreeB"];
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PumpReq {
            agent: AGENTS[rng.below(AGENTS.len())],
            prompt_tokens: 16 + rng.below(96) as u32,
            output_tokens: 4 + rng.below(4) as u32,
        })
        .collect()
}

fn pump_arm(stream: &[PumpReq], legacy: bool) -> PumpArm {
    let fleet = FleetSpec::parse("3*llama3-8b@0.12,llama2-13b@0.12")
        .expect("static fleet spec");
    let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
    c.set_affinity(
        &AffinitySpec::parse("Pinned8=llama3-8b,Pinned13=llama2-13b")
            .expect("static affinity spec"),
    );
    // Learned routing reads group pressures on every submission — the
    // pressure cache is part of what the A/B measures.
    c.set_route_policy(RoutePolicy::learned_default());
    c.set_legacy_hot_path(legacy);
    if !legacy {
        c.set_log_config(LogConfig::bounded(1024));
        c.metrics.lean = true;
    }
    let start = Instant::now();
    let mut hot = std::time::Duration::ZERO;
    let mut now = 0.0_f64;
    let mut i = 0usize;
    while i < stream.len() {
        let batch = (stream.len() - i).min(64);
        let t = Instant::now();
        for r in &stream[i..i + batch] {
            c.submit_external(r.agent, r.prompt_tokens, r.output_tokens, now);
            now += 1e-4;
        }
        c.pump(now);
        hot += t.elapsed();
        // Drain the fleet between batches (untimed: engine simulation is
        // not the system under test, but completions feed the profiles the
        // learned router reads, so it must run).
        loop {
            let mut idle = true;
            for j in 0..c.n_instances() {
                if !c.engines[j].has_work() {
                    continue;
                }
                idle = false;
                let out = c.step_engine(j, now);
                now += out.duration.max(1e-6);
                c.absorb(j, out, now);
            }
            let t = Instant::now();
            c.pump(now);
            hot += t.elapsed();
            if idle {
                break;
            }
        }
        i += batch;
    }
    // Unbounded logs only grow and bounded ones are capped, so the
    // end-of-run state IS the peak.
    PumpArm {
        hot_seconds: hot.as_secs_f64(),
        wall_seconds: start.elapsed().as_secs_f64(),
        dispatched_total: c.dispatch_log.total(),
        dropped: c.dropped,
        peak_log_bytes: c.log_state_bytes(),
    }
}

fn pump_arm_json(n: usize, a: &PumpArm) -> Json {
    Json::obj(vec![
        ("hot_seconds", Json::from(a.hot_seconds)),
        ("wall_seconds", Json::from(a.wall_seconds)),
        ("req_per_sec", Json::from(n as f64 / a.hot_seconds.max(1e-12))),
        (
            "ns_per_request",
            Json::from(a.hot_seconds * 1e9 / n.max(1) as f64),
        ),
        ("dispatched_total", Json::from(a.dispatched_total as f64)),
        ("dropped", Json::from(a.dropped as f64)),
        ("peak_log_bytes", Json::from(a.peak_log_bytes)),
    ])
}

/// One arm of the e2e benchmark: a full simulated run plus its wall time.
fn e2e_arm(
    arrivals: Vec<crate::workload::ArrivalEvent>,
    legacy: bool,
) -> (SimResult, f64) {
    let fleet = FleetSpec::parse("4*llama3-8b@0.12").expect("static fleet spec");
    let mut fc = FleetConfig::from(fleet);
    fc.legacy_hot_path = legacy;
    if !legacy {
        fc.logs = LogConfig::bounded(1024);
        fc.lean_metrics = true;
    }
    let t = Instant::now();
    let res = run_fleet(fc, "kairos", "kairos", arrivals);
    (res, t.elapsed().as_secs_f64())
}

fn e2e_arm_json(res: &SimResult, wall: f64) -> Json {
    let requests = res.metrics.total_requests;
    Json::obj(vec![
        ("wall_seconds", Json::from(wall)),
        ("requests", Json::from(requests as f64)),
        (
            "req_per_sec",
            Json::from(requests as f64 / wall.max(1e-12)),
        ),
        ("dispatched_total", Json::from(res.dispatched_total as f64)),
        ("dropped", Json::from(res.dropped_requests as f64)),
        ("peak_log_bytes", Json::from(res.log_state_bytes)),
        ("n_workflows", Json::from(res.summary.n_workflows)),
        ("avg_token_latency", Json::from(res.summary.avg_token_latency)),
        ("p99_token_latency", Json::from(res.summary.p99_token_latency)),
    ])
}

/// One arm of the pack benchmark: the same seeded trace through the
/// time-slot packer, with only [`FleetConfig::legacy_scoring`] differing.
/// Large mixed fleet so every decision scores many candidates, learned
/// routing so the packer prices learned KV demand.
fn pack_arm(
    arrivals: Vec<crate::workload::ArrivalEvent>,
    legacy_scoring: bool,
) -> (SimResult, f64) {
    let fleet = FleetSpec::parse("10*llama3-8b@0.12,6*llama2-13b@0.12")
        .expect("static fleet spec");
    let mut fc = FleetConfig::from(fleet);
    fc.affinity = Some(
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .expect("static affinity spec"),
    );
    fc.route = Some(RoutePolicy::learned_default());
    fc.logs = LogConfig::bounded(65_536);
    fc.lean_metrics = true;
    fc.legacy_scoring = legacy_scoring;
    let t = Instant::now();
    let res = run_fleet(fc, "kairos", "kairos", arrivals);
    (res, t.elapsed().as_secs_f64())
}

fn pack_arm_json(res: &SimResult, wall: f64) -> Json {
    let p = res.metrics.stream.packer;
    Json::obj(vec![
        ("wall_seconds", Json::from(wall)),
        ("requests", Json::from(res.metrics.total_requests as f64)),
        (
            "req_per_sec",
            Json::from(res.metrics.total_requests as f64 / wall.max(1e-12)),
        ),
        ("dispatched_total", Json::from(res.dispatched_total as f64)),
        ("dropped", Json::from(res.dropped_requests as f64)),
        ("decisions", Json::from(p.decisions as f64)),
        ("candidates", Json::from(p.candidates as f64)),
        ("evaluated", Json::from(p.evaluated as f64)),
        ("fast_accepted", Json::from(p.fast_accepted as f64)),
        ("fast_rejected", Json::from(p.fast_rejected as f64)),
        ("rejected_rounds", Json::from(p.rejected_rounds as f64)),
        ("suspensions", Json::from(p.suspensions as f64)),
    ])
}

/// Session keys make the trace cache-friendly: stage `i` of a workflow in
/// session `s` extends the prefix stage `i-1` left in `s`'s cache entry,
/// and successive workflows in the same session reuse it again. Round-robin
/// assignment keeps every session equally hot.
fn sessionize_arrivals(arrivals: &mut [crate::workload::ArrivalEvent], sessions: u64) {
    for (i, a) in arrivals.iter_mut().enumerate() {
        a.session = Some(i as u64 % sessions);
    }
}

/// One arm of the cache benchmark: the same session-heavy trace with the
/// prefix cache enabled; only the dispatcher differs (`kairos` = cache-blind
/// placement, `cache-affine` = session-sticky CHWBL).
fn cache_arm(
    arrivals: Vec<crate::workload::ArrivalEvent>,
    dispatcher: &str,
) -> (SimResult, f64) {
    let fleet = FleetSpec::parse("6*llama3-8b@0.12").expect("static fleet spec");
    let mut fc = FleetConfig::from(fleet);
    fc.cache = CacheTuning { enabled: true, budget_blocks: 512, load_factor: 1.25 };
    fc.logs = LogConfig::bounded(65_536);
    fc.lean_metrics = true;
    let t = Instant::now();
    let res = run_fleet(fc, "kairos", dispatcher, arrivals);
    (res, t.elapsed().as_secs_f64())
}

fn cache_arm_json(res: &SimResult, wall: f64) -> Json {
    let cs = res.cache_stats();
    let p = res.metrics.stream.packer;
    Json::obj(vec![
        ("wall_seconds", Json::from(wall)),
        ("requests", Json::from(res.metrics.total_requests as f64)),
        (
            "req_per_sec",
            Json::from(res.metrics.total_requests as f64 / wall.max(1e-12)),
        ),
        ("dispatched_total", Json::from(res.dispatched_total as f64)),
        ("dropped", Json::from(res.dropped_requests as f64)),
        ("cache_hits", Json::from(cs.hits as f64)),
        ("cache_misses", Json::from(cs.misses as f64)),
        ("hit_rate", Json::from(cs.hit_rate())),
        ("saved_prefill_tokens", Json::from(cs.saved_prefill_tokens as f64)),
        ("evictions", Json::from(cs.evictions as f64)),
        ("alloc_failures", Json::from(res.alloc_failures() as f64)),
        ("sticky_hits", Json::from(p.sticky_hits as f64)),
        ("sticky_fallbacks", Json::from(p.sticky_fallbacks as f64)),
        ("mean_e2e_seconds", Json::from(res.mean_request_e2e())),
        ("avg_token_latency", Json::from(res.summary.avg_token_latency)),
        ("p99_token_latency", Json::from(res.summary.p99_token_latency)),
    ])
}

/// Measured numbers of one worker count of the parallel-pump bench, plus
/// the full decision logs for the equal-logs assert.
#[derive(Debug, Clone)]
struct ParArm {
    threads: usize,
    /// Submission + pump time only (the measured hot path).
    hot_seconds: f64,
    wall_seconds: f64,
    dispatched_total: u64,
    dropped: u64,
    conflicts: u64,
    rescored: u64,
    par_rounds: u64,
    dispatches: Vec<(crate::engine::request::RequestId, usize)>,
    groups: Vec<crate::server::coordinator::GroupDispatch>,
}

/// One worker count of the parallel-pump bench: the pump stream through
/// the time-slot packer on a packing-heavy mixed fleet, with model-affine
/// shards so each pump round holds several group heads to score
/// concurrently. `threads == 1` is the sequential reference arm.
fn par_arm(stream: &[PumpReq], threads: usize) -> ParArm {
    let fleet = FleetSpec::parse("10*llama3-8b@0.12,6*llama2-13b@0.12")
        .expect("static fleet spec");
    let disp = crate::server::sim::make_dispatcher_tuned("kairos", &fleet, None, None);
    let mut c = Coordinator::sim(fleet, Box::new(Fcfs), disp);
    c.set_affinity(
        &AffinitySpec::parse("Pinned8=llama3-8b,Pinned13=llama2-13b")
            .expect("static affinity spec"),
    );
    c.set_pump_threads(threads);
    let start = Instant::now();
    let mut hot = std::time::Duration::ZERO;
    let mut now = 0.0_f64;
    let mut i = 0usize;
    while i < stream.len() {
        let batch = (stream.len() - i).min(64);
        let t = Instant::now();
        for r in &stream[i..i + batch] {
            c.submit_external(r.agent, r.prompt_tokens, r.output_tokens, now);
            now += 1e-4;
        }
        c.pump(now);
        hot += t.elapsed();
        // Drain between batches (untimed: engine simulation is not the
        // system under test).
        loop {
            let mut idle = true;
            for j in 0..c.n_instances() {
                if !c.engines[j].has_work() {
                    continue;
                }
                idle = false;
                let out = c.step_engine(j, now);
                now += out.duration.max(1e-6);
                c.absorb(j, out, now);
            }
            let t = Instant::now();
            c.pump(now);
            hot += t.elapsed();
            if idle {
                break;
            }
        }
        i += batch;
    }
    let stats = c.dispatch_stats();
    ParArm {
        threads,
        hot_seconds: hot.as_secs_f64(),
        wall_seconds: start.elapsed().as_secs_f64(),
        dispatched_total: c.dispatch_log.total(),
        dropped: c.dropped,
        conflicts: stats.conflicts,
        rescored: stats.rescored,
        par_rounds: stats.par_rounds,
        dispatches: c.dispatch_log.take_vec(),
        groups: c.group_log.take_vec(),
    }
}

/// One row of the `BENCH_par.json` scaling curve. `speedup` is this worker
/// count's pump throughput over the sequential (1-thread) arm's.
fn par_arm_json(n: usize, a: &ParArm, baseline_hot: f64) -> Json {
    Json::obj(vec![
        ("threads", Json::from(a.threads)),
        ("hot_seconds", Json::from(a.hot_seconds)),
        ("wall_seconds", Json::from(a.wall_seconds)),
        ("req_per_sec", Json::from(n as f64 / a.hot_seconds.max(1e-12))),
        ("speedup", Json::from(baseline_hot / a.hot_seconds.max(1e-12))),
        ("dispatched_total", Json::from(a.dispatched_total as f64)),
        ("dropped", Json::from(a.dropped as f64)),
        ("conflicts", Json::from(a.conflicts as f64)),
        ("rescored", Json::from(a.rescored as f64)),
        ("par_rounds", Json::from(a.par_rounds as f64)),
    ])
}

/// The worker counts of the scaling curve: 1, then doubling up to `top`.
fn par_thread_counts(top: usize) -> Vec<usize> {
    let mut counts = vec![1];
    let mut t = 2;
    while t < top {
        counts.push(t);
        t *= 2;
    }
    if top > 1 {
        counts.push(top);
    }
    counts
}

fn provenance(seed: u64, mode: &str) -> Json {
    // kairos-lint: allow(no-env-fs, provenance block records the measuring host; never feeds results)
    let host = if std::env::var_os("CI").is_some() { "ci" } else { "local" };
    Json::obj(vec![
        ("host", Json::from(host)),
        ("seed", Json::from(seed as f64)),
        ("mode", Json::from(mode)),
    ])
}

fn write_json(path: &std::path::Path, j: &Json) -> crate::Result<()> {
    // kairos-lint: allow(no-env-fs, result emission is the bench harness's contract; path comes from --out-dir)
    std::fs::write(path, format!("{j}\n"))?;
    Ok(())
}

/// Run all five benchmark stages and write `BENCH_pump.json`,
/// `BENCH_e2e.json`, `BENCH_pack.json`, `BENCH_cache.json` and
/// `BENCH_par.json`.
pub fn run(opts: &BenchOptions) -> crate::Result<()> {
    // kairos-lint: allow(no-env-fs, result emission is the bench harness's contract; path comes from --out-dir)
    std::fs::create_dir_all(&opts.out_dir)?;
    let mode = if opts.quick { "quick" } else { "full" };
    let (pump_n, e2e_tasks, e2e_rate) = if opts.quick {
        (20_000, 2_000, 8.0)
    } else {
        (1_000_000, 120_000, 8.0)
    };
    let (pack_tasks, pack_rate) = if opts.quick { (3_000, 16.0) } else { (200_000, 16.0) };
    let (cache_tasks, cache_rate, cache_sessions) =
        if opts.quick { (2_500, 10.0, 24) } else { (120_000, 10.0, 96) };
    let par_n = if opts.quick { 8_000 } else { 400_000 };

    println!(
        "bench ({mode}): pump {pump_n} requests, e2e {e2e_tasks} tasks, \
         pack {pack_tasks} tasks, cache {cache_tasks} tasks, par {par_n} requests \
         (1..={} threads), seed {}",
        opts.threads, opts.seed
    );

    // --- pump microbench -------------------------------------------------
    let stream = pump_stream(pump_n, opts.seed);
    let baseline = pump_arm(&stream, true);
    let optimized = pump_arm(&stream, false);
    // The A/B must measure speed, never behavior.
    assert_eq!(
        baseline.dispatched_total, optimized.dispatched_total,
        "hot-path arms diverged on dispatch decisions"
    );
    assert_eq!(baseline.dropped, optimized.dropped);
    let speedup = baseline.hot_seconds / optimized.hot_seconds.max(1e-12);
    let pump_json = Json::obj(vec![
        ("schema", Json::from("kairos-bench-pump/v1")),
        ("mode", Json::from(mode)),
        ("requests", Json::from(pump_n)),
        ("fleet", Json::from("3*llama3-8b@0.12,llama2-13b@0.12")),
        ("provenance", provenance(opts.seed, mode)),
        ("baseline", pump_arm_json(pump_n, &baseline)),
        ("optimized", pump_arm_json(pump_n, &optimized)),
        ("speedup", Json::from(speedup)),
    ]);
    let pump_path = opts.out_dir.join("BENCH_pump.json");
    write_json(&pump_path, &pump_json)?;
    println!(
        "pump: baseline {:.0} req/s, optimized {:.0} req/s ({speedup:.2}x), \
         log bytes {} -> {}",
        pump_n as f64 / baseline.hot_seconds.max(1e-12),
        pump_n as f64 / optimized.hot_seconds.max(1e-12),
        baseline.peak_log_bytes,
        optimized.peak_log_bytes,
    );

    // --- e2e benchmark ---------------------------------------------------
    let trace = TraceGen::default().generate(
        &WorkloadMix::colocated(),
        e2e_rate,
        e2e_tasks,
        &mut Rng::new(opts.seed),
    );
    let (base_res, base_wall) = e2e_arm(trace.clone(), true);
    let (opt_res, opt_wall) = e2e_arm(trace, false);
    assert_eq!(
        base_res.dispatched_total, opt_res.dispatched_total,
        "e2e arms diverged on dispatch decisions"
    );
    // Sketch fidelity, measured on the exact-mode arm: the streaming
    // summary must track the full-sample percentiles it replaces in lean
    // mode.
    let exact = base_res.metrics.summary().expect("baseline arm finished workflows");
    let sketch = base_res
        .metrics
        .streaming_summary()
        .expect("sketches fed in both modes");
    let e2e_speedup = base_wall / opt_wall.max(1e-12);
    let e2e_json = Json::obj(vec![
        ("schema", Json::from("kairos-bench-e2e/v1")),
        ("mode", Json::from(mode)),
        ("tasks", Json::from(e2e_tasks)),
        ("rate", Json::from(e2e_rate)),
        ("fleet", Json::from("4*llama3-8b@0.12")),
        ("provenance", provenance(opts.seed, mode)),
        ("baseline", e2e_arm_json(&base_res, base_wall)),
        ("optimized", e2e_arm_json(&opt_res, opt_wall)),
        ("speedup", Json::from(e2e_speedup)),
        (
            "sketch_vs_exact",
            Json::obj(vec![
                (
                    "p50_abs_err",
                    Json::from((sketch.p50_token_latency - exact.p50_token_latency).abs()),
                ),
                (
                    "p99_abs_err",
                    Json::from((sketch.p99_token_latency - exact.p99_token_latency).abs()),
                ),
                (
                    "distinct_agent_families",
                    Json::from(base_res.metrics.stream.distinct_agent_families()),
                ),
            ]),
        ),
    ]);
    let e2e_path = opts.out_dir.join("BENCH_e2e.json");
    write_json(&e2e_path, &e2e_json)?;
    println!(
        "e2e:  baseline {base_wall:.2}s, optimized {opt_wall:.2}s ({e2e_speedup:.2}x), \
         log bytes {} -> {}",
        base_res.log_state_bytes, opt_res.log_state_bytes,
    );

    // --- pack benchmark --------------------------------------------------
    let pack_trace = TraceGen::default().generate(
        &WorkloadMix::colocated(),
        pack_rate,
        pack_tasks,
        &mut Rng::new(opts.seed),
    );
    let (pack_base, pack_base_wall) = pack_arm(pack_trace.clone(), true);
    let (pack_opt, pack_opt_wall) = pack_arm(pack_trace, false);
    // Zero decision divergence between the scoring arms: same decision
    // count, same drop count, and the retained dispatch-log windows (both
    // arms carry the same cap) match entry for entry.
    assert_eq!(
        pack_base.dispatched_total, pack_opt.dispatched_total,
        "pack scoring arms diverged on dispatch decisions"
    );
    assert_eq!(pack_base.dropped_requests, pack_opt.dropped_requests);
    assert_eq!(
        pack_base.dispatch_log, pack_opt.dispatch_log,
        "pack scoring arms diverged inside the retained dispatch log"
    );
    let pack_speedup = pack_base_wall / pack_opt_wall.max(1e-12);
    let pack_json = Json::obj(vec![
        ("schema", Json::from("kairos-bench-pack/v1")),
        ("mode", Json::from(mode)),
        ("tasks", Json::from(pack_tasks)),
        ("rate", Json::from(pack_rate)),
        ("fleet", Json::from("10*llama3-8b@0.12,6*llama2-13b@0.12")),
        ("provenance", provenance(opts.seed, mode)),
        ("baseline", pack_arm_json(&pack_base, pack_base_wall)),
        ("optimized", pack_arm_json(&pack_opt, pack_opt_wall)),
        ("speedup", Json::from(pack_speedup)),
    ]);
    let pack_path = opts.out_dir.join("BENCH_pack.json");
    write_json(&pack_path, &pack_json)?;
    let pk = pack_opt.metrics.stream.packer;
    println!(
        "pack: baseline {pack_base_wall:.2}s, optimized {pack_opt_wall:.2}s \
         ({pack_speedup:.2}x); {} decisions, {} evaluated, {} fast-accepted, \
         {} fast-rejected, {} rejected rounds, {} suspensions",
        pk.decisions,
        pk.evaluated,
        pk.fast_accepted,
        pk.fast_rejected,
        pk.rejected_rounds,
        pk.suspensions,
    );
    // --- cache benchmark -------------------------------------------------
    let mut cache_trace = TraceGen::default().generate(
        &WorkloadMix::colocated(),
        cache_rate,
        cache_tasks,
        &mut Rng::new(opts.seed),
    );
    sessionize_arrivals(&mut cache_trace, cache_sessions);
    let (blind_res, blind_wall) = cache_arm(cache_trace.clone(), "kairos");
    let (affine_res, affine_wall) = cache_arm(cache_trace, "cache-affine");
    // Placement arms serve the same trace to completion: the comparison is
    // WHERE sessions land, never whether their requests finish.
    assert_eq!(
        blind_res.metrics.total_requests, affine_res.metrics.total_requests,
        "cache arms diverged on completed requests"
    );
    assert!(
        affine_res.cache_stats().hits > 0,
        "sticky placement produced no prefix-cache hits"
    );
    // The headline is simulated latency, not wall time: how much e2e the
    // sticky placement buys on the identical trace.
    let cache_speedup =
        blind_res.mean_request_e2e() / affine_res.mean_request_e2e().max(1e-12);
    let cache_json = Json::obj(vec![
        ("schema", Json::from("kairos-bench-cache/v1")),
        ("mode", Json::from(mode)),
        ("tasks", Json::from(cache_tasks)),
        ("rate", Json::from(cache_rate)),
        ("sessions", Json::from(cache_sessions as f64)),
        ("fleet", Json::from("6*llama3-8b@0.12")),
        ("provenance", provenance(opts.seed, mode)),
        ("blind", cache_arm_json(&blind_res, blind_wall)),
        ("affine", cache_arm_json(&affine_res, affine_wall)),
        ("e2e_speedup", Json::from(cache_speedup)),
    ]);
    let cache_path = opts.out_dir.join("BENCH_cache.json");
    write_json(&cache_path, &cache_json)?;
    let bcs = blind_res.cache_stats();
    let acs = affine_res.cache_stats();
    println!(
        "cache: blind {:.1}% hits / affine {:.1}% hits, saved prefill {} -> {} \
         tokens, mean e2e {:.3}s -> {:.3}s ({cache_speedup:.2}x)",
        bcs.hit_rate() * 100.0,
        acs.hit_rate() * 100.0,
        bcs.saved_prefill_tokens,
        acs.saved_prefill_tokens,
        blind_res.mean_request_e2e(),
        affine_res.mean_request_e2e(),
    );
    // --- parallel-pump benchmark -----------------------------------------
    let par_stream = pump_stream(par_n, opts.seed);
    let counts = par_thread_counts(opts.threads);
    let mut arms: Vec<ParArm> = Vec::new();
    for &t in &counts {
        arms.push(par_arm(&par_stream, t));
    }
    // Determinism is the contract: every worker count replays the
    // sequential arm's decisions bit for bit.
    let base_arm = &arms[0];
    for a in &arms[1..] {
        assert_eq!(
            base_arm.dispatches, a.dispatches,
            "parallel pump diverged from the sequential arm at {} threads",
            a.threads
        );
        assert_eq!(
            base_arm.groups, a.groups,
            "parallel pump group log diverged at {} threads",
            a.threads
        );
        assert_eq!(base_arm.dropped, a.dropped);
    }
    let base_hot = base_arm.hot_seconds;
    let top = match arms.last() {
        Some(a) => a,
        None => unreachable!("par_thread_counts always yields at least one count"),
    };
    let par_speedup = base_hot / top.hot_seconds.max(1e-12);
    let par_json = Json::obj(vec![
        ("schema", Json::from("kairos-bench-par/v1")),
        ("mode", Json::from(mode)),
        ("requests", Json::from(par_n)),
        ("fleet", Json::from("10*llama3-8b@0.12,6*llama2-13b@0.12")),
        ("provenance", provenance(opts.seed, mode)),
        ("baseline", par_arm_json(par_n, base_arm, base_hot)),
        (
            "curve",
            Json::Arr(
                arms.iter().map(|a| par_arm_json(par_n, a, base_hot)).collect(),
            ),
        ),
        ("equal_logs", Json::from(true)),
        ("speedup", Json::from(par_speedup)),
    ]);
    let par_path = opts.out_dir.join("BENCH_par.json");
    write_json(&par_path, &par_json)?;
    println!(
        "par:  sequential {:.0} req/s, {} threads {:.0} req/s ({par_speedup:.2}x); \
         {} conflicts, {} rescored, {} rounds; logs identical across {:?} threads",
        par_n as f64 / base_hot.max(1e-12),
        top.threads,
        par_n as f64 / top.hot_seconds.max(1e-12),
        top.conflicts,
        top.rescored,
        top.par_rounds,
        counts,
    );
    println!(
        "wrote {}, {}, {}, {} and {}",
        pump_path.display(),
        e2e_path.display(),
        pack_path.display(),
        cache_path.display(),
        par_path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pump_arms_agree_and_report_sane_numbers() {
        let stream = pump_stream(300, 7);
        let base = pump_arm(&stream, true);
        let opt = pump_arm(&stream, false);
        assert_eq!(base.dispatched_total, opt.dispatched_total);
        assert_eq!(base.dropped, opt.dropped);
        assert!(base.dispatched_total > 0);
        assert!(base.hot_seconds > 0.0 && opt.hot_seconds > 0.0);
        assert!(
            opt.peak_log_bytes <= base.peak_log_bytes,
            "bounded logs must not pin more than full logs ({} > {})",
            opt.peak_log_bytes,
            base.peak_log_bytes
        );
    }

    #[test]
    fn pack_arms_agree_on_every_decision() {
        let trace = TraceGen::default().generate(
            &WorkloadMix::colocated(),
            16.0,
            120,
            &mut Rng::new(11),
        );
        let (base, _) = pack_arm(trace.clone(), true);
        let (opt, _) = pack_arm(trace, false);
        assert_eq!(base.dispatched_total, opt.dispatched_total);
        assert_eq!(base.dropped_requests, opt.dropped_requests);
        assert_eq!(base.dispatch_log, opt.dispatch_log);
        assert!(opt.dispatched_total > 0);
        let p = opt.metrics.stream.packer;
        assert!(p.decisions > 0, "packer stats must reach the metrics surface");
        assert!(p.evaluated > 0);
        // The legacy arm must never report fast-path hits.
        let lp = base.metrics.stream.packer;
        assert_eq!(lp.fast_accepted + lp.fast_rejected, 0);
    }

    #[test]
    fn cache_arms_complete_the_same_trace_and_the_sticky_arm_hits() {
        let mut trace = TraceGen::default().generate(
            &WorkloadMix::colocated(),
            10.0,
            150,
            &mut Rng::new(5),
        );
        sessionize_arrivals(&mut trace, 12);
        let (blind, _) = cache_arm(trace.clone(), "kairos");
        let (affine, _) = cache_arm(trace, "cache-affine");
        // Same trace, same completions — placement only moves WHERE.
        assert_eq!(blind.metrics.total_requests, affine.metrics.total_requests);
        assert!(blind.metrics.total_requests > 0);
        let p = affine.metrics.stream.packer;
        assert!(p.sticky_hits > 0, "CHWBL never stuck a session to its instance");
        assert!(
            affine.cache_stats().hits > 0,
            "sticky placement produced no prefix-cache hits"
        );
        // The cache-blind packer records no sticky decisions.
        assert_eq!(blind.metrics.stream.packer.sticky_hits, 0);
        assert_eq!(blind.metrics.stream.packer.sticky_fallbacks, 0);
    }

    #[test]
    fn par_arms_agree_at_every_thread_count() {
        let stream = pump_stream(400, 13);
        let base = par_arm(&stream, 1);
        assert!(base.dispatched_total > 0);
        assert_eq!(
            (base.conflicts, base.rescored, base.par_rounds),
            (0, 0, 0),
            "the 1-thread arm must take the sequential path"
        );
        for threads in [2usize, 4] {
            let par = par_arm(&stream, threads);
            assert_eq!(base.dispatches, par.dispatches, "{threads} threads");
            assert_eq!(base.groups, par.groups, "{threads} threads");
            assert_eq!(base.dropped, par.dropped, "{threads} threads");
            assert!(
                par.par_rounds > 0,
                "threaded arm never fanned a scoring round out"
            );
        }
    }

    #[test]
    fn par_thread_counts_cover_one_to_top() {
        assert_eq!(par_thread_counts(1), vec![1]);
        assert_eq!(par_thread_counts(2), vec![1, 2]);
        assert_eq!(par_thread_counts(4), vec![1, 2, 4]);
        assert_eq!(par_thread_counts(6), vec![1, 2, 4, 6]);
        assert_eq!(par_thread_counts(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn pump_stream_is_seed_deterministic() {
        let a = pump_stream(100, 3);
        let b = pump_stream(100, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.agent, y.agent);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn bench_json_round_trips_through_parser() {
        let arm = PumpArm {
            hot_seconds: 0.25,
            wall_seconds: 1.0,
            dispatched_total: 1000,
            dropped: 0,
            peak_log_bytes: 4096,
        };
        let j = Json::obj(vec![
            ("schema", Json::from("kairos-bench-pump/v1")),
            ("baseline", pump_arm_json(1000, &arm)),
        ]);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("baseline").unwrap().get("req_per_sec").unwrap().as_f64(),
            Some(4000.0)
        );
    }
}
