//! In-process message bus — the Kafka substitute (DESIGN.md §3).
//!
//! The paper deploys agents as distributed processes communicating through
//! Kafka topics; identifiers (`msg_id`, `upstream_name`, timestamps) ride on
//! the messages so the orchestrator can reconstruct workflows. This module
//! reproduces the semantics the system relies on — named topics, append-only
//! partitions, independent consumer-group offsets, blocking polls — as a
//! thread-safe in-process broker (threads + condvars; no network, no tokio).

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A message delivered through the bus. `key` selects the partition (same
/// key → same partition → per-key ordering, as in Kafka).
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub key: String,
    pub payload: String,
    /// Headers carry the Kairos system identifiers transparently.
    pub headers: Vec<(String, String)>,
}

impl Message {
    pub fn new(key: impl Into<String>, payload: impl Into<String>) -> Message {
        Message { key: key.into(), payload: payload.into(), headers: vec![] }
    }

    pub fn header(mut self, k: impl Into<String>, v: impl Into<String>) -> Message {
        self.headers.push((k.into(), v.into()));
        self
    }

    pub fn get_header(&self, k: &str) -> Option<&str> {
        self.headers.iter().find(|(hk, _)| hk == k).map(|(_, v)| v.as_str())
    }
}

#[derive(Debug, Default)]
struct Partition {
    log: Vec<Message>,
}

#[derive(Debug, Default)]
struct TopicState {
    partitions: Vec<Partition>,
    /// consumer group -> per-partition committed offset
    offsets: HashMap<String, Vec<usize>>,
    closed: bool,
}

#[derive(Debug, Default)]
struct BrokerState {
    /// Keyed by topic name; ordered so [`Broker::topics`] lists
    /// deterministically (lint rule D2).
    topics: BTreeMap<String, TopicState>,
}

/// The broker: cheaply clonable handle over shared state.
#[derive(Clone, Default)]
pub struct Broker {
    state: Arc<(Mutex<BrokerState>, Condvar)>,
}

impl Broker {
    pub fn new() -> Broker {
        Broker::default()
    }

    /// Create a topic with `partitions` partitions. Idempotent.
    pub fn create_topic(&self, name: &str, partitions: usize) {
        assert!(partitions > 0);
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap();
        st.topics.entry(name.to_string()).or_insert_with(|| TopicState {
            partitions: (0..partitions).map(|_| Partition::default()).collect(),
            offsets: HashMap::new(),
            closed: false,
        });
    }

    fn partition_for(key: &str, n: usize) -> usize {
        // FNV-1a over the key.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        (h % n as u64) as usize
    }

    /// Append a message to `topic`. Returns (partition, offset).
    pub fn publish(&self, topic: &str, msg: Message) -> Result<(usize, usize), BusError> {
        let (lock, cvar) = &*self.state;
        let mut st = lock.lock().unwrap();
        let t = st.topics.get_mut(topic).ok_or(BusError::NoSuchTopic)?;
        if t.closed {
            return Err(BusError::TopicClosed);
        }
        let p = Self::partition_for(&msg.key, t.partitions.len());
        t.partitions[p].log.push(msg);
        let off = t.partitions[p].log.len() - 1;
        cvar.notify_all();
        Ok((p, off))
    }

    /// Non-blocking poll: next unconsumed message for `group`, advancing the
    /// group's offset. Scans partitions round-robin-ish (lowest backlog of
    /// unread first to avoid starvation).
    pub fn try_poll(&self, topic: &str, group: &str) -> Result<Option<Message>, BusError> {
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap();
        let t = st.topics.get_mut(topic).ok_or(BusError::NoSuchTopic)?;
        let nparts = t.partitions.len();
        let offsets = t
            .offsets
            .entry(group.to_string())
            .or_insert_with(|| vec![0; nparts]);
        // Pick the partition with the largest unread backlog (fair-ish).
        let mut best: Option<(usize, usize)> = None;
        for p in 0..nparts {
            let unread = t.partitions[p].log.len().saturating_sub(offsets[p]);
            if unread > 0 && best.map(|(_, b)| unread > b).unwrap_or(true) {
                best = Some((p, unread));
            }
        }
        if let Some((p, _)) = best {
            let off = offsets[p];
            offsets[p] += 1;
            return Ok(Some(t.partitions[p].log[off].clone()));
        }
        if t.closed {
            return Err(BusError::TopicClosed);
        }
        Ok(None)
    }

    /// Blocking poll with timeout. Returns `Ok(None)` on timeout and
    /// `Err(TopicClosed)` when the topic is closed and fully drained.
    #[allow(clippy::disallowed_methods)] // condvar deadlines need real wall time
    pub fn poll(
        &self,
        topic: &str,
        group: &str,
        timeout: Duration,
    ) -> Result<Option<Message>, BusError> {
        // kairos-lint: allow(wall-clock, condvar deadline arithmetic; never feeds scheduling decisions)
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match self.try_poll(topic, group)? {
                Some(m) => return Ok(Some(m)),
                None => {
                    let (lock, cvar) = &*self.state;
                    let st = lock.lock().unwrap();
                    // kairos-lint: allow(wall-clock, condvar deadline arithmetic; never feeds scheduling decisions)
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Ok(None);
                    }
                    let (_st, timed_out) =
                        cvar.wait_timeout(st, deadline - now).unwrap();
                    if timed_out.timed_out() {
                        // One last non-blocking check happens via the loop.
                    }
                }
            }
        }
    }

    /// Close a topic: publishes fail; consumers drain the backlog then get
    /// `TopicClosed`.
    pub fn close_topic(&self, topic: &str) {
        let (lock, cvar) = &*self.state;
        let mut st = lock.lock().unwrap();
        if let Some(t) = st.topics.get_mut(topic) {
            t.closed = true;
        }
        cvar.notify_all();
    }

    /// Unread backlog for a group across all partitions of a topic.
    pub fn backlog(&self, topic: &str, group: &str) -> usize {
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap();
        let Some(t) = st.topics.get(topic) else { return 0 };
        let total: usize = t.partitions.iter().map(|p| p.log.len()).sum();
        let consumed: usize = t
            .offsets
            .get(group)
            .map(|offs| offs.iter().sum())
            .unwrap_or(0);
        total - consumed
    }

    pub fn topics(&self) -> Vec<String> {
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap();
        st.topics.keys().cloned().collect()
    }
}

/// Bus error type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusError {
    /// The named topic was never created.
    NoSuchTopic,
    /// The topic is closed and (for polls) fully drained.
    TopicClosed,
}

impl std::fmt::Display for BusError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BusError::NoSuchTopic => write!(f, "no such topic"),
            BusError::TopicClosed => write!(f, "topic closed"),
        }
    }
}

impl std::error::Error for BusError {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn publish_then_poll() {
        let b = Broker::new();
        b.create_topic("agent.router", 2);
        b.publish("agent.router", Message::new("m1", "hello")).unwrap();
        let m = b.try_poll("agent.router", "g").unwrap().unwrap();
        assert_eq!(m.payload, "hello");
        assert!(b.try_poll("agent.router", "g").unwrap().is_none());
    }

    #[test]
    fn groups_have_independent_offsets() {
        let b = Broker::new();
        b.create_topic("t", 1);
        b.publish("t", Message::new("k", "x")).unwrap();
        assert!(b.try_poll("t", "g1").unwrap().is_some());
        assert!(b.try_poll("t", "g2").unwrap().is_some());
        assert!(b.try_poll("t", "g1").unwrap().is_none());
    }

    #[test]
    fn same_key_preserves_order() {
        let b = Broker::new();
        b.create_topic("t", 4);
        for i in 0..10 {
            b.publish("t", Message::new("same", format!("{i}"))).unwrap();
        }
        let mut seen = vec![];
        while let Some(m) = b.try_poll("t", "g").unwrap() {
            seen.push(m.payload.parse::<usize>().unwrap());
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn headers_round_trip() {
        let b = Broker::new();
        b.create_topic("t", 1);
        let m = Message::new("k", "p")
            .header("msg_id", "abc-123")
            .header("upstream", "Router");
        b.publish("t", m).unwrap();
        let got = b.try_poll("t", "g").unwrap().unwrap();
        assert_eq!(got.get_header("msg_id"), Some("abc-123"));
        assert_eq!(got.get_header("upstream"), Some("Router"));
        assert_eq!(got.get_header("missing"), None);
    }

    #[test]
    fn missing_topic_errors() {
        let b = Broker::new();
        assert_eq!(
            b.publish("nope", Message::new("k", "p")).unwrap_err(),
            BusError::NoSuchTopic
        );
        assert_eq!(b.try_poll("nope", "g").unwrap_err(), BusError::NoSuchTopic);
    }

    #[test]
    fn closed_topic_drains_then_errors() {
        let b = Broker::new();
        b.create_topic("t", 1);
        b.publish("t", Message::new("k", "last")).unwrap();
        b.close_topic("t");
        assert!(b.publish("t", Message::new("k", "x")).is_err());
        assert_eq!(b.try_poll("t", "g").unwrap().unwrap().payload, "last");
        assert_eq!(b.try_poll("t", "g").unwrap_err(), BusError::TopicClosed);
    }

    #[test]
    fn blocking_poll_wakes_on_publish() {
        let b = Broker::new();
        b.create_topic("t", 1);
        let b2 = b.clone();
        let h = thread::spawn(move || {
            b2.poll("t", "g", Duration::from_secs(5)).unwrap().unwrap().payload
        });
        thread::sleep(Duration::from_millis(30));
        b.publish("t", Message::new("k", "wake")).unwrap();
        assert_eq!(h.join().unwrap(), "wake");
    }

    #[test]
    fn blocking_poll_times_out() {
        let b = Broker::new();
        b.create_topic("t", 1);
        let got = b.poll("t", "g", Duration::from_millis(30)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn concurrent_producers_consumers_conserve_messages() {
        let b = Broker::new();
        b.create_topic("t", 4);
        let n_producers = 4;
        let per = 250;
        let mut handles = vec![];
        for p in 0..n_producers {
            let b = b.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    b.publish("t", Message::new(format!("k{p}"), format!("{p}:{i}")))
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let consumed = Arc::new(Mutex::new(Vec::new()));
        let mut handles = vec![];
        for _ in 0..3 {
            let b = b.clone();
            let consumed = consumed.clone();
            handles.push(thread::spawn(move || loop {
                match b.try_poll("t", "g").unwrap() {
                    Some(m) => consumed.lock().unwrap().push(m.payload),
                    None => break,
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = consumed.lock().unwrap().clone();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n_producers * per, "every message exactly once");
    }

    #[test]
    fn backlog_accounting() {
        let b = Broker::new();
        b.create_topic("t", 2);
        for i in 0..5 {
            b.publish("t", Message::new(format!("k{i}"), "x")).unwrap();
        }
        assert_eq!(b.backlog("t", "g"), 5);
        b.try_poll("t", "g").unwrap();
        assert_eq!(b.backlog("t", "g"), 4);
    }
}
