//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! kairos serve   [--config file.toml] [--scheduler S] [--dispatcher D]
//!                [--rate R] [--tasks N] [--instances I] [--model M]
//!                [--fleet SPEC] [--seed X] [--autoscale] [--pressure TRACE]
//!                [--affinity SPEC] [--route-policy POLICY]
//! kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
//! kairos elastic-sweep [--fleet SPEC] [--rate R] [--tasks N] [--min N]
//!                [--max N] [--pressure TRACE] [--boot-delay S]
//!                [--per-group BOUNDS]
//! kairos shard-sweep [--fleet SPEC] [--affinity SPEC] [--rate R] [--tasks N]
//! kairos route-sweep [--fleet SPEC] [--affinity SPEC] [--route-policy P]
//!                [--rate R] [--tasks N]
//! kairos figures <id|all> [--out results/]
//! kairos quickstart [--artifacts DIR] [--model NAME]
//! ```

use std::collections::HashMap;

use crate::agents::apps::App;
use crate::config::ServingConfig;
use crate::engine::cost_model::ModelKind;
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::router::{RoutePolicy, RouteReason};
use crate::server::autoscale::{parse_per_group, AutoscaleConfig};
use crate::server::coordinator::{FleetSpec, PROVISIONING};
use crate::server::pressure::PressureTrace;
use crate::server::sim::{run_fleet, FleetConfig, SimResult};
use crate::stats::rng::Rng;
use crate::workload::{TraceGen, WorkloadMix};

/// Flags that take no value (`--flag` alone means `true`; an explicit
/// `--flag false` still parses).
const BOOL_FLAGS: &[&str] = &["autoscale"];

/// Parsed `--key value` flags plus positional args.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(args: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value` form: split here so the value flows through
                // the same validation as `--key value` (the ISSUE's
                // `--tasks=4OO` must error in num(), not corrupt parsing).
                if let Some((k, v)) = key.split_once('=') {
                    if k.is_empty() {
                        return Err(format!("malformed flag {a:?}"));
                    }
                    out.flags.insert(k.to_string(), v.to_string());
                    i += 1;
                    continue;
                }
                let next = args.get(i + 1);
                let next_is_flag = match next {
                    None => true,
                    Some(v) => v.starts_with("--"),
                };
                if BOOL_FLAGS.contains(&key) && next_is_flag {
                    out.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                let val =
                    next.ok_or_else(|| format!("flag --{key} needs a value"))?;
                out.flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Numeric flag: the default when absent — and an error naming the
    /// flag and the offending text when present but malformed. (This used
    /// to fall back to the default silently, so `--tasks=4OO` typos ran
    /// with a config the user never asked for.)
    pub fn num(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{key}: invalid numeric value {v:?}")),
        }
    }

    /// Boolean flag: false when absent, true for bare `--flag` or a
    /// truthy value — and an error naming the flag and the offending text
    /// otherwise (same contract as [`Args::num`]: a typo must not silently
    /// run a config the user never asked for).
    pub fn bool_flag(&self, key: &str) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(false),
            Some("true" | "1" | "on" | "yes") => Ok(true),
            Some("false" | "0" | "off" | "no") => Ok(false),
            Some(v) => Err(format!("flag --{key}: invalid boolean value {v:?}")),
        }
    }
}

const USAGE: &str = "\
kairos — low-latency multi-agent LLM serving (paper reproduction)

USAGE:
  kairos serve       [--config F] [--scheduler kairos|parrot|ayo|oracle]
                     [--dispatcher kairos|rr|oracle|least] [--rate R]
                     [--tasks N] [--instances I] [--model llama3-8b|llama2-13b]
                     [--fleet SPEC] [--seed S] [--workload colocated|qa|rg|cg]
                     [--autoscale] [--pressure TRACE] [--affinity SPEC]
                     [--route-policy pinned|learned[:KEY=VAL,...]]
  kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
                     [--seed S] [--workload W]
  kairos elastic-sweep
                     [--fleet SPEC] [--rate R] [--tasks N] [--seed S]
                     [--workload W] [--min N] [--max N] [--pressure TRACE]
                     [--boot-delay S] [--per-group BOUNDS]
  kairos shard-sweep [--fleet SPEC] [--affinity SPEC] [--scheduler S]
                     [--dispatcher D] [--rate R] [--tasks N] [--seed S]
                     [--workload W]
  kairos route-sweep [--fleet SPEC] [--affinity SPEC] [--scheduler S]
                     [--dispatcher D] [--route-policy P] [--rate R]
                     [--tasks N] [--seed S] [--workload W]
  kairos figures     <table1|fig3..fig18|overhead|all> [--out results]
  kairos quickstart  [--artifacts artifacts] [--model tiny]

FLEET SPEC — comma-separated `[COUNT*]MODEL[@KV_SCALE][:MAX_BATCH]`, e.g.
  `2*llama3-8b@0.12,2*llama3-8b@0.04:128` (uneven co-tenant pressure) or
  `llama3-8b,llama2-13b@0.5` (mixed models). Per-instance KV budgets flow
  to the dispatchers, so memory-aware policies pack each instance against
  its own capacity.

AFFINITY SPEC — comma-separated `AGENT=CLASS` with CLASS a model name or
  `any`; `*=CLASS` sets the default for unpinned agents, e.g.
  `*=llama3-8b,Engineer=llama2-13b`. Pinned requests are routed through
  per-model-family queue shards and only dispatch to instances of their
  family; `shard-sweep` compares the sharded and unsharded configurations
  on the same trace.

ROUTE POLICY — `pinned` (the static affinity stamp) or
  `learned[:explore=R,min_samples=N]`: learn each agent's best family
  online from measured per-family latency, fall back to pins until
  converged, and balance `Any` requests to the least-pressured group;
  `route-sweep` compares both policies on the same trace.

PRESSURE TRACE — `;`-separated `TARGET:TIME=MULT,...` with TARGET an
  instance index or `*`: piecewise co-tenant KV-pressure multipliers, e.g.
  `*:0=1.0,30=0.5,90=1.0;2:0=0.8`. `--autoscale` (or `[autoscale]` in the
  config) lets the fleet grow under load bursts and drain back down;
  `elastic-sweep` compares the fixed and elastic fleets side by side.
  `--boot-delay` models instance boot latency (a grow provisions first,
  registers after the delay); `--per-group` caps/floors each family, e.g.
  `llama3-8b=1..4,llama2-13b=0..2`.
";

/// CLI entrypoint.
pub fn run(raw: Vec<String>) -> crate::Result<()> {
    let args = Args::parse(&raw).map_err(|e| anyhow::anyhow!(e))?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("fleet-sweep") => fleet_sweep(&args),
        Some("elastic-sweep") => elastic_sweep(&args),
        Some("shard-sweep") => shard_sweep(&args),
        Some("route-sweep") => route_sweep(&args),
        Some("figures") => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let out = args.get("out").unwrap_or("results");
            crate::figures::run(id, out)
        }
        Some("quickstart") => quickstart(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// `args.num` with the error lifted into the CLI's anyhow result.
fn numf(args: &Args, key: &str, default: f64) -> crate::Result<f64> {
    args.num(key, default).map_err(|e| anyhow::anyhow!(e))
}

/// Count-like flag (tasks, instances, fleet bounds): a positive integer.
/// `--tasks -5` or `--instances 2.5` must error, not saturate through an
/// `as usize` cast into a run the user never asked for.
fn num_count(args: &Args, key: &str, default: usize) -> crate::Result<usize> {
    let v = numf(args, key, default as f64)?;
    if !v.is_finite() || v < 1.0 || v.fract() != 0.0 {
        anyhow::bail!("flag --{key}: expected a positive integer, got {v}");
    }
    Ok(v as usize)
}

/// Seed-like flag: a non-negative integer.
fn num_u64(args: &Args, key: &str, default: u64) -> crate::Result<u64> {
    let v = numf(args, key, default as f64)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
        anyhow::bail!("flag --{key}: expected a non-negative integer, got {v}");
    }
    Ok(v as u64)
}

/// Rate-like flag: a positive number (the trace generator asserts
/// `rate > 0`, so reject it here with the flag's name instead).
fn num_rate(args: &Args, key: &str, default: f64) -> crate::Result<f64> {
    let v = numf(args, key, default)?;
    if !v.is_finite() || v <= 0.0 {
        anyhow::bail!("flag --{key}: expected a positive number, got {v}");
    }
    Ok(v)
}

fn serve(args: &Args) -> crate::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ServingConfig::from_toml(&text).map_err(|e| anyhow::anyhow!(e))?
        }
        None => ServingConfig::default(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.to_string();
    }
    if let Some(d) = args.get("dispatcher") {
        cfg.dispatcher = d.to_string();
    }
    cfg.rate = num_rate(args, "rate", cfg.rate)?;
    cfg.n_tasks = num_count(args, "tasks", cfg.n_tasks)?;
    cfg.seed = num_u64(args, "seed", cfg.seed)?;
    cfg.sim.n_instances = num_count(args, "instances", cfg.sim.n_instances)?;
    if let Some(m) = args.get("model") {
        cfg.sim.model = ModelKind::parse(m).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(f) = args.get("fleet") {
        cfg.fleet = Some(f.to_string());
    }
    if let Some(p) = args.get("pressure") {
        cfg.pressure = Some(p.to_string());
    }
    if let Some(a) = args.get("affinity") {
        cfg.affinity = Some(a.to_string());
    }
    if let Some(r) = args.get("route-policy") {
        cfg.route_policy = Some(r.to_string());
    }
    let fleet = cfg.resolve_fleet().map_err(|e| anyhow::anyhow!(e))?;
    // `--autoscale` overrides the config like every other flag: bare/true
    // enables (with the requested fleet as the floor when the config has
    // no `[autoscale]` thresholds), an explicit `--autoscale false`
    // disables a config-enabled autoscaler.
    let mut autoscale = cfg.autoscale;
    if args.get("autoscale").is_some() {
        if !args.bool_flag("autoscale").map_err(|e| anyhow::anyhow!(e))? {
            autoscale = None;
        } else if autoscale.is_none() {
            let d = AutoscaleConfig::default();
            autoscale = Some(AutoscaleConfig {
                // Never drain below what the user explicitly asked for via
                // --instances/--fleet — and leave burst headroom above it
                // (2x) so a large fleet doesn't silently build min == max
                // bounds where no scale event can ever fire.
                min_instances: fleet.len().max(1),
                max_instances: d.max_instances.max(fleet.len() * 2),
                ..d
            });
        }
    }
    if let Some(a) = autoscale.as_mut() {
        a.template = fleet.instances[0];
        // A configured floor is honored as-is: a fleet starting below it
        // simply never drains further (the autoscaler only grows on load).
        a.min_instances = a.min_instances.max(1);
    }
    let pressure = cfg
        .pressure
        .as_deref()
        .map(PressureTrace::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let affinity = cfg
        .affinity
        .as_deref()
        .map(AffinitySpec::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let route = cfg
        .route_policy
        .as_deref()
        .map(RoutePolicy::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!(
        "serving {} tasks at {} req/s on {} instances{}{}{}{}{} — scheduler={} dispatcher={}",
        cfg.n_tasks,
        cfg.rate,
        fleet.len(),
        if fleet.is_heterogeneous() { " (heterogeneous)" } else { "" },
        if autoscale.is_some() { " (elastic)" } else { "" },
        if pressure.is_some() { " (co-tenant pressure)" } else { "" },
        if affinity.is_some() { " (model-affine)" } else { "" },
        match route {
            Some(RoutePolicy::Learned { .. }) => " (learned routing)",
            _ => "",
        },
        cfg.scheduler,
        cfg.dispatcher
    );
    let arrivals =
        TraceGen::default().generate(&mix, cfg.rate, cfg.n_tasks, &mut Rng::new(cfg.seed));
    let fc = FleetConfig {
        fleet,
        refresh_interval: cfg.sim.refresh_interval,
        warmup_frac: cfg.sim.warmup_frac,
        autoscale,
        pressure,
        affinity,
        route,
    };
    let affine = fc.affinity.is_some() || matches!(fc.route, Some(RoutePolicy::Learned { .. }));
    let res = run_fleet(fc, &cfg.scheduler, &cfg.dispatcher, arrivals);
    let s = &res.summary;
    println!("\ncompleted {} workflows over {:.1} sim-seconds", s.n_workflows, res.sim_duration);
    println!("program-level token latency:");
    println!("  avg  {:.4} s/tok", s.avg_token_latency);
    println!("  P50  {:.4}   P90 {:.4}   P95 {:.4}   P99 {:.4}",
        s.p50_token_latency, s.p90_token_latency, s.p95_token_latency, s.p99_token_latency);
    println!("queueing-time ratio: {:.1}%", s.mean_queue_ratio * 100.0);
    println!("preempted requests:  {:.1}%", s.preemption_rate * 100.0);
    println!("dropped requests:    {}", res.dropped_requests);
    if affine {
        println!("cross-model dispatches: {}", res.cross_model_dispatches());
    }
    if !res.scale_log.is_empty() {
        let (grows, shrinks) = res.scale_counts();
        println!(
            "fleet scaling:       {grows} grow(s), {shrinks} retire(s), {} active at end",
            res.final_active_instances
        );
    }
    Ok(())
}

fn workload_mix(name: &str) -> crate::Result<WorkloadMix> {
    Ok(match name {
        "colocated" => WorkloadMix::colocated(),
        "qa" => WorkloadMix::single(App::Qa, "G+M"),
        "rg" => WorkloadMix::single(App::Rg, "TQ"),
        "cg" => WorkloadMix::single(App::Cg, "HE"),
        other => anyhow::bail!("unknown workload {other:?}"),
    })
}

/// End-to-end heterogeneous-fleet scenario: one fleet, every dispatcher.
/// Shows how memory-aware dispatching degrades (or not) when half the
/// fleet runs under heavier co-tenant KV pressure.
fn fleet_sweep(args: &Args) -> crate::Result<()> {
    let spec = args
        .get("fleet")
        .unwrap_or("2*llama3-8b@0.12,2*llama3-8b@0.04:128");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let rate = num_rate(args, "rate", 6.0)?;
    let n_tasks = num_count(args, "tasks", 400)?;
    let seed = num_u64(args, "seed", 42)?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!("fleet sweep over {spec:?} — {} instances, scheduler={scheduler}", fleet.len());
    println!("{} tasks at {rate} req/s (seed {seed})\n", n_tasks);
    let mut t = crate::util::table::Table::new(&[
        "dispatcher", "avg s/tok", "P99 s/tok", "queue%", "preempt%", "dropped",
    ]);
    for disp in ["rr", "least", "oracle", "kairos"] {
        let arrivals =
            TraceGen::default().generate(&mix, rate, n_tasks, &mut Rng::new(seed));
        let fc = FleetConfig::from(fleet.clone());
        let res = run_fleet(fc, scheduler, disp, arrivals);
        let s = &res.summary;
        t.row(vec![
            res.dispatcher_name.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            format!("{:.1}%", s.preemption_rate * 100.0),
            res.dropped_requests.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// Elastic-fleet scenario: the same bursty overload served by a fixed
/// fleet and by an elastic one (autoscaler growing under the burst,
/// draining back down), optionally under a co-tenant pressure trace.
fn elastic_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("2*llama3-8b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let rate = num_rate(args, "rate", 12.0)?;
    let n_tasks = num_count(args, "tasks", 500)?;
    let seed = num_u64(args, "seed", 42)?;
    let min = num_count(args, "min", fleet.len())?;
    let max = num_count(args, "max", fleet.len() * 3)?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;
    let pressure = args
        .get("pressure")
        .map(PressureTrace::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;

    let boot_delay = numf(args, "boot-delay", 0.0)?;
    if !boot_delay.is_finite() || boot_delay < 0.0 {
        anyhow::bail!("flag --boot-delay: expected a non-negative number, got {boot_delay}");
    }
    let per_group = args
        .get("per-group")
        .map(parse_per_group)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or_default();

    let mut auto = AutoscaleConfig::for_template(fleet.instances[0]);
    auto.min_instances = min.max(1);
    auto.max_instances = max.max(auto.min_instances);
    auto.up_after = 1;
    auto.down_after = 2;
    auto.cooldown = 5.0;
    auto.boot_delay = boot_delay;
    auto.per_group = per_group;

    println!(
        "elastic sweep over {spec:?} — {} tasks at {rate} req/s (seed {seed}), \
         bounds [{}, {}]{}{}",
        n_tasks,
        auto.min_instances,
        auto.max_instances,
        if pressure.is_some() { ", with co-tenant pressure" } else { "" },
        if boot_delay > 0.0 { ", with boot latency" } else { "" },
    );
    let mut t = crate::util::table::Table::new(&[
        "fleet", "avg s/tok", "P99 s/tok", "queue%", "dropped", "grows", "retires",
        "active@end",
    ]);
    for (label, autoscale) in [("fixed", None), ("elastic", Some(auto))] {
        let arrivals =
            TraceGen::default().generate(&mix, rate, n_tasks, &mut Rng::new(seed));
        let mut fc = FleetConfig::from(fleet.clone());
        fc.autoscale = autoscale;
        fc.pressure = pressure.clone();
        let res = run_fleet(fc, "kairos", "kairos", arrivals);
        let (grows, shrinks) = res.scale_counts();
        let s = &res.summary;
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            res.dropped_requests.to_string(),
            grows.to_string(),
            shrinks.to_string(),
            res.final_active_instances.to_string(),
        ]);
        if !res.scale_log.is_empty() {
            println!("  {label} scale events:");
            for ev in &res.scale_log {
                if ev.instance == PROVISIONING {
                    println!("    t={:7.2}s  (booting)   {:?}", ev.at, ev.kind);
                } else {
                    println!(
                        "    t={:7.2}s  instance {}  {:?}",
                        ev.at, ev.instance, ev.kind
                    );
                }
            }
        }
    }
    t.print();
    Ok(())
}

/// Serving-group scenario: the same mixed-model trace served unsharded
/// (every request may land anywhere — including on a model it was never
/// meant for) and sharded (agents pinned to model families, one queue
/// shard per group). Reports queuing delay, cross-model dispatches and
/// per-group dispatch counts.
fn shard_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("3*llama3-8b@0.12,llama2-13b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let aff_spec = args.get("affinity").unwrap_or("*=llama3-8b");
    let affinity = AffinitySpec::parse(aff_spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let dispatcher = args.get("dispatcher").unwrap_or("rr");
    let rate = num_rate(args, "rate", 4.0)?;
    let n_tasks = num_count(args, "tasks", 300)?;
    let seed = num_u64(args, "seed", 42)?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!(
        "shard sweep over {spec:?} — affinity {aff_spec:?}, \
         scheduler={scheduler} dispatcher={dispatcher}"
    );
    println!("{n_tasks} tasks at {rate} req/s (seed {seed})\n");
    let mut t = crate::util::table::Table::new(&[
        "queue", "avg s/tok", "P99 s/tok", "mean queue s", "cross-model", "dropped",
    ]);
    let mut sharded_res: Option<SimResult> = None;
    for (label, aff) in [("unsharded", None), ("sharded", Some(affinity.clone()))] {
        let arrivals =
            TraceGen::default().generate(&mix, rate, n_tasks, &mut Rng::new(seed));
        let mut fc = FleetConfig::from(fleet.clone());
        fc.affinity = aff;
        let res = run_fleet(fc, scheduler, dispatcher, arrivals);
        let s = &res.summary;
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.3}", res.mean_queue_delay()),
            res.cross_model_dispatches().to_string(),
            res.dropped_requests.to_string(),
        ]);
        if label == "sharded" {
            sharded_res = Some(res);
        }
    }
    t.print();
    if let Some(res) = sharded_res {
        println!("\nsharded per-group dispatches:");
        let mut seen: Vec<(crate::engine::cost_model::ModelClass, usize)> = Vec::new();
        for g in &res.group_log {
            match seen.iter_mut().find(|(c, _)| *c == g.class) {
                Some((_, n)) => *n += 1,
                None => seen.push((g.class, 1)),
            }
        }
        for (class, n) in seen {
            println!("  {:<12} {n}", class.name());
        }
    }
    Ok(())
}

/// Routing-layer scenario: the same mixed-model trace served with the
/// static pinned routing and with the learned policy (profile-driven
/// agent → family affinities, pressure-balanced `Any` placement). Reports
/// mean request E2E latency, queuing delay, and the learned run's route
/// decisions broken down by reason and family.
fn route_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("2*llama3-8b@0.12,2*llama2-13b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    // The default affinity is deliberately bad — everything pinned to the
    // slower, KV-denser 13B family — so the sweep shows learning escaping
    // a wrong static pin.
    let aff_spec = args.get("affinity").unwrap_or("*=llama2-13b");
    let affinity = AffinitySpec::parse(aff_spec).map_err(|e| anyhow::anyhow!(e))?;
    let learned = RoutePolicy::parse(args.get("route-policy").unwrap_or("learned"))
        .map_err(|e| anyhow::anyhow!(e))?;
    if !matches!(learned, RoutePolicy::Learned { .. }) {
        anyhow::bail!(
            "flag --route-policy: route-sweep compares against the pinned baseline; \
             pass a learned policy (e.g. learned:explore=0.2,min_samples=16)"
        );
    }
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let dispatcher = args.get("dispatcher").unwrap_or("kairos");
    let rate = num_rate(args, "rate", 3.0)?;
    let n_tasks = num_count(args, "tasks", 300)?;
    let seed = num_u64(args, "seed", 42)?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!(
        "route sweep over {spec:?} — affinity {aff_spec:?}, \
         scheduler={scheduler} dispatcher={dispatcher}"
    );
    println!("{n_tasks} tasks at {rate} req/s (seed {seed})\n");
    let mut t = crate::util::table::Table::new(&[
        "routing", "avg s/tok", "P99 s/tok", "mean e2e s", "mean queue s", "dropped",
    ]);
    let mut learned_res: Option<SimResult> = None;
    for (label, route) in [("pinned", RoutePolicy::Pinned), ("learned", learned)] {
        let arrivals =
            TraceGen::default().generate(&mix, rate, n_tasks, &mut Rng::new(seed));
        let mut fc = FleetConfig::from(fleet.clone());
        fc.affinity = Some(affinity.clone());
        fc.route = Some(route);
        let res = run_fleet(fc, scheduler, dispatcher, arrivals);
        let s = &res.summary;
        let mean_e2e = res.mean_request_e2e();
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{mean_e2e:.3}"),
            format!("{:.3}", res.mean_queue_delay()),
            res.dropped_requests.to_string(),
        ]);
        if label == "learned" {
            learned_res = Some(res);
        }
    }
    t.print();
    if let Some(res) = learned_res {
        println!("\nlearned route decisions by reason:");
        let mut reasons: Vec<(RouteReason, usize)> = Vec::new();
        for d in &res.route_log {
            match reasons.iter_mut().find(|(r, _)| *r == d.reason) {
                Some((_, n)) => *n += 1,
                None => reasons.push((d.reason, 1)),
            }
        }
        for (reason, n) in reasons {
            println!("  {reason:<16?} {n}");
        }
        println!("\nlearned dispatches by family:");
        let mut fams: Vec<(ModelKind, usize)> = Vec::new();
        for g in &res.group_log {
            match fams.iter_mut().find(|(m, _)| *m == g.model) {
                Some((_, n)) => *n += 1,
                None => fams.push((g.model, 1)),
            }
        }
        for (model, n) in fams {
            println!("  {:<12} {n}", model.name());
        }
    }
    Ok(())
}

fn quickstart(args: &Args) -> crate::Result<()> {
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use crate::server::real::{RealServer, ServeRequest};
    use std::path::Path;

    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let model = args.get("model").unwrap_or("tiny");
    println!("loading AOT artifacts '{model}' from {dir}/ via PJRT ...");
    let mut server = RealServer::new(
        Path::new(&dir),
        model,
        1,
        Box::new(Fcfs),
        Box::new(RoundRobin::new()),
    )?;
    let prompts = [
        ("Router", "Route: what is 17 * 23?"),
        ("MathAgent", "Solve: 17 * 23 = "),
        ("HumanitiesAgent", "Describe the causes of WW1."),
        ("WriterAgent", "Write a report on LLM serving."),
    ];
    let reqs = prompts
        .iter()
        .map(|(agent, p)| ServeRequest {
            agent: agent.to_string(),
            prompt: p.to_string(),
            max_tokens: 12,
        })
        .collect();
    let (responses, stats) = server.serve(reqs)?;
    for r in &responses {
        println!(
            "[{}] {} tok in {:.3}s  prompt={:?}",
            r.agent, r.output_tokens, r.e2e_seconds, r.prompt
        );
    }
    println!(
        "\n{} requests, {} tokens, {:.2} tok/s wall, mean e2e {:.3}s, p90 {:.3}s",
        stats.n_requests, stats.total_tokens, stats.tokens_per_second, stats.mean_e2e,
        stats.p90_e2e
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&sv(&["figures", "fig14", "--out", "res"])).unwrap();
        assert_eq!(a.positional, vec!["figures", "fig14"]);
        assert_eq!(a.get("out"), Some("res"));
    }

    #[test]
    fn equals_form_flags_parse_and_validate() {
        let a = Args::parse(&sv(&["serve", "--tasks=400", "--rate", "3"])).unwrap();
        assert_eq!(a.num("tasks", 1.0), Ok(400.0));
        assert_eq!(a.num("rate", 1.0), Ok(3.0));
        // The ISSUE's motivating typo: `--tasks=4OO` must error, not run
        // 400 tasks (nor corrupt the flags that follow).
        let b = Args::parse(&sv(&["serve", "--tasks=4OO"])).unwrap();
        assert!(b.num("tasks", 400.0).is_err());
        assert!(Args::parse(&sv(&["serve", "--=x"])).is_err());
    }

    #[test]
    fn missing_flag_value_errors() {
        assert!(Args::parse(&sv(&["serve", "--rate"])).is_err());
    }

    #[test]
    fn num_parses_with_default() {
        let a = Args::parse(&sv(&["serve", "--rate", "3.5"])).unwrap();
        assert_eq!(a.num("rate", 1.0), Ok(3.5));
        assert_eq!(a.num("missing", 9.0), Ok(9.0));
    }

    #[test]
    fn malformed_numeric_flag_is_an_error_naming_the_flag() {
        // Regression: `--tasks 4OO` used to fall back to the default
        // silently and run a job the user never asked for.
        let a = Args::parse(&sv(&["serve", "--tasks", "4OO"])).unwrap();
        let err = a.num("tasks", 400.0).unwrap_err();
        assert!(err.contains("--tasks"), "error must name the flag: {err}");
        assert!(err.contains("4OO"), "error must show the bad value: {err}");
        // And the serve path surfaces it instead of serving 400 tasks.
        assert!(serve(&a).is_err());
    }

    #[test]
    fn integer_flags_reject_negative_and_fractional_values() {
        // `as usize` saturation must never turn `--tasks -5` into a run of
        // zero tasks (or `--instances -1` into an empty-fleet panic).
        let a = Args::parse(&sv(&["serve", "--tasks", "-5"])).unwrap();
        assert!(serve(&a).is_err());
        let b = Args::parse(&sv(&["serve", "--instances", "2.5"])).unwrap();
        assert!(serve(&b).is_err());
        let c = Args::parse(&sv(&["serve", "--rate", "-3"])).unwrap();
        assert!(serve(&c).is_err());
        let d = Args::parse(&sv(&["serve", "--seed", "-1"])).unwrap();
        assert!(serve(&d).is_err());
    }

    #[test]
    fn bare_autoscale_flag_parses_as_bool() {
        let a = Args::parse(&sv(&["serve", "--autoscale", "--rate", "3.0"])).unwrap();
        assert_eq!(a.bool_flag("autoscale"), Ok(true));
        assert_eq!(a.num("rate", 1.0), Ok(3.0));
        let b = Args::parse(&sv(&["serve", "--autoscale"])).unwrap();
        assert_eq!(b.bool_flag("autoscale"), Ok(true));
        let c = Args::parse(&sv(&["serve", "--autoscale", "false"])).unwrap();
        assert_eq!(c.bool_flag("autoscale"), Ok(false));
        let d = Args::parse(&sv(&["serve"])).unwrap();
        assert_eq!(d.bool_flag("autoscale"), Ok(false));
    }

    #[test]
    fn malformed_boolean_flag_is_an_error_naming_the_flag() {
        // Same contract as the numeric fix: a typo'd value must error,
        // not silently run the non-elastic config.
        let a = Args::parse(&sv(&["serve", "--autoscale", "enabld"])).unwrap();
        let err = a.bool_flag("autoscale").unwrap_err();
        assert!(err.contains("--autoscale"), "error must name the flag: {err}");
        assert!(err.contains("enabld"), "error must show the bad value: {err}");
        assert!(serve(&a).is_err());
    }
}
