//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! kairos serve   [--config file.toml] [--scheduler S] [--dispatcher D]
//!                [--rate R] [--tasks N] [--instances I] [--model M]
//!                [--fleet SPEC] [--seed X] [--autoscale] [--pressure TRACE]
//!                [--affinity SPEC] [--route-policy POLICY] [--trace FILE]
//!                [--burst-shape B] [--profile-half-life S]
//! kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
//!                [--trace FILE]
//! kairos elastic-sweep [--fleet SPEC] [--rate R] [--tasks N] [--min N]
//!                [--max N] [--pressure TRACE] [--boot-delay S|SPEC]
//!                [--per-group BOUNDS] [--trace FILE]
//! kairos shard-sweep [--fleet SPEC] [--affinity SPEC] [--rate R] [--tasks N]
//!                [--trace FILE]
//! kairos route-sweep [--fleet SPEC] [--affinity SPEC] [--route-policy P]
//!                [--rate R] [--tasks N] [--trace FILE]
//! kairos cache-sweep [--fleet SPEC] [--rate R] [--tasks N] [--sessions N]
//!                [--cache-budget BLOCKS] [--load-factors LIST] [--trace FILE]
//! kairos trace   gen|record|scale|stats [...]
//! kairos check   --trace FILE [--fleet SPEC] [--affinity SPEC]
//!                [--scheduler S] [--dispatcher D] [--cache]
//!                [--cache-budget N] [--cache-load-factor F]
//! kairos figures <id|all> [--out results/]
//! kairos quickstart [--artifacts DIR] [--model NAME]
//! ```

use std::collections::HashMap;
use std::path::Path;

use crate::agents::apps::App;
use crate::config::ServingConfig;
use crate::engine::cost_model::ModelKind;
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::router::{RoutePolicy, RouteReason};
use crate::server::autoscale::{parse_boot_delays, parse_per_group, AutoscaleConfig};
use crate::server::coordinator::{FleetSpec, PROVISIONING};
use crate::server::pressure::PressureTrace;
use crate::server::sim::{
    make_dispatcher_tuned, make_policy, run_fleet, CacheTuning, FleetConfig, SimResult,
    SimServer,
};
use crate::workload::{FileSource, GenSource, Trace, TraceGen, TraceSource, WorkloadMix};

/// Flags that take no value (`--flag` alone means `true`; an explicit
/// `--flag false` still parses).
const BOOL_FLAGS: &[&str] = &["autoscale", "quick", "cache"];

/// Parsed `--key value` flags plus positional args.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(args: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value` form: split here so the value flows through
                // the same validation as `--key value` (the ISSUE's
                // `--tasks=4OO` must error in num(), not corrupt parsing).
                if let Some((k, v)) = key.split_once('=') {
                    if k.is_empty() {
                        return Err(format!("malformed flag {a:?}"));
                    }
                    out.flags.insert(k.to_string(), v.to_string());
                    i += 1;
                    continue;
                }
                let next = args.get(i + 1);
                let next_is_flag = match next {
                    None => true,
                    Some(v) => v.starts_with("--"),
                };
                if BOOL_FLAGS.contains(&key) && next_is_flag {
                    out.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                let val =
                    next.ok_or_else(|| format!("flag --{key} needs a value"))?;
                out.flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Numeric flag: the default when absent — and an error naming the
    /// flag and the offending text when present but malformed. (This used
    /// to fall back to the default silently, so `--tasks=4OO` typos ran
    /// with a config the user never asked for.)
    pub fn num(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{key}: invalid numeric value {v:?}")),
        }
    }

    /// Boolean flag: false when absent, true for bare `--flag` or a
    /// truthy value — and an error naming the flag and the offending text
    /// otherwise (same contract as [`Args::num`]: a typo must not silently
    /// run a config the user never asked for).
    pub fn bool_flag(&self, key: &str) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(false),
            Some("true" | "1" | "on" | "yes") => Ok(true),
            Some("false" | "0" | "off" | "no") => Ok(false),
            Some(v) => Err(format!("flag --{key}: invalid boolean value {v:?}")),
        }
    }
}

const USAGE: &str = "\
kairos — low-latency multi-agent LLM serving (paper reproduction)

USAGE:
  kairos serve       [--config F] [--scheduler kairos|parrot|ayo|oracle]
                     [--dispatcher kairos|rr|oracle|least] [--rate R]
                     [--tasks N] [--instances I] [--model llama3-8b|llama2-13b]
                     [--fleet SPEC] [--seed S] [--workload colocated|qa|rg|cg]
                     [--autoscale] [--pressure TRACE] [--affinity SPEC]
                     [--route-policy pinned|learned[:KEY=VAL,...]]
                     [--trace FILE] [--burst-shape B] [--profile-half-life S]
                     [--cache] [--cache-budget N] [--cache-load-factor F]
                     [--threads N]
  kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
                     [--seed S] [--workload W] [--trace FILE]
  kairos elastic-sweep
                     [--fleet SPEC] [--rate R] [--tasks N] [--seed S]
                     [--workload W] [--min N] [--max N] [--pressure TRACE]
                     [--boot-delay SECS|MODEL=SECS,...] [--per-group BOUNDS]
                     [--trace FILE]
  kairos shard-sweep [--fleet SPEC] [--affinity SPEC] [--scheduler S]
                     [--dispatcher D] [--rate R] [--tasks N] [--seed S]
                     [--workload W] [--trace FILE]
  kairos route-sweep [--fleet SPEC] [--affinity SPEC] [--scheduler S]
                     [--dispatcher D] [--route-policy P] [--rate R]
                     [--tasks N] [--seed S] [--workload W] [--trace FILE]
  kairos cache-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
                     [--seed S] [--workload W] [--sessions N]
                     [--cache-budget BLOCKS] [--load-factors F1,F2,...]
                     [--trace FILE]
  kairos trace gen    --out FILE [--rate R] [--tasks N] [--seed S]
                     [--workload W] [--burst-shape B]
  kairos trace record --out FILE [--fleet SPEC] [--affinity SPEC]
                     [--scheduler S] [--dispatcher D] [--rate R] [--tasks N]
                     [--seed S] [--workload W] [--burst-shape B]
  kairos trace scale  --in FILE --out FILE [--factor F] [--clip START..END]
                     [--filter-app QA|RG|CG] [--splice FILE2]
  kairos trace stats  --in FILE
  kairos check       --trace FILE [--fleet SPEC] [--affinity SPEC]
                     [--scheduler S] [--dispatcher D] [--cache]
                     [--cache-budget N] [--cache-load-factor F]
  kairos figures     <table1|fig3..fig18|overhead|all> [--out results]
  kairos quickstart  [--artifacts artifacts] [--model tiny]
  kairos bench       [--quick] [--seed S] [--out DIR] [--threads N]

TRACE FILES — JSONL, one arrival record per line (see the TraceRecord
  rustdoc for the schema). Every sweep arm replays the SAME materialized
  trace (`--trace FILE`, or one generator materialization), so baselines
  are apples-to-apples by construction. `trace gen` writes a generated
  trace, `trace record` captures a run's submitted plans with their
  ground-truth timings, `trace scale` derives scenarios (filter → clip →
  rate-scale → splice, in that order), `trace stats` summarizes a file.

FLEET SPEC — comma-separated `[COUNT*]MODEL[@KV_SCALE][:MAX_BATCH]`, e.g.
  `2*llama3-8b@0.12,2*llama3-8b@0.04:128` (uneven co-tenant pressure) or
  `llama3-8b,llama2-13b@0.5` (mixed models). Per-instance KV budgets flow
  to the dispatchers, so memory-aware policies pack each instance against
  its own capacity.

AFFINITY SPEC — comma-separated `AGENT=CLASS` with CLASS a model name or
  `any`; `*=CLASS` sets the default for unpinned agents, e.g.
  `*=llama3-8b,Engineer=llama2-13b`. Pinned requests are routed through
  per-model-family queue shards and only dispatch to instances of their
  family; `shard-sweep` compares the sharded and unsharded configurations
  on the same trace.

ROUTE POLICY — `pinned` (the static affinity stamp) or
  `learned[:explore=R,min_samples=N]`: learn each agent's best family
  online from measured per-family latency, fall back to pins until
  converged, and balance `Any` requests to the least-pressured group;
  `route-sweep` compares both policies on the same trace.

BENCH — seeded speed runs of the serving hot path: a pump microbench
  (submit→pump→drain of external requests), a full simulated run, a
  packing-heavy run isolating the time-slot packer's candidate scoring
  (naive linear scans vs the max-tree fast paths), a session-heavy
  run comparing cache-blind vs cache-affine placement on one trace, and
  a parallel-pump run scaling the score-in-parallel dispatch round from
  1 to `--threads` workers (asserting bit-identical dispatch logs at
  every count), each as an in-binary A/B with an agreement check.
  Writes `BENCH_pump.json`, `BENCH_e2e.json`, `BENCH_pack.json`,
  `BENCH_cache.json` and `BENCH_par.json` to `--out` (default `.`);
  `--quick` shrinks all runs to CI-smoke size. Decision counts are
  seed-deterministic; wall-clock fields vary by host.

CACHE — `--cache` (or `[cache] enabled = true`) gives every instance a
  deterministic LRU prefix cache of `--cache-budget` KV blocks keyed by
  session: a completed stage's context becomes its session's cached
  prefix, and the next stage's prefill shortens by the cached tokens.
  The `cache-affine` dispatcher adds session-sticky placement — CHWBL
  (consistent hashing with bounded loads) keeps a session's stages on
  the instance already holding its prefix unless that instance exceeds
  `ceil(load_factor × mean load)` in-flight dispatches, then falls back
  to the packer score. `cache-sweep` compares cache-blind and
  cache-affine arms over `--load-factors` on one session-heavy trace
  (`--sessions` long-running conversations).

PRESSURE TRACE — `;`-separated `TARGET:TIME=MULT,...` with TARGET an
  instance index or `*`: piecewise co-tenant KV-pressure multipliers, e.g.
  `*:0=1.0,30=0.5,90=1.0;2:0=0.8`. `--autoscale` (or `[autoscale]` in the
  config) lets the fleet grow under load bursts and drain back down;
  `elastic-sweep` compares the fixed and elastic fleets side by side.
  `--boot-delay` models instance boot latency (a grow provisions first,
  registers after the delay); `--per-group` caps/floors each family, e.g.
  `llama3-8b=1..4,llama2-13b=0..2`.

CHECK — replay a recorded trace with the coordinator's runtime invariant
  audits enabled (family-index consistency, pressure-cache freshness,
  no tombstoned-slot dispatch): the dynamic counterpart of the
  `kairos-lint` static pass. Exits nonzero listing every violation.
";

/// CLI entrypoint.
pub fn run(raw: Vec<String>) -> crate::Result<()> {
    let args = Args::parse(&raw).map_err(|e| anyhow::anyhow!(e))?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("fleet-sweep") => fleet_sweep(&args),
        Some("elastic-sweep") => elastic_sweep(&args),
        Some("shard-sweep") => shard_sweep(&args),
        Some("route-sweep") => route_sweep(&args),
        Some("cache-sweep") => cache_sweep(&args),
        Some("trace") => trace_cmd(&args),
        Some("check") => check_cmd(&args),
        Some("figures") => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let out = args.get("out").unwrap_or("results");
            crate::figures::run(id, out)
        }
        Some("quickstart") => quickstart(&args),
        Some("bench") => bench_cmd(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// `args.num` with the error lifted into the CLI's anyhow result.
fn numf(args: &Args, key: &str, default: f64) -> crate::Result<f64> {
    args.num(key, default).map_err(|e| anyhow::anyhow!(e))
}

/// Count-like flag (tasks, instances, fleet bounds): a positive integer.
/// `--tasks -5` or `--instances 2.5` must error, not saturate through an
/// `as usize` cast into a run the user never asked for.
fn num_count(args: &Args, key: &str, default: usize) -> crate::Result<usize> {
    let v = numf(args, key, default as f64)?;
    if !v.is_finite() || v < 1.0 || v.fract() != 0.0 {
        anyhow::bail!("flag --{key}: expected a positive integer, got {v}");
    }
    Ok(v as usize)
}

/// Seed-like flag: a non-negative integer.
fn num_u64(args: &Args, key: &str, default: u64) -> crate::Result<u64> {
    let v = numf(args, key, default as f64)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
        anyhow::bail!("flag --{key}: expected a non-negative integer, got {v}");
    }
    Ok(v as u64)
}

/// Rate-like flag: a positive number (the trace generator asserts
/// `rate > 0`, so reject it here with the flag's name instead).
fn num_rate(args: &Args, key: &str, default: f64) -> crate::Result<f64> {
    let v = numf(args, key, default)?;
    if !v.is_finite() || v <= 0.0 {
        anyhow::bail!("flag --{key}: expected a positive number, got {v}");
    }
    Ok(v)
}

/// The arrival generator with a validated `--burst-shape` (rejected at
/// parse time, naming the value — a NaN shape would produce NaN
/// inter-arrival gaps).
fn burst_gen(args: &Args, default_shape: f64) -> crate::Result<TraceGen> {
    let shape = numf(args, "burst-shape", default_shape)?;
    TraceGen::new(shape).map_err(|e| anyhow::anyhow!("flag --burst-shape: {e}"))
}

/// Resolve the `--cache` / `--cache-budget` / `--cache-load-factor`
/// flags over a base tuning (the config's `[cache]` section, or the
/// defaults). Bad values error naming the flag.
fn cache_tuning_flags(args: &Args, mut base: CacheTuning) -> crate::Result<CacheTuning> {
    if args.get("cache").is_some() {
        base.enabled = args.bool_flag("cache").map_err(|e| anyhow::anyhow!(e))?;
    }
    if args.get("cache-budget").is_some() {
        base.budget_blocks =
            num_count(args, "cache-budget", base.budget_blocks as usize)? as u32;
    }
    if args.get("cache-load-factor").is_some() {
        let f = numf(args, "cache-load-factor", base.load_factor)?;
        if !f.is_finite() || f < 1.0 {
            anyhow::bail!(
                "flag --cache-load-factor: expected a finite number >= 1, got {f}"
            );
        }
        base.load_factor = f;
    }
    Ok(base)
}

/// A recorded trace file fixes the workload, so the generator's flags
/// would be silently ignored next to it — and nothing may run a config
/// the user didn't ask for (the malformed-flag contract). Their presence
/// alongside `--trace` is an error naming the flag.
fn reject_generator_flags_with_trace(args: &Args) -> crate::Result<()> {
    for key in ["rate", "tasks", "seed", "workload", "burst-shape"] {
        if args.get(key).is_some() {
            anyhow::bail!(
                "flag --{key}: conflicts with --trace (the recorded file \
                 fixes the workload)"
            );
        }
    }
    Ok(())
}

/// Materialize the ONE workload trace every arm of a sweep shares: a
/// recorded file (`--trace FILE`) or the generator
/// (`--rate/--tasks/--seed/--workload/--burst-shape`). Cross-arm
/// comparisons are apples-to-apples by construction — arms replay clones
/// of this materialization instead of regenerating under seed discipline.
/// Returns the trace and its provenance line.
fn shared_trace(
    args: &Args,
    default_rate: f64,
    default_tasks: usize,
) -> crate::Result<(Trace, String)> {
    let source: Box<dyn TraceSource> = match args.get("trace") {
        Some(path) => {
            reject_generator_flags_with_trace(args)?;
            Box::new(FileSource::new(path))
        }
        None => {
            let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;
            Box::new(GenSource {
                gen: burst_gen(args, TraceGen::default().burst_shape)?,
                mix,
                rate: num_rate(args, "rate", default_rate)?,
                n: num_count(args, "tasks", default_tasks)?,
                seed: num_u64(args, "seed", 42)?,
            })
        }
    };
    let desc = source.describe();
    let trace = source.materialize().map_err(|e| anyhow::anyhow!(e))?;
    Ok((trace, desc))
}

fn serve(args: &Args) -> crate::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ServingConfig::from_toml(&text).map_err(|e| anyhow::anyhow!(e))?
        }
        None => ServingConfig::default(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.to_string();
    }
    if let Some(d) = args.get("dispatcher") {
        cfg.dispatcher = d.to_string();
    }
    cfg.rate = num_rate(args, "rate", cfg.rate)?;
    cfg.n_tasks = num_count(args, "tasks", cfg.n_tasks)?;
    cfg.seed = num_u64(args, "seed", cfg.seed)?;
    cfg.sim.n_instances = num_count(args, "instances", cfg.sim.n_instances)?;
    if let Some(m) = args.get("model") {
        cfg.sim.model = ModelKind::parse(m).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(f) = args.get("fleet") {
        cfg.fleet = Some(f.to_string());
    }
    if let Some(p) = args.get("pressure") {
        cfg.pressure = Some(p.to_string());
    }
    if let Some(a) = args.get("affinity") {
        cfg.affinity = Some(a.to_string());
    }
    if let Some(r) = args.get("route-policy") {
        cfg.route_policy = Some(r.to_string());
    }
    if let Some(t) = args.get("trace") {
        cfg.trace = Some(t.to_string());
    }
    if cfg.trace.is_some() {
        // The trace file fixes the workload; generator flags next to it
        // would be silently ignored, so they error instead.
        reject_generator_flags_with_trace(args)?;
    }
    // One validation site for the burst shape: the shared helper (flag
    // over config default), reused for generation below.
    let gen = burst_gen(args, cfg.burst_shape)?;
    cfg.burst_shape = gen.burst_shape;
    if args.get("profile-half-life").is_some() {
        let h = numf(args, "profile-half-life", 0.0)?;
        if !h.is_finite() || h <= 0.0 {
            anyhow::bail!("flag --profile-half-life: expected a positive number, got {h}");
        }
        cfg.profile_half_life = Some(h);
    }
    cfg.cache = cache_tuning_flags(args, cfg.cache)?;
    let fleet = cfg.resolve_fleet().map_err(|e| anyhow::anyhow!(e))?;
    // `--autoscale` overrides the config like every other flag: bare/true
    // enables (with the requested fleet as the floor when the config has
    // no `[autoscale]` thresholds), an explicit `--autoscale false`
    // disables a config-enabled autoscaler.
    let mut autoscale = cfg.autoscale;
    if args.get("autoscale").is_some() {
        if !args.bool_flag("autoscale").map_err(|e| anyhow::anyhow!(e))? {
            autoscale = None;
        } else if autoscale.is_none() {
            let d = AutoscaleConfig::default();
            autoscale = Some(AutoscaleConfig {
                // Never drain below what the user explicitly asked for via
                // --instances/--fleet — and leave burst headroom above it
                // (2x) so a large fleet doesn't silently build min == max
                // bounds where no scale event can ever fire.
                min_instances: fleet.len().max(1),
                max_instances: d.max_instances.max(fleet.len() * 2),
                ..d
            });
        }
    }
    if let Some(a) = autoscale.as_mut() {
        a.template = fleet.instances[0];
        // A configured floor is honored as-is: a fleet starting below it
        // simply never drains further (the autoscaler only grows on load).
        a.min_instances = a.min_instances.max(1);
    }
    let pressure = cfg
        .pressure
        .as_deref()
        .map(PressureTrace::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let affinity = cfg
        .affinity
        .as_deref()
        .map(AffinitySpec::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let route = cfg
        .route_policy
        .as_deref()
        .map(RoutePolicy::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    // The workload: a recorded trace when configured (`--trace` /
    // `[workload] trace`), the generator otherwise — materialized ONCE.
    let source: Box<dyn TraceSource> = match &cfg.trace {
        Some(path) => Box::new(FileSource::new(path)),
        None => Box::new(GenSource {
            gen,
            mix: workload_mix(args.get("workload").unwrap_or("colocated"))?,
            rate: cfg.rate,
            n: cfg.n_tasks,
            seed: cfg.seed,
        }),
    };
    let trace = source.materialize().map_err(|e| anyhow::anyhow!(e))?;
    let arrivals = trace.arrivals();

    println!(
        "serving {} tasks ({}) on {} instances{}{}{}{}{}{} — scheduler={} dispatcher={}",
        arrivals.len(),
        source.describe(),
        fleet.len(),
        if fleet.is_heterogeneous() { " (heterogeneous)" } else { "" },
        if autoscale.is_some() { " (elastic)" } else { "" },
        if pressure.is_some() { " (co-tenant pressure)" } else { "" },
        if affinity.is_some() { " (model-affine)" } else { "" },
        match route {
            Some(RoutePolicy::Learned { .. }) => " (learned routing)",
            _ => "",
        },
        if cfg.cache.enabled { " (prefix cache)" } else { "" },
        cfg.scheduler,
        cfg.dispatcher
    );
    let fc = FleetConfig {
        fleet,
        refresh_interval: cfg.sim.refresh_interval,
        warmup_frac: cfg.sim.warmup_frac,
        autoscale,
        pressure,
        affinity,
        route,
        profile_half_life: cfg.profile_half_life,
        logs: crate::server::coordinator::LogConfig::full(),
        lean_metrics: false,
        legacy_hot_path: false,
        legacy_scoring: false,
        cache: cfg.cache,
        threads: num_count(args, "threads", 1)?,
    };
    let affine = fc.affinity.is_some() || matches!(fc.route, Some(RoutePolicy::Learned { .. }));
    let res = run_fleet(fc, &cfg.scheduler, &cfg.dispatcher, arrivals);
    let s = &res.summary;
    println!("\ncompleted {} workflows over {:.1} sim-seconds", s.n_workflows, res.sim_duration);
    println!("program-level token latency:");
    println!("  avg  {:.4} s/tok", s.avg_token_latency);
    println!("  P50  {:.4}   P90 {:.4}   P95 {:.4}   P99 {:.4}",
        s.p50_token_latency, s.p90_token_latency, s.p95_token_latency, s.p99_token_latency);
    println!("queueing-time ratio: {:.1}%", s.mean_queue_ratio * 100.0);
    println!("preempted requests:  {:.1}%", s.preemption_rate * 100.0);
    println!("dropped requests:    {}", res.dropped_requests);
    if cfg.cache.enabled {
        let cs = res.cache_stats();
        println!(
            "prefix cache:        {:.1}% hit rate ({} hits / {} lookups), \
             {} prefill tokens saved",
            cs.hit_rate() * 100.0,
            cs.hits,
            cs.hits + cs.misses,
            cs.saved_prefill_tokens
        );
    }
    if res.alloc_failures() > 0 {
        println!("kv alloc failures:   {}", res.alloc_failures());
    }
    if affine {
        println!("cross-model dispatches: {}", res.cross_model_dispatches());
    }
    if !res.scale_log.is_empty() {
        let (grows, shrinks) = res.scale_counts();
        println!(
            "fleet scaling:       {grows} grow(s), {shrinks} retire(s), {} active at end",
            res.final_active_instances
        );
    }
    Ok(())
}

/// `kairos check`: replay a recorded trace through the coordinator with
/// [`Coordinator::audit_invariants`] running on every refresh tick and at
/// end of run — the dynamic counterpart of the `kairos-lint` static pass.
/// Exits nonzero listing every violation.
///
/// [`Coordinator::audit_invariants`]: crate::server::coordinator::Coordinator::audit_invariants
fn check_cmd(args: &Args) -> crate::Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("kairos check requires --trace FILE"))?;
    reject_generator_flags_with_trace(args)?;
    let source = FileSource::new(path);
    let desc = source.describe();
    let trace = source.materialize().map_err(|e| anyhow::anyhow!(e))?;
    let fleet = FleetSpec::parse(args.get("fleet").unwrap_or("2*llama3-8b@0.12"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let affinity = args
        .get("affinity")
        .map(AffinitySpec::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let dispatcher = args.get("dispatcher").unwrap_or("kairos");
    let cache = cache_tuning_flags(args, CacheTuning::default())?;
    let mut fc = FleetConfig::from(fleet.clone());
    fc.affinity = affinity;
    fc.cache = cache;
    let mut server = SimServer::with_fleet(
        fc,
        make_policy(scheduler),
        make_dispatcher_tuned(dispatcher, &fleet, None, Some(&cache)),
    );
    server.enable_audit();
    println!(
        "checking {} tasks ({desc}) on {} instances — scheduler={scheduler} \
         dispatcher={dispatcher}, invariant audits on{}",
        trace.len(),
        fleet.len(),
        if cache.enabled {
            " (prefix-cache bookkeeping audited)"
        } else {
            ""
        }
    );
    let res = server.run(trace.arrivals());
    println!(
        "replayed {} workflows over {:.1} sim-seconds; {} invariant audits run",
        res.summary.n_workflows, res.sim_duration, res.audit_checks
    );
    let p = res.metrics.stream.packer;
    if p.decisions > 0 {
        println!(
            "packer: {} decisions, {} candidates, {} evaluated, \
             {} fast-accepted, {} fast-rejected, {} rejected rounds, \
             {} suspensions",
            p.decisions,
            p.candidates,
            p.evaluated,
            p.fast_accepted,
            p.fast_rejected,
            p.rejected_rounds,
            p.suspensions,
        );
    }
    if p.sticky_hits + p.sticky_fallbacks > 0 {
        println!(
            "sticky dispatch: {} session-sticky picks, {} bounded-load fallbacks",
            p.sticky_hits, p.sticky_fallbacks
        );
    }
    if cache.enabled {
        let cs = res.cache_stats();
        println!(
            "prefix cache: {} hits, {} misses, {} prefill tokens saved, \
             {} insertions, {} evictions",
            cs.hits, cs.misses, cs.saved_prefill_tokens, cs.insertions, cs.evictions
        );
    }
    if res.audit_violations.is_empty() {
        println!("all audits passed");
        Ok(())
    } else {
        for v in &res.audit_violations {
            eprintln!("audit violation: {v}");
        }
        anyhow::bail!(
            "{} invariant violation(s) during replay",
            res.audit_violations.len()
        )
    }
}

fn workload_mix(name: &str) -> crate::Result<WorkloadMix> {
    Ok(match name {
        "colocated" => WorkloadMix::colocated(),
        "qa" => WorkloadMix::single(App::Qa, "G+M"),
        "rg" => WorkloadMix::single(App::Rg, "TQ"),
        "cg" => WorkloadMix::single(App::Cg, "HE"),
        other => anyhow::bail!("unknown workload {other:?}"),
    })
}

/// End-to-end heterogeneous-fleet scenario: one fleet, every dispatcher.
/// Shows how memory-aware dispatching degrades (or not) when half the
/// fleet runs under heavier co-tenant KV pressure.
fn fleet_sweep(args: &Args) -> crate::Result<()> {
    let spec = args
        .get("fleet")
        .unwrap_or("2*llama3-8b@0.12,2*llama3-8b@0.04:128");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let (trace, desc) = shared_trace(args, 6.0, 400)?;

    println!("fleet sweep over {spec:?} — {} instances, scheduler={scheduler}", fleet.len());
    println!("{} tasks ({desc})\n", trace.len());
    let mut t = crate::util::table::Table::new(&[
        "dispatcher", "avg s/tok", "P99 s/tok", "queue%", "preempt%", "dropped",
    ]);
    for disp in ["rr", "least", "oracle", "kairos"] {
        let arrivals = trace.arrivals();
        let fc = FleetConfig::from(fleet.clone());
        let res = run_fleet(fc, scheduler, disp, arrivals);
        let s = &res.summary;
        t.row(vec![
            res.dispatcher_name.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            format!("{:.1}%", s.preemption_rate * 100.0),
            res.dropped_requests.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// Elastic-fleet scenario: the same bursty overload served by a fixed
/// fleet and by an elastic one (autoscaler growing under the burst,
/// draining back down), optionally under a co-tenant pressure trace.
fn elastic_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("2*llama3-8b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let min = num_count(args, "min", fleet.len())?;
    let max = num_count(args, "max", fleet.len() * 3)?;
    let (trace, desc) = shared_trace(args, 12.0, 500)?;
    let pressure = args
        .get("pressure")
        .map(PressureTrace::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;

    let (boot_delay, boot_delay_per_group) = parse_boot_delay_flag(args)?;
    let per_group = args
        .get("per-group")
        .map(parse_per_group)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or_default();

    let mut auto = AutoscaleConfig::for_template(fleet.instances[0]);
    auto.min_instances = min.max(1);
    auto.max_instances = max.max(auto.min_instances);
    auto.up_after = 1;
    auto.down_after = 2;
    auto.cooldown = 5.0;
    auto.boot_delay = boot_delay;
    auto.boot_delay_per_group = boot_delay_per_group;
    auto.per_group = per_group;

    let has_boot_delay = auto.boot_delay > 0.0 || !auto.boot_delay_per_group.is_empty();
    println!(
        "elastic sweep over {spec:?} — {} tasks ({desc}), bounds [{}, {}]{}{}",
        trace.len(),
        auto.min_instances,
        auto.max_instances,
        if pressure.is_some() { ", with co-tenant pressure" } else { "" },
        if has_boot_delay { ", with boot latency" } else { "" },
    );
    let mut t = crate::util::table::Table::new(&[
        "fleet", "avg s/tok", "P99 s/tok", "queue%", "dropped", "grows", "retires",
        "active@end",
    ]);
    for (label, autoscale) in [("fixed", None), ("elastic", Some(auto))] {
        let arrivals = trace.arrivals();
        let mut fc = FleetConfig::from(fleet.clone());
        fc.autoscale = autoscale;
        fc.pressure = pressure.clone();
        let res = run_fleet(fc, "kairos", "kairos", arrivals);
        let (grows, shrinks) = res.scale_counts();
        let s = &res.summary;
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            res.dropped_requests.to_string(),
            grows.to_string(),
            shrinks.to_string(),
            res.final_active_instances.to_string(),
        ]);
        if !res.scale_log.is_empty() {
            println!("  {label} scale events:");
            for ev in &res.scale_log {
                if ev.instance == PROVISIONING {
                    println!("    t={:7.2}s  (booting)   {:?}", ev.at, ev.kind);
                } else {
                    println!(
                        "    t={:7.2}s  instance {}  {:?}",
                        ev.at, ev.instance, ev.kind
                    );
                }
            }
        }
    }
    t.print();
    Ok(())
}

/// Serving-group scenario: the same mixed-model trace served unsharded
/// (every request may land anywhere — including on a model it was never
/// meant for) and sharded (agents pinned to model families, one queue
/// shard per group). Reports queuing delay, cross-model dispatches and
/// per-group dispatch counts.
fn shard_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("3*llama3-8b@0.12,llama2-13b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let aff_spec = args.get("affinity").unwrap_or("*=llama3-8b");
    let affinity = AffinitySpec::parse(aff_spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let dispatcher = args.get("dispatcher").unwrap_or("rr");
    let (trace, desc) = shared_trace(args, 4.0, 300)?;

    println!(
        "shard sweep over {spec:?} — affinity {aff_spec:?}, \
         scheduler={scheduler} dispatcher={dispatcher}"
    );
    println!("{} tasks ({desc})\n", trace.len());
    let mut t = crate::util::table::Table::new(&[
        "queue", "avg s/tok", "P99 s/tok", "mean queue s", "cross-model", "dropped",
    ]);
    let mut sharded_res: Option<SimResult> = None;
    for (label, aff) in [("unsharded", None), ("sharded", Some(affinity.clone()))] {
        let arrivals = trace.arrivals();
        let mut fc = FleetConfig::from(fleet.clone());
        fc.affinity = aff;
        let res = run_fleet(fc, scheduler, dispatcher, arrivals);
        let s = &res.summary;
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.3}", res.mean_queue_delay()),
            res.cross_model_dispatches().to_string(),
            res.dropped_requests.to_string(),
        ]);
        if label == "sharded" {
            sharded_res = Some(res);
        }
    }
    t.print();
    if let Some(res) = sharded_res {
        println!("\nsharded per-group dispatches:");
        let mut seen: Vec<(crate::engine::cost_model::ModelClass, usize)> = Vec::new();
        for g in &res.group_log {
            match seen.iter_mut().find(|(c, _)| *c == g.class) {
                Some((_, n)) => *n += 1,
                None => seen.push((g.class, 1)),
            }
        }
        for (class, n) in seen {
            println!("  {:<12} {n}", class.name());
        }
    }
    Ok(())
}

/// Routing-layer scenario: the same mixed-model trace served with the
/// static pinned routing and with the learned policy (profile-driven
/// agent → family affinities, pressure-balanced `Any` placement). Reports
/// mean request E2E latency, queuing delay, and the learned run's route
/// decisions broken down by reason and family.
fn route_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("2*llama3-8b@0.12,2*llama2-13b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    // The default affinity is deliberately bad — everything pinned to the
    // slower, KV-denser 13B family — so the sweep shows learning escaping
    // a wrong static pin.
    let aff_spec = args.get("affinity").unwrap_or("*=llama2-13b");
    let affinity = AffinitySpec::parse(aff_spec).map_err(|e| anyhow::anyhow!(e))?;
    let learned = RoutePolicy::parse(args.get("route-policy").unwrap_or("learned"))
        .map_err(|e| anyhow::anyhow!(e))?;
    if !matches!(learned, RoutePolicy::Learned { .. }) {
        anyhow::bail!(
            "flag --route-policy: route-sweep compares against the pinned baseline; \
             pass a learned policy (e.g. learned:explore=0.2,min_samples=16)"
        );
    }
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let dispatcher = args.get("dispatcher").unwrap_or("kairos");
    let (trace, desc) = shared_trace(args, 3.0, 300)?;

    println!(
        "route sweep over {spec:?} — affinity {aff_spec:?}, \
         scheduler={scheduler} dispatcher={dispatcher}"
    );
    println!("{} tasks ({desc})\n", trace.len());
    let mut t = crate::util::table::Table::new(&[
        "routing", "avg s/tok", "P99 s/tok", "mean e2e s", "mean queue s", "dropped",
    ]);
    let mut learned_res: Option<SimResult> = None;
    for (label, route) in [("pinned", RoutePolicy::Pinned), ("learned", learned)] {
        let arrivals = trace.arrivals();
        let mut fc = FleetConfig::from(fleet.clone());
        fc.affinity = Some(affinity.clone());
        fc.route = Some(route);
        let res = run_fleet(fc, scheduler, dispatcher, arrivals);
        let s = &res.summary;
        let mean_e2e = res.mean_request_e2e();
        t.row(vec![
            label.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{mean_e2e:.3}"),
            format!("{:.3}", res.mean_queue_delay()),
            res.dropped_requests.to_string(),
        ]);
        if label == "learned" {
            learned_res = Some(res);
        }
    }
    t.print();
    if let Some(res) = learned_res {
        println!("\nlearned route decisions by reason:");
        let mut reasons: Vec<(RouteReason, usize)> = Vec::new();
        for d in &res.route_log {
            match reasons.iter_mut().find(|(r, _)| *r == d.reason) {
                Some((_, n)) => *n += 1,
                None => reasons.push((d.reason, 1)),
            }
        }
        for (reason, n) in reasons {
            println!("  {reason:<16?} {n}");
        }
        println!("\nlearned dispatches by family:");
        let mut fams: Vec<(ModelKind, usize)> = Vec::new();
        for g in &res.group_log {
            match fams.iter_mut().find(|(m, _)| *m == g.model) {
                Some((_, n)) => *n += 1,
                None => fams.push((g.model, 1)),
            }
        }
        for (model, n) in fams {
            println!("  {:<12} {n}", model.name());
        }
    }
    Ok(())
}

/// Prefix-cache scenario: one session-heavy trace (`--sessions`
/// long-running conversations, round-robin over arrivals) served by the
/// cache-blind `kairos` packer and by the session-sticky `cache-affine`
/// dispatcher at each `--load-factors` bound. Every arm runs with the
/// engine-side cache enabled, so the comparison isolates *placement*: the
/// sticky arms land a session's stages on the instance already holding
/// its prefix and convert that into cache hits and shorter prefills.
fn cache_sweep(args: &Args) -> crate::Result<()> {
    let spec = args.get("fleet").unwrap_or("4*llama3-8b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let (trace, desc) = shared_trace(args, 8.0, 400)?;
    let sessions = num_count(args, "sessions", 32)? as u64;
    let trace = trace.sessionize(sessions);
    let budget = num_count(args, "cache-budget", 512)? as u32;
    let mut factors: Vec<f64> = Vec::new();
    for part in args.get("load-factors").unwrap_or("1.25,1.5,2.0").split(',') {
        let f: f64 = part.trim().parse().map_err(|_| {
            anyhow::anyhow!("flag --load-factors: bad number {part:?}")
        })?;
        if !f.is_finite() || f < 1.0 {
            anyhow::bail!("flag --load-factors: expected numbers >= 1, got {part:?}");
        }
        factors.push(f);
    }

    println!(
        "cache sweep over {spec:?} — {} sessions, {budget}-block budget, \
         scheduler={scheduler}",
        sessions
    );
    println!("{} tasks ({desc})\n", trace.len());
    let mut t = crate::util::table::Table::new(&[
        "arm", "hit%", "saved tok", "sticky", "fallback", "mean e2e s", "P99 s/tok",
        "dropped",
    ]);
    let mut arms: Vec<(String, &str, f64)> = vec![
        ("blind".to_string(), "kairos", factors[0]),
    ];
    for &f in &factors {
        arms.push((format!("affine c={f}"), "cache-affine", f));
    }
    for (label, disp, load_factor) in arms {
        let arrivals = trace.arrivals();
        let mut fc = FleetConfig::from(fleet.clone());
        fc.cache = CacheTuning { enabled: true, budget_blocks: budget, load_factor };
        let res = run_fleet(fc, scheduler, disp, arrivals);
        let cs = res.cache_stats();
        let p = res.metrics.stream.packer;
        t.row(vec![
            label,
            format!("{:.1}%", cs.hit_rate() * 100.0),
            cs.saved_prefill_tokens.to_string(),
            p.sticky_hits.to_string(),
            p.sticky_fallbacks.to_string(),
            format!("{:.3}", res.mean_request_e2e()),
            format!("{:.4}", res.summary.p99_token_latency),
            res.dropped_requests.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// `--boot-delay` takes two forms: a scalar (`--boot-delay 5`, one global
/// delay) or per-family clauses (`--boot-delay llama3-8b=2,llama2-13b=12`
/// — big models provision slower; families absent from the list boot
/// instantly).
fn parse_boot_delay_flag(args: &Args) -> crate::Result<(f64, Vec<(ModelKind, f64)>)> {
    match args.get("boot-delay") {
        None => Ok((0.0, Vec::new())),
        Some(v) => match v.parse::<f64>() {
            Ok(secs) => {
                if !secs.is_finite() || secs < 0.0 {
                    anyhow::bail!(
                        "flag --boot-delay: expected a non-negative number, got {secs}"
                    );
                }
                Ok((secs, Vec::new()))
            }
            Err(_) => {
                let per = parse_boot_delays(v)
                    .map_err(|e| anyhow::anyhow!("flag --boot-delay: {e}"))?;
                Ok((0.0, per))
            }
        },
    }
}

/// `kairos trace <gen|record|scale|stats>` — the trace-file toolbox.
fn trace_cmd(args: &Args) -> crate::Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("gen") => trace_gen_cmd(args),
        Some("record") => trace_record_cmd(args),
        Some("scale") => trace_scale_cmd(args),
        Some("stats") => trace_stats_cmd(args),
        other => anyhow::bail!(
            "unknown trace subcommand {other:?} (gen|record|scale|stats)"
        ),
    }
}

/// The `--out FILE` a trace subcommand writes to.
fn out_path(args: &Args, cmd: &str) -> crate::Result<String> {
    args.get("out")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("trace {cmd} needs --out FILE"))
}

/// Load the `--in FILE` a trace subcommand reads.
fn in_trace(args: &Args, cmd: &str) -> crate::Result<Trace> {
    let path = args
        .get("in")
        .ok_or_else(|| anyhow::anyhow!("trace {cmd} needs --in FILE"))?;
    Trace::load(Path::new(path)).map_err(|e| anyhow::anyhow!(e))
}

/// `kairos trace gen`: materialize a generated workload to JSONL.
fn trace_gen_cmd(args: &Args) -> crate::Result<()> {
    let out = out_path(args, "gen")?;
    let (trace, desc) = shared_trace(args, 8.0, 400)?;
    trace.save(Path::new(&out)).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "wrote {} records ({desc}) spanning {:.1}s -> {out}",
        trace.len(),
        trace.span()
    );
    Ok(())
}

/// `kairos trace record`: run a sim and capture the coordinator's
/// recording path — every submitted plan with its ground-truth submission
/// time and affinity stamps — to JSONL. Replaying the file reproduces the
/// run bit-identically (the `tests/runtime_seam.rs` contract).
fn trace_record_cmd(args: &Args) -> crate::Result<()> {
    let out = out_path(args, "record")?;
    let (workload, desc) = shared_trace(args, 8.0, 400)?;
    let spec = args.get("fleet").unwrap_or("4*llama3-8b@0.12");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let affinity = args
        .get("affinity")
        .map(AffinitySpec::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut fc = FleetConfig::from(fleet);
    fc.affinity = affinity;
    let res = run_fleet(
        fc,
        args.get("scheduler").unwrap_or("kairos"),
        args.get("dispatcher").unwrap_or("kairos"),
        workload.arrivals(),
    );
    let recorded = Trace::from_records(res.trace_log);
    recorded.save(Path::new(&out)).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "recorded {} submitted plans from a run over {spec:?} ({desc}) -> {out}",
        recorded.len()
    );
    Ok(())
}

/// `kairos trace scale`: derive a scenario from a recorded trace. The
/// transforms apply in a fixed order — `--filter-app`, then `--clip`,
/// then `--factor` (rate scaling), then `--splice` — each deterministic
/// and order-preserving.
fn trace_scale_cmd(args: &Args) -> crate::Result<()> {
    let out = out_path(args, "scale")?;
    let mut trace = in_trace(args, "scale")?;
    if let Some(app) = args.get("filter-app") {
        trace = trace.filter_app(App::parse(app).map_err(|e| anyhow::anyhow!(e))?);
    }
    if let Some(window) = args.get("clip") {
        let (a, b) = window.split_once("..").ok_or_else(|| {
            anyhow::anyhow!("flag --clip: expected START..END, got {window:?}")
        })?;
        let parse = |s: &str| -> crate::Result<f64> {
            s.parse()
                .map_err(|_| anyhow::anyhow!("flag --clip: bad number {s:?}"))
        };
        trace = trace
            .clip(parse(a)?, parse(b)?)
            .map_err(|e| anyhow::anyhow!("flag --clip: {e}"))?;
    }
    if args.get("factor").is_some() {
        let f = numf(args, "factor", 1.0)?;
        trace = trace
            .scale_rate(f)
            .map_err(|e| anyhow::anyhow!("flag --factor: {e}"))?;
    }
    if let Some(other) = args.get("splice") {
        let o = Trace::load(Path::new(other)).map_err(|e| anyhow::anyhow!(e))?;
        trace = trace.splice(&o);
    }
    trace.save(Path::new(&out)).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "wrote {} records spanning {:.1}s ({:.2} req/s mean) -> {out}",
        trace.len(),
        trace.span(),
        trace.mean_rate()
    );
    Ok(())
}

/// `kairos trace stats`: summarize a trace file.
fn trace_stats_cmd(args: &Args) -> crate::Result<()> {
    let trace = in_trace(args, "stats")?;
    println!("records:    {}", trace.len());
    println!("span:       {:.2} s", trace.span());
    println!("mean rate:  {:.3} req/s", trace.mean_rate());
    let stages: usize = trace.records.iter().map(|r| r.stages.len()).sum();
    let prompt: u64 = trace
        .records
        .iter()
        .flat_map(|r| r.stages.iter())
        .map(|s| s.prompt_tokens as u64)
        .sum();
    let output: u64 = trace
        .records
        .iter()
        .flat_map(|r| r.stages.iter())
        .map(|s| s.output_tokens as u64)
        .sum();
    println!("stages:     {stages} ({prompt} prompt tokens, {output} output tokens)");
    println!("per app:");
    for app in App::all() {
        let n = trace.records.iter().filter(|r| r.app == app).count();
        if n > 0 {
            println!("  {:<4} {n}", app.name());
        }
    }
    let stamped = trace
        .records
        .iter()
        .flat_map(|r| r.stages.iter())
        .filter(|s| s.class.is_some())
        .count();
    println!("class stamps: {stamped} of {stages} stages");
    // Session reuse: how much prefix-cache locality the trace offers. A
    // record with no `session` key defaults to its own conversation at
    // submit time, so only explicitly keyed records count as reuse.
    let keyed: Vec<_> = trace.records.iter().filter(|r| r.session.is_some()).collect();
    if !keyed.is_empty() {
        let mut hll = crate::metrics::hll::Hll::default();
        for r in &keyed {
            hll.insert_u64(r.session.unwrap_or(0));
        }
        let distinct = hll.estimate().max(1.0);
        let keyed_stages: usize = keyed.iter().map(|r| r.stages.len()).sum();
        println!(
            "sessions:   {} of {} records keyed, ~{distinct:.0} distinct (HLL)",
            keyed.len(),
            trace.len()
        );
        println!(
            "  reuse:    {:.1} records/session, {:.1} stages/session",
            keyed.len() as f64 / distinct,
            keyed_stages as f64 / distinct
        );
        let mut top: Option<(App, usize)> = None;
        for app in App::all() {
            let n = keyed.iter().filter(|r| r.app == app).count();
            if n > top.map_or(0, |(_, m)| m) {
                top = Some((app, n));
            }
        }
        if let Some((app, n)) = top {
            println!(
                "  top app:  {} ({:.0}% of keyed records)",
                app.name(),
                100.0 * n as f64 / keyed.len() as f64
            );
        }
    }
    Ok(())
}

/// `kairos bench`: the seeded speed harness (see [`crate::bench`]).
fn bench_cmd(args: &Args) -> crate::Result<()> {
    let opts = crate::bench::BenchOptions {
        quick: args.bool_flag("quick").map_err(|e| anyhow::anyhow!(e))?,
        seed: num_u64(args, "seed", 42)?,
        out_dir: std::path::PathBuf::from(args.get("out").unwrap_or(".")),
        threads: num_count(args, "threads", 4)?,
    };
    crate::bench::run(&opts)
}

fn quickstart(args: &Args) -> crate::Result<()> {
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use crate::server::real::{RealServer, ServeRequest};

    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let model = args.get("model").unwrap_or("tiny");
    println!("loading AOT artifacts '{model}' from {dir}/ via PJRT ...");
    let mut server = RealServer::new(
        Path::new(&dir),
        model,
        1,
        Box::new(Fcfs),
        Box::new(RoundRobin::new()),
    )?;
    let prompts = [
        ("Router", "Route: what is 17 * 23?"),
        ("MathAgent", "Solve: 17 * 23 = "),
        ("HumanitiesAgent", "Describe the causes of WW1."),
        ("WriterAgent", "Write a report on LLM serving."),
    ];
    let reqs = prompts
        .iter()
        .map(|(agent, p)| ServeRequest {
            agent: agent.to_string(),
            prompt: p.to_string(),
            max_tokens: 12,
        })
        .collect();
    let (responses, stats) = server.serve(reqs)?;
    for r in &responses {
        println!(
            "[{}] {} tok in {:.3}s  prompt={:?}",
            r.agent, r.output_tokens, r.e2e_seconds, r.prompt
        );
    }
    println!(
        "\n{} requests, {} tokens, {:.2} tok/s wall, mean e2e {:.3}s, p90 {:.3}s",
        stats.n_requests, stats.total_tokens, stats.tokens_per_second, stats.mean_e2e,
        stats.p90_e2e
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&sv(&["figures", "fig14", "--out", "res"])).unwrap();
        assert_eq!(a.positional, vec!["figures", "fig14"]);
        assert_eq!(a.get("out"), Some("res"));
    }

    #[test]
    fn equals_form_flags_parse_and_validate() {
        let a = Args::parse(&sv(&["serve", "--tasks=400", "--rate", "3"])).unwrap();
        assert_eq!(a.num("tasks", 1.0), Ok(400.0));
        assert_eq!(a.num("rate", 1.0), Ok(3.0));
        // The ISSUE's motivating typo: `--tasks=4OO` must error, not run
        // 400 tasks (nor corrupt the flags that follow).
        let b = Args::parse(&sv(&["serve", "--tasks=4OO"])).unwrap();
        assert!(b.num("tasks", 400.0).is_err());
        assert!(Args::parse(&sv(&["serve", "--=x"])).is_err());
    }

    #[test]
    fn missing_flag_value_errors() {
        assert!(Args::parse(&sv(&["serve", "--rate"])).is_err());
    }

    #[test]
    fn num_parses_with_default() {
        let a = Args::parse(&sv(&["serve", "--rate", "3.5"])).unwrap();
        assert_eq!(a.num("rate", 1.0), Ok(3.5));
        assert_eq!(a.num("missing", 9.0), Ok(9.0));
    }

    #[test]
    fn malformed_numeric_flag_is_an_error_naming_the_flag() {
        // Regression: `--tasks 4OO` used to fall back to the default
        // silently and run a job the user never asked for.
        let a = Args::parse(&sv(&["serve", "--tasks", "4OO"])).unwrap();
        let err = a.num("tasks", 400.0).unwrap_err();
        assert!(err.contains("--tasks"), "error must name the flag: {err}");
        assert!(err.contains("4OO"), "error must show the bad value: {err}");
        // And the serve path surfaces it instead of serving 400 tasks.
        assert!(serve(&a).is_err());
    }

    #[test]
    fn integer_flags_reject_negative_and_fractional_values() {
        // `as usize` saturation must never turn `--tasks -5` into a run of
        // zero tasks (or `--instances -1` into an empty-fleet panic).
        let a = Args::parse(&sv(&["serve", "--tasks", "-5"])).unwrap();
        assert!(serve(&a).is_err());
        let b = Args::parse(&sv(&["serve", "--instances", "2.5"])).unwrap();
        assert!(serve(&b).is_err());
        let c = Args::parse(&sv(&["serve", "--rate", "-3"])).unwrap();
        assert!(serve(&c).is_err());
        let d = Args::parse(&sv(&["serve", "--seed", "-1"])).unwrap();
        assert!(serve(&d).is_err());
    }

    #[test]
    fn bare_autoscale_flag_parses_as_bool() {
        let a = Args::parse(&sv(&["serve", "--autoscale", "--rate", "3.0"])).unwrap();
        assert_eq!(a.bool_flag("autoscale"), Ok(true));
        assert_eq!(a.num("rate", 1.0), Ok(3.0));
        let b = Args::parse(&sv(&["serve", "--autoscale"])).unwrap();
        assert_eq!(b.bool_flag("autoscale"), Ok(true));
        let c = Args::parse(&sv(&["serve", "--autoscale", "false"])).unwrap();
        assert_eq!(c.bool_flag("autoscale"), Ok(false));
        let d = Args::parse(&sv(&["serve"])).unwrap();
        assert_eq!(d.bool_flag("autoscale"), Ok(false));
    }

    #[test]
    fn sweep_arms_share_one_materialized_trace() {
        // The apples-to-apples contract: every sweep arm replays the SAME
        // materialized trace. shared_trace is the single source all four
        // sweeps draw from; repeated materialization (what two arms see)
        // must yield identical arrival sequences — times AND plans.
        let a = Args::parse(&sv(&["fleet-sweep", "--rate", "4", "--tasks", "30"])).unwrap();
        let (t1, _) = shared_trace(&a, 6.0, 400).unwrap();
        let (t2, _) = shared_trace(&a, 6.0, 400).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(t1.arrivals(), t2.arrivals(), "identical sequences across arms");
        assert_eq!(t1.len(), 30);
        // File mode: --trace replays the recorded artifact.
        let path = std::env::temp_dir().join("kairos_cli_shared_trace.jsonl");
        t1.save(&path).unwrap();
        let b = Args::parse(&sv(&[
            "shard-sweep",
            "--trace",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        let (from_file, desc) = shared_trace(&b, 4.0, 300).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(from_file, t1, "file arm replays the generated arm's trace");
        assert!(desc.contains("recorded"), "{desc}");
        // A missing file is an error, not a silent fallback to generation.
        let c = Args::parse(&sv(&["route-sweep", "--trace", "/nonexistent.jsonl"]))
            .unwrap();
        assert!(shared_trace(&c, 3.0, 300).is_err());
        // Generator flags next to --trace would be silently ignored, so
        // they error naming the flag (the malformed-flag contract).
        let d = Args::parse(&sv(&[
            "fleet-sweep",
            "--trace",
            "f.jsonl",
            "--tasks",
            "50",
        ]))
        .unwrap();
        let err = shared_trace(&d, 6.0, 400).unwrap_err().to_string();
        assert!(err.contains("--tasks"), "{err}");
        assert!(err.contains("--trace"), "{err}");
        // Same contract on the serve path.
        let e = Args::parse(&sv(&["serve", "--trace", "f.jsonl", "--rate", "3"]))
            .unwrap();
        assert!(serve(&e).is_err());
    }

    #[test]
    fn trace_gen_scale_stats_round_trip_through_files() {
        let dir = std::env::temp_dir();
        let raw = dir.join("kairos_cli_trace_gen.jsonl");
        let scaled = dir.join("kairos_cli_trace_scaled.jsonl");
        let gen = Args::parse(&sv(&[
            "trace", "gen",
            "--out", raw.to_str().unwrap(),
            "--rate", "5",
            "--tasks", "40",
            "--seed", "9",
        ]))
        .unwrap();
        trace_cmd(&gen).unwrap();
        let t = Trace::load(&raw).unwrap();
        assert_eq!(t.len(), 40);
        // Transform: double the rate and keep only RG tasks.
        let sc = Args::parse(&sv(&[
            "trace", "scale",
            "--in", raw.to_str().unwrap(),
            "--out", scaled.to_str().unwrap(),
            "--factor", "2",
            "--filter-app", "RG",
        ]))
        .unwrap();
        trace_cmd(&sc).unwrap();
        let t2 = Trace::load(&scaled).unwrap();
        assert!(t2.records.iter().all(|r| r.app == App::Rg));
        assert!(!t2.is_empty() && t2.len() < t.len());
        // Stats runs over both artifacts.
        let st = Args::parse(&sv(&["trace", "stats", "--in", scaled.to_str().unwrap()]))
            .unwrap();
        trace_cmd(&st).unwrap();
        std::fs::remove_file(&raw).ok();
        std::fs::remove_file(&scaled).ok();
        // Missing flags / unknown subcommands error.
        assert!(trace_cmd(&Args::parse(&sv(&["trace", "gen"])).unwrap()).is_err());
        assert!(trace_cmd(&Args::parse(&sv(&["trace", "stats"])).unwrap()).is_err());
        assert!(trace_cmd(&Args::parse(&sv(&["trace", "zap"])).unwrap()).is_err());
    }

    #[test]
    fn check_replays_trace_with_audits_on() {
        let path = std::env::temp_dir().join("kairos_cli_check_trace.jsonl");
        let gen = Args::parse(&sv(&[
            "trace", "gen",
            "--out", path.to_str().unwrap(),
            "--rate", "4",
            "--tasks", "30",
            "--seed", "7",
        ]))
        .unwrap();
        trace_cmd(&gen).unwrap();
        // A healthy replay passes every audit and exits cleanly.
        let ok = Args::parse(&sv(&["check", "--trace", path.to_str().unwrap()]))
            .unwrap();
        assert!(check_cmd(&ok).is_ok());
        std::fs::remove_file(&path).ok();
        // --trace is mandatory, and generator flags next to it error.
        assert!(check_cmd(&Args::parse(&sv(&["check"])).unwrap()).is_err());
        let bad = Args::parse(&sv(&[
            "check", "--trace", "f.jsonl", "--tasks", "10",
        ]))
        .unwrap();
        assert!(check_cmd(&bad).is_err());
    }

    #[test]
    fn check_audits_prefix_cache_bookkeeping_with_cache_on() {
        // Satellite: `kairos check --trace FILE --cache` replays the trace
        // with the prefix cache enabled and the bookkeeping audits armed
        // (cached blocks <= budget, hit tokens <= prompt tokens). A healthy
        // replay must pass them all.
        let path = std::env::temp_dir().join("kairos_cli_check_cache_trace.jsonl");
        let gen = Args::parse(&sv(&[
            "trace", "gen",
            "--out", path.to_str().unwrap(),
            "--rate", "4",
            "--tasks", "30",
            "--seed", "11",
        ]))
        .unwrap();
        trace_cmd(&gen).unwrap();
        let ok = Args::parse(&sv(&[
            "check", "--trace", path.to_str().unwrap(),
            "--cache", "--cache-budget", "64",
        ]))
        .unwrap();
        assert!(check_cmd(&ok).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_tuning_flags_parse_and_validate() {
        let a = Args::parse(&sv(&[
            "serve", "--cache", "--cache-budget", "128", "--cache-load-factor", "1.5",
        ]))
        .unwrap();
        let t = cache_tuning_flags(&a, CacheTuning::default()).unwrap();
        assert!(t.enabled);
        assert_eq!(t.budget_blocks, 128);
        assert_eq!(t.load_factor, 1.5);
        // Absent flags keep the base (config-file) values.
        let b = Args::parse(&sv(&["serve"])).unwrap();
        let base = CacheTuning { enabled: true, budget_blocks: 99, load_factor: 2.0 };
        assert_eq!(cache_tuning_flags(&b, base).unwrap(), base);
        // `--cache false` disables a config-enabled cache.
        let c = Args::parse(&sv(&["serve", "--cache", "false"])).unwrap();
        assert!(!cache_tuning_flags(&c, base).unwrap().enabled);
        // Malformed values error naming the flag, never run a silent default.
        for bad in [
            sv(&["serve", "--cache-load-factor", "0.5"]),
            sv(&["serve", "--cache-load-factor", "nan"]),
            sv(&["serve", "--cache-budget", "0"]),
            sv(&["serve", "--cache-budget", "-3"]),
        ] {
            let args = Args::parse(&bad).unwrap();
            let err = cache_tuning_flags(&args, CacheTuning::default())
                .unwrap_err()
                .to_string();
            assert!(err.contains("--cache-"), "error must name the flag: {err}");
        }
    }

    #[test]
    fn cache_sweep_runs_blind_and_affine_arms() {
        let a = Args::parse(&sv(&[
            "cache-sweep",
            "--rate", "6",
            "--tasks", "40",
            "--sessions", "8",
            "--load-factors", "1.25,2.0",
        ]))
        .unwrap();
        assert!(cache_sweep(&a).is_ok());
        // Bad load factors error naming the flag.
        for bad in [
            sv(&["cache-sweep", "--load-factors", "0.5"]),
            sv(&["cache-sweep", "--load-factors", "1.5,oops"]),
        ] {
            let args = Args::parse(&bad).unwrap();
            let err = cache_sweep(&args).unwrap_err().to_string();
            assert!(err.contains("--load-factors"), "{err}");
        }
    }

    #[test]
    fn boot_delay_flag_accepts_scalar_and_per_family_forms() {
        let a = Args::parse(&sv(&["elastic-sweep", "--boot-delay", "5"])).unwrap();
        assert_eq!(parse_boot_delay_flag(&a).unwrap(), (5.0, Vec::new()));
        let b = Args::parse(&sv(&[
            "elastic-sweep",
            "--boot-delay",
            "llama3-8b=2,llama2-13b=12",
        ]))
        .unwrap();
        let (scalar, per) = parse_boot_delay_flag(&b).unwrap();
        assert_eq!(scalar, 0.0);
        assert_eq!(per.len(), 2);
        assert_eq!(per[1], (ModelKind::Llama2_13B, 12.0));
        let none = Args::parse(&sv(&["elastic-sweep"])).unwrap();
        assert_eq!(parse_boot_delay_flag(&none).unwrap(), (0.0, Vec::new()));
        // Garbage in either form errors naming the flag.
        for bad in ["-1", "NaN", "gpt5=3", "llama3-8b=-2", "llama3-8b"] {
            let args =
                Args::parse(&sv(&["elastic-sweep", "--boot-delay", bad])).unwrap();
            let err = parse_boot_delay_flag(&args).unwrap_err().to_string();
            assert!(err.contains("--boot-delay"), "{bad}: {err}");
        }
    }

    #[test]
    fn burst_shape_flag_is_validated() {
        let a = Args::parse(&sv(&["serve", "--burst-shape", "0.5"])).unwrap();
        assert!((burst_gen(&a, 0.31).unwrap().burst_shape - 0.5).abs() < 1e-12);
        for bad in ["0", "-1", "NaN", "inf"] {
            let args = Args::parse(&sv(&["serve", "--burst-shape", bad])).unwrap();
            let err = burst_gen(&args, 0.31).unwrap_err().to_string();
            assert!(err.contains("--burst-shape"), "{bad}: {err}");
            assert!(err.contains("burst_shape"), "{bad}: {err}");
        }
        // And the serve path surfaces it.
        let s = Args::parse(&sv(&["serve", "--burst-shape", "0"])).unwrap();
        assert!(serve(&s).is_err());
    }

    #[test]
    fn malformed_boolean_flag_is_an_error_naming_the_flag() {
        // Same contract as the numeric fix: a typo'd value must error,
        // not silently run the non-elastic config.
        let a = Args::parse(&sv(&["serve", "--autoscale", "enabld"])).unwrap();
        let err = a.bool_flag("autoscale").unwrap_err();
        assert!(err.contains("--autoscale"), "error must name the flag: {err}");
        assert!(err.contains("enabld"), "error must show the bad value: {err}");
        assert!(serve(&a).is_err());
    }
}
