//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! kairos serve   [--config file.toml] [--scheduler S] [--dispatcher D]
//!                [--rate R] [--tasks N] [--instances I] [--model M]
//!                [--fleet SPEC] [--seed X]
//! kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
//! kairos figures <id|all> [--out results/]
//! kairos quickstart [--artifacts DIR] [--model NAME]
//! ```

use std::collections::HashMap;

use crate::agents::apps::App;
use crate::config::ServingConfig;
use crate::engine::cost_model::ModelKind;
use crate::server::coordinator::FleetSpec;
use crate::server::sim::{run_fleet, FleetConfig};
use crate::stats::rng::Rng;
use crate::workload::{TraceGen, WorkloadMix};

/// Parsed `--key value` flags plus positional args.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(args: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| format!("flag --{key} needs a value"))?;
                out.flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn num(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

const USAGE: &str = "\
kairos — low-latency multi-agent LLM serving (paper reproduction)

USAGE:
  kairos serve       [--config F] [--scheduler kairos|parrot|ayo|oracle]
                     [--dispatcher kairos|rr|oracle|least] [--rate R]
                     [--tasks N] [--instances I] [--model llama3-8b|llama2-13b]
                     [--fleet SPEC] [--seed S] [--workload colocated|qa|rg|cg]
  kairos fleet-sweep [--fleet SPEC] [--scheduler S] [--rate R] [--tasks N]
                     [--seed S] [--workload W]
  kairos figures     <table1|fig3..fig18|overhead|all> [--out results]
  kairos quickstart  [--artifacts artifacts] [--model tiny]

FLEET SPEC — comma-separated `[COUNT*]MODEL[@KV_SCALE][:MAX_BATCH]`, e.g.
  `2*llama3-8b@0.12,2*llama3-8b@0.04:128` (uneven co-tenant pressure) or
  `llama3-8b,llama2-13b@0.5` (mixed models). Per-instance KV budgets flow
  to the dispatchers, so memory-aware policies pack each instance against
  its own capacity.
";

/// CLI entrypoint.
pub fn run(raw: Vec<String>) -> crate::Result<()> {
    let args = Args::parse(&raw).map_err(|e| anyhow::anyhow!(e))?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("fleet-sweep") => fleet_sweep(&args),
        Some("figures") => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let out = args.get("out").unwrap_or("results");
            crate::figures::run(id, out)
        }
        Some("quickstart") => quickstart(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn serve(args: &Args) -> crate::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ServingConfig::from_toml(&text).map_err(|e| anyhow::anyhow!(e))?
        }
        None => ServingConfig::default(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.to_string();
    }
    if let Some(d) = args.get("dispatcher") {
        cfg.dispatcher = d.to_string();
    }
    cfg.rate = args.num("rate", cfg.rate);
    cfg.n_tasks = args.num("tasks", cfg.n_tasks as f64) as usize;
    cfg.seed = args.num("seed", cfg.seed as f64) as u64;
    cfg.sim.n_instances = args.num("instances", cfg.sim.n_instances as f64) as usize;
    if let Some(m) = args.get("model") {
        cfg.sim.model = match m {
            "llama3-8b" => ModelKind::Llama3_8B,
            "llama2-13b" => ModelKind::Llama2_13B,
            other => anyhow::bail!("unknown model {other:?}"),
        };
    }
    if let Some(f) = args.get("fleet") {
        cfg.fleet = Some(f.to_string());
    }
    let fleet = cfg.resolve_fleet().map_err(|e| anyhow::anyhow!(e))?;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!(
        "serving {} tasks at {} req/s on {} instances{} — scheduler={} dispatcher={}",
        cfg.n_tasks,
        cfg.rate,
        fleet.len(),
        if fleet.is_heterogeneous() { " (heterogeneous)" } else { "" },
        cfg.scheduler,
        cfg.dispatcher
    );
    let arrivals =
        TraceGen::default().generate(&mix, cfg.rate, cfg.n_tasks, &mut Rng::new(cfg.seed));
    let fc = FleetConfig {
        fleet,
        refresh_interval: cfg.sim.refresh_interval,
        warmup_frac: cfg.sim.warmup_frac,
    };
    let res = run_fleet(fc, &cfg.scheduler, &cfg.dispatcher, arrivals);
    let s = &res.summary;
    println!("\ncompleted {} workflows over {:.1} sim-seconds", s.n_workflows, res.sim_duration);
    println!("program-level token latency:");
    println!("  avg  {:.4} s/tok", s.avg_token_latency);
    println!("  P50  {:.4}   P90 {:.4}   P95 {:.4}   P99 {:.4}",
        s.p50_token_latency, s.p90_token_latency, s.p95_token_latency, s.p99_token_latency);
    println!("queueing-time ratio: {:.1}%", s.mean_queue_ratio * 100.0);
    println!("preempted requests:  {:.1}%", s.preemption_rate * 100.0);
    println!("dropped requests:    {}", res.dropped_requests);
    Ok(())
}

fn workload_mix(name: &str) -> crate::Result<WorkloadMix> {
    Ok(match name {
        "colocated" => WorkloadMix::colocated(),
        "qa" => WorkloadMix::single(App::Qa, "G+M"),
        "rg" => WorkloadMix::single(App::Rg, "TQ"),
        "cg" => WorkloadMix::single(App::Cg, "HE"),
        other => anyhow::bail!("unknown workload {other:?}"),
    })
}

/// End-to-end heterogeneous-fleet scenario: one fleet, every dispatcher.
/// Shows how memory-aware dispatching degrades (or not) when half the
/// fleet runs under heavier co-tenant KV pressure.
fn fleet_sweep(args: &Args) -> crate::Result<()> {
    let spec = args
        .get("fleet")
        .unwrap_or("2*llama3-8b@0.12,2*llama3-8b@0.04:128");
    let fleet = FleetSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    let scheduler = args.get("scheduler").unwrap_or("kairos");
    let rate = args.num("rate", 6.0);
    let n_tasks = args.num("tasks", 400.0) as usize;
    let seed = args.num("seed", 42.0) as u64;
    let mix = workload_mix(args.get("workload").unwrap_or("colocated"))?;

    println!("fleet sweep over {spec:?} — {} instances, scheduler={scheduler}", fleet.len());
    println!("{} tasks at {rate} req/s (seed {seed})\n", n_tasks);
    let mut t = crate::util::table::Table::new(&[
        "dispatcher", "avg s/tok", "P99 s/tok", "queue%", "preempt%", "dropped",
    ]);
    for disp in ["rr", "least", "oracle", "kairos"] {
        let arrivals =
            TraceGen::default().generate(&mix, rate, n_tasks, &mut Rng::new(seed));
        let fc = FleetConfig::from(fleet.clone());
        let res = run_fleet(fc, scheduler, disp, arrivals);
        let s = &res.summary;
        t.row(vec![
            res.dispatcher_name.to_string(),
            format!("{:.4}", s.avg_token_latency),
            format!("{:.4}", s.p99_token_latency),
            format!("{:.1}%", s.mean_queue_ratio * 100.0),
            format!("{:.1}%", s.preemption_rate * 100.0),
            res.dropped_requests.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn quickstart(args: &Args) -> crate::Result<()> {
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use crate::server::real::{RealServer, ServeRequest};
    use std::path::Path;

    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let model = args.get("model").unwrap_or("tiny");
    println!("loading AOT artifacts '{model}' from {dir}/ via PJRT ...");
    let mut server = RealServer::new(
        Path::new(&dir),
        model,
        1,
        Box::new(Fcfs),
        Box::new(RoundRobin::new()),
    )?;
    let prompts = [
        ("Router", "Route: what is 17 * 23?"),
        ("MathAgent", "Solve: 17 * 23 = "),
        ("HumanitiesAgent", "Describe the causes of WW1."),
        ("WriterAgent", "Write a report on LLM serving."),
    ];
    let reqs = prompts
        .iter()
        .map(|(agent, p)| ServeRequest {
            agent: agent.to_string(),
            prompt: p.to_string(),
            max_tokens: 12,
        })
        .collect();
    let (responses, stats) = server.serve(reqs)?;
    for r in &responses {
        println!(
            "[{}] {} tok in {:.3}s  prompt={:?}",
            r.agent, r.output_tokens, r.e2e_seconds, r.prompt
        );
    }
    println!(
        "\n{} requests, {} tokens, {:.2} tok/s wall, mean e2e {:.3}s, p90 {:.3}s",
        stats.n_requests, stats.total_tokens, stats.tokens_per_second, stats.mean_e2e,
        stats.p90_e2e
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&sv(&["figures", "fig14", "--out", "res"])).unwrap();
        assert_eq!(a.positional, vec!["figures", "fig14"]);
        assert_eq!(a.get("out"), Some("res"));
    }

    #[test]
    fn missing_flag_value_errors() {
        assert!(Args::parse(&sv(&["serve", "--rate"])).is_err());
    }

    #[test]
    fn num_parses_with_default() {
        let a = Args::parse(&sv(&["serve", "--rate", "3.5"])).unwrap();
        assert_eq!(a.num("rate", 1.0), 3.5);
        assert_eq!(a.num("missing", 9.0), 9.0);
    }
}
