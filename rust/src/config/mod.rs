//! System configuration: a TOML-subset parser (serde/toml are unavailable
//! offline) and the typed serving config the CLI loads.
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! number, and boolean values, `#` comments.

use std::collections::BTreeMap;

use crate::engine::cost_model::ModelKind;
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::router::RoutePolicy;
use crate::server::autoscale::{parse_boot_delays, parse_per_group, AutoscaleConfig};
use crate::server::coordinator::InstanceSpec;
use crate::server::pressure::PressureTrace;
use crate::server::sim::{CacheTuning, SimConfig};
use crate::workload::TraceGen;

/// A parsed flat TOML-subset document: section -> key -> raw value.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// Scalar values the subset supports.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            let val = Self::parse_value(v.trim())
                .ok_or_else(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            doc.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(doc)
    }

    fn parse_value(s: &str) -> Option<TomlValue> {
        if s == "true" {
            return Some(TomlValue::Bool(true));
        }
        if s == "false" {
            return Some(TomlValue::Bool(false));
        }
        if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Some(TomlValue::Str(inner.to_string()));
        }
        s.parse::<f64>().ok().map(TomlValue::Num)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn num(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(TomlValue::as_str)
            .unwrap_or(default)
            .to_string()
    }
}

/// Strict numeric read: the default when the key is absent — and an error
/// naming section/key when present but not a number (a typo must not
/// silently run a config the user never asked for; same contract as the
/// CLI's `Args::num`).
fn num_key(doc: &TomlDoc, section: &str, key: &str, default: f64) -> Result<f64, String> {
    match doc.get(section, key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("[{section}] {key}: expected a number, got {v:?}")),
    }
}

/// Strict count read: a positive integer. `-1` or `0.5` must error at
/// load, not saturate through an `as usize` cast into an empty fleet or a
/// zero-task run.
fn count_key(
    doc: &TomlDoc,
    section: &str,
    key: &str,
    default: usize,
) -> Result<usize, String> {
    let v = num_key(doc, section, key, default as f64)?;
    if !v.is_finite() || v < 1.0 || v.fract() != 0.0 {
        return Err(format!("[{section}] {key}: expected a positive integer, got {v}"));
    }
    Ok(v as usize)
}

/// Strict non-negative-integer read (seeds).
fn u64_key(doc: &TomlDoc, section: &str, key: &str, default: u64) -> Result<u64, String> {
    let v = num_key(doc, section, key, default as f64)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
        return Err(format!(
            "[{section}] {key}: expected a non-negative integer, got {v}"
        ));
    }
    Ok(v as u64)
}

/// Top-level serving configuration (CLI `--config <file>`).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub sim: SimConfig,
    /// Optional heterogeneous fleet spec (`[cluster] fleet = "..."`), in
    /// [`crate::server::coordinator::FleetSpec::parse`] syntax. When set it
    /// overrides `instances`/`model`/`max_batch`/`kv_scale`.
    pub fleet: Option<String>,
    pub scheduler: String,
    pub dispatcher: String,
    pub rate: f64,
    pub n_tasks: usize,
    pub seed: u64,
    /// Elastic-fleet policy (`[autoscale] enabled = true` + thresholds).
    /// The template spec for new instances is resolved against the fleet
    /// at serve time (first instance's spec).
    pub autoscale: Option<AutoscaleConfig>,
    /// Co-tenant pressure trace (`[pressure] trace = "..."`), in
    /// [`PressureTrace::parse`] syntax. Validated eagerly at load.
    pub pressure: Option<String>,
    /// Agent → model-class pins (`[workload] affinity = "..."`), in
    /// [`AffinitySpec::parse`] syntax. Validated eagerly at load.
    pub affinity: Option<String>,
    /// Routing-layer policy (`[policy] route_policy = "..."`), in
    /// [`RoutePolicy::parse`] syntax (`pinned` | `learned[:...]`).
    /// Validated eagerly at load; absent = the static pinned behavior.
    pub route_policy: Option<String>,
    /// Recorded workload trace path (`[workload] trace = "file.jsonl"`):
    /// when set, serving replays the file instead of generating arrivals
    /// (rate/tasks/seed/burst_shape then only describe the generator
    /// fallback). The file is read at serve time, not load time.
    pub trace: Option<String>,
    /// Gamma shape of generated inter-arrival gaps (`[workload]
    /// burst_shape`); validated at load via [`TraceGen::new`].
    pub burst_shape: f64,
    /// Per-family profile half-life in seconds (`[policy]
    /// profile_half_life`): learned routing tracks drifting latencies
    /// instead of averaging forever. Absent = stationary profiles.
    pub profile_half_life: Option<f64>,
    /// Prefix-cache tuning (`[cache] enabled = true` + `budget_blocks` /
    /// `load_factor`): per-instance prefix caches, the packer's
    /// session-aware prefill estimate, and the `cache-affine`
    /// dispatcher's CHWBL bounded-load factor.
    pub cache: CacheTuning,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            sim: SimConfig::default(),
            fleet: None,
            scheduler: "kairos".into(),
            dispatcher: "kairos".into(),
            rate: 8.0,
            n_tasks: 400,
            seed: 42,
            autoscale: None,
            pressure: None,
            affinity: None,
            route_policy: None,
            trace: None,
            burst_shape: TraceGen::default().burst_shape,
            profile_half_life: None,
            cache: CacheTuning::default(),
        }
    }
}

impl ServingConfig {
    pub fn from_toml(text: &str) -> Result<ServingConfig, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServingConfig::default();
        cfg.sim.n_instances = count_key(&doc, "cluster", "instances", 4)?;
        cfg.sim.block_size = count_key(&doc, "cluster", "block_size", 16)? as u32;
        cfg.sim.max_batch = count_key(&doc, "cluster", "max_batch", 64)?;
        cfg.sim.kv_scale = num_key(&doc, "cluster", "kv_scale", 1.0)?;
        if !cfg.sim.kv_scale.is_finite() || cfg.sim.kv_scale <= 0.0 {
            return Err(format!("[cluster] kv_scale invalid: {}", cfg.sim.kv_scale));
        }
        cfg.sim.refresh_interval = num_key(&doc, "kairos", "refresh_interval", 5.0)?;
        if !cfg.sim.refresh_interval.is_finite() || cfg.sim.refresh_interval <= 0.0 {
            // A zero interval would re-schedule the refresh event at the
            // same timestamp forever.
            return Err(format!(
                "[kairos] refresh_interval invalid: {}",
                cfg.sim.refresh_interval
            ));
        }
        cfg.sim.warmup_frac = num_key(&doc, "workload", "warmup_frac", 0.2)?;
        if !(0.0..=1.0).contains(&cfg.sim.warmup_frac) {
            return Err(format!(
                "[workload] warmup_frac must be in [0, 1], got {}",
                cfg.sim.warmup_frac
            ));
        }
        cfg.sim.model = ModelKind::parse(doc.str("cluster", "model", "llama3-8b").as_str())?;
        cfg.fleet = doc
            .get("cluster", "fleet")
            .and_then(TomlValue::as_str)
            .map(|s| s.to_string());
        if let Some(spec) = &cfg.fleet {
            // Validate eagerly so a bad config fails at load, not dispatch.
            crate::server::coordinator::FleetSpec::parse(spec)?;
        }
        cfg.scheduler = doc.str("policy", "scheduler", "kairos");
        cfg.dispatcher = doc.str("policy", "dispatcher", "kairos");
        cfg.rate = num_key(&doc, "workload", "rate", 8.0)?;
        if !cfg.rate.is_finite() || cfg.rate <= 0.0 {
            return Err(format!("[workload] rate must be positive, got {}", cfg.rate));
        }
        cfg.n_tasks = count_key(&doc, "workload", "tasks", 400)?;
        cfg.seed = u64_key(&doc, "workload", "seed", 42)?;
        cfg.burst_shape = num_key(&doc, "workload", "burst_shape", cfg.burst_shape)?;
        // Validate through the generator's own constructor so the error
        // names the offending value (a NaN/zero shape would otherwise
        // produce NaN inter-arrival gaps silently).
        TraceGen::new(cfg.burst_shape)
            .map_err(|e| format!("[workload] burst_shape: {e}"))?;
        cfg.trace = match doc.get("workload", "trace") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        format!("[workload] trace: expected a string path, got {v:?}")
                    })?
                    .to_string(),
            ),
        };
        cfg.profile_half_life = match doc.get("policy", "profile_half_life") {
            None => None,
            Some(v) => {
                let h = v.as_f64().ok_or_else(|| {
                    format!("[policy] profile_half_life: expected a number, got {v:?}")
                })?;
                if !h.is_finite() || h <= 0.0 {
                    return Err(format!(
                        "[policy] profile_half_life must be a positive finite number, \
                         got {h}"
                    ));
                }
                Some(h)
            }
        };
        let autoscale_enabled = match doc.get("autoscale", "enabled") {
            None => false,
            Some(v) => v.as_bool().ok_or_else(|| {
                format!("[autoscale] enabled: expected a boolean, got {v:?}")
            })?,
        };
        if autoscale_enabled {
            let num = |key: &str, default: f64| num_key(&doc, "autoscale", key, default);
            // Counts (bounds, hysteresis streaks) must be positive
            // integers: a zero/negative streak would make the hysteresis
            // trivially true and flap the fleet on every refresh.
            let count =
                |key: &str, default: usize| count_key(&doc, "autoscale", key, default);
            let template =
                InstanceSpec::new(cfg.sim.model).with_kv_scale(cfg.sim.kv_scale);
            let d = AutoscaleConfig::for_template(template);
            let per_group = match doc.get("autoscale", "per_group") {
                None => Vec::new(),
                Some(v) => {
                    let s = v.as_str().ok_or_else(|| {
                        format!("[autoscale] per_group: expected a string, got {v:?}")
                    })?;
                    parse_per_group(s)?
                }
            };
            // `boot_delay` takes two forms: a number (one global delay)
            // or a string `"MODEL=SECS,..."` (per-family delays; families
            // absent from the list boot instantly unless a scalar is also
            // the default).
            let (boot_delay, boot_delay_per_group) =
                match doc.get("autoscale", "boot_delay") {
                    None => (d.boot_delay, Vec::new()),
                    Some(TomlValue::Num(n)) => (*n, Vec::new()),
                    Some(TomlValue::Str(s)) => (d.boot_delay, parse_boot_delays(s)?),
                    Some(v) => {
                        return Err(format!(
                            "[autoscale] boot_delay: expected a number or a \
                             \"MODEL=SECS,...\" string, got {v:?}"
                        ))
                    }
                };
            let a = AutoscaleConfig {
                min_instances: count("min", d.min_instances)?,
                max_instances: count("max", d.max_instances)?,
                queue_high: num("queue_high", d.queue_high)?,
                queue_low: num("queue_low", d.queue_low)?,
                ratio_high: num("ratio_high", d.ratio_high)?,
                up_after: count("up_after", d.up_after as usize)? as u32,
                down_after: count("down_after", d.down_after as usize)? as u32,
                cooldown: num("cooldown", d.cooldown)?,
                boot_delay,
                boot_delay_per_group,
                per_group,
                template,
            };
            if !a.boot_delay.is_finite() || a.boot_delay < 0.0 {
                return Err(format!("[autoscale] boot_delay invalid: {}", a.boot_delay));
            }
            if a.max_instances < a.min_instances {
                return Err(format!(
                    "[autoscale] bounds invalid: min={} max={}",
                    a.min_instances, a.max_instances
                ));
            }
            // Thresholds must be finite and non-negative BEFORE the band
            // comparison — a NaN sails through `queue_low > queue_high`
            // (all NaN comparisons are false) and then disarms or forces
            // the scaler at runtime with no error ever reported.
            for (name, v) in [
                ("queue_high", a.queue_high),
                ("queue_low", a.queue_low),
                ("ratio_high", a.ratio_high),
                ("cooldown", a.cooldown),
            ] {
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("[autoscale] {name} invalid: {v}"));
                }
            }
            if a.queue_low > a.queue_high {
                return Err(format!(
                    "[autoscale] queue_low ({}) must not exceed queue_high ({})",
                    a.queue_low, a.queue_high
                ));
            }
            cfg.autoscale = Some(a);
        }
        cfg.cache.enabled = match doc.get("cache", "enabled") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("[cache] enabled: expected a boolean, got {v:?}"))?,
        };
        cfg.cache.budget_blocks =
            count_key(&doc, "cache", "budget_blocks", cfg.cache.budget_blocks as usize)?
                as u32;
        cfg.cache.load_factor =
            num_key(&doc, "cache", "load_factor", cfg.cache.load_factor)?;
        if !cfg.cache.load_factor.is_finite() || cfg.cache.load_factor < 1.0 {
            // A factor below 1 would refuse every sticky pick; NaN would
            // disarm the bounded-load ceiling entirely.
            return Err(format!(
                "[cache] load_factor must be a finite number >= 1, got {}",
                cfg.cache.load_factor
            ));
        }
        cfg.pressure = match doc.get("pressure", "trace") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        format!("[pressure] trace: expected a string, got {v:?}")
                    })?
                    .to_string(),
            ),
        };
        if let Some(spec) = &cfg.pressure {
            // Validate eagerly so a bad trace fails at load, not mid-run.
            PressureTrace::parse(spec)?;
        }
        cfg.affinity = match doc.get("workload", "affinity") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        format!("[workload] affinity: expected a string, got {v:?}")
                    })?
                    .to_string(),
            ),
        };
        if let Some(spec) = &cfg.affinity {
            // Validate eagerly so a bad pin fails at load, not dispatch.
            AffinitySpec::parse(spec)?;
        }
        cfg.route_policy = match doc.get("policy", "route_policy") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        format!("[policy] route_policy: expected a string, got {v:?}")
                    })?
                    .to_string(),
            ),
        };
        if let Some(spec) = &cfg.route_policy {
            // Validate eagerly so a bad policy fails at load, not serve.
            RoutePolicy::parse(spec)?;
        }
        Ok(cfg)
    }

    /// The resolved fleet: the explicit `fleet` spec when present,
    /// otherwise the homogeneous fleet described by `sim`.
    pub fn resolve_fleet(&self) -> Result<crate::server::coordinator::FleetSpec, String> {
        match &self.fleet {
            Some(s) => crate::server::coordinator::FleetSpec::parse(s),
            None => Ok(self.sim.fleet()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Kairos serving config
[cluster]
instances = 4
model = "llama3-8b"
block_size = 16

[policy]
scheduler = "kairos"
dispatcher = "kairos"

[workload]
rate = 10.5
tasks = 200
seed = 7
warmup_frac = 0.25

[kairos]
refresh_interval = 2.0
"#;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.num("cluster", "instances", 0.0), 4.0);
        assert_eq!(doc.str("cluster", "model", ""), "llama3-8b");
        assert_eq!(doc.num("workload", "rate", 0.0), 10.5);
    }

    #[test]
    fn serving_config_from_toml() {
        let cfg = ServingConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.sim.n_instances, 4);
        assert_eq!(cfg.scheduler, "kairos");
        assert_eq!(cfg.rate, 10.5);
        assert_eq!(cfg.n_tasks, 200);
        assert_eq!(cfg.seed, 7);
        assert!((cfg.sim.refresh_interval - 2.0).abs() < 1e-12);
        assert!((cfg.sim.warmup_frac - 0.25).abs() < 1e-12);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = ServingConfig::from_toml("[cluster]\ninstances = 2\n").unwrap();
        assert_eq!(cfg.sim.n_instances, 2);
        assert_eq!(cfg.dispatcher, "kairos");
        assert_eq!(cfg.sim.max_batch, 64);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("keyonly\n").is_err());
        assert!(TomlDoc::parse("k = @bad\n").is_err());
        assert!(ServingConfig::from_toml("[cluster]\nmodel = \"gpt5\"\n").is_err());
    }

    #[test]
    fn fleet_spec_parses_and_overrides() {
        let cfg = ServingConfig::from_toml(
            "[cluster]\ninstances = 2\nfleet = \"2*llama3-8b@0.12,llama2-13b@0.5\"\n",
        )
        .unwrap();
        let fleet = cfg.resolve_fleet().unwrap();
        assert_eq!(fleet.len(), 3);
        assert!(fleet.is_heterogeneous());
        // Without a fleet spec, the homogeneous sim config wins.
        let cfg = ServingConfig::from_toml("[cluster]\ninstances = 2\n").unwrap();
        assert_eq!(cfg.resolve_fleet().unwrap().len(), 2);
    }

    #[test]
    fn bad_fleet_spec_rejected_at_load() {
        assert!(ServingConfig::from_toml("[cluster]\nfleet = \"gpt5@1.0\"\n").is_err());
    }

    #[test]
    fn autoscale_section_parses_with_defaults() {
        let cfg = ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nmin = 2\nmax = 6\nqueue_high = 12\n",
        )
        .unwrap();
        let a = cfg.autoscale.expect("autoscale enabled");
        assert_eq!(a.min_instances, 2);
        assert_eq!(a.max_instances, 6);
        assert!((a.queue_high - 12.0).abs() < 1e-12);
        // Unset thresholds fall back to the defaults.
        assert!((a.cooldown - 10.0).abs() < 1e-12);
        // Absent or disabled section: no autoscaler.
        let off = ServingConfig::from_toml("[autoscale]\nenabled = false\n").unwrap();
        assert!(off.autoscale.is_none());
        assert!(ServingConfig::from_toml("").unwrap().autoscale.is_none());
        // Mis-typed `enabled`/`trace` must error, never silently drop the
        // whole section.
        assert!(ServingConfig::from_toml("[autoscale]\nenabled = 1\n").is_err());
        assert!(ServingConfig::from_toml("[pressure]\ntrace = 5\n").is_err());
    }

    #[test]
    fn autoscale_bad_bounds_rejected() {
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nmin = 4\nmax = 2\n"
        )
        .is_err());
        assert!(ServingConfig::from_toml("[autoscale]\nenabled = true\nmin = 0\n")
            .is_err());
    }

    #[test]
    fn autoscale_non_numeric_threshold_is_an_error_not_a_default() {
        // A string where a number belongs must fail at load, not silently
        // run with the default threshold.
        let err = ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nqueue_high = \"12x\"\n",
        )
        .unwrap_err();
        assert!(err.contains("queue_high"), "error must name the key: {err}");
        assert!(
            ServingConfig::from_toml("[autoscale]\nenabled = true\nup_after = \"l\"\n")
                .is_err()
        );
        // Zero/negative streaks and inverted hysteresis bands are rejected.
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nup_after = 0\n"
        )
        .is_err());
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\ndown_after = -1\n"
        )
        .is_err());
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nqueue_low = 9\nqueue_high = 4\n"
        )
        .is_err());
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nratio_high = -1\n"
        )
        .is_err());
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nqueue_high = nan\n"
        )
        .is_err());
    }

    #[test]
    fn cluster_and_workload_numerics_are_strict_too() {
        // The strict-parse contract covers every numeric key, not just
        // [autoscale]: a string where a number belongs fails at load.
        let err =
            ServingConfig::from_toml("[workload]\nrate = \"12x\"\n").unwrap_err();
        assert!(err.contains("rate"), "error must name the key: {err}");
        assert!(ServingConfig::from_toml("[cluster]\ninstances = \"two\"\n").is_err());
    }

    #[test]
    fn affinity_spec_validated_at_load() {
        let cfg = ServingConfig::from_toml(
            "[workload]\naffinity = \"*=llama3-8b,Engineer=llama2-13b\"\n",
        )
        .unwrap();
        assert_eq!(cfg.affinity.as_deref(), Some("*=llama3-8b,Engineer=llama2-13b"));
        // Bad pins fail at load, and a mis-typed value never silently
        // drops the key.
        assert!(ServingConfig::from_toml("[workload]\naffinity = \"A=gpt5\"\n").is_err());
        assert!(ServingConfig::from_toml("[workload]\naffinity = 5\n").is_err());
        assert!(ServingConfig::from_toml("").unwrap().affinity.is_none());
    }

    #[test]
    fn route_policy_validated_at_load() {
        let cfg = ServingConfig::from_toml(
            "[policy]\nroute_policy = \"learned:explore=0.2,min_samples=16\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.route_policy.as_deref(),
            Some("learned:explore=0.2,min_samples=16")
        );
        assert!(ServingConfig::from_toml("").unwrap().route_policy.is_none());
        // Bad policies fail at load; a mis-typed value never silently
        // drops the key.
        assert!(ServingConfig::from_toml("[policy]\nroute_policy = \"greedy\"\n").is_err());
        assert!(ServingConfig::from_toml("[policy]\nroute_policy = 5\n").is_err());
        assert!(ServingConfig::from_toml(
            "[policy]\nroute_policy = \"learned:explore=7\"\n"
        )
        .is_err());
    }

    #[test]
    fn autoscale_per_group_and_boot_delay_parse() {
        let cfg = ServingConfig::from_toml(concat!(
            "[autoscale]\nenabled = true\nboot_delay = 3.5\n",
            "per_group = \"llama3-8b=1..4,llama2-13b=0..2\"\n",
        ))
        .unwrap();
        let a = cfg.autoscale.expect("autoscale enabled");
        assert!((a.boot_delay - 3.5).abs() < 1e-12);
        assert_eq!(a.per_group.len(), 2);
        assert_eq!(a.family_max(crate::engine::cost_model::ModelKind::Llama2_13B), 2);
        // Defaults: instant boot, unbounded families.
        let d = ServingConfig::from_toml("[autoscale]\nenabled = true\n").unwrap();
        let d = d.autoscale.unwrap();
        assert_eq!(d.boot_delay, 0.0);
        assert!(d.per_group.is_empty());
        // Bad values fail at load, naming the key/clause.
        let err = ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nboot_delay = -1\n",
        )
        .unwrap_err();
        assert!(err.contains("boot_delay"), "{err}");
        let err = ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nper_group = \"llama3-8b=4..1\"\n",
        )
        .unwrap_err();
        assert!(err.contains("llama3-8b=4..1"), "{err}");
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nper_group = 5\n"
        )
        .is_err());
    }

    #[test]
    fn workload_trace_and_burst_shape_parse() {
        let cfg = ServingConfig::from_toml(
            "[workload]\ntrace = \"runs/night.jsonl\"\nburst_shape = 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.trace.as_deref(), Some("runs/night.jsonl"));
        assert!((cfg.burst_shape - 0.5).abs() < 1e-12);
        // Defaults: no trace, the generator's bursty shape.
        let d = ServingConfig::from_toml("").unwrap();
        assert_eq!(d.trace, None);
        assert!((d.burst_shape - 0.31).abs() < 1e-12);
        // A mis-typed trace value never silently drops the key, and bad
        // burst shapes fail at load naming the value.
        assert!(ServingConfig::from_toml("[workload]\ntrace = 5\n").is_err());
        let err =
            ServingConfig::from_toml("[workload]\nburst_shape = 0\n").unwrap_err();
        assert!(err.contains("burst_shape") && err.contains('0'), "{err}");
        assert!(ServingConfig::from_toml("[workload]\nburst_shape = nan\n").is_err());
        assert!(ServingConfig::from_toml("[workload]\nburst_shape = -0.3\n").is_err());
    }

    #[test]
    fn profile_half_life_parses_and_validates() {
        let cfg =
            ServingConfig::from_toml("[policy]\nprofile_half_life = 30\n").unwrap();
        assert_eq!(cfg.profile_half_life, Some(30.0));
        assert_eq!(ServingConfig::from_toml("").unwrap().profile_half_life, None);
        for bad in ["0", "-5", "nan", "inf", "\"soon\""] {
            let doc = format!("[policy]\nprofile_half_life = {bad}\n");
            let err = ServingConfig::from_toml(&doc).unwrap_err();
            assert!(err.contains("profile_half_life"), "{bad}: {err}");
        }
    }

    #[test]
    fn boot_delay_accepts_scalar_and_per_family_forms() {
        use crate::engine::cost_model::ModelKind;
        // Scalar form: unchanged behavior.
        let cfg =
            ServingConfig::from_toml("[autoscale]\nenabled = true\nboot_delay = 3\n")
                .unwrap();
        let a = cfg.autoscale.unwrap();
        assert_eq!(a.boot_delay, 3.0);
        assert!(a.boot_delay_per_group.is_empty());
        assert_eq!(a.boot_delay_for(ModelKind::Llama2_13B), 3.0);
        // Per-family string form: big models provision slower.
        let cfg = ServingConfig::from_toml(concat!(
            "[autoscale]\nenabled = true\n",
            "boot_delay = \"llama3-8b=2,llama2-13b=12\"\n",
        ))
        .unwrap();
        let a = cfg.autoscale.unwrap();
        assert_eq!(a.boot_delay_for(ModelKind::Llama3_8B), 2.0);
        assert_eq!(a.boot_delay_for(ModelKind::Llama2_13B), 12.0);
        assert_eq!(a.boot_delay_for(ModelKind::Tiny), 0.0, "scalar fallback");
        // Bad clauses fail at load naming the clause; booleans are
        // rejected outright.
        let err = ServingConfig::from_toml(concat!(
            "[autoscale]\nenabled = true\nboot_delay = \"llama2-13b=-4\"\n",
        ))
        .unwrap_err();
        assert!(err.contains("llama2-13b=-4"), "{err}");
        assert!(ServingConfig::from_toml(
            "[autoscale]\nenabled = true\nboot_delay = true\n"
        )
        .is_err());
    }

    #[test]
    fn cache_section_parses_and_validates() {
        let cfg = ServingConfig::from_toml(
            "[cache]\nenabled = true\nbudget_blocks = 256\nload_factor = 1.5\n",
        )
        .unwrap();
        assert!(cfg.cache.enabled);
        assert_eq!(cfg.cache.budget_blocks, 256);
        assert!((cfg.cache.load_factor - 1.5).abs() < 1e-12);
        // Defaults: disabled, 512-block budget, 1.25 bound.
        let d = ServingConfig::from_toml("").unwrap();
        assert!(!d.cache.enabled);
        assert_eq!(d.cache.budget_blocks, 512);
        assert!((d.cache.load_factor - 1.25).abs() < 1e-12);
        // Bad values fail at load, naming the key.
        assert!(ServingConfig::from_toml("[cache]\nenabled = 1\n").is_err());
        assert!(ServingConfig::from_toml("[cache]\nbudget_blocks = 0\n").is_err());
        let err =
            ServingConfig::from_toml("[cache]\nload_factor = 0.5\n").unwrap_err();
        assert!(err.contains("load_factor"), "{err}");
        assert!(ServingConfig::from_toml("[cache]\nload_factor = nan\n").is_err());
    }

    #[test]
    fn pressure_trace_validated_at_load() {
        let cfg = ServingConfig::from_toml(
            "[pressure]\ntrace = \"*:0=1.0,30=0.5;1:0=0.8\"\n",
        )
        .unwrap();
        assert_eq!(cfg.pressure.as_deref(), Some("*:0=1.0,30=0.5;1:0=0.8"));
        assert!(ServingConfig::from_toml("[pressure]\ntrace = \"*:0=-1\"\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = TomlDoc::parse("# top\n\n[a]\nx = 1 # trailing\n").unwrap();
        assert_eq!(doc.num("a", "x", 0.0), 1.0);
    }
}
