//! System configuration: a TOML-subset parser (serde/toml are unavailable
//! offline) and the typed serving config the CLI loads.
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! number, and boolean values, `#` comments.

use std::collections::BTreeMap;

use crate::engine::cost_model::ModelKind;
use crate::server::sim::SimConfig;

/// A parsed flat TOML-subset document: section -> key -> raw value.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// Scalar values the subset supports.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            let val = Self::parse_value(v.trim())
                .ok_or_else(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            doc.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(doc)
    }

    fn parse_value(s: &str) -> Option<TomlValue> {
        if s == "true" {
            return Some(TomlValue::Bool(true));
        }
        if s == "false" {
            return Some(TomlValue::Bool(false));
        }
        if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Some(TomlValue::Str(inner.to_string()));
        }
        s.parse::<f64>().ok().map(TomlValue::Num)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn num(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(TomlValue::as_str)
            .unwrap_or(default)
            .to_string()
    }
}

/// Top-level serving configuration (CLI `--config <file>`).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub sim: SimConfig,
    /// Optional heterogeneous fleet spec (`[cluster] fleet = "..."`), in
    /// [`crate::server::coordinator::FleetSpec::parse`] syntax. When set it
    /// overrides `instances`/`model`/`max_batch`/`kv_scale`.
    pub fleet: Option<String>,
    pub scheduler: String,
    pub dispatcher: String,
    pub rate: f64,
    pub n_tasks: usize,
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            sim: SimConfig::default(),
            fleet: None,
            scheduler: "kairos".into(),
            dispatcher: "kairos".into(),
            rate: 8.0,
            n_tasks: 400,
            seed: 42,
        }
    }
}

impl ServingConfig {
    pub fn from_toml(text: &str) -> Result<ServingConfig, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServingConfig::default();
        cfg.sim.n_instances = doc.num("cluster", "instances", 4.0) as usize;
        cfg.sim.block_size = doc.num("cluster", "block_size", 16.0) as u32;
        cfg.sim.max_batch = doc.num("cluster", "max_batch", 64.0) as usize;
        cfg.sim.kv_scale = doc.num("cluster", "kv_scale", 1.0);
        cfg.sim.refresh_interval = doc.num("kairos", "refresh_interval", 5.0);
        cfg.sim.warmup_frac = doc.num("workload", "warmup_frac", 0.2);
        cfg.sim.model = match doc.str("cluster", "model", "llama3-8b").as_str() {
            "llama3-8b" => ModelKind::Llama3_8B,
            "llama2-13b" => ModelKind::Llama2_13B,
            "tiny" => ModelKind::Tiny,
            other => return Err(format!("unknown model {other:?}")),
        };
        cfg.fleet = doc
            .get("cluster", "fleet")
            .and_then(TomlValue::as_str)
            .map(|s| s.to_string());
        if let Some(spec) = &cfg.fleet {
            // Validate eagerly so a bad config fails at load, not dispatch.
            crate::server::coordinator::FleetSpec::parse(spec)?;
        }
        cfg.scheduler = doc.str("policy", "scheduler", "kairos");
        cfg.dispatcher = doc.str("policy", "dispatcher", "kairos");
        cfg.rate = doc.num("workload", "rate", 8.0);
        cfg.n_tasks = doc.num("workload", "tasks", 400.0) as usize;
        cfg.seed = doc.num("workload", "seed", 42.0) as u64;
        Ok(cfg)
    }

    /// The resolved fleet: the explicit `fleet` spec when present,
    /// otherwise the homogeneous fleet described by `sim`.
    pub fn resolve_fleet(&self) -> Result<crate::server::coordinator::FleetSpec, String> {
        match &self.fleet {
            Some(s) => crate::server::coordinator::FleetSpec::parse(s),
            None => Ok(self.sim.fleet()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Kairos serving config
[cluster]
instances = 4
model = "llama3-8b"
block_size = 16

[policy]
scheduler = "kairos"
dispatcher = "kairos"

[workload]
rate = 10.5
tasks = 200
seed = 7
warmup_frac = 0.25

[kairos]
refresh_interval = 2.0
"#;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.num("cluster", "instances", 0.0), 4.0);
        assert_eq!(doc.str("cluster", "model", ""), "llama3-8b");
        assert_eq!(doc.num("workload", "rate", 0.0), 10.5);
    }

    #[test]
    fn serving_config_from_toml() {
        let cfg = ServingConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.sim.n_instances, 4);
        assert_eq!(cfg.scheduler, "kairos");
        assert_eq!(cfg.rate, 10.5);
        assert_eq!(cfg.n_tasks, 200);
        assert_eq!(cfg.seed, 7);
        assert!((cfg.sim.refresh_interval - 2.0).abs() < 1e-12);
        assert!((cfg.sim.warmup_frac - 0.25).abs() < 1e-12);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = ServingConfig::from_toml("[cluster]\ninstances = 2\n").unwrap();
        assert_eq!(cfg.sim.n_instances, 2);
        assert_eq!(cfg.dispatcher, "kairos");
        assert_eq!(cfg.sim.max_batch, 64);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("keyonly\n").is_err());
        assert!(TomlDoc::parse("k = @bad\n").is_err());
        assert!(ServingConfig::from_toml("[cluster]\nmodel = \"gpt5\"\n").is_err());
    }

    #[test]
    fn fleet_spec_parses_and_overrides() {
        let cfg = ServingConfig::from_toml(
            "[cluster]\ninstances = 2\nfleet = \"2*llama3-8b@0.12,llama2-13b@0.5\"\n",
        )
        .unwrap();
        let fleet = cfg.resolve_fleet().unwrap();
        assert_eq!(fleet.len(), 3);
        assert!(fleet.is_heterogeneous());
        // Without a fleet spec, the homogeneous sim config wins.
        let cfg = ServingConfig::from_toml("[cluster]\ninstances = 2\n").unwrap();
        assert_eq!(cfg.resolve_fleet().unwrap().len(), 2);
    }

    #[test]
    fn bad_fleet_spec_rejected_at_load() {
        assert!(ServingConfig::from_toml("[cluster]\nfleet = \"gpt5@1.0\"\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = TomlDoc::parse("# top\n\n[a]\nx = 1 # trailing\n").unwrap();
        assert_eq!(doc.num("a", "x", 0.0), 1.0);
    }
}
