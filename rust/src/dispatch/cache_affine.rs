//! Session-sticky cache-affine dispatch: consistent hashing with bounded
//! loads (CHWBL) layered over any inner [`DispatchPolicy`].
//!
//! Multi-agent workflows grow one context across stages: stage *k+1*'s
//! prompt extends stage *k*'s prompt + output. An instance that already
//! holds the session's KV prefix (see
//! [`crate::engine::block_manager::PrefixCache`]) can skip recomputing it,
//! so placement wants to be *sticky per session* — but naive stickiness
//! lets one hot session family overload an instance. CHWBL (Mirrokni et
//! al.) caps stickiness: the ring target is taken only while its in-flight
//! load stays under `ceil(load_factor × mean)`; otherwise the decision
//! falls back to the inner scorer (here: the time-slot packer), which sees
//! the exact same candidate set through the
//! [`DispatchPolicy::choose_among`] seam.
//!
//! Everything is deterministic: the ring is built from
//! [`crate::metrics::hll::mix64`] vnode hashes, ties sort by instance
//! index, and loads are integer in-flight counts.

use super::{DispatchPolicy, DispatchStats, ScoreScope, Scored};
use crate::engine::core::InstanceStatus;
use crate::engine::request::{Request, RequestId};
use crate::metrics::hll::mix64;
use crate::Time;

/// Tuning for the sticky layer.
#[derive(Debug, Clone, Copy)]
pub struct CacheAffineConfig {
    /// Bounded-load factor `c ≥ 1`: a sticky pick is accepted only while
    /// the target's in-flight load stays ≤ `ceil(c × (total+1) / n)`.
    /// Smaller values fall back to the packer sooner (better balance,
    /// fewer cache hits); larger values stick harder.
    pub load_factor: f64,
    /// Virtual nodes per instance on the hash ring. More vnodes smooth the
    /// session→instance distribution; 64 is plenty for small fleets.
    pub vnodes: usize,
}

impl Default for CacheAffineConfig {
    fn default() -> CacheAffineConfig {
        CacheAffineConfig { load_factor: 1.25, vnodes: 64 }
    }
}

/// The consistent-hashing-with-bounded-loads core, exposed standalone so
/// property tests can drive it directly.
///
/// State is three integers per instance worth of bookkeeping: a sorted
/// vnode ring, an in-flight load vector, and the load total. All methods
/// are O(log ring) or O(n).
#[derive(Debug, Clone)]
pub struct Chwbl {
    load_factor: f64,
    vnodes: usize,
    /// `(vnode_hash, instance)` sorted ascending; ties break by instance.
    ring: Vec<(u64, usize)>,
    /// Ring members (distinct instances), for the mean-load denominator.
    members: usize,
    /// In-flight dispatch count per instance slot.
    loads: Vec<u64>,
    /// Sum of `loads`.
    total: u64,
}

impl Chwbl {
    /// A ring over instances `0..n` (all assumed live); `rebuild` replaces
    /// the membership when the fleet changes.
    pub fn new(cfg: CacheAffineConfig, n: usize) -> Chwbl {
        assert!(cfg.load_factor >= 1.0, "load_factor must be >= 1");
        assert!(cfg.vnodes > 0, "vnodes must be > 0");
        let mut c = Chwbl {
            load_factor: cfg.load_factor,
            vnodes: cfg.vnodes,
            ring: Vec::new(),
            members: 0,
            loads: vec![0; n],
            total: 0,
        };
        let all: Vec<usize> = (0..n).collect();
        c.rebuild(&all, n);
        c
    }

    /// Replace the ring membership with `members` (instance indices) and
    /// resize the load vector to `n_slots`, preserving surviving loads.
    pub fn rebuild(&mut self, members: &[usize], n_slots: usize) {
        self.ring.clear();
        for &j in members {
            for v in 0..self.vnodes {
                // Composite (instance, vnode) key through the fixed mixer;
                // instance indices stay well under 2^48.
                self.ring.push((mix64(((j as u64) << 16) | v as u64), j));
            }
        }
        self.ring.sort_unstable();
        self.members = members.len();
        if self.loads.len() != n_slots {
            // Shrink drops retired slots' loads; growth starts new slots
            // empty. Recompute the total from what survives.
            self.loads.resize(n_slots, 0);
            self.total = self.loads.iter().sum();
        }
    }

    /// The bounded-load ceiling for the *next* dispatch:
    /// `ceil(load_factor × (total+1) / members)`.
    pub fn ceiling(&self) -> u64 {
        if self.members == 0 {
            return 0;
        }
        (self.load_factor * (self.total + 1) as f64 / self.members as f64).ceil()
            as u64
    }

    /// The sticky target for `session`: the first ring successor of
    /// `mix64(session)` that satisfies `eligible`, if its load after one
    /// more dispatch would stay within [`Chwbl::ceiling`]. `None` means
    /// "no eligible member" or "target saturated" — the caller falls back.
    pub fn pick(&self, session: u64, eligible: impl Fn(usize) -> bool) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let h = mix64(session);
        let start = self.ring.partition_point(|&(vh, _)| vh < h) % self.ring.len();
        for k in 0..self.ring.len() {
            let (_, j) = self.ring[(start + k) % self.ring.len()];
            if !eligible(j) {
                continue;
            }
            let load = self.loads.get(j).copied().unwrap_or(u64::MAX);
            return (load.saturating_add(1) <= self.ceiling()).then_some(j);
        }
        None
    }

    /// Record a dispatch to instance `j` (chosen by any path).
    pub fn on_dispatch(&mut self, j: usize) {
        if let Some(l) = self.loads.get_mut(j) {
            *l += 1;
            self.total += 1;
        }
    }

    /// Record a completion on instance `j`.
    pub fn on_complete(&mut self, j: usize) {
        if let Some(l) = self.loads.get_mut(j) {
            if *l > 0 {
                *l -= 1;
                self.total -= 1;
            }
        }
    }

    /// Forget slot `j`'s in-flight load (the engine behind it was rebuilt).
    pub fn reset_slot(&mut self, j: usize) {
        if let Some(l) = self.loads.get_mut(j) {
            self.total -= *l;
            *l = 0;
        }
    }

    /// Current in-flight load per instance slot.
    pub fn loads(&self) -> &[u64] {
        &self.loads
    }

    /// Number of distinct ring members.
    pub fn members(&self) -> usize {
        self.members
    }
}

/// Session-sticky wrapper policy: CHWBL first, inner policy on fallback.
///
/// Every lifecycle callback is forwarded to the inner policy unchanged —
/// its predictions stay warm for the dispatches it did not choose, so a
/// fallback decision scores against the true fleet state.
pub struct CacheAffine {
    inner: Box<dyn DispatchPolicy>,
    chwbl: Chwbl,
    sticky_hits: u64,
    sticky_fallbacks: u64,
}

impl CacheAffine {
    /// Wrap `inner` with a sticky layer over an `n`-instance fleet.
    pub fn new(cfg: CacheAffineConfig, n: usize, inner: Box<dyn DispatchPolicy>) -> CacheAffine {
        CacheAffine { inner, chwbl: Chwbl::new(cfg, n), sticky_hits: 0, sticky_fallbacks: 0 }
    }

    /// The CHWBL core (inspection in tests and audits).
    pub fn chwbl(&self) -> &Chwbl {
        &self.chwbl
    }
}

impl DispatchPolicy for CacheAffine {
    fn name(&self) -> &'static str {
        "cache-affine"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
    ) -> Option<usize> {
        let sticky = self.chwbl.pick(req.session, |j| {
            statuses
                .get(j)
                .is_some_and(|s| s.accepting && req.model_class.matches(s.model))
        });
        if let Some(j) = sticky {
            self.sticky_hits += 1;
            return Some(j);
        }
        self.sticky_fallbacks += 1;
        self.inner.choose(req, statuses, now)
    }

    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        now: Time,
    ) -> Option<usize> {
        // With `candidates` = all indices matching the request's family
        // (the contract), membership + the model check below reduce to
        // exactly `choose`'s filter, so the sticky pick is identical.
        let sticky = self.chwbl.pick(req.session, |j| {
            candidates.binary_search(&j).is_ok()
                && statuses
                    .get(j)
                    .is_some_and(|s| s.accepting && req.model_class.matches(s.model))
        });
        if let Some(j) = sticky {
            self.sticky_hits += 1;
            return Some(j);
        }
        self.sticky_fallbacks += 1;
        self.inner.choose_among(req, statuses, candidates, now)
    }

    fn supports_parallel(&self) -> bool {
        self.inner.supports_parallel()
    }

    fn score_scope(&self) -> ScoreScope {
        // Every sticky score reads the CHWBL load vector and every
        // dispatch (to any instance) mutates it, so no score survives a
        // commit regardless of the inner policy's scope.
        ScoreScope::Global
    }

    fn begin_round(&mut self, statuses: &[InstanceStatus], now: Time) {
        self.inner.begin_round(statuses, now);
    }

    fn score(
        &self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: Option<&[usize]>,
        now: Time,
    ) -> Scored {
        // `Chwbl::pick` is already a pure read; mirror both choose paths'
        // eligibility closures exactly.
        let sticky = match candidates {
            Some(c) => self.chwbl.pick(req.session, |j| {
                c.binary_search(&j).is_ok()
                    && statuses
                        .get(j)
                        .is_some_and(|s| s.accepting && req.model_class.matches(s.model))
            }),
            None => self.chwbl.pick(req.session, |j| {
                statuses
                    .get(j)
                    .is_some_and(|s| s.accepting && req.model_class.matches(s.model))
            }),
        };
        if let Some(j) = sticky {
            let detail = DispatchStats { sticky_hits: 1, ..DispatchStats::default() };
            return Scored { pick: Some(j), detail };
        }
        let mut scored = self.inner.score(req, statuses, candidates, now);
        scored.detail.sticky_fallbacks += 1;
        scored
    }

    fn commit_score(
        &mut self,
        req: &Request,
        scored: &Scored,
        statuses: &[InstanceStatus],
        now: Time,
    ) {
        if scored.detail.sticky_hits > 0 {
            // Sticky decisions never reach the inner scorer.
            self.sticky_hits += scored.detail.sticky_hits;
        } else {
            self.sticky_fallbacks += 1;
            self.inner.commit_score(req, scored, statuses, now);
        }
    }

    fn set_legacy_scoring(&mut self, legacy: bool) {
        self.inner.set_legacy_scoring(legacy);
    }

    fn state_fingerprint(&self) -> u64 {
        // The CHWBL in-flight loads are the sticky layer's mutable
        // decision state; the inner policy contributes its own digest.
        let mut h = self.inner.state_fingerprint() ^ 0xcbf2_9ce4_8422_2325;
        for &l in self.chwbl.loads() {
            h ^= l;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn stats(&self) -> DispatchStats {
        let mut s = self.inner.stats();
        // Sticky decisions never reach the inner scorer; fold them in so
        // `decisions` still counts every choose call.
        s.decisions += self.sticky_hits;
        s.sticky_hits = self.sticky_hits;
        s.sticky_fallbacks = self.sticky_fallbacks;
        s
    }

    fn on_dispatch(&mut self, req: &Request, instance: usize, now: Time) {
        self.chwbl.on_dispatch(instance);
        self.inner.on_dispatch(req, instance, now);
    }

    fn on_complete(&mut self, req: RequestId, instance: usize, now: Time) {
        self.chwbl.on_complete(instance);
        self.inner.on_complete(req, instance, now);
    }

    fn on_preemption(&mut self, instance: usize, now: Time) {
        self.inner.on_preemption(instance, now);
    }

    fn on_fleet_change(&mut self, statuses: &[InstanceStatus]) {
        // Ring membership = accepting instances; draining/tombstone slots
        // drop off and their sessions remap to ring successors. Model
        // compatibility stays a per-request check in the pick closure.
        let members: Vec<usize> = statuses
            .iter()
            .enumerate()
            .filter(|(_, s)| s.accepting)
            .map(|(j, _)| j)
            .collect();
        self.chwbl.rebuild(&members, statuses.len());
        self.inner.on_fleet_change(statuses);
    }

    fn on_instance_reset(&mut self, instance: usize) {
        self.chwbl.reset_slot(instance);
        self.inner.on_instance_reset(instance);
    }

    fn refresh(&mut self, orch: &crate::orchestrator::Orchestrator) {
        self.inner.refresh(orch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::LeastLoaded;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::orchestrator::ids::AgentId;

    fn st(id: usize) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 100,
            used_blocks: 0,
            total_blocks: 100,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: 160_000,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req(id: u64, session: u64) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: 10,
            true_output_tokens: 10,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    fn affine(n: usize) -> CacheAffine {
        CacheAffine::new(
            CacheAffineConfig::default(),
            n,
            Box::new(LeastLoaded::new()),
        )
    }

    #[test]
    fn same_session_sticks_to_one_instance() {
        let mut d = affine(4);
        let statuses: Vec<_> = (0..4).map(st).collect();
        let first = d.choose(&req(1, 77), &statuses, 0.0).unwrap();
        d.on_dispatch(&req(1, 77), first, 0.0);
        for i in 2..6 {
            let j = d.choose(&req(i, 77), &statuses, 0.0).unwrap();
            assert_eq!(j, first, "stage {i} moved off the sticky instance");
            d.on_dispatch(&req(i, 77), j, 0.0);
            d.on_complete(i, j, 0.0);
        }
        assert_eq!(d.stats().sticky_hits, 5);
        assert_eq!(d.stats().sticky_fallbacks, 0);
    }

    #[test]
    fn sessions_spread_across_the_ring() {
        let d = affine(4);
        let mut seen = std::collections::BTreeSet::new();
        for s in 0..64u64 {
            if let Some(j) = d.chwbl().pick(s, |_| true) {
                seen.insert(j);
            }
        }
        assert!(seen.len() >= 3, "64 sessions hit only {seen:?}");
    }

    #[test]
    fn saturated_sticky_target_falls_back_to_inner() {
        let mut d = affine(2);
        let statuses: Vec<_> = (0..2).map(st).collect();
        let sticky = d.choose(&req(1, 9), &statuses, 0.0).unwrap();
        // Pile in-flight load onto the sticky target without completions:
        // ceiling = ceil(1.25 * (total+1) / 2) stays below the pile.
        for i in 0..10 {
            d.on_dispatch(&req(100 + i, 9), sticky, 0.0);
        }
        let next = d.choose(&req(50, 9), &statuses, 0.0).unwrap();
        assert_ne!(next, sticky, "saturated target must be refused");
        assert!(d.stats().sticky_fallbacks >= 1);
    }

    #[test]
    fn model_pinned_request_skips_incompatible_sticky_target() {
        let mut d = affine(3);
        let mut statuses: Vec<_> = (0..3).map(st).collect();
        let mut r = req(1, 5);
        r.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        // Make only instance 1 compatible: the pick must land there no
        // matter where the session hashes.
        statuses[1].model = ModelKind::Llama2_13B;
        let j = d.choose(&r, &statuses, 0.0).unwrap();
        assert_eq!(j, 1);
    }

    #[test]
    fn choose_among_matches_full_scan() {
        let mut full = affine(4);
        let mut pruned = affine(4);
        let mut statuses: Vec<_> = (0..4).map(st).collect();
        statuses[2].model = ModelKind::Llama2_13B;
        let mut r = req(1, 123);
        r.model_class = ModelClass::Model(ModelKind::Llama3_8B);
        for s in 0..32u64 {
            r.session = s;
            let a = full.choose(&r, &statuses, 0.0);
            let b = pruned.choose_among(&r, &statuses, &[0, 1, 3], 0.0);
            assert_eq!(a, b, "session {s}");
            if let Some(j) = a {
                full.on_dispatch(&r, j, 0.0);
                pruned.on_dispatch(&r, j, 0.0);
            }
        }
        // Stale out-of-range candidates are skipped, not indexed.
        assert!(pruned.choose_among(&r, &statuses, &[9], 0.0).is_none());
    }

    #[test]
    fn draining_instance_drops_off_the_ring() {
        let mut d = affine(2);
        let mut statuses: Vec<_> = (0..2).map(st).collect();
        let sticky = d.choose(&req(1, 3), &statuses, 0.0).unwrap();
        statuses[sticky].accepting = false;
        d.on_fleet_change(&statuses);
        let other = d.choose(&req(2, 3), &statuses, 0.0).unwrap();
        assert_ne!(other, sticky);
        assert_eq!(d.chwbl().members(), 1);
    }

    #[test]
    fn reset_slot_forgets_inflight_load() {
        let mut d = affine(2);
        let statuses: Vec<_> = (0..2).map(st).collect();
        let j = d.choose(&req(1, 3), &statuses, 0.0).unwrap();
        for i in 0..5 {
            d.on_dispatch(&req(10 + i, 3), j, 0.0);
        }
        assert_eq!(d.chwbl().loads()[j], 5);
        d.on_instance_reset(j);
        assert_eq!(d.chwbl().loads()[j], 0);
        assert_eq!(d.chwbl().loads().iter().sum::<u64>(), 0);
    }

    #[test]
    fn per_pick_bound_holds_under_random_streams() {
        // Property: every accepted sticky pick satisfies
        // loads[j] + 1 <= ceil(c * (total+1) / n) at decision time, and on
        // completion-free streams no instance ever exceeds the global
        // ceiling (the fallback is least-loaded, which preserves it for
        // c >= 1).
        crate::testing::forall(
            "chwbl_bounded_load",
            150,
            0xC4B1,
            |rng| {
                let n = 1 + rng.below(6) as usize;
                let ops: Vec<u64> = (0..80).map(|_| rng.below(12)).collect();
                (n, ops)
            },
            |(n, ops)| {
                let cfg = CacheAffineConfig { load_factor: 1.25, vnodes: 16 };
                let mut c = Chwbl::new(cfg, *n);
                for &session in ops {
                    let ceil_before = c.ceiling();
                    let j = match c.pick(session, |_| true) {
                        Some(j) => {
                            if c.loads()[j] + 1 > ceil_before {
                                return Err(format!(
                                    "sticky pick {j} breaks bound: load {} ceil {}",
                                    c.loads()[j],
                                    ceil_before
                                ));
                            }
                            j
                        }
                        // Least-loaded fallback (ties to lowest index).
                        None => (0..*n)
                            .min_by_key(|&j| c.loads()[j])
                            .ok_or("empty fleet")?,
                    };
                    c.on_dispatch(j);
                    let ceiling = c.ceiling();
                    for (k, &l) in c.loads().iter().enumerate() {
                        if l > ceiling {
                            return Err(format!(
                                "instance {k} load {l} exceeds ceiling {ceiling}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
