//! Least-loaded dispatching (ablation): send each request to the instance
//! with the fewest committed KV tokens *right now*. Memory-aware but
//! temporally blind — no ramp model, no future slots. Isolates the value of
//! Kairos' time-dimension (DESIGN.md ablation benches).

use super::{DispatchPolicy, ScoreScope, Scored};
use crate::engine::core::InstanceStatus;
use crate::engine::request::Request;
use crate::Time;

#[derive(Debug, Default)]
pub struct LeastLoaded;

impl LeastLoaded {
    pub fn new() -> LeastLoaded {
        LeastLoaded
    }
}

impl DispatchPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        _now: Time,
    ) -> Option<usize> {
        statuses
            .iter()
            .enumerate()
            .filter(|(_, s)| s.accepting && req.model_class.matches(s.model))
            .min_by_key(|(_, s)| s.committed_tokens + s.n_waiting as u64 * 256)
            .map(|(i, _)| i)
    }

    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        _now: Time,
    ) -> Option<usize> {
        // Same load key over the pruned set; `min_by_key` keeps the first
        // minimal element and candidates are ascending, so ties break
        // exactly as the full scan's.
        candidates
            .iter()
            .copied()
            .filter_map(|i| statuses.get(i).map(|s| (i, s)))
            .filter(|(_, s)| s.accepting && req.model_class.matches(s.model))
            .min_by_key(|(_, s)| s.committed_tokens + s.n_waiting as u64 * 256)
            .map(|(i, _)| i)
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    fn score_scope(&self) -> ScoreScope {
        // The load key reads only the candidate's own status entry.
        ScoreScope::Slots
    }

    fn score(
        &self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: Option<&[usize]>,
        _now: Time,
    ) -> Scored {
        // Stateless policy: the pure score IS the choose body. `min_by_key`
        // keeps the first minimal element and both iteration orders are
        // ascending, so ties break exactly as the mutable paths'.
        let pick = match candidates {
            Some(c) => c
                .iter()
                .copied()
                .filter_map(|i| statuses.get(i).map(|s| (i, s)))
                .filter(|(_, s)| s.accepting && req.model_class.matches(s.model))
                .min_by_key(|(_, s)| s.committed_tokens + s.n_waiting as u64 * 256)
                .map(|(i, _)| i),
            None => statuses
                .iter()
                .enumerate()
                .filter(|(_, s)| s.accepting && req.model_class.matches(s.model))
                .min_by_key(|(_, s)| s.committed_tokens + s.n_waiting as u64 * 256)
                .map(|(i, _)| i),
        };
        Scored { pick, detail: Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::orchestrator::ids::AgentId;

    fn st(id: usize, committed: u64) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 100,
            used_blocks: 0,
            total_blocks: 100,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: committed,
            capacity_tokens: 160_000,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req() -> Request {
        Request {
            id: 0,
            msg_id: 0,
            agent: AgentId(0),
            session: 0,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: 1,
            true_output_tokens: 1,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn picks_lowest_commitment() {
        let mut d = LeastLoaded::new();
        let statuses = vec![st(0, 500), st(1, 100), st(2, 900)];
        assert_eq!(d.choose(&req(), &statuses, 0.0), Some(1));
    }

    #[test]
    fn waiting_queue_counts_as_load() {
        let mut d = LeastLoaded::new();
        let mut a = st(0, 100);
        a.n_waiting = 10;
        let statuses = vec![a, st(1, 200)];
        assert_eq!(d.choose(&req(), &statuses, 0.0), Some(1));
    }

    #[test]
    fn pinned_request_ignores_emptier_foreign_instance() {
        let mut d = LeastLoaded::new();
        // The emptiest instance serves the wrong family: skip it.
        let mut statuses = vec![st(0, 0), st(1, 900)];
        statuses[1].model = ModelKind::Llama2_13B;
        let mut r = req();
        r.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        assert_eq!(d.choose(&r, &statuses, 0.0), Some(1));
    }

    #[test]
    fn choose_among_matches_full_scan() {
        let mut d = LeastLoaded::new();
        let mut statuses = vec![st(0, 500), st(1, 100), st(2, 900), st(3, 100)];
        statuses[1].model = ModelKind::Llama2_13B;
        let mut r = req();
        r.model_class = ModelClass::Model(ModelKind::Llama3_8B);
        let full = d.choose(&r, &statuses, 0.0);
        // The matching set for the pinned family is [0, 2, 3].
        let pruned = d.choose_among(&r, &statuses, &[0, 2, 3], 0.0);
        assert_eq!(full, pruned);
        assert_eq!(pruned, Some(3));
        // Stale out-of-range candidates are skipped, not indexed.
        assert_eq!(d.choose_among(&r, &statuses, &[9, 0], 0.0), Some(0));
    }

    #[test]
    fn draining_instance_never_chosen() {
        let mut d = LeastLoaded::new();
        // The emptiest instance is draining: it must be skipped.
        let mut idle = st(0, 0);
        idle.accepting = false;
        let statuses = vec![idle, st(1, 900)];
        assert_eq!(d.choose(&req(), &statuses, 0.0), Some(1));
    }
}
