//! Request dispatching across LLM instances (paper §6 + baselines).
//!
//! * [`round_robin::RoundRobin`] — Parrot's / Ayo's baseline dispatcher.
//! * [`timeslot::TimeSlotDispatcher`] — Kairos' memory-aware time-slot
//!   packing: per-instance slot grids of predicted KV usage, linear memory
//!   ramps per request, lowest-expected-peak instance selection, adaptive
//!   slot release on early completion and OOM-suspect suspension.
//! * [`oracle_fit::OracleFit`] — best-fit with ground-truth output lengths
//!   (the "Oracle" of Fig. 9).
//! * [`least_loaded::LeastLoaded`] — ablation: committed-tokens balancing
//!   without temporal modeling.

pub mod least_loaded;
pub mod oracle_fit;
pub mod round_robin;
pub mod timeslot;

use crate::engine::core::InstanceStatus;
use crate::engine::request::{Request, RequestId};
use crate::Time;

/// Picks the target instance for each scheduled request.
pub trait DispatchPolicy: Send {
    fn name(&self) -> &'static str;

    /// Choose an instance for `req`, or `None` to keep it queued for the
    /// next scheduling round (paper §6: "if none of the instances are
    /// available, the request remains in the scheduling queue").
    ///
    /// Group-aware candidate filtering is every policy's obligation: only
    /// instances that are accepting AND whose [`InstanceStatus::model`]
    /// matches `req.model_class` are candidates. The coordinator asserts
    /// both on the chosen index.
    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
    ) -> Option<usize>;

    /// Request actually dispatched to `instance` (stateful policies commit
    /// their prediction here).
    fn on_dispatch(&mut self, _req: &Request, _instance: usize, _now: Time) {}

    /// Request finished on `instance` (release predicted future usage).
    fn on_complete(&mut self, _req: RequestId, _instance: usize, _now: Time) {}

    /// Engine reported a preemption on `instance` (OOM-suspect signal).
    fn on_preemption(&mut self, _instance: usize, _now: Time) {}

    /// The fleet was resized (an instance registered live or began
    /// retiring). `statuses` is the new full per-instance snapshot —
    /// instance indices are stable (retired slots stay as non-accepting
    /// tombstones), so stateful policies must grow (or truncate) their
    /// instance-indexed state to `statuses.len()` here instead of panicking
    /// or mis-indexing on the next [`DispatchPolicy::choose`].
    fn on_fleet_change(&mut self, _statuses: &[InstanceStatus]) {}

    /// Instance slot `instance` was re-initialized in place: a retired
    /// tombstone re-filled with a fresh engine
    /// ([`crate::server::coordinator::Coordinator::add_instance`] reuses
    /// compatible tombstone slots instead of growing the fleet vector).
    /// Stateful policies must clear every per-instance datum for the slot —
    /// slot-ring predictions, suspensions, outstanding demand — as if it
    /// were brand new.
    fn on_instance_reset(&mut self, _instance: usize) {}

    /// Refresh internal state from the orchestrator's profiles (Kairos
    /// pulls each agent's expected execution time — the distribution mode —
    /// here; baselines ignore it).
    fn refresh(&mut self, _orch: &crate::orchestrator::Orchestrator) {}
}

pub use least_loaded::LeastLoaded;
pub use oracle_fit::OracleFit;
pub use round_robin::RoundRobin;
pub use timeslot::{TimeSlotConfig, TimeSlotDispatcher};
