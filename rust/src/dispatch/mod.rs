//! Request dispatching across LLM instances (paper §6 + baselines).
//!
//! * [`round_robin::RoundRobin`] — Parrot's / Ayo's baseline dispatcher.
//! * [`timeslot::TimeSlotDispatcher`] — Kairos' memory-aware time-slot
//!   packing: per-instance slot grids of predicted KV usage, linear memory
//!   ramps per request, lowest-expected-peak instance selection, adaptive
//!   slot release on early completion and OOM-suspect suspension.
//! * [`oracle_fit::OracleFit`] — best-fit with ground-truth output lengths
//!   (the "Oracle" of Fig. 9).
//! * [`least_loaded::LeastLoaded`] — ablation: committed-tokens balancing
//!   without temporal modeling.
//! * [`cache_affine::CacheAffine`] — session-sticky layer over any inner
//!   policy: consistent hashing with bounded loads (CHWBL) routes a
//!   session's stages to one instance so its KV prefix cache hits, falling
//!   back to the inner scorer when the sticky target is saturated.

pub mod cache_affine;
pub mod least_loaded;
pub mod oracle_fit;
pub mod round_robin;
pub mod timeslot;

use crate::engine::core::InstanceStatus;
use crate::engine::request::{Request, RequestId};
use crate::Time;

/// Streaming decision counters a dispatcher accumulates over its lifetime.
///
/// All counters are monotone; deltas between two snapshots describe an
/// interval. The bench summary and `kairos check` print them, and
/// [`crate::metrics::StreamingMetrics`] carries the latest snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DispatchStats {
    /// Scheduling decisions taken (one per [`DispatchPolicy::choose`] /
    /// [`DispatchPolicy::choose_among`] call on policies that track stats).
    pub decisions: u64,
    /// Candidate instances offered across all decisions (fleet size for
    /// full scans, pruned-set size for `choose_among`).
    pub candidates: u64,
    /// Candidates that survived the cheap filters (accepting, family,
    /// cooldown, live budget) and were actually scored.
    pub evaluated: u64,
    /// Scored candidates settled by the O(log H) fast-accept band (peak
    /// taken from the maintained tree root, no per-slot scan).
    pub fast_accepted: u64,
    /// Scored candidates settled by the O(log H) fast-reject band.
    pub fast_rejected: u64,
    /// Decisions in which no instance was feasible and the request stayed
    /// queued for the next round.
    pub rejected_rounds: u64,
    /// OOM-suspect preemption events that triggered a cooldown suspension.
    pub suspensions: u64,
    /// Session-sticky picks accepted by the cache-affine layer (the CHWBL
    /// ring target was eligible and under its bounded-load ceiling).
    pub sticky_hits: u64,
    /// Session-sticky picks refused (overloaded, non-accepting, or
    /// model-incompatible ring target) that fell back to the inner scorer.
    pub sticky_fallbacks: u64,
    /// Parallel pump only: cached scores invalidated because an earlier
    /// commit mutated an instance slot the score had read (optimistic
    /// concurrency conflicts detected on the per-slot version counters).
    pub conflicts: u64,
    /// Parallel pump only: heads whose stale score was recomputed after a
    /// conflict. Always ≤ `conflicts` + the number of scoring rounds.
    pub rescored: u64,
    /// Parallel pump only: scoring rounds fanned out to the scoped worker
    /// pool (zero on the sequential arm).
    pub par_rounds: u64,
}

/// What instance state a policy's pure [`DispatchPolicy::score`] reads —
/// the parallel pump's conflict-detection granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreScope {
    /// The score depends only on per-instance state of the candidate slots
    /// it was offered (plus immutable config): a commit to instance `j`
    /// invalidates only cached scores whose candidate set contains `j`,
    /// so cross-family scores survive and commit without re-scoring.
    Slots,
    /// The score reads policy-global mutable state (a rotation cursor,
    /// CHWBL loads, a session-prefix expectation): every commit
    /// invalidates every cached score.
    Global,
}

/// A pure scoring outcome: the pick [`DispatchPolicy::choose_among`] would
/// have made, plus the [`DispatchStats`] delta it would have folded into
/// the policy's counters. The delta is applied only when the score is
/// actually used ([`DispatchPolicy::commit_score`]) — discarded scores
/// (e.g. for requests the coordinator drops before consulting the
/// dispatcher) leave the counters exactly as the sequential arm would.
#[derive(Debug, Clone, Default)]
pub struct Scored {
    /// The instance the policy would place the request on, or `None` to
    /// keep it queued for the next round.
    pub pick: Option<usize>,
    /// Counter delta of this one decision (not yet folded into
    /// [`DispatchPolicy::stats`]).
    pub detail: DispatchStats,
}

/// Picks the target instance for each scheduled request.
///
/// `Sync` is part of the contract because the parallel pump scores heads
/// concurrently through shared references ([`DispatchPolicy::score`] takes
/// `&self`); every policy in the tree holds only owned containers and
/// scalars, so the bound is automatic.
pub trait DispatchPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Choose an instance for `req`, or `None` to keep it queued for the
    /// next scheduling round (paper §6: "if none of the instances are
    /// available, the request remains in the scheduling queue").
    ///
    /// Group-aware candidate filtering is every policy's obligation: only
    /// instances that are accepting AND whose [`InstanceStatus::model`]
    /// matches `req.model_class` are candidates. The coordinator asserts
    /// both on the chosen index.
    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
    ) -> Option<usize>;

    /// Candidate-set-aware variant of [`DispatchPolicy::choose`]: the
    /// caller has already pruned the fleet to `candidates` (ascending
    /// instance indices — the coordinator passes its `FamilyIndex` slot set
    /// for the request's pinned family), so the policy may skip its own
    /// family filter and scan only those instances.
    ///
    /// Contract: with `candidates` equal to the indices of all instances
    /// matching `req.model_class`, the decision must equal
    /// [`DispatchPolicy::choose`] on the full fleet — pruning is a pure
    /// optimization and must never change a pick (the seam tests assert
    /// this through the driver). `statuses` is still the FULL fleet
    /// snapshot, indexed by instance; entries of `candidates` beyond
    /// `statuses.len()` (a stale set across a fleet shrink) are skipped.
    /// The default implementation ignores the pruning and full-scans.
    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        now: Time,
    ) -> Option<usize> {
        let _ = candidates;
        self.choose(req, statuses, now)
    }

    /// Whether the policy implements the pure [`DispatchPolicy::score`] /
    /// [`DispatchPolicy::commit_score`] split faithfully enough for the
    /// coordinator's parallel pump. `false` (the default) makes the
    /// coordinator fall back to the sequential pump regardless of its
    /// thread setting, so a policy without the split can never diverge.
    fn supports_parallel(&self) -> bool {
        false
    }

    /// Conflict-detection granularity of [`DispatchPolicy::score`] (see
    /// [`ScoreScope`]). Only consulted when
    /// [`DispatchPolicy::supports_parallel`] is true. Defaults to the
    /// always-safe [`ScoreScope::Global`].
    fn score_scope(&self) -> ScoreScope {
        ScoreScope::Global
    }

    /// Hoisted per-pump mutations of the scoring path, called once by the
    /// parallel pump before its first scoring round (at the same `now`
    /// every score of the pump will see): defensive instance-state
    /// resizing, ring-window advancement — anything
    /// [`DispatchPolicy::choose_among`] does to `&mut self` that is
    /// idempotent at fixed `now` and independent of the request. After
    /// this call, [`DispatchPolicy::score`] at the same `now` must equal
    /// [`DispatchPolicy::choose_among`]'s decision bit-for-bit.
    fn begin_round(&mut self, _statuses: &[InstanceStatus], _now: Time) {}

    /// Pure-read scoring: the decision [`DispatchPolicy::choose_among`]
    /// (`candidates = Some`) or [`DispatchPolicy::choose`] (`None`) would
    /// make, without mutating the policy. Requires a prior
    /// [`DispatchPolicy::begin_round`] at the same `now`. The returned
    /// [`Scored::detail`] carries this decision's counter delta; it is
    /// folded only via [`DispatchPolicy::commit_score`]. The default is a
    /// refusal (`pick: None`, zero detail) — correct only for policies
    /// that also leave [`DispatchPolicy::supports_parallel`] false, since
    /// the coordinator never scores through such a policy.
    fn score(
        &self,
        _req: &Request,
        _statuses: &[InstanceStatus],
        _candidates: Option<&[usize]>,
        _now: Time,
    ) -> Scored {
        Scored::default()
    }

    /// Fold a used score into the policy's mutable state, exactly as the
    /// [`DispatchPolicy::choose_among`] call that produced the same
    /// decision would have: bump the stats counters by [`Scored::detail`]
    /// and apply any decision-coupled state change (a rotation cursor
    /// advance, a sticky-hit tally). Engine-side bookkeeping still flows
    /// through [`DispatchPolicy::on_dispatch`] afterwards, unchanged.
    fn commit_score(
        &mut self,
        _req: &Request,
        _scored: &Scored,
        _statuses: &[InstanceStatus],
        _now: Time,
    ) {
    }

    /// A deterministic digest of the policy's mutable decision state —
    /// ring windows, cursors, per-instance demand — independent of how the
    /// state was reached (rotation-invariant where the representation is).
    /// The parallel-pump equivalence tests assert it bit-identical across
    /// thread counts next to the decision logs: equal logs with unequal
    /// internal state would still diverge on FUTURE decisions, and this
    /// surface catches that. Stateless policies return the 0 default.
    fn state_fingerprint(&self) -> u64 {
        0
    }

    /// A/B switch for the scoring arms (same pattern as the coordinator's
    /// `set_legacy_hot_path`): `true` scores candidates with the naive
    /// reference path, `false` (the default) with the optimized one. Both
    /// arms must make identical decisions — the `pack` bench stage asserts
    /// it. Policies without a dual path ignore the switch.
    fn set_legacy_scoring(&mut self, _legacy: bool) {}

    /// Snapshot of the policy's streaming decision counters. Policies that
    /// do not track stats return the zero default.
    fn stats(&self) -> DispatchStats {
        DispatchStats::default()
    }

    /// Request actually dispatched to `instance` (stateful policies commit
    /// their prediction here).
    fn on_dispatch(&mut self, _req: &Request, _instance: usize, _now: Time) {}

    /// Request finished on `instance` (release predicted future usage).
    fn on_complete(&mut self, _req: RequestId, _instance: usize, _now: Time) {}

    /// Engine reported a preemption on `instance` (OOM-suspect signal).
    fn on_preemption(&mut self, _instance: usize, _now: Time) {}

    /// The fleet was resized (an instance registered live or began
    /// retiring). `statuses` is the new full per-instance snapshot —
    /// instance indices are stable (retired slots stay as non-accepting
    /// tombstones), so stateful policies must grow (or truncate) their
    /// instance-indexed state to `statuses.len()` here instead of panicking
    /// or mis-indexing on the next [`DispatchPolicy::choose`].
    fn on_fleet_change(&mut self, _statuses: &[InstanceStatus]) {}

    /// Instance slot `instance` was re-initialized in place: a retired
    /// tombstone re-filled with a fresh engine
    /// ([`crate::server::coordinator::Coordinator::add_instance`] reuses
    /// compatible tombstone slots instead of growing the fleet vector).
    /// Stateful policies must clear every per-instance datum for the slot —
    /// slot-ring predictions, suspensions, outstanding demand — as if it
    /// were brand new.
    fn on_instance_reset(&mut self, _instance: usize) {}

    /// Refresh internal state from the orchestrator's profiles (Kairos
    /// pulls each agent's expected execution time — the distribution mode —
    /// here; baselines ignore it).
    fn refresh(&mut self, _orch: &crate::orchestrator::Orchestrator) {}
}

pub use cache_affine::{CacheAffine, CacheAffineConfig, Chwbl};
pub use least_loaded::LeastLoaded;
pub use oracle_fit::OracleFit;
pub use round_robin::RoundRobin;
pub use timeslot::{TimeSlotConfig, TimeSlotDispatcher};
