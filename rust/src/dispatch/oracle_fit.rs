//! Oracle dispatching (paper Fig. 9): knows each request's TRUE output
//! length, hence its true peak KV demand, and places it on the instance
//! whose expected peak stays lowest — the upper bound the time-slot
//! dispatcher approximates without ground truth.

use std::collections::HashMap;

use super::{DispatchPolicy, ScoreScope, Scored};
use crate::engine::core::InstanceStatus;
use crate::engine::request::{Request, RequestId};
use crate::Time;

#[derive(Debug, Default)]
pub struct OracleFit {
    /// instance -> outstanding true token demand of dispatched requests.
    outstanding: Vec<u64>,
    /// request -> (instance, tokens), to release on completion.
    placed: HashMap<RequestId, (usize, u64)>,
}

impl OracleFit {
    pub fn new(n_instances: usize) -> OracleFit {
        OracleFit { outstanding: vec![0; n_instances], placed: HashMap::new() }
    }
}

impl DispatchPolicy for OracleFit {
    fn name(&self) -> &'static str {
        "oracle-fit"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        _now: Time,
    ) -> Option<usize> {
        if self.outstanding.len() != statuses.len() {
            self.outstanding.resize(statuses.len(), 0);
        }
        let demand = req.total_tokens() as u64;
        // Feasible instances: accepting dispatches, serving the request's
        // model family, with the true peak (outstanding + demand) within
        // capacity. Choose the one with the smallest resulting peak.
        statuses
            .iter()
            .enumerate()
            .filter(|(i, s)| {
                s.accepting
                    && req.model_class.matches(s.model)
                    && self.outstanding[*i] + demand <= s.capacity_tokens
            })
            .min_by_key(|(i, _)| self.outstanding[*i] + demand)
            .map(|(i, _)| i)
    }

    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        _now: Time,
    ) -> Option<usize> {
        if self.outstanding.len() != statuses.len() {
            self.outstanding.resize(statuses.len(), 0);
        }
        let demand = req.total_tokens() as u64;
        // Same feasibility filter and peak key over the pruned set;
        // `min_by_key` keeps the first minimal element and candidates are
        // ascending, so ties break exactly as the full scan's.
        candidates
            .iter()
            .copied()
            .filter_map(|i| statuses.get(i).map(|s| (i, s)))
            .filter(|(i, s)| {
                s.accepting
                    && req.model_class.matches(s.model)
                    && self.outstanding[*i] + demand <= s.capacity_tokens
            })
            .min_by_key(|(i, _)| self.outstanding[*i] + demand)
            .map(|(i, _)| i)
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    fn score_scope(&self) -> ScoreScope {
        // Feasibility and the peak key read only `outstanding[candidate]`
        // and the candidate's own status; a commit to instance j mutates
        // only `outstanding[j]` (via on_dispatch).
        ScoreScope::Slots
    }

    fn begin_round(&mut self, statuses: &[InstanceStatus], _now: Time) {
        // Hoist the defensive resize the choose paths perform, so `score`
        // can stay a pure read.
        if self.outstanding.len() != statuses.len() {
            self.outstanding.resize(statuses.len(), 0);
        }
    }

    fn score(
        &self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: Option<&[usize]>,
        _now: Time,
    ) -> Scored {
        let demand = req.total_tokens() as u64;
        let load = |i: usize| self.outstanding.get(i).copied().unwrap_or(0);
        let feasible = |i: &usize, s: &&InstanceStatus| {
            s.accepting
                && req.model_class.matches(s.model)
                && load(*i) + demand <= s.capacity_tokens
        };
        let pick = match candidates {
            Some(c) => c
                .iter()
                .copied()
                .filter_map(|i| statuses.get(i).map(|s| (i, s)))
                .filter(|(i, s)| feasible(i, s))
                .min_by_key(|(i, _)| load(*i) + demand)
                .map(|(i, _)| i),
            None => statuses
                .iter()
                .enumerate()
                .filter(|(i, s)| feasible(i, s))
                .min_by_key(|(i, _)| load(*i) + demand)
                .map(|(i, _)| i),
        };
        Scored { pick, detail: Default::default() }
    }

    fn state_fingerprint(&self) -> u64 {
        // FNV-1a over the per-instance outstanding demand — the only state
        // the scoring reads. (`placed` is derived from the same dispatch
        // sequence, so equal logs imply it is equal too.)
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &o in &self.outstanding {
            h ^= o;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn on_dispatch(&mut self, req: &Request, instance: usize, _now: Time) {
        let demand = req.total_tokens() as u64;
        if instance >= self.outstanding.len() {
            self.outstanding.resize(instance + 1, 0);
        }
        self.outstanding[instance] += demand;
        self.placed.insert(req.id, (instance, demand));
    }

    fn on_complete(&mut self, req: RequestId, _instance: usize, _now: Time) {
        if let Some((inst, demand)) = self.placed.remove(&req) {
            if inst < self.outstanding.len() {
                self.outstanding[inst] = self.outstanding[inst].saturating_sub(demand);
            }
        }
    }

    fn on_fleet_change(&mut self, statuses: &[InstanceStatus]) {
        // Indices are stable (retired slots become tombstones), so growing
        // with zeroed demand is always safe; truncation drops tombstone
        // tails along with their placements.
        let n = statuses.len();
        if self.outstanding.len() < n {
            self.outstanding.resize(n, 0);
        } else if self.outstanding.len() > n {
            self.outstanding.truncate(n);
            self.placed.retain(|_, (inst, _)| *inst < n);
        }
    }

    fn on_instance_reset(&mut self, instance: usize) {
        // The slot holds a fresh engine: none of the demand tracked for the
        // retired tenant applies anymore.
        if instance < self.outstanding.len() {
            self.outstanding[instance] = 0;
        }
        self.placed.retain(|_, (inst, _)| *inst != instance);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::orchestrator::ids::AgentId;

    fn st(id: usize, capacity: u64) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 0,
            used_blocks: 0,
            total_blocks: 1,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: capacity,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req(id: u64, prompt: u32, output: u32) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session: id,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: prompt,
            true_output_tokens: output,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn balances_true_demand() {
        let mut d = OracleFit::new(2);
        let statuses = vec![st(0, 1000), st(1, 1000)];
        let r1 = req(1, 100, 400); // 500 tokens
        let i1 = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i1, 0.0);
        let r2 = req(2, 100, 100); // 200 tokens -> other instance
        let i2 = d.choose(&r2, &statuses, 0.0).unwrap();
        assert_ne!(i1, i2);
    }

    #[test]
    fn refuses_when_nothing_fits() {
        let mut d = OracleFit::new(1);
        let statuses = vec![st(0, 100)];
        let r = req(1, 100, 400);
        assert_eq!(d.choose(&r, &statuses, 0.0), None, "stays queued");
    }

    #[test]
    fn completion_releases_demand() {
        let mut d = OracleFit::new(1);
        let statuses = vec![st(0, 600)];
        let r1 = req(1, 100, 400);
        let i = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i, 0.0);
        // 500/600 used; a 200-token request cannot fit.
        assert_eq!(d.choose(&req(2, 100, 100), &statuses, 0.0), None);
        d.on_complete(1, 0, 1.0);
        assert_eq!(d.choose(&req(2, 100, 100), &statuses, 0.0), Some(0));
    }

    #[test]
    fn pinned_request_only_fits_its_family() {
        let mut d = OracleFit::new(2);
        let mut statuses = vec![st(0, 1000), st(1, 1000)];
        statuses[1].model = ModelKind::Llama2_13B;
        let mut r = req(1, 100, 100);
        r.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        assert_eq!(d.choose(&r, &statuses, 0.0), Some(1));
        // Load up the 13B instance near capacity: the pinned request now
        // defers instead of spilling onto the 8B instance.
        d.on_dispatch(&req(2, 400, 500), 1, 0.0);
        let mut big = req(3, 100, 100);
        big.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        assert_eq!(d.choose(&big, &statuses, 0.0), None, "stays queued");
    }

    #[test]
    fn choose_among_matches_full_scan() {
        let mut d = OracleFit::new(3);
        let mut statuses = vec![st(0, 1000), st(1, 1000), st(2, 1000)];
        statuses[1].model = ModelKind::Llama2_13B;
        d.on_dispatch(&req(1, 100, 400), 0, 0.0);
        let mut r = req(2, 100, 100);
        r.model_class = ModelClass::Model(ModelKind::Llama3_8B);
        let full = d.choose(&r, &statuses, 0.0);
        let pruned = d.choose_among(&r, &statuses, &[0, 2], 0.0);
        assert_eq!(full, pruned);
        assert_eq!(pruned, Some(2));
        // Stale out-of-range candidates are skipped, not indexed.
        assert_eq!(d.choose_among(&r, &statuses, &[9, 2], 0.0), Some(2));
    }

    #[test]
    fn instance_reset_clears_slot_demand() {
        let mut d = OracleFit::new(2);
        let statuses = vec![st(0, 600), st(1, 600)];
        d.on_dispatch(&req(1, 100, 400), 0, 0.0);
        assert_eq!(d.choose(&req(2, 100, 100), &statuses, 0.0), Some(1));
        // Slot 0 is re-filled with a fresh engine: its demand vanishes and
        // a late completion of the old tenant must not underflow.
        d.on_instance_reset(0);
        assert_eq!(d.choose(&req(2, 100, 100), &statuses, 0.0), Some(0));
        d.on_complete(1, 0, 1.0);
        assert_eq!(d.outstanding[0], 0, "stale completion is a no-op");
    }

    #[test]
    fn fleet_change_resizes_and_draining_excluded() {
        let mut d = OracleFit::new(1);
        let mut statuses = vec![st(0, 1000), st(1, 1000), st(2, 1000)];
        d.on_fleet_change(&statuses);
        assert_eq!(d.outstanding.len(), 3);
        // Load instance 0, then start draining instance 1: despite being
        // empty it must never be chosen.
        let r1 = req(1, 100, 400);
        d.on_dispatch(&r1, 0, 0.0);
        statuses[1].accepting = false;
        let pick = d.choose(&req(2, 10, 10), &statuses, 0.0).unwrap();
        assert_eq!(pick, 2, "draining instance chosen over an active one");
    }
}
