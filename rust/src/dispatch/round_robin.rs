//! Round-Robin dispatching — the baseline both Parrot and Ayo use
//! (paper §2.2.3): blind to memory demand and instance state.

use super::{DispatchPolicy, Scored};
use crate::engine::core::InstanceStatus;
use crate::engine::request::Request;
use crate::Time;

/// Cycles through instances in order of arrival.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        _now: Time,
    ) -> Option<usize> {
        let n = statuses.len();
        if n == 0 {
            return None;
        }
        // Blind to load, but never to fleet membership: skip instances that
        // are draining toward retirement (or retired tombstones) and
        // instances whose model family the request is not pinned to.
        for k in 0..n {
            let pick = (self.next + k) % n;
            let s = &statuses[pick];
            if s.accepting && req.model_class.matches(s.model) {
                self.next = (pick + 1) % n;
                return Some(pick);
            }
        }
        None
    }

    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        _now: Time,
    ) -> Option<usize> {
        let n = statuses.len();
        if n == 0 {
            return None;
        }
        // The full scan picks the eligible instance with the smallest
        // cyclic distance from the cursor; minimize the same rank over the
        // pruned set (first-wins on ties, candidates are ascending).
        let mut best: Option<(usize, usize)> = None; // (rank, instance)
        for &j in candidates {
            if j >= n {
                continue;
            }
            let s = &statuses[j];
            if !(s.accepting && req.model_class.matches(s.model)) {
                continue;
            }
            let rank = (j + n - self.next % n) % n;
            if best.map(|(r, _)| rank < r).unwrap_or(true) {
                best = Some((rank, j));
            }
        }
        let (_, pick) = best?;
        self.next = (pick + 1) % n;
        Some(pick)
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    // score_scope stays the default Global: every score reads the cursor
    // and every committed pick advances it, so a commit invalidates all
    // outstanding scores. The parallel pump then re-scores — cheap here —
    // and stays bit-identical to the rotation.

    fn score(
        &self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: Option<&[usize]>,
        _now: Time,
    ) -> Scored {
        let n = statuses.len();
        let mut best: Option<(usize, usize)> = None; // (rank, instance)
        if n > 0 {
            // The full scan takes the first eligible instance in cyclic
            // order from the cursor — exactly the minimal cyclic rank, so
            // one rank-minimization mirrors both choose paths (ranks are
            // distinct per instance; candidate order cannot matter).
            let upper = candidates.map_or(n, <[usize]>::len);
            for k in 0..upper {
                let j = match candidates {
                    Some(c) => c[k],
                    None => k,
                };
                if j >= n {
                    continue;
                }
                let s = &statuses[j];
                if !(s.accepting && req.model_class.matches(s.model)) {
                    continue;
                }
                let rank = (j + n - self.next % n) % n;
                if best.map(|(r, _)| rank < r).unwrap_or(true) {
                    best = Some((rank, j));
                }
            }
        }
        Scored { pick: best.map(|(_, j)| j), detail: Default::default() }
    }

    fn state_fingerprint(&self) -> u64 {
        // The cursor IS the mutable decision state.
        self.next as u64
    }

    fn commit_score(
        &mut self,
        _req: &Request,
        scored: &Scored,
        statuses: &[InstanceStatus],
        _now: Time,
    ) {
        // The decision-coupled mutation of both choose paths: advance the
        // cursor past the pick. A refusal leaves the cursor untouched.
        if let Some(pick) = scored.pick {
            let n = statuses.len();
            if n > 0 {
                self.next = (pick + 1) % n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::orchestrator::ids::AgentId;

    fn st(id: usize) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 100,
            used_blocks: 0,
            total_blocks: 100,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: 1600,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req() -> Request {
        Request {
            id: 0,
            msg_id: 0,
            agent: AgentId(0),
            session: 0,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: 1,
            true_output_tokens: 1,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn cycles_through_instances() {
        let mut rr = RoundRobin::new();
        let statuses = vec![st(0), st(1), st(2)];
        let picks: Vec<usize> = (0..6)
            .map(|_| rr.choose(&req(), &statuses, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn ignores_load_entirely() {
        // The defining (mis)behaviour: a saturated instance still gets work.
        let mut rr = RoundRobin::new();
        let mut busy = st(0);
        busy.free_blocks = 0;
        busy.used_blocks = 100;
        busy.committed_tokens = 1600;
        let statuses = vec![busy, st(1)];
        assert_eq!(rr.choose(&req(), &statuses, 0.0), Some(0));
    }

    #[test]
    fn empty_cluster_returns_none() {
        let mut rr = RoundRobin::new();
        assert_eq!(rr.choose(&req(), &[], 0.0), None);
    }

    #[test]
    fn skips_draining_instances_and_still_cycles() {
        let mut rr = RoundRobin::new();
        let mut statuses = vec![st(0), st(1), st(2)];
        statuses[1].accepting = false;
        let picks: Vec<usize> = (0..4)
            .map(|_| rr.choose(&req(), &statuses, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        // All draining: nothing to pick, request stays queued.
        statuses[0].accepting = false;
        statuses[2].accepting = false;
        assert_eq!(rr.choose(&req(), &statuses, 0.0), None);
    }

    #[test]
    fn pinned_request_only_cycles_its_own_family() {
        let mut rr = RoundRobin::new();
        let mut statuses = vec![st(0), st(1), st(2)];
        statuses[1].model = ModelKind::Llama2_13B;
        let mut pinned = req();
        pinned.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        // Every pick for the pinned request lands on the lone 13B instance.
        for _ in 0..3 {
            assert_eq!(rr.choose(&pinned, &statuses, 0.0), Some(1));
        }
        // A request pinned to a family with no instance defers.
        let mut orphan = req();
        orphan.model_class = ModelClass::Model(ModelKind::Tiny);
        assert_eq!(rr.choose(&orphan, &statuses, 0.0), None);
    }

    #[test]
    fn choose_among_preserves_the_rotation() {
        // Two cursors, one fed the full scan and one the pruned set the
        // coordinator would pass (every matching index): the pick sequence
        // must be identical, including cursor evolution across picks.
        let mut full = RoundRobin::new();
        let mut pruned = RoundRobin::new();
        let mut statuses = vec![st(0), st(1), st(2), st(3)];
        statuses[2].accepting = false;
        let all: Vec<usize> = (0..statuses.len()).collect();
        for _ in 0..8 {
            let a = full.choose(&req(), &statuses, 0.0);
            let b = pruned.choose_among(&req(), &statuses, &all, 0.0);
            assert_eq!(a, b);
        }
        // Out-of-range candidates are skipped; empty fleet stays None.
        assert_eq!(pruned.choose_among(&req(), &statuses, &[9], 0.0), None);
        assert_eq!(pruned.choose_among(&req(), &[], &[0], 0.0), None);
    }

    #[test]
    fn fleet_growth_brings_new_instance_into_rotation() {
        let mut rr = RoundRobin::new();
        let two = vec![st(0), st(1)];
        assert_eq!(rr.choose(&req(), &two, 0.0), Some(0));
        let three = vec![st(0), st(1), st(2)];
        rr.on_fleet_change(&three);
        let picks: Vec<usize> = (0..3)
            .map(|_| rr.choose(&req(), &three, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![1, 2, 0]);
    }
}
