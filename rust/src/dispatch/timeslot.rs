//! Kairos' memory-aware time-slot dispatcher (paper §6).
//!
//! Each request's KV usage is modelled as a linear ramp (Eq. 1):
//!
//! ```text
//! f_i(t) = P_i + k · (t − t_start)   for t in [t_start, t_end), else 0
//! ```
//!
//! with `P_i` the prompt (prefill) KV bytes — computable online from the
//! prompt length — `k` the memory ramp slope from prior hardware profiling,
//! and `t_end = t_start + T_i` where `T_i` is the **mode** of the agent's
//! single-request execution-latency distribution.
//!
//! The future timeline is discretized into fixed 0.5 s slots; per instance a
//! ring of slots accumulates `F_j(t) = Σ f_i(t)` (Eq. 3). A request may go
//! to instance `j` only if no spanned slot would exceed capacity; among the
//! available instances the one with the lowest expected **total peak**
//! memory wins. Adaptive measures: slots are released early when a request
//! finishes before its prediction, and an instance that reports a
//! preemption (OOM-suspect) is suspended for a cooldown.

use std::collections::HashMap;

use super::DispatchPolicy;
use crate::engine::core::InstanceStatus;
use crate::engine::cost_model::{CostModel, ModelKind};
use crate::engine::request::{Request, RequestId};
use crate::Time;

/// Tuning parameters of the time-slot packer.
#[derive(Debug, Clone, Copy)]
pub struct TimeSlotConfig {
    /// Slot length in seconds (paper: 0.5 s is the empirical sweet spot).
    pub slot_len: f64,
    /// Horizon in slots (predictions beyond it are clamped to the last slot).
    pub horizon_slots: usize,
    /// KV bytes per token (from the model's cost calibration).
    pub kv_bytes_per_token: f64,
    /// Memory ramp slope `k` in bytes/second (decode rate × bytes/token).
    pub mem_slope: f64,
    /// Fallback KV capacity in bytes, used only when an instance's live
    /// status is unavailable. On every decision the packer reads each
    /// instance's real budget from [`InstanceStatus::capacity_tokens`], so
    /// heterogeneous fleets (mixed GPUs, uneven co-tenant pressure) are
    /// packed against their actual per-instance capacities.
    pub capacity_bytes: f64,
    /// Fallback expected execution time before profiles exist (s).
    pub default_exec_time: f64,
    /// Safety factor on expected execution times: the mode of a
    /// heavy-tailed latency distribution under-estimates the tail, so
    /// packing with the raw mode over-commits; >1 compensates (the paper's
    /// "estimation errors" margin, §6).
    pub safety: f64,
    /// OOM-suspect suspension cooldown (s).
    pub suspend_cooldown: f64,
    /// Demand-prediction hook of the routing layer: when true, the
    /// feasibility check prices each request's lifetime KV demand from the
    /// profiler's learned per-agent demand distribution (mode of observed
    /// prompt + generated tokens, refreshed via
    /// [`DispatchPolicy::refresh`]) instead of the slope-based guess.
    /// Off by default — enabled alongside learned routing.
    pub learned_demand: bool,
}

impl TimeSlotConfig {
    pub fn slots_spanned(&self, duration: f64) -> usize {
        ((duration / self.slot_len).ceil() as usize).clamp(1, self.horizon_slots)
    }
}

/// A committed prediction for one dispatched request.
#[derive(Debug, Clone)]
struct Placement {
    instance: usize,
    start: Time,
    end: Time,
    prefill_bytes: f64,
    /// Ramp slope charged at dispatch time (the instance's own slope; the
    /// release must subtract exactly what was added).
    mem_slope: f64,
    /// Ring window `[base, last]` at dispatch time. Out-of-window
    /// contributions were folded into this range by [`SlotRing::fold`];
    /// the release must recompute placement against the SAME fold rule, or
    /// (once the ring base advances) the negative release lands in a
    /// different absolute slot than the positive add and phantom KV load
    /// accumulates in the last slot, starving dispatch.
    fold_base: i64,
    fold_limit: i64,
}

/// Per-instance ramp constants from the instance's OWN cost model —
/// per-instance cost awareness: a 13B co-tenant decodes slower and holds
/// denser KV than an 8B neighbor, so both its prefill footprint and its
/// ramp slope differ from the fleet's reference model.
#[derive(Debug, Clone, Copy)]
struct InstanceCost {
    kv_bytes_per_token: f64,
    mem_slope: f64,
}

impl InstanceCost {
    /// Fallback constants from the packer config (the fleet reference
    /// model) — used by [`TimeSlotDispatcher::new`] and in tests.
    fn from_config(cfg: &TimeSlotConfig) -> InstanceCost {
        InstanceCost { kv_bytes_per_token: cfg.kv_bytes_per_token, mem_slope: cfg.mem_slope }
    }

    /// Constants for an instance serving `model`, profiled at the same
    /// representative operating point as
    /// [`TimeSlotConfig::for_cost_model`].
    fn for_model(model: ModelKind) -> InstanceCost {
        let cost = CostModel::new(model);
        InstanceCost {
            kv_bytes_per_token: cost.kv_bytes_per_token as f64,
            mem_slope: cost.mem_slope(16, 600) / 16.0,
        }
    }
}

/// Per-instance future memory profile as a slot ring.
#[derive(Debug, Clone)]
struct SlotRing {
    /// Absolute index of `slots[cursor]`; slot s covers
    /// [s·slot_len, (s+1)·slot_len).
    base_slot: i64,
    cursor: usize,
    slots: Vec<f64>,
}

impl SlotRing {
    fn new(horizon: usize) -> SlotRing {
        SlotRing { base_slot: 0, cursor: 0, slots: vec![0.0; horizon] }
    }

    fn idx(&self, abs_slot: i64) -> Option<usize> {
        let off = abs_slot - self.base_slot;
        if off < 0 || off >= self.slots.len() as i64 {
            None
        } else {
            Some((self.cursor + off as usize) % self.slots.len())
        }
    }

    /// Absolute index of the last live slot.
    fn horizon_end(&self) -> i64 {
        self.base_slot + self.slots.len() as i64 - 1
    }

    /// The fold rule for out-of-window predictions: past slots charge the
    /// current base, beyond-horizon slots fold into the last slot
    /// (conservative). Adds and releases must both go through this rule so
    /// a prediction is released from the exact slot it was charged to.
    fn fold(&self, abs_slot: i64) -> i64 {
        abs_slot.max(self.base_slot).min(self.horizon_end())
    }

    /// Advance the ring so `abs_slot` becomes the base; expired slots reset.
    /// Cost is bounded by the ring length: a gap of one idle hour (~7200
    /// slots at 0.5 s) must not spin per-slot — once the gap covers the
    /// whole window, every live slot has expired and the base jumps.
    fn advance_to(&mut self, abs_slot: i64) {
        if abs_slot <= self.base_slot {
            return;
        }
        let gap = abs_slot - self.base_slot;
        if gap >= self.slots.len() as i64 {
            self.slots.fill(0.0);
            self.cursor = 0;
            self.base_slot = abs_slot;
            return;
        }
        for _ in 0..gap {
            self.slots[self.cursor] = 0.0;
            self.cursor = (self.cursor + 1) % self.slots.len();
        }
        self.base_slot = abs_slot;
    }

    fn add(&mut self, abs_slot: i64, v: f64) {
        let clamped = self.fold(abs_slot);
        if let Some(i) = self.idx(clamped) {
            self.slots[i] += v;
            if self.slots[i] < 0.0 {
                self.slots[i] = 0.0; // numeric dust from release
            }
        }
    }

    fn get(&self, abs_slot: i64) -> f64 {
        self.idx(abs_slot.max(self.base_slot)).map_or(0.0, |i| self.slots[i])
    }

    fn peak(&self) -> f64 {
        self.slots.iter().cloned().fold(0.0, f64::max)
    }
}

/// The memory-aware time-slot dispatcher.
pub struct TimeSlotDispatcher {
    cfg: TimeSlotConfig,
    rings: Vec<SlotRing>,
    /// Per-instance ramp constants (each instance's own cost model).
    costs: Vec<InstanceCost>,
    placements: HashMap<RequestId, Placement>,
    /// Expected exec-time provider: agent -> T_i (mode of the exec-latency
    /// distribution). Refreshed by the server from the orchestrator.
    expected_exec: HashMap<crate::orchestrator::ids::AgentId, f64>,
    /// Learned KV demand per agent (mode of observed total tokens held at
    /// completion); read by the feasibility check when
    /// [`TimeSlotConfig::learned_demand`] is on.
    expected_kv: HashMap<crate::orchestrator::ids::AgentId, f64>,
    /// Instance -> suspended-until time (OOM-suspect cooldown).
    suspended_until: Vec<Time>,
    /// Diagnostics.
    pub rejected_rounds: u64,
}

impl TimeSlotDispatcher {
    /// A packer whose every instance uses the config's reference ramp
    /// constants (homogeneous fleet / unit tests). For mixed-model fleets
    /// use [`TimeSlotDispatcher::for_models`].
    pub fn new(n_instances: usize, cfg: TimeSlotConfig) -> TimeSlotDispatcher {
        TimeSlotDispatcher {
            cfg,
            rings: (0..n_instances).map(|_| SlotRing::new(cfg.horizon_slots)).collect(),
            costs: vec![InstanceCost::from_config(&cfg); n_instances],
            placements: HashMap::new(),
            expected_exec: HashMap::new(),
            expected_kv: HashMap::new(),
            suspended_until: vec![0.0; n_instances],
            rejected_rounds: 0,
        }
    }

    /// A packer that prices each instance with its OWN cost model: ramp
    /// slope and KV density per `models[j]`, so packing on a mixed-model
    /// fleet predicts each instance's real memory trajectory instead of
    /// the fleet reference's.
    pub fn for_models(models: &[ModelKind], cfg: TimeSlotConfig) -> TimeSlotDispatcher {
        let mut d = TimeSlotDispatcher::new(models.len(), cfg);
        for (j, model) in models.iter().enumerate() {
            d.costs[j] = InstanceCost::for_model(*model);
        }
        d
    }

    pub fn config(&self) -> &TimeSlotConfig {
        &self.cfg
    }

    /// Refresh the per-agent expected execution times from the profiler
    /// (mode of the single-request latency distribution, §6).
    pub fn set_expected_exec(
        &mut self,
        agent: crate::orchestrator::ids::AgentId,
        t_mode: f64,
    ) {
        self.expected_exec.insert(agent, t_mode.max(1e-3));
    }

    /// Install an agent's learned total-KV-token demand (mode of the
    /// profiler's demand distribution). Only read when
    /// [`TimeSlotConfig::learned_demand`] is enabled.
    pub fn set_expected_kv(
        &mut self,
        agent: crate::orchestrator::ids::AgentId,
        tokens: f64,
    ) {
        self.expected_kv.insert(agent, tokens.max(1.0));
    }

    /// Expected lifetime KV tokens of `req` on an instance with the given
    /// ramp constants: the learned per-agent demand when the hook is on
    /// and profiled (floored at the prompt — the part known exactly),
    /// otherwise the slope-based guess over the expected execution time.
    fn expected_demand_tokens(&self, req: &Request, cost: InstanceCost, t_i: f64) -> u64 {
        if self.cfg.learned_demand {
            if let Some(&kv) = self.expected_kv.get(&req.agent) {
                return (kv.ceil() as u64).max(req.prompt_tokens as u64 + 1);
            }
        }
        req.prompt_tokens as u64 + (cost.mem_slope * t_i / cost.kv_bytes_per_token) as u64
    }

    fn abs_slot(&self, t: Time) -> i64 {
        (t / self.cfg.slot_len).floor() as i64
    }

    /// The request's predicted memory in the slot covering `t`
    /// (midpoint-evaluated linear ramp with the given slope, clamped to
    /// [P_i, peak]).
    fn ramp_at(
        &self,
        prefill_bytes: f64,
        mem_slope: f64,
        start: Time,
        end: Time,
        slot: i64,
    ) -> f64 {
        let mid = (slot as f64 + 0.5) * self.cfg.slot_len;
        if mid < start || mid >= end {
            // Slot partially covered at the edges: charge the boundary value
            // if the slot intersects [start, end) at all.
            let slot_lo = slot as f64 * self.cfg.slot_len;
            let slot_hi = slot_lo + self.cfg.slot_len;
            if slot_hi <= start || slot_lo >= end {
                return 0.0;
            }
        }
        let t = mid.clamp(start, end);
        prefill_bytes + mem_slope * (t - start)
    }

    fn expected_time(&self, req: &Request) -> f64 {
        self.expected_exec
            .get(&req.agent)
            .copied()
            .unwrap_or(self.cfg.default_exec_time)
            * self.cfg.safety
    }

    /// KV capacity of instance `j` in bytes — the live per-instance token
    /// budget priced at the instance's own KV density when a status is
    /// available, the configured fallback otherwise.
    fn capacity_of(&self, j: usize, status: Option<&InstanceStatus>) -> f64 {
        status
            .map(|s| s.capacity_tokens as f64 * self.costs[j].kv_bytes_per_token)
            .unwrap_or(self.cfg.capacity_bytes)
    }

    /// Evaluate placing `req` on instance `j` starting `now`, under the
    /// instance's own cost model; returns the resulting peak usage over the
    /// spanned slots, or None if any slot would exceed `capacity` (bytes).
    fn evaluate(&self, j: usize, req: &Request, now: Time, capacity: f64) -> Option<f64> {
        let t_i = self.expected_time(req);
        let start = now;
        let end = now + t_i;
        let cost = self.costs[j];
        let prefill_bytes = req.prompt_tokens as f64 * cost.kv_bytes_per_token;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        let ring = &self.rings[j];
        let mut peak: f64 = ring.peak();
        for s in s0..=s1 {
            let add = self.ramp_at(prefill_bytes, cost.mem_slope, start, end, s);
            if add == 0.0 {
                continue;
            }
            let total = ring.get(s) + add;
            if total > capacity {
                return None; // this instance is temporarily unavailable
            }
            peak = peak.max(total);
        }
        Some(peak)
    }
}

impl DispatchPolicy for TimeSlotDispatcher {
    fn name(&self) -> &'static str {
        "kairos-timeslot"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
    ) -> Option<usize> {
        if statuses.len() != self.rings.len() {
            // Defensive resize: a driver that skipped `on_fleet_change`
            // must still never make us mis-index the rings.
            self.on_fleet_change(statuses);
        }
        let cur = self.abs_slot(now);
        for ring in self.rings.iter_mut() {
            ring.advance_to(cur);
        }
        // Evaluate all instances "in parallel" (paper §6 step 2) and pick
        // the lowest expected total peak among the available ones.
        let t_i = self.expected_time(req);
        let mut best: Option<(usize, f64)> = None;
        for j in 0..self.rings.len() {
            let st = &statuses[j];
            if !st.accepting {
                continue; // draining toward retirement / retired tombstone
            }
            if !req.model_class.matches(st.model) {
                continue; // wrong serving group for a pinned request
            }
            if now < self.suspended_until[j] {
                continue; // OOM-suspect cooldown
            }
            // Expected total KV tokens of this request over its lifetime on
            // THIS instance (learned demand profile when enabled, else the
            // per-instance decode rate and KV density).
            let cost = self.costs[j];
            let expected_tokens = self.expected_demand_tokens(req, cost, t_i);
            // Live-status feasibility: dispatching is deferred while the
            // instance's committed + queued demand leaves no room — the
            // request "remains in the scheduling queue" (§6). This keeps
            // engine-side queues short so the slot-ramp predictions (which
            // assume execution starts at dispatch) stay accurate.
            if st.committed_tokens + st.waiting_tokens + expected_tokens
                > st.capacity_tokens
            {
                continue;
            }
            let capacity = self.capacity_of(j, Some(st));
            if let Some(peak) = self.evaluate(j, req, now, capacity) {
                if best.map(|(_, p)| peak < p).unwrap_or(true) {
                    best = Some((j, peak));
                }
            }
        }
        if best.is_none() {
            self.rejected_rounds += 1;
        }
        best.map(|(j, _)| j)
    }

    fn on_dispatch(&mut self, req: &Request, instance: usize, now: Time) {
        let t_i = self.expected_time(req);
        let start = now;
        let end = now + t_i;
        let cost = self.costs[instance];
        let prefill_bytes = req.prompt_tokens as f64 * cost.kv_bytes_per_token;
        let mem_slope = cost.mem_slope;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        // Record the fold window so the release recomputes the exact slots
        // the adds landed in (see `Placement::fold_limit`).
        let fold_base = self.rings[instance].base_slot;
        let fold_limit = self.rings[instance].horizon_end();
        for s in s0..=s1 {
            let add = self.ramp_at(prefill_bytes, mem_slope, start, end, s);
            if add > 0.0 {
                self.rings[instance].add(s, add);
            }
        }
        self.placements.insert(
            req.id,
            Placement { instance, start, end, prefill_bytes, mem_slope, fold_base, fold_limit },
        );
    }

    fn on_complete(&mut self, req: RequestId, _instance: usize, _now: Time) {
        // Early (or late) completion: remove the request's remaining
        // predicted usage (§6 adaptive measure). Each contribution was
        // charged at `fold(s)` under the dispatch-time window, so the
        // release re-applies the same rule — with the dispatch-time slope;
        // slots the ring base has already passed were cleared by
        // `advance_to` and are skipped.
        let Some(p) = self.placements.remove(&req) else { return };
        let s0 = self.abs_slot(p.start);
        let s1 = self.abs_slot(p.end) + 1;
        for s in s0..=s1 {
            let v = self.ramp_at(p.prefill_bytes, p.mem_slope, p.start, p.end, s);
            if v <= 0.0 {
                continue;
            }
            let target = s.clamp(p.fold_base, p.fold_limit);
            if target < self.rings[p.instance].base_slot {
                continue; // expired with the ring; nothing left to release
            }
            self.rings[p.instance].add(target, -v);
        }
    }

    fn on_preemption(&mut self, instance: usize, now: Time) {
        // OOM-suspect: temporarily suspend new dispatches to this instance.
        if instance < self.suspended_until.len() {
            self.suspended_until[instance] = now + self.cfg.suspend_cooldown;
        }
    }

    fn on_fleet_change(&mut self, statuses: &[InstanceStatus]) {
        let n = statuses.len();
        while self.rings.len() < n {
            let j = self.rings.len();
            self.rings.push(SlotRing::new(self.cfg.horizon_slots));
            self.suspended_until.push(0.0);
            // New instances are priced with their own model's constants.
            self.costs.push(InstanceCost::for_model(statuses[j].model));
        }
        if self.rings.len() > n {
            self.rings.truncate(n);
            self.suspended_until.truncate(n);
            self.costs.truncate(n);
            self.placements.retain(|_, p| p.instance < n);
        }
    }

    fn on_instance_reset(&mut self, instance: usize) {
        // The slot holds a fresh engine: drop the retired tenant's
        // predictions and suspension. The ramp constants stay — tombstone
        // reuse is same-family only, so the model did not change.
        if instance < self.rings.len() {
            self.rings[instance] = SlotRing::new(self.cfg.horizon_slots);
            self.suspended_until[instance] = 0.0;
        }
        self.placements.retain(|_, p| p.instance != instance);
    }

    fn refresh(&mut self, orch: &crate::orchestrator::Orchestrator) {
        for agent in orch.registry.all() {
            if let Some(mode) = orch.profiler.expected_exec(agent) {
                self.set_expected_exec(agent, mode);
            }
            if self.cfg.learned_demand {
                if let Some(kv) = orch.profiler.expected_kv_demand(agent) {
                    self.set_expected_kv(agent, kv);
                }
            }
        }
    }
}

/// Default config for a cost-model-calibrated cluster.
impl TimeSlotConfig {
    pub fn for_cost_model(cost: &crate::engine::cost_model::CostModel) -> TimeSlotConfig {
        TimeSlotConfig {
            slot_len: 0.5,
            horizon_slots: 600, // 5 minutes of look-ahead
            kv_bytes_per_token: cost.kv_bytes_per_token as f64,
            // Profile at a representative operating point (batch 16,
            // context 600) — "determined through prior hardware profiling".
            mem_slope: cost.mem_slope(16, 600) / 16.0,
            capacity_bytes: cost.kv_budget_bytes as f64,
            default_exec_time: 5.0,
            safety: 1.8,
            suspend_cooldown: 2.0,
            learned_demand: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::ModelClass;
    use crate::orchestrator::ids::AgentId;

    fn cfg() -> TimeSlotConfig {
        TimeSlotConfig {
            slot_len: 0.5,
            horizon_slots: 100,
            kv_bytes_per_token: 1.0, // 1 byte per token: easy arithmetic
            mem_slope: 10.0,         // bytes per second
            capacity_bytes: 1000.0,
            default_exec_time: 4.0,
            safety: 1.0,
            suspend_cooldown: 2.0,
            learned_demand: false,
        }
    }

    fn st(id: usize) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 100,
            used_blocks: 0,
            total_blocks: 100,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: 1000,
            preemptions: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req(id: u64, agent: u32, prompt: u32) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(agent),
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: prompt,
            true_output_tokens: 10,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn balances_across_instances() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        let r1 = req(1, 0, 500);
        let i1 = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i1, 0.0);
        // Second heavy request should take the other instance.
        let r2 = req(2, 0, 500);
        let i2 = d.choose(&r2, &statuses, 0.0).unwrap();
        assert_ne!(i1, i2);
    }

    #[test]
    fn rejects_when_all_slots_full() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        // Fill the instance close to capacity.
        let r1 = req(1, 0, 900);
        let i = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i, 0.0);
        // 900 + ramp(40) ~ 940; a 200-prompt request would cross 1000.
        let r2 = req(2, 0, 200);
        assert_eq!(d.choose(&r2, &statuses, 0.0), None);
        assert_eq!(d.rejected_rounds, 1);
    }

    #[test]
    fn completion_frees_future_slots() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        let r1 = req(1, 0, 900);
        let i = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i, 0.0);
        assert_eq!(d.choose(&req(2, 0, 200), &statuses, 0.5), None);
        // r1 finishes much earlier than predicted.
        d.on_complete(1, 0, 1.0);
        assert_eq!(d.choose(&req(2, 0, 200), &statuses, 1.0), Some(0));
    }

    #[test]
    fn preemption_suspends_instance() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        d.on_preemption(0, 0.0);
        // During the cooldown all traffic goes to instance 1.
        for k in 0..4 {
            assert_eq!(d.choose(&req(k, 0, 10), &statuses, 0.1), Some(1));
        }
        // After the cooldown instance 0 becomes eligible again.
        let pick = d.choose(&req(9, 0, 10), &statuses, 3.0);
        assert!(pick.is_some());
    }

    #[test]
    fn expected_time_uses_agent_profile() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        // Agent 7 runs 20 s (long ramp); default is 4 s.
        d.set_expected_exec(AgentId(7), 20.0);
        let long = req(1, 7, 100);
        let short = req(2, 0, 100);
        // Longer expected time => more future slots occupied => higher peak.
        let statuses = vec![st(0)];
        let _ = d.choose(&long, &statuses, 0.0);
        d.on_dispatch(&long, 0, 0.0);
        let peak_long = d.rings[0].peak();
        let mut d2 = TimeSlotDispatcher::new(1, cfg());
        let _ = d2.choose(&short, &statuses, 0.0);
        d2.on_dispatch(&short, 0, 0.0);
        let peak_short = d2.rings[0].peak();
        assert!(peak_long > peak_short);
    }

    #[test]
    fn ring_advances_and_recycles() {
        let mut ring = SlotRing::new(4);
        ring.add(0, 5.0);
        ring.add(3, 7.0);
        assert_eq!(ring.get(0), 5.0);
        ring.advance_to(2);
        assert_eq!(ring.get(0), 0.0, "expired slots drop");
        assert_eq!(ring.get(3), 7.0, "future slots survive");
        ring.add(5, 1.0);
        assert_eq!(ring.get(5), 1.0);
    }

    #[test]
    fn beyond_horizon_folds_into_last_slot() {
        let mut ring = SlotRing::new(4);
        ring.add(1000, 9.0);
        assert_eq!(ring.get(3), 9.0);
    }

    #[test]
    fn beyond_horizon_release_lands_in_fold_slot() {
        // Regression for the fold leak: with a 4-slot horizon (2 s) and a
        // 4 s expected execution, most of the prediction folds into the
        // last slot (abs slot 3). By completion time the ring base has
        // advanced past that slot's original position, so the old release
        // (recomputed against the CURRENT window) subtracted from different
        // absolute slots, was floor-clamped to 0, and left the folded mass
        // stranded: phantom KV load that starves dispatch forever.
        let mut c = cfg();
        c.horizon_slots = 4; // 2 s window, default_exec_time = 4 s
        let mut d = TimeSlotDispatcher::new(1, c);
        let statuses = vec![st(0)];
        let r1 = req(1, 0, 100);
        let j = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, j, 0.0);
        assert!(d.rings[0].peak() > 0.0);
        // Time passes: a later scheduling round advances the ring base
        // (the dispatch-time fold slot, abs slot 3, is still live, but the
        // CURRENT window's last slot is now abs slot 5).
        let _ = d.choose(&req(2, 0, 900), &statuses, 1.0);
        assert_eq!(d.rings[0].base_slot, 2);
        // The request finishes; every charged slot must be released.
        d.on_complete(1, 0, 1.0);
        assert!(
            d.rings[0].peak() < 1e-6,
            "phantom KV load left in the ring: peak={}",
            d.rings[0].peak()
        );
        // And a near-capacity request can now be placed again.
        assert_eq!(d.choose(&req(3, 0, 900), &statuses, 1.0), Some(0));
    }

    #[test]
    fn advance_to_jumps_large_gaps() {
        // A wall-clock driver idle for an hour advances ~7200 slots per
        // ring per pump; advance_to must clear at most slots.len() entries
        // and jump the base directly. With the old O(Δslots) loop this
        // multi-billion-slot gap would effectively hang the test.
        let mut ring = SlotRing::new(8);
        ring.add(3, 5.0);
        ring.add(7, 2.0);
        ring.advance_to(10_000_000_000);
        assert_eq!(ring.base_slot, 10_000_000_000);
        assert_eq!(ring.peak(), 0.0, "all live slots expired across the gap");
        ring.add(10_000_000_001, 2.5);
        assert_eq!(ring.get(10_000_000_001), 2.5);
        // A moderate (sub-window) gap still expires exactly the slots it
        // covers and keeps the future ones.
        ring.add(10_000_000_006, 1.5);
        ring.advance_to(10_000_000_004);
        assert_eq!(ring.get(10_000_000_001), 0.0);
        assert_eq!(ring.get(10_000_000_006), 1.5);
    }

    #[test]
    fn fleet_change_resizes_rings_and_skips_non_accepting() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        // The fleet grows to 3 instances; choose must not mis-index.
        let mut statuses = vec![st(0), st(1), st(2)];
        d.on_fleet_change(&statuses);
        assert_eq!(d.rings.len(), 3);
        assert_eq!(d.suspended_until.len(), 3);
        // Load up instance 0 so the packer prefers the new empty ones.
        let r = req(1, 0, 500);
        let j = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, j, 0.0);
        // Instance 1 starts draining: it must never be chosen again even
        // when it has the lowest expected peak.
        statuses[1].accepting = false;
        for k in 2..8 {
            let pick = d.choose(&req(k, 0, 100), &statuses, 0.0).unwrap();
            assert_ne!(pick, 1, "dispatched to a draining instance");
            d.on_dispatch(&req(k, 0, 100), pick, 0.0);
        }
    }

    #[test]
    fn choose_resizes_defensively_without_fleet_change() {
        // A driver that forgot on_fleet_change still must not panic.
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0), st(1), st(2), st(3)];
        let pick = d.choose(&req(1, 0, 10), &statuses, 0.0);
        assert!(pick.is_some());
        assert_eq!(d.rings.len(), 4);
    }

    #[test]
    fn heterogeneous_budgets_respected_per_instance() {
        // Instance 0 is squeezed by a co-tenant (150-token KV budget);
        // instance 1 has the full 1000. The packer must read each budget
        // from the statuses, not a fleet-wide constant.
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let mut small = st(0);
        small.capacity_tokens = 150;
        let statuses = vec![small, st(1)];

        // 500-token prompt exceeds the squeezed instance's entire budget.
        let r1 = req(1, 0, 500);
        let j1 = d.choose(&r1, &statuses, 0.0).unwrap();
        assert_eq!(j1, 1, "oversized request must avoid the squeezed instance");
        d.on_dispatch(&r1, j1, 0.0);

        // A small request fits the squeezed instance (peak 140 <= 150) and
        // prefers it over the loaded big one.
        let r2 = req(2, 0, 100);
        let j2 = d.choose(&r2, &statuses, 0.0).unwrap();
        assert_eq!(j2, 0);
        d.on_dispatch(&r2, j2, 0.0);

        // A second small request would push the squeezed instance to 280 >
        // 150, so it must go to the big instance despite its higher peak.
        let r3 = req(3, 0, 100);
        let j3 = d.choose(&r3, &statuses, 0.0).unwrap();
        assert_eq!(j3, 1, "per-instance budget must bound packing");
    }

    #[test]
    fn pinned_request_stays_in_its_serving_group() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let mut statuses = vec![st(0), st(1)];
        statuses[1].model = ModelKind::Llama2_13B;
        // Load the 13B instance's ring so the 8B one has the lower peak:
        // the pinned request must still land on the 13B instance.
        let filler = req(1, 0, 400);
        d.on_dispatch(&filler, 1, 0.0);
        let mut pinned = req(2, 0, 100);
        pinned.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        assert_eq!(d.choose(&pinned, &statuses, 0.0), Some(1));
        // And a family with no instance defers rather than spilling over.
        let mut orphan = req(3, 0, 100);
        orphan.model_class = ModelClass::Model(ModelKind::Tiny);
        assert_eq!(d.choose(&orphan, &statuses, 0.0), None);
    }

    #[test]
    fn per_instance_cost_models_shape_the_ramp() {
        // Same request, same cfg — but the 13B instance holds ~6x denser
        // KV per token, so its predicted footprint must be larger than the
        // 8B instance's for the identical placement.
        let real_cfg = TimeSlotConfig::for_cost_model(&CostModel::new(ModelKind::Llama3_8B));
        let models = [ModelKind::Llama3_8B, ModelKind::Llama2_13B];
        let mut d = TimeSlotDispatcher::for_models(&models, real_cfg);
        let r1 = req(1, 0, 200);
        let r2 = req(2, 0, 200);
        d.on_dispatch(&r1, 0, 0.0);
        d.on_dispatch(&r2, 1, 0.0);
        let peak8 = d.rings[0].peak();
        let peak13 = d.rings[1].peak();
        assert!(
            peak13 > peak8 * 2.0,
            "13B KV density must dominate: peak13={peak13} peak8={peak8}"
        );
        // Completion releases exactly what was charged on each instance.
        d.on_complete(1, 0, 0.0);
        d.on_complete(2, 1, 0.0);
        assert!(d.rings[0].peak() < 1e-6);
        assert!(d.rings[1].peak() < 1e-6);
    }

    #[test]
    fn instance_reset_clears_ring_and_suspension() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        let r = req(1, 0, 900);
        let j = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, j, 0.0);
        d.on_preemption(j, 0.0);
        assert!(d.rings[j].peak() > 0.0);
        // The slot is re-filled with a fresh engine: predictions and the
        // cooldown vanish, and the slot is immediately placeable again.
        d.on_instance_reset(j);
        assert!(d.rings[j].peak() < 1e-6);
        assert_eq!(d.choose(&req(2, 0, 900), &statuses, 0.1), Some(j));
        // A late completion of the evicted tenant is a no-op.
        d.on_complete(1, j, 0.2);
        assert!(d.rings[j].peak() >= 0.0);
    }

    #[test]
    fn learned_demand_overrides_the_slope_guess() {
        // Instance budget 1000 tokens. A 100-token prompt with the slope
        // guess predicts 100 + 10*4/1 = 140 tokens; the learned profile
        // knows this agent's requests balloon to 2000 tokens — over the
        // whole budget, so the dispatch must defer.
        let mut c = cfg();
        c.learned_demand = true;
        let mut d = TimeSlotDispatcher::new(1, c);
        d.set_expected_kv(AgentId(0), 2000.0);
        let statuses = vec![st(0)];
        assert_eq!(d.choose(&req(1, 0, 100), &statuses, 0.0), None);
        // An unprofiled agent still uses the slope guess and fits.
        assert_eq!(d.choose(&req(2, 1, 100), &statuses, 0.0), Some(0));
        // With the hook disabled the learned profile is ignored.
        let mut d2 = TimeSlotDispatcher::new(1, cfg());
        d2.set_expected_kv(AgentId(0), 2000.0);
        assert_eq!(d2.choose(&req(3, 0, 100), &statuses, 0.0), Some(0));
    }

    #[test]
    fn slot_accounting_never_negative() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        let r = req(1, 0, 100);
        let i = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, i, 0.0);
        d.on_complete(1, 0, 0.0);
        // Double-complete must be a no-op.
        d.on_complete(1, 0, 0.0);
        assert!(d.rings[0].peak() >= 0.0);
        assert!(d.rings[0].peak() < 1e-6, "all predicted usage released");
    }
}
