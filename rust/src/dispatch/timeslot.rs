//! Kairos' memory-aware time-slot dispatcher (paper §6).
//!
//! Each request's KV usage is modelled as a linear ramp (Eq. 1):
//!
//! ```text
//! f_i(t) = P_i + k · (t − t_start)   for t in [t_start, t_end), else 0
//! ```
//!
//! with `P_i` the prompt (prefill) KV bytes — computable online from the
//! prompt length — `k` the memory ramp slope from prior hardware profiling,
//! and `t_end = t_start + T_i` where `T_i` is the **mode** of the agent's
//! single-request execution-latency distribution.
//!
//! The future timeline is discretized into fixed 0.5 s slots; per instance a
//! ring of slots accumulates `F_j(t) = Σ f_i(t)` (Eq. 3). A request may go
//! to instance `j` only if no spanned slot would exceed capacity; among the
//! available instances the one with the lowest expected **total peak**
//! memory wins. Adaptive measures: slots are released early when a request
//! finishes before its prediction, and an instance that reports a
//! preemption (OOM-suspect) is suspended for a cooldown.
//!
//! ## Decision cost: the max-tree
//!
//! Scoring a candidate needs the ring's global peak and a feasibility scan
//! over the spanned slots. A naive ring pays O(H) per candidate for the
//! peak alone (H = 600 slots at the default horizon), which the bench
//! program flagged as the dominant per-decision cost. `SlotRing` is
//! therefore a ring window layered over an implicit tournament (segment)
//! max-tree: point add/release in O(log H), the global peak in O(1) from
//! the maintained root, and an O(log H) range-max that lets scoring
//! fast-accept (`range_max + peak_ramp_add ≤ capacity` ⇒ feasible without
//! touching individual slots) and fast-reject, falling back to the exact
//! per-slot loop only in the ambiguous band. The naive scoring path is kept
//! behind [`TimeSlotDispatcher`]'s `set_legacy_scoring` switch; both arms
//! produce bit-identical peaks, so they agree on every dispatch decision —
//! asserted by the `pack` bench stage and a property test below.

use std::collections::HashMap;

use super::{DispatchPolicy, DispatchStats, ScoreScope, Scored};
use crate::engine::core::InstanceStatus;
use crate::engine::cost_model::{CostModel, ModelKind};
use crate::engine::request::{Request, RequestId};
use crate::Time;

/// Tuning parameters of the time-slot packer.
#[derive(Debug, Clone, Copy)]
pub struct TimeSlotConfig {
    /// Slot length in seconds (paper: 0.5 s is the empirical sweet spot).
    pub slot_len: f64,
    /// Horizon in slots (predictions beyond it are clamped to the last slot).
    pub horizon_slots: usize,
    /// KV bytes per token (from the model's cost calibration).
    pub kv_bytes_per_token: f64,
    /// Memory ramp slope `k` in bytes/second (decode rate × bytes/token).
    pub mem_slope: f64,
    /// Fallback KV capacity in bytes, used only when an instance's live
    /// status is unavailable. On every decision the packer reads each
    /// instance's real budget from [`InstanceStatus::capacity_tokens`], so
    /// heterogeneous fleets (mixed GPUs, uneven co-tenant pressure) are
    /// packed against their actual per-instance capacities.
    pub capacity_bytes: f64,
    /// Fallback expected execution time before profiles exist (s).
    pub default_exec_time: f64,
    /// Safety factor on expected execution times: the mode of a
    /// heavy-tailed latency distribution under-estimates the tail, so
    /// packing with the raw mode over-commits; >1 compensates (the paper's
    /// "estimation errors" margin, §6).
    pub safety: f64,
    /// OOM-suspect suspension cooldown (s).
    pub suspend_cooldown: f64,
    /// Demand-prediction hook of the routing layer: when true, the
    /// feasibility check prices each request's lifetime KV demand from the
    /// profiler's learned per-agent demand distribution (mode of observed
    /// prompt + generated tokens, refreshed via
    /// [`DispatchPolicy::refresh`]) instead of the slope-based guess.
    /// Off by default — enabled alongside learned routing.
    pub learned_demand: bool,
    /// Prefix-cache awareness: when true, the ramp precompute prices each
    /// request at its *effective* prefill — the prompt minus the session's
    /// expected cached prefix (tracked from the packer's own dispatch
    /// stream, so both drivers see the identical expectation). Off by
    /// default; enabled alongside the engine-side prefix cache.
    pub cache_aware: bool,
}

impl TimeSlotConfig {
    pub fn slots_spanned(&self, duration: f64) -> usize {
        ((duration / self.slot_len).ceil() as usize).clamp(1, self.horizon_slots)
    }
}

/// A committed prediction for one dispatched request.
#[derive(Debug, Clone)]
struct Placement {
    instance: usize,
    start: Time,
    end: Time,
    prefill_bytes: f64,
    /// Ramp slope charged at dispatch time (the instance's own slope; the
    /// release must subtract exactly what was added).
    mem_slope: f64,
    /// Ring window `[base, last]` at dispatch time. Out-of-window
    /// contributions were folded into this range by [`SlotRing::fold`];
    /// the release must recompute placement against the SAME fold rule, or
    /// (once the ring base advances) the negative release lands in a
    /// different absolute slot than the positive add and phantom KV load
    /// accumulates in the last slot, starving dispatch.
    fold_base: i64,
    fold_limit: i64,
}

/// Per-instance ramp constants from the instance's OWN cost model —
/// per-instance cost awareness: a 13B co-tenant decodes slower and holds
/// denser KV than an 8B neighbor, so both its prefill footprint and its
/// ramp slope differ from the fleet's reference model. `PartialEq` lets the
/// per-request ramp precompute be shared across candidates with identical
/// constants instead of recomputed per instance.
#[derive(Debug, Clone, Copy, PartialEq)]
struct InstanceCost {
    kv_bytes_per_token: f64,
    mem_slope: f64,
}

impl InstanceCost {
    /// Fallback constants from the packer config (the fleet reference
    /// model) — used by [`TimeSlotDispatcher::new`] and in tests.
    fn from_config(cfg: &TimeSlotConfig) -> InstanceCost {
        InstanceCost { kv_bytes_per_token: cfg.kv_bytes_per_token, mem_slope: cfg.mem_slope }
    }

    /// Constants for an instance serving `model`, profiled at the same
    /// representative operating point as
    /// [`TimeSlotConfig::for_cost_model`].
    fn for_model(model: ModelKind) -> InstanceCost {
        let cost = CostModel::new(model);
        InstanceCost {
            kv_bytes_per_token: cost.kv_bytes_per_token as f64,
            mem_slope: cost.mem_slope(16, 600) / 16.0,
        }
    }
}

/// Per-instance future memory profile: a ring window over absolute slot
/// indices, backed by an implicit tournament (segment) max-tree.
///
/// Layout and invariants:
///
/// * `tree` has length `2·len`. Leaf `p` (a **physical** ring position in
///   `[0, len)`) lives at `tree[len + p]`; every internal node `i` in
///   `[1, len)` satisfies `tree[i] = max(tree[2i], tree[2i+1])`, so
///   `tree[1]` is the max over all live slots — [`SlotRing::peak`] is O(1).
/// * Absolute slot `s` maps to physical position
///   `(cursor + (s − base_slot)) % len` while `base_slot ≤ s < base_slot +
///   len`; [`SlotRing::advance_to`] rotates the window by clearing expired
///   leaves (point updates) or, once a gap covers the whole window, by
///   zeroing the tree outright.
/// * Leaves are never negative ([`SlotRing::add`] clamps release dust to
///   0.0) and never NaN, so `max` is associative over them and the root is
///   **bit-identical** to a linear left-to-right fold over the leaves
///   ([`SlotRing::peak_scan`], kept as the legacy scoring arm's scan).
/// * [`SlotRing::range_max`] answers max over an absolute slot range in
///   O(log len) by splitting the (up to two) contiguous physical intervals
///   the rotated range covers.
#[derive(Debug, Clone)]
struct SlotRing {
    /// Absolute index of the physical slot at `cursor`; slot s covers
    /// [s·slot_len, (s+1)·slot_len).
    base_slot: i64,
    cursor: usize,
    /// Number of live slots (the window length H).
    len: usize,
    /// Implicit max-tree nodes; see the struct docs for the layout.
    tree: Vec<f64>,
}

impl SlotRing {
    fn new(horizon: usize) -> SlotRing {
        let len = horizon.max(1);
        SlotRing { base_slot: 0, cursor: 0, len, tree: vec![0.0; 2 * len] }
    }

    fn idx(&self, abs_slot: i64) -> Option<usize> {
        let off = abs_slot - self.base_slot;
        if off < 0 || off >= self.len as i64 {
            None
        } else {
            Some((self.cursor + off as usize) % self.len)
        }
    }

    /// Absolute index of the last live slot.
    fn horizon_end(&self) -> i64 {
        self.base_slot + self.len as i64 - 1
    }

    /// The fold rule for out-of-window predictions: past slots charge the
    /// current base, beyond-horizon slots fold into the last slot
    /// (conservative). Adds and releases must both go through this rule so
    /// a prediction is released from the exact slot it was charged to.
    fn fold(&self, abs_slot: i64) -> i64 {
        abs_slot.max(self.base_slot).min(self.horizon_end())
    }

    /// Write leaf `p` and recompute the max along its ancestor path
    /// (O(log len)).
    fn set_leaf(&mut self, p: usize, v: f64) {
        let mut i = self.len + p;
        self.tree[i] = v;
        i >>= 1;
        while i >= 1 {
            self.tree[i] = self.tree[i << 1].max(self.tree[(i << 1) | 1]);
            i >>= 1;
        }
    }

    /// Advance the ring so `abs_slot` becomes the base; expired slots reset.
    /// Cost is bounded by the ring length: a gap of one idle hour (~7200
    /// slots at 0.5 s) must not spin per-slot — once the gap covers the
    /// whole window, every live slot has expired and the base jumps.
    fn advance_to(&mut self, abs_slot: i64) {
        if abs_slot <= self.base_slot {
            return;
        }
        let gap = abs_slot - self.base_slot;
        if gap >= self.len as i64 {
            self.tree.fill(0.0);
            self.cursor = 0;
            self.base_slot = abs_slot;
            return;
        }
        for _ in 0..gap {
            if self.tree[self.len + self.cursor] != 0.0 {
                self.set_leaf(self.cursor, 0.0);
            }
            self.cursor = (self.cursor + 1) % self.len;
        }
        self.base_slot = abs_slot;
    }

    fn add(&mut self, abs_slot: i64, v: f64) {
        let clamped = self.fold(abs_slot);
        if let Some(i) = self.idx(clamped) {
            let mut next = self.tree[self.len + i] + v;
            if next < 0.0 {
                next = 0.0; // numeric dust from release
            }
            self.set_leaf(i, next);
        }
    }

    /// Load in absolute slot `abs_slot`; expired and beyond-horizon slots
    /// read 0.0. (Past slots must NOT clamp to the base slot — that would
    /// report the base's live load for a slot that no longer exists.)
    fn get(&self, abs_slot: i64) -> f64 {
        if abs_slot < self.base_slot {
            return 0.0;
        }
        self.idx(abs_slot).map_or(0.0, |i| self.tree[self.len + i])
    }

    /// Global peak in O(1) from the maintained tree root.
    fn peak(&self) -> f64 {
        self.tree[1]
    }

    /// The legacy O(len) peak: a linear fold over the leaves. Kept as the
    /// `set_legacy_scoring` arm's scan; bit-identical to [`SlotRing::peak`]
    /// (leaves are non-negative and NaN-free, so max association cannot
    /// change the result).
    fn peak_scan(&self) -> f64 {
        self.tree[self.len..].iter().cloned().fold(0.0, f64::max)
    }

    /// Max over the absolute slot range `[lo, hi]` (inclusive), counting
    /// only live window slots; expired and beyond-horizon slots contribute
    /// 0.0. O(log len).
    fn range_max(&self, lo: i64, hi: i64) -> f64 {
        let lo = lo.max(self.base_slot);
        let hi = hi.min(self.horizon_end());
        if lo > hi {
            return 0.0;
        }
        let off = (lo - self.base_slot) as usize;
        let m = (hi - lo) as usize + 1;
        let a = (self.cursor + off) % self.len;
        if a + m <= self.len {
            self.range_max_phys(a, a + m)
        } else {
            // The rotated range wraps: two contiguous physical intervals.
            self.range_max_phys(a, self.len).max(self.range_max_phys(0, a + m - self.len))
        }
    }

    /// Max over the physical leaf range `[l, r)` via the implicit tree.
    fn range_max_phys(&self, mut l: usize, mut r: usize) -> f64 {
        let mut m = 0.0_f64;
        l += self.len;
        r += self.len;
        while l < r {
            if l & 1 == 1 {
                m = m.max(self.tree[l]);
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                m = m.max(self.tree[r]);
            }
            l >>= 1;
            r >>= 1;
        }
        m
    }
}

/// Per-request ramp contributions, shared across every candidate whose
/// [`InstanceCost`] constants are identical (the common case on a fleet
/// with a handful of model families): the ramp depends only on the
/// constants and the request's `(start, end)` window, never on the
/// candidate's ring.
#[derive(Debug, Clone)]
struct RampPre {
    cost: InstanceCost,
    /// Ramp contribution per spanned slot `s0..=s1`, from
    /// [`TimeSlotDispatcher::ramp_at`] — the exact values the legacy
    /// per-candidate loop recomputes.
    adds: Vec<f64>,
    /// Max of `adds`.
    add_max: f64,
    /// True when every slot except the trailing one carries positive ramp
    /// mass and the trailing slot carries none — the span shape the fast
    /// feasibility band relies on (degenerate shapes fall back to the
    /// exact loop).
    clean_span: bool,
}

impl RampPre {
    fn empty() -> RampPre {
        RampPre {
            cost: InstanceCost { kv_bytes_per_token: 0.0, mem_slope: 0.0 },
            adds: Vec::new(),
            add_max: 0.0,
            clean_span: false,
        }
    }
}

/// How the optimized scoring arm resolved one candidate.
enum EvalPath {
    /// O(log H) accept: feasibility and peak both settled by range-max.
    FastAccept,
    /// O(log H) reject: capacity exceeded without touching per-slot loads.
    FastReject,
    /// Ambiguous band: the exact per-slot loop ran.
    Exact,
}

/// The memory-aware time-slot dispatcher.
pub struct TimeSlotDispatcher {
    cfg: TimeSlotConfig,
    rings: Vec<SlotRing>,
    /// Per-instance ramp constants (each instance's own cost model).
    costs: Vec<InstanceCost>,
    placements: HashMap<RequestId, Placement>,
    /// Expected exec-time provider: agent -> T_i (mode of the exec-latency
    /// distribution). Refreshed by the server from the orchestrator.
    expected_exec: HashMap<crate::orchestrator::ids::AgentId, f64>,
    /// Learned KV demand per agent (mode of observed total tokens held at
    /// completion); read by the feasibility check when
    /// [`TimeSlotConfig::learned_demand`] is on.
    expected_kv: HashMap<crate::orchestrator::ids::AgentId, f64>,
    /// Instance -> suspended-until time (OOM-suspect cooldown).
    suspended_until: Vec<Time>,
    /// Diagnostics.
    pub rejected_rounds: u64,
    /// When true, score candidates with the naive O(H)-per-candidate path
    /// (linear peak scan, per-candidate ramp recompute) instead of the
    /// max-tree arm. Decisions are bit-identical either way.
    legacy_scoring: bool,
    /// Streaming decision counters (see [`DispatchStats`]).
    stats: DispatchStats,
    /// Reusable shared-ramp cache; entries beyond the per-decision live
    /// count are stale capacity kept to avoid reallocating.
    ramp_scratch: Vec<RampPre>,
    /// Session → expected cached prefix tokens (the longest prompt the
    /// packer has dispatched for the session), read by the ramp precompute
    /// when [`TimeSlotConfig::cache_aware`] is on. Only keyed lookups —
    /// never iterated — so hash order cannot reach a decision; bounded by
    /// [`SESSION_PREFIX_CAP`] with a deterministic full reset.
    session_prefix: HashMap<u64, u32>,
}

/// Bound on the packer's session-prefix expectation map. Crossing it resets
/// the whole map (a deterministic, order-free eviction); expectations then
/// rebuild from the live dispatch stream.
const SESSION_PREFIX_CAP: usize = 16_384;

impl TimeSlotDispatcher {
    /// A packer whose every instance uses the config's reference ramp
    /// constants (homogeneous fleet / unit tests). For mixed-model fleets
    /// use [`TimeSlotDispatcher::for_models`].
    pub fn new(n_instances: usize, cfg: TimeSlotConfig) -> TimeSlotDispatcher {
        TimeSlotDispatcher {
            cfg,
            rings: (0..n_instances).map(|_| SlotRing::new(cfg.horizon_slots)).collect(),
            costs: vec![InstanceCost::from_config(&cfg); n_instances],
            placements: HashMap::new(),
            expected_exec: HashMap::new(),
            expected_kv: HashMap::new(),
            suspended_until: vec![0.0; n_instances],
            rejected_rounds: 0,
            legacy_scoring: false,
            stats: DispatchStats::default(),
            ramp_scratch: Vec::new(),
            session_prefix: HashMap::new(),
        }
    }

    /// A packer that prices each instance with its OWN cost model: ramp
    /// slope and KV density per `models[j]`, so packing on a mixed-model
    /// fleet predicts each instance's real memory trajectory instead of
    /// the fleet reference's.
    pub fn for_models(models: &[ModelKind], cfg: TimeSlotConfig) -> TimeSlotDispatcher {
        let mut d = TimeSlotDispatcher::new(models.len(), cfg);
        for (j, model) in models.iter().enumerate() {
            d.costs[j] = InstanceCost::for_model(*model);
        }
        d
    }

    pub fn config(&self) -> &TimeSlotConfig {
        &self.cfg
    }

    /// Refresh the per-agent expected execution times from the profiler
    /// (mode of the single-request latency distribution, §6). Skips the
    /// map write when the profiled mode is unchanged.
    pub fn set_expected_exec(
        &mut self,
        agent: crate::orchestrator::ids::AgentId,
        t_mode: f64,
    ) {
        let t = t_mode.max(1e-3);
        if self.expected_exec.get(&agent).copied() != Some(t) {
            self.expected_exec.insert(agent, t);
        }
    }

    /// Install an agent's learned total-KV-token demand (mode of the
    /// profiler's demand distribution). Only read when
    /// [`TimeSlotConfig::learned_demand`] is enabled. Skips the map write
    /// when the profiled demand is unchanged.
    pub fn set_expected_kv(
        &mut self,
        agent: crate::orchestrator::ids::AgentId,
        tokens: f64,
    ) {
        let t = tokens.max(1.0);
        if self.expected_kv.get(&agent).copied() != Some(t) {
            self.expected_kv.insert(agent, t);
        }
    }

    /// Expected lifetime KV tokens of `req` on an instance with the given
    /// ramp constants: the learned per-agent demand when the hook is on
    /// and profiled (floored at the prompt — the part known exactly),
    /// otherwise the slope-based guess over the expected execution time.
    fn expected_demand_tokens(&self, req: &Request, cost: InstanceCost, t_i: f64) -> u64 {
        if self.cfg.learned_demand {
            if let Some(&kv) = self.expected_kv.get(&req.agent) {
                return (kv.ceil() as u64).max(req.prompt_tokens as u64 + 1);
            }
        }
        self.expected_prefill_tokens(req) as u64
            + (cost.mem_slope * t_i / cost.kv_bytes_per_token) as u64
    }

    /// Effective prefill the ramp precompute prices `req` at: the full
    /// prompt, shortened by the session's expected cached prefix when
    /// [`TimeSlotConfig::cache_aware`] is on. Depends only on the request
    /// and the packer's own dispatch history (never on the candidate), so
    /// `choose`/`choose_among` and the legacy/max-tree scoring arms all
    /// price a candidate identically.
    fn expected_prefill_tokens(&self, req: &Request) -> u32 {
        if !self.cfg.cache_aware {
            return req.prompt_tokens;
        }
        let hit = self.session_prefix.get(&req.session).copied().unwrap_or(0);
        crate::engine::cost_model::effective_prefill(req.prompt_tokens, hit)
    }

    fn abs_slot(&self, t: Time) -> i64 {
        (t / self.cfg.slot_len).floor() as i64
    }

    /// The request's predicted memory in the slot covering `t`
    /// (midpoint-evaluated linear ramp with the given slope, clamped to
    /// [P_i, peak]).
    fn ramp_at(
        &self,
        prefill_bytes: f64,
        mem_slope: f64,
        start: Time,
        end: Time,
        slot: i64,
    ) -> f64 {
        let mid = (slot as f64 + 0.5) * self.cfg.slot_len;
        if mid < start || mid >= end {
            // Slot partially covered at the edges: charge the boundary value
            // if the slot intersects [start, end) at all.
            let slot_lo = slot as f64 * self.cfg.slot_len;
            let slot_hi = slot_lo + self.cfg.slot_len;
            if slot_hi <= start || slot_lo >= end {
                return 0.0;
            }
        }
        let t = mid.clamp(start, end);
        prefill_bytes + mem_slope * (t - start)
    }

    fn expected_time(&self, req: &Request) -> f64 {
        self.expected_exec
            .get(&req.agent)
            .copied()
            .unwrap_or(self.cfg.default_exec_time)
            * self.cfg.safety
    }

    /// KV capacity of instance `j` in bytes — the live per-instance token
    /// budget priced at the instance's own KV density when a status is
    /// available, the configured fallback otherwise.
    fn capacity_of(&self, j: usize, status: Option<&InstanceStatus>) -> f64 {
        status
            .map(|s| s.capacity_tokens as f64 * self.costs[j].kv_bytes_per_token)
            .unwrap_or(self.cfg.capacity_bytes)
    }

    /// Legacy scoring of placing `req` on instance `j` starting `now`:
    /// linear peak scan plus a per-slot ramp recompute. Returns the
    /// resulting peak usage over the spanned slots, or None if any slot
    /// would exceed `capacity` (bytes). Kept verbatim behind the
    /// `set_legacy_scoring` switch as the A/B baseline the max-tree arm
    /// must agree with bit-for-bit.
    fn evaluate_legacy(
        &self,
        j: usize,
        eff_prompt: u32,
        t_i: f64,
        now: Time,
        capacity: f64,
    ) -> Option<f64> {
        let start = now;
        let end = now + t_i;
        let cost = self.costs[j];
        let prefill_bytes = eff_prompt as f64 * cost.kv_bytes_per_token;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        let ring = &self.rings[j];
        let mut peak: f64 = ring.peak_scan();
        for s in s0..=s1 {
            let add = self.ramp_at(prefill_bytes, cost.mem_slope, start, end, s);
            if add == 0.0 {
                continue;
            }
            let total = ring.get(s) + add;
            if total > capacity {
                return None; // this instance is temporarily unavailable
            }
            peak = peak.max(total);
        }
        Some(peak)
    }

    /// Max-tree scoring: O(1) root peak plus an O(log H) range-max
    /// feasibility band, falling back to the exact per-slot loop (over the
    /// shared precomputed ramp) only when neither band settles the
    /// candidate. Peaks are bit-identical to [`Self::evaluate_legacy`]:
    ///
    /// * the root equals the linear peak scan (non-negative, NaN-free
    ///   leaves);
    /// * fast-reject fires only when some slot the legacy loop inspects
    ///   already exceeds capacity on its own (`add_max > capacity`, or ring
    ///   load `> capacity` in a span whose every slot carries positive ramp
    ///   mass);
    /// * fast-accept fires when the spanned range is untouched
    ///   (`range_max == 0.0`, so every total is exactly its ramp add and
    ///   the peak is `max(root, add_max)`), or when every spanned total is
    ///   bounded by `range_max + add_max ≤ capacity` AND the global root
    ///   dominates that bound, so the exact peak is the root itself.
    fn evaluate_fast(
        &self,
        j: usize,
        pre: &RampPre,
        s0: i64,
        s1: i64,
        capacity: f64,
    ) -> (Option<f64>, EvalPath) {
        let ring = &self.rings[j];
        let root = ring.peak();
        if pre.clean_span {
            if pre.add_max > capacity {
                // The slot holding add_max totals at least add_max alone.
                return (None, EvalPath::FastReject);
            }
            let rm = ring.range_max(s0, s1 - 1);
            if rm > capacity {
                // That slot carries positive ramp mass (clean span), so the
                // legacy loop checks it and its total already exceeds
                // capacity on ring load alone.
                return (None, EvalPath::FastReject);
            }
            if rm == 0.0 {
                // Untouched span: every spanned slot reads 0.0, so each
                // total is exactly its ramp add (`0.0 + a` is bitwise `a`)
                // and the peak is max(root, add_max) — the common case on
                // lightly-loaded instances.
                return (Some(root.max(pre.add_max)), EvalPath::FastAccept);
            }
            let bound = rm + pre.add_max;
            if bound <= capacity && root >= bound {
                return (Some(root), EvalPath::FastAccept);
            }
        }
        // Ambiguous band: the exact per-slot loop, sharing the precomputed
        // ramp instead of recomputing it per candidate.
        let mut peak = root;
        for (i, &add) in pre.adds.iter().enumerate() {
            if add == 0.0 {
                continue;
            }
            let total = ring.get(s0 + i as i64) + add;
            if total > capacity {
                return (None, EvalPath::Exact);
            }
            peak = peak.max(total);
        }
        (Some(peak), EvalPath::Exact)
    }

    /// Shared body of [`DispatchPolicy::choose`] (candidates = the whole
    /// fleet) and [`DispatchPolicy::choose_among`] (candidates = the
    /// coordinator's family-index prune). Candidate order is ascending in
    /// both callers, so the strict `<` first-wins tie-break picks the same
    /// instance either way.
    fn choose_filtered(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
        candidates: Option<&[usize]>,
    ) -> Option<usize> {
        if statuses.len() != self.rings.len() {
            // Defensive resize: a driver that skipped `on_fleet_change`
            // must still never make us mis-index the rings.
            self.on_fleet_change(statuses);
        }
        // Every ring advances — even non-candidates — so ring state (and
        // therefore every later decision) is independent of which candidate
        // subsets earlier rounds were called with.
        let cur = self.abs_slot(now);
        for ring in self.rings.iter_mut() {
            ring.advance_to(cur);
        }
        // Evaluate the candidates "in parallel" (paper §6 step 2) and pick
        // the lowest expected total peak among the available ones.
        let t_i = self.expected_time(req);
        let eff_prompt = self.expected_prefill_tokens(req);
        let start = now;
        let end = now + t_i;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        self.stats.decisions += 1;
        let n = self.rings.len();
        let mut scratch = std::mem::take(&mut self.ramp_scratch);
        let mut scratch_used = 0usize;
        let mut best: Option<(usize, f64)> = None;
        let upper = candidates.map_or(n, <[usize]>::len);
        for k in 0..upper {
            let j = match candidates {
                Some(c) => c[k],
                None => k,
            };
            if j >= n {
                continue; // stale candidate set across a fleet shrink
            }
            self.stats.candidates += 1;
            let st = &statuses[j];
            if !st.accepting {
                continue; // draining toward retirement / retired tombstone
            }
            if !req.model_class.matches(st.model) {
                continue; // wrong serving group for a pinned request
            }
            if now < self.suspended_until[j] {
                continue; // OOM-suspect cooldown
            }
            // Expected total KV tokens of this request over its lifetime on
            // THIS instance (learned demand profile when enabled, else the
            // per-instance decode rate and KV density).
            let cost = self.costs[j];
            let expected_tokens = self.expected_demand_tokens(req, cost, t_i);
            // Live-status feasibility: dispatching is deferred while the
            // instance's committed + queued demand leaves no room — the
            // request "remains in the scheduling queue" (§6). This keeps
            // engine-side queues short so the slot-ramp predictions (which
            // assume execution starts at dispatch) stay accurate.
            if st.committed_tokens + st.waiting_tokens + expected_tokens
                > st.capacity_tokens
            {
                continue;
            }
            let capacity = self.capacity_of(j, Some(st));
            self.stats.evaluated += 1;
            let peak = if self.legacy_scoring {
                self.evaluate_legacy(j, eff_prompt, t_i, now, capacity)
            } else {
                let pi = Self::ramp_pre(
                    &self.cfg,
                    &mut scratch,
                    &mut scratch_used,
                    cost,
                    eff_prompt,
                    start,
                    end,
                    s0,
                    s1,
                );
                let (peak, path) = self.evaluate_fast(j, &scratch[pi], s0, s1, capacity);
                match path {
                    EvalPath::FastAccept => self.stats.fast_accepted += 1,
                    EvalPath::FastReject => self.stats.fast_rejected += 1,
                    EvalPath::Exact => {}
                }
                peak
            };
            if let Some(peak) = peak {
                if best.map(|(_, p)| peak < p).unwrap_or(true) {
                    best = Some((j, peak));
                }
            }
        }
        self.ramp_scratch = scratch;
        if best.is_none() {
            self.rejected_rounds += 1;
        }
        best.map(|(j, _)| j)
    }

    /// Find-or-build the shared [`RampPre`] for `cost` in the per-decision
    /// scratch, returning its index. Entries are keyed by the exact ramp
    /// constants; on a fleet with a handful of model families this computes
    /// each ramp once per decision instead of once per candidate.
    #[allow(clippy::too_many_arguments)]
    fn ramp_pre(
        cfg: &TimeSlotConfig,
        scratch: &mut Vec<RampPre>,
        used: &mut usize,
        cost: InstanceCost,
        prompt_tokens: u32,
        start: Time,
        end: Time,
        s0: i64,
        s1: i64,
    ) -> usize {
        for (i, p) in scratch[..*used].iter().enumerate() {
            if p.cost == cost {
                return i;
            }
        }
        if *used == scratch.len() {
            scratch.push(RampPre::empty());
        }
        let p = &mut scratch[*used];
        p.cost = cost;
        p.adds.clear();
        let prefill_bytes = prompt_tokens as f64 * cost.kv_bytes_per_token;
        let mut add_max = 0.0_f64;
        for s in s0..=s1 {
            // Same arithmetic as `ramp_at`, inlined against `cfg` so the
            // precompute can run while `self` stays borrowed by the caller.
            let mid = (s as f64 + 0.5) * cfg.slot_len;
            let a = if mid < start || mid >= end {
                let slot_lo = s as f64 * cfg.slot_len;
                let slot_hi = slot_lo + cfg.slot_len;
                if slot_hi <= start || slot_lo >= end {
                    0.0
                } else {
                    prefill_bytes + cost.mem_slope * (mid.clamp(start, end) - start)
                }
            } else {
                prefill_bytes + cost.mem_slope * (mid.clamp(start, end) - start)
            };
            add_max = add_max.max(a);
            p.adds.push(a);
        }
        p.add_max = add_max;
        let n = p.adds.len();
        p.clean_span =
            n >= 2 && p.adds[n - 1] == 0.0 && p.adds[..n - 1].iter().all(|&a| a > 0.0);
        *used += 1;
        *used - 1
    }

    /// Bit-exact snapshot of every ring's state (base, cursor, tree bits) —
    /// the property tests compare legacy vs. max-tree arms with this.
    #[cfg(test)]
    fn ring_bits(&self) -> Vec<(i64, usize, Vec<u64>)> {
        self.rings
            .iter()
            .map(|r| (r.base_slot, r.cursor, r.tree.iter().map(|v| v.to_bits()).collect()))
            .collect()
    }
}

impl DispatchPolicy for TimeSlotDispatcher {
    fn name(&self) -> &'static str {
        "kairos-timeslot"
    }

    fn choose(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        now: Time,
    ) -> Option<usize> {
        self.choose_filtered(req, statuses, now, None)
    }

    fn choose_among(
        &mut self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: &[usize],
        now: Time,
    ) -> Option<usize> {
        self.choose_filtered(req, statuses, now, Some(candidates))
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    fn score_scope(&self) -> ScoreScope {
        if self.cfg.cache_aware {
            // Cache-aware pricing reads the policy-global session-prefix
            // expectation, which every dispatch may move: no score
            // survives a commit.
            ScoreScope::Global
        } else {
            // Scoring instance j reads rings[j], costs[j],
            // suspended_until[j] and j's status entry; on_dispatch to j'
            // mutates only slot j' state. Cross-family scores survive.
            ScoreScope::Slots
        }
    }

    fn begin_round(&mut self, statuses: &[InstanceStatus], now: Time) {
        // The two &mut self preambles of `choose_filtered`, hoisted: the
        // defensive fleet resize and the every-ring window advance. Both
        // are idempotent at fixed `now`, so the sequential arm's
        // per-decision advances and this one per-pump advance leave
        // identical ring state.
        if statuses.len() != self.rings.len() {
            self.on_fleet_change(statuses);
        }
        let cur = self.abs_slot(now);
        for ring in self.rings.iter_mut() {
            ring.advance_to(cur);
        }
    }

    fn score(
        &self,
        req: &Request,
        statuses: &[InstanceStatus],
        candidates: Option<&[usize]>,
        now: Time,
    ) -> Scored {
        // Pure mirror of `choose_filtered` (same candidate order, same
        // strict-`<` first-wins tie-break, same legacy/max-tree arms, same
        // shared-ramp precompute), with the counter bumps collected into
        // the detail delta and the ramp scratch kept local. Requires
        // `begin_round` at the same `now` (rings sized and advanced).
        let mut detail = DispatchStats::default();
        let t_i = self.expected_time(req);
        let eff_prompt = self.expected_prefill_tokens(req);
        let start = now;
        let end = now + t_i;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        detail.decisions += 1;
        let n = self.rings.len();
        let mut scratch: Vec<RampPre> = Vec::new();
        let mut scratch_used = 0usize;
        let mut best: Option<(usize, f64)> = None;
        let upper = candidates.map_or(n, <[usize]>::len);
        for k in 0..upper {
            let j = match candidates {
                Some(c) => c[k],
                None => k,
            };
            if j >= n {
                continue; // stale candidate set across a fleet shrink
            }
            detail.candidates += 1;
            let Some(st) = statuses.get(j) else { continue };
            if !st.accepting {
                continue;
            }
            if !req.model_class.matches(st.model) {
                continue;
            }
            if now < self.suspended_until[j] {
                continue;
            }
            let cost = self.costs[j];
            let expected_tokens = self.expected_demand_tokens(req, cost, t_i);
            if st.committed_tokens + st.waiting_tokens + expected_tokens
                > st.capacity_tokens
            {
                continue;
            }
            let capacity = self.capacity_of(j, Some(st));
            detail.evaluated += 1;
            let peak = if self.legacy_scoring {
                self.evaluate_legacy(j, eff_prompt, t_i, now, capacity)
            } else {
                let pi = Self::ramp_pre(
                    &self.cfg,
                    &mut scratch,
                    &mut scratch_used,
                    cost,
                    eff_prompt,
                    start,
                    end,
                    s0,
                    s1,
                );
                let (peak, path) = self.evaluate_fast(j, &scratch[pi], s0, s1, capacity);
                match path {
                    EvalPath::FastAccept => detail.fast_accepted += 1,
                    EvalPath::FastReject => detail.fast_rejected += 1,
                    EvalPath::Exact => {}
                }
                peak
            };
            if let Some(peak) = peak {
                if best.map(|(_, p)| peak < p).unwrap_or(true) {
                    best = Some((j, peak));
                }
            }
        }
        if best.is_none() {
            detail.rejected_rounds += 1;
        }
        Scored { pick: best.map(|(j, _)| j), detail }
    }

    fn commit_score(
        &mut self,
        _req: &Request,
        scored: &Scored,
        _statuses: &[InstanceStatus],
        _now: Time,
    ) {
        // Fold the decision's counter delta exactly where choose_filtered
        // bumps its own counters. (The ring/placement mutation of an
        // accepted pick still arrives through `on_dispatch`.)
        let d = &scored.detail;
        self.stats.decisions += d.decisions;
        self.stats.candidates += d.candidates;
        self.stats.evaluated += d.evaluated;
        self.stats.fast_accepted += d.fast_accepted;
        self.stats.fast_rejected += d.fast_rejected;
        self.rejected_rounds += d.rejected_rounds;
    }

    fn set_legacy_scoring(&mut self, legacy: bool) {
        self.legacy_scoring = legacy;
    }

    fn state_fingerprint(&self) -> u64 {
        // FNV-1a over the semantic ring contents — absolute slot → load
        // bits, read through `get` so the digest is invariant to the
        // circular buffer's internal rotation — plus every window base and
        // the per-instance suspensions. These are the "ring bits" the
        // parallel pump must keep bit-identical to the sequential arm at
        // every thread count.
        fn fold(h: &mut u64, v: u64) {
            *h ^= v;
            *h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for ring in &self.rings {
            fold(&mut h, ring.base_slot as u64);
            for i in 0..ring.len as i64 {
                fold(&mut h, ring.get(ring.base_slot + i).to_bits());
            }
        }
        for &t in &self.suspended_until {
            fold(&mut h, t.to_bits());
        }
        h
    }

    fn stats(&self) -> DispatchStats {
        let mut s = self.stats;
        s.rejected_rounds = self.rejected_rounds;
        s
    }

    fn on_dispatch(&mut self, req: &Request, instance: usize, now: Time) {
        let t_i = self.expected_time(req);
        let start = now;
        let end = now + t_i;
        let cost = self.costs[instance];
        // Same effective prefill the decision was priced at (the session
        // expectation is updated only after the charge below, so the add
        // and the score agree); the release subtracts the recorded bytes.
        let prefill_bytes =
            self.expected_prefill_tokens(req) as f64 * cost.kv_bytes_per_token;
        if self.cfg.cache_aware {
            if self.session_prefix.len() >= SESSION_PREFIX_CAP
                && !self.session_prefix.contains_key(&req.session)
            {
                self.session_prefix.clear();
            }
            let e = self.session_prefix.entry(req.session).or_insert(0);
            *e = (*e).max(req.prompt_tokens);
        }
        let mem_slope = cost.mem_slope;
        let s0 = self.abs_slot(start);
        let s1 = self.abs_slot(end) + 1;
        // Record the fold window so the release recomputes the exact slots
        // the adds landed in (see `Placement::fold_limit`).
        let fold_base = self.rings[instance].base_slot;
        let fold_limit = self.rings[instance].horizon_end();
        for s in s0..=s1 {
            let add = self.ramp_at(prefill_bytes, mem_slope, start, end, s);
            if add > 0.0 {
                self.rings[instance].add(s, add);
            }
        }
        self.placements.insert(
            req.id,
            Placement { instance, start, end, prefill_bytes, mem_slope, fold_base, fold_limit },
        );
    }

    fn on_complete(&mut self, req: RequestId, _instance: usize, _now: Time) {
        // Early (or late) completion: remove the request's remaining
        // predicted usage (§6 adaptive measure). Each contribution was
        // charged at `fold(s)` under the dispatch-time window, so the
        // release re-applies the same rule — with the dispatch-time slope;
        // slots the ring base has already passed were cleared by
        // `advance_to` and are skipped.
        let Some(p) = self.placements.remove(&req) else { return };
        let s0 = self.abs_slot(p.start);
        let s1 = self.abs_slot(p.end) + 1;
        for s in s0..=s1 {
            let v = self.ramp_at(p.prefill_bytes, p.mem_slope, p.start, p.end, s);
            if v <= 0.0 {
                continue;
            }
            let target = s.clamp(p.fold_base, p.fold_limit);
            if target < self.rings[p.instance].base_slot {
                continue; // expired with the ring; nothing left to release
            }
            self.rings[p.instance].add(target, -v);
        }
    }

    fn on_preemption(&mut self, instance: usize, now: Time) {
        // OOM-suspect: temporarily suspend new dispatches to this instance.
        if instance < self.suspended_until.len() {
            self.suspended_until[instance] = now + self.cfg.suspend_cooldown;
            self.stats.suspensions += 1;
        }
    }

    fn on_fleet_change(&mut self, statuses: &[InstanceStatus]) {
        let n = statuses.len();
        while self.rings.len() < n {
            let j = self.rings.len();
            self.rings.push(SlotRing::new(self.cfg.horizon_slots));
            self.suspended_until.push(0.0);
            // New instances are priced with their own model's constants.
            self.costs.push(InstanceCost::for_model(statuses[j].model));
        }
        if self.rings.len() > n {
            self.rings.truncate(n);
            self.suspended_until.truncate(n);
            self.costs.truncate(n);
            self.placements.retain(|_, p| p.instance < n);
        }
    }

    fn on_instance_reset(&mut self, instance: usize) {
        // The slot holds a fresh engine: drop the retired tenant's
        // predictions and suspension. The ramp constants stay — tombstone
        // reuse is same-family only, so the model did not change.
        if instance < self.rings.len() {
            self.rings[instance] = SlotRing::new(self.cfg.horizon_slots);
            self.suspended_until[instance] = 0.0;
        }
        self.placements.retain(|_, p| p.instance != instance);
    }

    fn refresh(&mut self, orch: &crate::orchestrator::Orchestrator) {
        for agent in orch.registry.all() {
            if let Some(mode) = orch.profiler.expected_exec(agent) {
                self.set_expected_exec(agent, mode);
            }
            if self.cfg.learned_demand {
                if let Some(kv) = orch.profiler.expected_kv_demand(agent) {
                    self.set_expected_kv(agent, kv);
                }
            }
        }
    }
}

/// Default config for a cost-model-calibrated cluster.
impl TimeSlotConfig {
    pub fn for_cost_model(cost: &crate::engine::cost_model::CostModel) -> TimeSlotConfig {
        TimeSlotConfig {
            slot_len: 0.5,
            horizon_slots: 600, // 5 minutes of look-ahead
            kv_bytes_per_token: cost.kv_bytes_per_token as f64,
            // Profile at a representative operating point (batch 16,
            // context 600) — "determined through prior hardware profiling".
            mem_slope: cost.mem_slope(16, 600) / 16.0,
            capacity_bytes: cost.kv_budget_bytes as f64,
            default_exec_time: 5.0,
            safety: 1.8,
            suspend_cooldown: 2.0,
            learned_demand: false,
            cache_aware: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::ModelClass;
    use crate::orchestrator::ids::AgentId;

    fn cfg() -> TimeSlotConfig {
        TimeSlotConfig {
            slot_len: 0.5,
            horizon_slots: 100,
            kv_bytes_per_token: 1.0, // 1 byte per token: easy arithmetic
            mem_slope: 10.0,         // bytes per second
            capacity_bytes: 1000.0,
            default_exec_time: 4.0,
            safety: 1.0,
            suspend_cooldown: 2.0,
            learned_demand: false,
            cache_aware: false,
        }
    }

    fn st(id: usize) -> InstanceStatus {
        InstanceStatus {
            id,
            free_blocks: 100,
            used_blocks: 0,
            total_blocks: 100,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: 1000,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        }
    }

    fn req(id: u64, agent: u32, prompt: u32) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(agent),
            session: id,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: prompt,
            true_output_tokens: 10,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn balances_across_instances() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        let r1 = req(1, 0, 500);
        let i1 = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i1, 0.0);
        // Second heavy request should take the other instance.
        let r2 = req(2, 0, 500);
        let i2 = d.choose(&r2, &statuses, 0.0).unwrap();
        assert_ne!(i1, i2);
    }

    #[test]
    fn rejects_when_all_slots_full() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        // Fill the instance close to capacity.
        let r1 = req(1, 0, 900);
        let i = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i, 0.0);
        // 900 + ramp(40) ~ 940; a 200-prompt request would cross 1000.
        let r2 = req(2, 0, 200);
        assert_eq!(d.choose(&r2, &statuses, 0.0), None);
        assert_eq!(d.rejected_rounds, 1);
    }

    #[test]
    fn completion_frees_future_slots() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        let r1 = req(1, 0, 900);
        let i = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, i, 0.0);
        assert_eq!(d.choose(&req(2, 0, 200), &statuses, 0.5), None);
        // r1 finishes much earlier than predicted.
        d.on_complete(1, 0, 1.0);
        assert_eq!(d.choose(&req(2, 0, 200), &statuses, 1.0), Some(0));
    }

    #[test]
    fn preemption_suspends_instance() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        d.on_preemption(0, 0.0);
        // During the cooldown all traffic goes to instance 1.
        for k in 0..4 {
            assert_eq!(d.choose(&req(k, 0, 10), &statuses, 0.1), Some(1));
        }
        // After the cooldown instance 0 becomes eligible again.
        let pick = d.choose(&req(9, 0, 10), &statuses, 3.0);
        assert!(pick.is_some());
        assert_eq!(d.stats().suspensions, 1);
    }

    #[test]
    fn expected_time_uses_agent_profile() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        // Agent 7 runs 20 s (long ramp); default is 4 s.
        d.set_expected_exec(AgentId(7), 20.0);
        let long = req(1, 7, 100);
        let short = req(2, 0, 100);
        // Longer expected time => more future slots occupied => higher peak.
        let statuses = vec![st(0)];
        let _ = d.choose(&long, &statuses, 0.0);
        d.on_dispatch(&long, 0, 0.0);
        let peak_long = d.rings[0].peak();
        let mut d2 = TimeSlotDispatcher::new(1, cfg());
        let _ = d2.choose(&short, &statuses, 0.0);
        d2.on_dispatch(&short, 0, 0.0);
        let peak_short = d2.rings[0].peak();
        assert!(peak_long > peak_short);
    }

    #[test]
    fn ring_advances_and_recycles() {
        let mut ring = SlotRing::new(4);
        ring.add(0, 5.0);
        ring.add(3, 7.0);
        assert_eq!(ring.get(0), 5.0);
        ring.advance_to(2);
        assert_eq!(ring.get(0), 0.0, "expired slots drop");
        assert_eq!(ring.get(3), 7.0, "future slots survive");
        ring.add(5, 1.0);
        assert_eq!(ring.get(5), 1.0);
    }

    #[test]
    fn beyond_horizon_folds_into_last_slot() {
        let mut ring = SlotRing::new(4);
        ring.add(1000, 9.0);
        assert_eq!(ring.get(3), 9.0);
    }

    #[test]
    fn expired_slots_read_zero_not_base() {
        // Regression: `get` used to clamp past slots to the base slot and
        // silently reported the CURRENT base slot's load for any expired
        // slot — a mis-scoring footgun for anything that reads behind the
        // window.
        let mut ring = SlotRing::new(4);
        ring.advance_to(10);
        ring.add(10, 42.0);
        assert_eq!(ring.get(10), 42.0);
        assert_eq!(ring.get(9), 0.0, "expired slot must read 0, not the base's load");
        assert_eq!(ring.get(0), 0.0);
        assert_eq!(ring.get(13), 0.0, "last live slot is empty");
        assert_eq!(ring.get(14), 0.0, "beyond-horizon reads 0");
    }

    #[test]
    fn max_tree_matches_linear_scan_under_churn() {
        // The maintained root and range-max must track a brute-force scan
        // through adds, releases, folds and window rotations (including
        // wrap-around ranges).
        let mut rng = crate::stats::rng::Rng::new(0x5107);
        let mut ring = SlotRing::new(7);
        let mut base = 0i64;
        for _ in 0..500 {
            match rng.below(4) {
                0 => {
                    base += rng.below(5) as i64;
                    ring.advance_to(base);
                }
                1 => {
                    let s = base + rng.below(10) as i64 - 2;
                    ring.add(s, (rng.below(100) as f64) / 10.0);
                }
                2 => {
                    let s = base + rng.below(7) as i64;
                    ring.add(s, -((rng.below(50) as f64) / 10.0));
                }
                _ => {
                    let lo = base + rng.below(9) as i64 - 1;
                    let hi = lo + rng.below(9) as i64;
                    let mut want = 0.0_f64;
                    for s in lo..=hi {
                        want = want.max(ring.get(s));
                    }
                    assert_eq!(ring.range_max(lo, hi).to_bits(), want.to_bits());
                }
            }
            let scan = ring.peak_scan();
            assert_eq!(
                ring.peak().to_bits(),
                scan.to_bits(),
                "root {} != scan {}",
                ring.peak(),
                scan
            );
        }
    }

    #[test]
    fn range_max_wraps_across_the_ring_seam() {
        let mut ring = SlotRing::new(5);
        ring.advance_to(3); // cursor now mid-array: ranges can wrap
        ring.add(3, 1.0);
        ring.add(5, 9.0);
        ring.add(7, 4.0);
        assert_eq!(ring.range_max(3, 7), 9.0);
        assert_eq!(ring.range_max(6, 7), 4.0);
        assert_eq!(ring.range_max(0, 2), 0.0, "expired range is empty");
        assert_eq!(ring.range_max(8, 20), 0.0, "beyond-horizon range is empty");
        assert_eq!(ring.range_max(-5, 100), 9.0, "clamps to the live window");
    }

    #[test]
    fn advance_to_jumps_large_gaps() {
        // A wall-clock driver idle for an hour advances ~7200 slots per
        // ring per pump; advance_to must clear at most slots.len() entries
        // and jump the base directly. With the old O(Δslots) loop this
        // multi-billion-slot gap would effectively hang the test.
        let mut ring = SlotRing::new(8);
        ring.add(3, 5.0);
        ring.add(7, 2.0);
        ring.advance_to(10_000_000_000);
        assert_eq!(ring.base_slot, 10_000_000_000);
        assert_eq!(ring.peak(), 0.0, "all live slots expired across the gap");
        ring.add(10_000_000_001, 2.5);
        assert_eq!(ring.get(10_000_000_001), 2.5);
        // A moderate (sub-window) gap still expires exactly the slots it
        // covers and keeps the future ones.
        ring.add(10_000_000_006, 1.5);
        ring.advance_to(10_000_000_004);
        assert_eq!(ring.get(10_000_000_001), 0.0);
        assert_eq!(ring.get(10_000_000_006), 1.5);
    }

    #[test]
    fn beyond_horizon_release_lands_in_fold_slot() {
        // Regression for the fold leak: with a 4-slot horizon (2 s) and a
        // 4 s expected execution, most of the prediction folds into the
        // last slot (abs slot 3). By completion time the ring base has
        // advanced past that slot's original position, so the old release
        // (recomputed against the CURRENT window) subtracted from different
        // absolute slots, was floor-clamped to 0, and left the folded mass
        // stranded: phantom KV load that starves dispatch forever.
        let mut c = cfg();
        c.horizon_slots = 4; // 2 s window, default_exec_time = 4 s
        let mut d = TimeSlotDispatcher::new(1, c);
        let statuses = vec![st(0)];
        let r1 = req(1, 0, 100);
        let j = d.choose(&r1, &statuses, 0.0).unwrap();
        d.on_dispatch(&r1, j, 0.0);
        assert!(d.rings[0].peak() > 0.0);
        // Time passes: a later scheduling round advances the ring base
        // (the dispatch-time fold slot, abs slot 3, is still live, but the
        // CURRENT window's last slot is now abs slot 5).
        let _ = d.choose(&req(2, 0, 900), &statuses, 1.0);
        assert_eq!(d.rings[0].base_slot, 2);
        // The request finishes; every charged slot must be released.
        d.on_complete(1, 0, 1.0);
        assert!(
            d.rings[0].peak() < 1e-6,
            "phantom KV load left in the ring: peak={}",
            d.rings[0].peak()
        );
        // And a near-capacity request can now be placed again.
        assert_eq!(d.choose(&req(3, 0, 900), &statuses, 1.0), Some(0));
    }

    #[test]
    fn fleet_change_resizes_rings_and_skips_non_accepting() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        // The fleet grows to 3 instances; choose must not mis-index.
        let mut statuses = vec![st(0), st(1), st(2)];
        d.on_fleet_change(&statuses);
        assert_eq!(d.rings.len(), 3);
        assert_eq!(d.suspended_until.len(), 3);
        // Load up instance 0 so the packer prefers the new empty ones.
        let r = req(1, 0, 500);
        let j = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, j, 0.0);
        // Instance 1 starts draining: it must never be chosen again even
        // when it has the lowest expected peak.
        statuses[1].accepting = false;
        for k in 2..8 {
            let pick = d.choose(&req(k, 0, 100), &statuses, 0.0).unwrap();
            assert_ne!(pick, 1, "dispatched to a draining instance");
            d.on_dispatch(&req(k, 0, 100), pick, 0.0);
        }
    }

    #[test]
    fn choose_resizes_defensively_without_fleet_change() {
        // A driver that forgot on_fleet_change still must not panic.
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0), st(1), st(2), st(3)];
        let pick = d.choose(&req(1, 0, 10), &statuses, 0.0);
        assert!(pick.is_some());
        assert_eq!(d.rings.len(), 4);
    }

    #[test]
    fn heterogeneous_budgets_respected_per_instance() {
        // Instance 0 is squeezed by a co-tenant (150-token KV budget);
        // instance 1 has the full 1000. The packer must read each budget
        // from the statuses, not a fleet-wide constant.
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let mut small = st(0);
        small.capacity_tokens = 150;
        let statuses = vec![small, st(1)];

        // 500-token prompt exceeds the squeezed instance's entire budget.
        let r1 = req(1, 0, 500);
        let j1 = d.choose(&r1, &statuses, 0.0).unwrap();
        assert_eq!(j1, 1, "oversized request must avoid the squeezed instance");
        d.on_dispatch(&r1, j1, 0.0);

        // A small request fits the squeezed instance (peak 140 <= 150) and
        // prefers it over the loaded big one.
        let r2 = req(2, 0, 100);
        let j2 = d.choose(&r2, &statuses, 0.0).unwrap();
        assert_eq!(j2, 0);
        d.on_dispatch(&r2, j2, 0.0);

        // A second small request would push the squeezed instance to 280 >
        // 150, so it must go to the big instance despite its higher peak.
        let r3 = req(3, 0, 100);
        let j3 = d.choose(&r3, &statuses, 0.0).unwrap();
        assert_eq!(j3, 1, "per-instance budget must bound packing");
    }

    #[test]
    fn pinned_request_stays_in_its_serving_group() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let mut statuses = vec![st(0), st(1)];
        statuses[1].model = ModelKind::Llama2_13B;
        // Load the 13B instance's ring so the 8B one has the lower peak:
        // the pinned request must still land on the 13B instance.
        let filler = req(1, 0, 400);
        d.on_dispatch(&filler, 1, 0.0);
        let mut pinned = req(2, 0, 100);
        pinned.model_class = ModelClass::Model(ModelKind::Llama2_13B);
        assert_eq!(d.choose(&pinned, &statuses, 0.0), Some(1));
        // And a family with no instance defers rather than spilling over.
        let mut orphan = req(3, 0, 100);
        orphan.model_class = ModelClass::Model(ModelKind::Tiny);
        assert_eq!(d.choose(&orphan, &statuses, 0.0), None);
    }

    #[test]
    fn choose_among_prunes_without_changing_the_pick() {
        let mut full = TimeSlotDispatcher::new(3, cfg());
        let mut pruned = TimeSlotDispatcher::new(3, cfg());
        let mut statuses = vec![st(0), st(1), st(2)];
        statuses[1].model = ModelKind::Llama2_13B;
        // Pinned 8B requests: the coordinator's family index would offer
        // exactly [0, 2]. The pruned pick must equal the full scan's for
        // every request in a packing sequence.
        for k in 0..12 {
            let mut r = req(k, 0, 300);
            r.model_class = ModelClass::Model(ModelKind::Llama3_8B);
            let now = k as f64 * 0.25;
            let a = full.choose(&r, &statuses, now);
            let b = pruned.choose_among(&r, &statuses, &[0, 2], now);
            assert_eq!(a, b, "candidate pruning changed the decision for req {k}");
            if let Some(j) = a {
                full.on_dispatch(&r, j, now);
                pruned.on_dispatch(&r, j, now);
            }
        }
        assert_eq!(full.ring_bits(), pruned.ring_bits());
        // A stale candidate set (index beyond the fleet) is skipped, not
        // indexed out of bounds.
        let r = req(99, 0, 10);
        assert!(pruned.choose_among(&r, &statuses, &[7, 0], 10.0).is_some());
    }

    #[test]
    fn per_instance_cost_models_shape_the_ramp() {
        // Same request, same cfg — but the 13B instance holds ~6x denser
        // KV per token, so its predicted footprint must be larger than the
        // 8B instance's for the identical placement.
        let real_cfg = TimeSlotConfig::for_cost_model(&CostModel::new(ModelKind::Llama3_8B));
        let models = [ModelKind::Llama3_8B, ModelKind::Llama2_13B];
        let mut d = TimeSlotDispatcher::for_models(&models, real_cfg);
        let r1 = req(1, 0, 200);
        let r2 = req(2, 0, 200);
        d.on_dispatch(&r1, 0, 0.0);
        d.on_dispatch(&r2, 1, 0.0);
        let peak8 = d.rings[0].peak();
        let peak13 = d.rings[1].peak();
        assert!(
            peak13 > peak8 * 2.0,
            "13B KV density must dominate: peak13={peak13} peak8={peak8}"
        );
        // Completion releases exactly what was charged on each instance.
        d.on_complete(1, 0, 0.0);
        d.on_complete(2, 1, 0.0);
        assert!(d.rings[0].peak() < 1e-6);
        assert!(d.rings[1].peak() < 1e-6);
    }

    #[test]
    fn instance_reset_clears_ring_and_suspension() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        let r = req(1, 0, 900);
        let j = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, j, 0.0);
        d.on_preemption(j, 0.0);
        assert!(d.rings[j].peak() > 0.0);
        // The slot is re-filled with a fresh engine: predictions and the
        // cooldown vanish, and the slot is immediately placeable again.
        d.on_instance_reset(j);
        assert!(d.rings[j].peak() < 1e-6);
        assert_eq!(d.choose(&req(2, 0, 900), &statuses, 0.1), Some(j));
        // A late completion of the evicted tenant is a no-op.
        d.on_complete(1, j, 0.2);
        assert!(d.rings[j].peak() >= 0.0);
    }

    #[test]
    fn learned_demand_overrides_the_slope_guess() {
        // Instance budget 1000 tokens. A 100-token prompt with the slope
        // guess predicts 100 + 10*4/1 = 140 tokens; the learned profile
        // knows this agent's requests balloon to 2000 tokens — over the
        // whole budget, so the dispatch must defer.
        let mut c = cfg();
        c.learned_demand = true;
        let mut d = TimeSlotDispatcher::new(1, c);
        d.set_expected_kv(AgentId(0), 2000.0);
        let statuses = vec![st(0)];
        assert_eq!(d.choose(&req(1, 0, 100), &statuses, 0.0), None);
        // An unprofiled agent still uses the slope guess and fits.
        assert_eq!(d.choose(&req(2, 1, 100), &statuses, 0.0), Some(0));
        // With the hook disabled the learned profile is ignored.
        let mut d2 = TimeSlotDispatcher::new(1, cfg());
        d2.set_expected_kv(AgentId(0), 2000.0);
        assert_eq!(d2.choose(&req(3, 0, 100), &statuses, 0.0), Some(0));
    }

    #[test]
    fn slot_accounting_never_negative() {
        let mut d = TimeSlotDispatcher::new(1, cfg());
        let statuses = vec![st(0)];
        let r = req(1, 0, 100);
        let i = d.choose(&r, &statuses, 0.0).unwrap();
        d.on_dispatch(&r, i, 0.0);
        d.on_complete(1, 0, 0.0);
        // Double-complete must be a no-op.
        d.on_complete(1, 0, 0.0);
        assert!(d.rings[0].peak() >= 0.0);
        assert!(d.rings[0].peak() < 1e-6, "all predicted usage released");
    }

    #[test]
    fn packer_stats_count_fast_paths() {
        let mut d = TimeSlotDispatcher::new(2, cfg());
        let statuses = vec![st(0), st(1)];
        for k in 0..6 {
            if let Some(j) = d.choose(&req(k, 0, 120), &statuses, k as f64 * 0.1) {
                d.on_dispatch(&req(k, 0, 120), j, k as f64 * 0.1);
            }
        }
        let s = d.stats();
        assert_eq!(s.decisions, 6);
        assert_eq!(s.candidates, 12);
        assert_eq!(s.evaluated, 12);
        assert!(s.fast_accepted > 0, "empty-span candidates must fast-accept");
        // The legacy arm never takes a fast path.
        let mut l = TimeSlotDispatcher::new(2, cfg());
        l.set_legacy_scoring(true);
        for k in 0..6 {
            if let Some(j) = l.choose(&req(k, 0, 120), &statuses, k as f64 * 0.1) {
                l.on_dispatch(&req(k, 0, 120), j, k as f64 * 0.1);
            }
        }
        let ls = l.stats();
        assert_eq!(ls.fast_accepted + ls.fast_rejected, 0);
        assert_eq!(ls.decisions, 6);
    }

    // ---- property: legacy vs. max-tree scoring are bit-identical --------

    #[derive(Debug, Clone)]
    enum Op {
        Submit { agent: u32, prompt: u32, pinned: bool },
        Complete { nth: usize },
        Wait { ms: usize },
        Fleet { n: usize },
        Preempt { j: usize },
    }

    fn gen_ops(rng: &mut crate::stats::rng::Rng) -> Vec<Op> {
        let n_ops = 30 + rng.below(50);
        (0..n_ops)
            .map(|_| match rng.below(10) {
                0 => Op::Wait { ms: 1 + rng.below(4000) },
                1 => Op::Complete { nth: rng.below(8) },
                2 => Op::Fleet { n: 1 + rng.below(5) },
                3 => Op::Preempt { j: rng.below(5) },
                _ => Op::Submit {
                    agent: rng.below(4) as u32,
                    prompt: 1 + rng.below(600) as u32,
                    pinned: rng.below(4) == 0,
                },
            })
            .collect()
    }

    fn st_mixed(id: usize) -> InstanceStatus {
        let mut s = st(id);
        if id % 2 == 1 {
            s.model = ModelKind::Llama2_13B;
        }
        // Uneven budgets so rejections and near-capacity bands happen.
        s.capacity_tokens = 300 + 250 * id as u64;
        s
    }

    fn run_scoring_equivalence(ops: &[Op]) -> Result<(), String> {
        let mut legacy = TimeSlotDispatcher::new(3, cfg());
        let mut fast = TimeSlotDispatcher::new(3, cfg());
        legacy.set_legacy_scoring(true);
        let mut statuses: Vec<InstanceStatus> = (0..3).map(st_mixed).collect();
        let mut now = 0.0_f64;
        let mut next_id = 1u64;
        let mut live: Vec<u64> = Vec::new();
        for op in ops {
            match op {
                Op::Wait { ms } => now += *ms as f64 / 1000.0,
                Op::Fleet { n } => {
                    statuses = (0..*n).map(st_mixed).collect();
                    legacy.on_fleet_change(&statuses);
                    fast.on_fleet_change(&statuses);
                }
                Op::Preempt { j } => {
                    if *j < statuses.len() {
                        legacy.on_preemption(*j, now);
                        fast.on_preemption(*j, now);
                    }
                }
                Op::Complete { nth } => {
                    if !live.is_empty() {
                        let id = live.remove(nth % live.len());
                        legacy.on_complete(id, 0, now);
                        fast.on_complete(id, 0, now);
                    }
                }
                Op::Submit { agent, prompt, pinned } => {
                    let mut r = req(next_id, *agent, *prompt);
                    next_id += 1;
                    if *pinned {
                        r.model_class = ModelClass::Model(ModelKind::Llama2_13B);
                    }
                    let a = legacy.choose(&r, &statuses, now);
                    let b = fast.choose(&r, &statuses, now);
                    if a != b {
                        return Err(format!(
                            "decision divergence at req {}: legacy {a:?} fast {b:?}",
                            r.id
                        ));
                    }
                    // The candidate-pruned entry point must agree with the
                    // full scan when offered exactly the matching set.
                    let cands: Vec<usize> = (0..statuses.len())
                        .filter(|&j| r.model_class.matches(statuses[j].model))
                        .collect();
                    let c = fast.choose_among(&r, &statuses, &cands, now);
                    if c != b {
                        return Err(format!(
                            "choose_among divergence at req {}: full {b:?} pruned {c:?}",
                            r.id
                        ));
                    }
                    if let Some(j) = a {
                        legacy.on_dispatch(&r, j, now);
                        fast.on_dispatch(&r, j, now);
                        live.push(r.id);
                    }
                }
            }
            if legacy.ring_bits() != fast.ring_bits() {
                return Err(format!("ring state divergence after {op:?} at t={now}"));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_legacy_and_max_tree_scoring_bit_identical() {
        crate::testing::forall(
            "timeslot-scoring-equivalence",
            64,
            0xC0FFEE,
            gen_ops,
            |ops| run_scoring_equivalence(ops),
        );
    }
}
