//! Paged KV-cache block accounting (vLLM's PagedAttention block manager).
//!
//! The dispatcher experiments hinge on this: when a batch's KV demand
//! exceeds the instance's block pool, the engine must preempt and recompute
//! (paper §2.2.3 measures 18.4% of requests preempted under Round-Robin).

/// Allocator for fixed-size KV blocks of one engine instance.
#[derive(Debug, Clone)]
pub struct BlockManager {
    block_size: u32,
    total_blocks: u32,
    used_blocks: u32,
    /// Cumulative allocation failures (diagnostics).
    pub alloc_failures: u64,
}

impl BlockManager {
    pub fn new(total_blocks: u32, block_size: u32) -> BlockManager {
        assert!(block_size > 0 && total_blocks > 0);
        BlockManager { block_size, total_blocks, used_blocks: 0, alloc_failures: 0 }
    }

    /// Blocks required to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_size)
    }

    pub fn free_blocks(&self) -> u32 {
        self.total_blocks - self.used_blocks
    }

    pub fn used_blocks(&self) -> u32 {
        self.used_blocks
    }

    pub fn total_blocks(&self) -> u32 {
        self.total_blocks
    }

    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_blocks as f64 / self.total_blocks as f64
    }

    /// Try to allocate `n` blocks; returns false (and counts the failure)
    /// if the pool cannot satisfy it.
    pub fn allocate(&mut self, n: u32) -> bool {
        if n <= self.free_blocks() {
            self.used_blocks += n;
            true
        } else {
            self.alloc_failures += 1;
            false
        }
    }

    /// Release `n` blocks back to the pool.
    pub fn free(&mut self, n: u32) {
        assert!(n <= self.used_blocks, "double free: {} > {}", n, self.used_blocks);
        self.used_blocks -= n;
    }

    /// Whether a sequence growing from `tokens` to `tokens + 1` needs a new
    /// block appended.
    pub fn needs_new_block(&self, tokens: u32) -> bool {
        tokens % self.block_size == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::testing::forall;

    #[test]
    fn blocks_for_rounds_up() {
        let bm = BlockManager::new(100, 16);
        assert_eq!(bm.blocks_for(0), 0);
        assert_eq!(bm.blocks_for(1), 1);
        assert_eq!(bm.blocks_for(16), 1);
        assert_eq!(bm.blocks_for(17), 2);
    }

    #[test]
    fn alloc_free_cycle() {
        let mut bm = BlockManager::new(10, 16);
        assert!(bm.allocate(4));
        assert_eq!(bm.free_blocks(), 6);
        assert!(bm.allocate(6));
        assert!(!bm.allocate(1));
        assert_eq!(bm.alloc_failures, 1);
        bm.free(10);
        assert_eq!(bm.free_blocks(), 10);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut bm = BlockManager::new(10, 16);
        bm.allocate(2);
        bm.free(3);
    }

    #[test]
    fn needs_new_block_at_boundaries() {
        let bm = BlockManager::new(10, 16);
        assert!(bm.needs_new_block(0));
        assert!(!bm.needs_new_block(1));
        assert!(!bm.needs_new_block(15));
        assert!(bm.needs_new_block(16));
        assert!(bm.needs_new_block(32));
    }

    #[test]
    fn conservation_property() {
        // Random alloc/free traces never violate used + free == total.
        forall(
            "block-conservation",
            200,
            0xB10C,
            |rng: &mut Rng| {
                let ops: Vec<(bool, u32)> = (0..50)
                    .map(|_| (rng.chance(0.6), rng.below(8) as u32 + 1))
                    .collect();
                ops
            },
            |ops| {
                let mut bm = BlockManager::new(32, 16);
                let mut held: Vec<u32> = vec![];
                for &(is_alloc, n) in ops {
                    if is_alloc {
                        if bm.allocate(n) {
                            held.push(n);
                        }
                    } else if let Some(n) = held.pop() {
                        bm.free(n);
                    }
                    let held_sum: u32 = held.iter().sum();
                    if bm.used_blocks() != held_sum {
                        return Err(format!(
                            "used {} != held {}",
                            bm.used_blocks(),
                            held_sum
                        ));
                    }
                    if bm.used_blocks() + bm.free_blocks() != bm.total_blocks() {
                        return Err("used + free != total".into());
                    }
                }
                Ok(())
            },
        );
    }
}
