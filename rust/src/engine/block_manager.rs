//! Paged KV-cache block accounting (vLLM's PagedAttention block manager).
//!
//! The dispatcher experiments hinge on this: when a batch's KV demand
//! exceeds the instance's block pool, the engine must preempt and recompute
//! (paper §2.2.3 measures 18.4% of requests preempted under Round-Robin).

/// Allocator for fixed-size KV blocks of one engine instance.
#[derive(Debug, Clone)]
pub struct BlockManager {
    block_size: u32,
    total_blocks: u32,
    used_blocks: u32,
    /// Cumulative allocation failures (diagnostics).
    pub alloc_failures: u64,
}

impl BlockManager {
    pub fn new(total_blocks: u32, block_size: u32) -> BlockManager {
        assert!(block_size > 0 && total_blocks > 0);
        BlockManager { block_size, total_blocks, used_blocks: 0, alloc_failures: 0 }
    }

    /// Blocks required to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_size)
    }

    pub fn free_blocks(&self) -> u32 {
        self.total_blocks - self.used_blocks
    }

    pub fn used_blocks(&self) -> u32 {
        self.used_blocks
    }

    pub fn total_blocks(&self) -> u32 {
        self.total_blocks
    }

    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_blocks as f64 / self.total_blocks as f64
    }

    /// Try to allocate `n` blocks; returns false (and counts the failure)
    /// if the pool cannot satisfy it.
    pub fn allocate(&mut self, n: u32) -> bool {
        if n <= self.free_blocks() {
            self.used_blocks += n;
            true
        } else {
            self.alloc_failures += 1;
            false
        }
    }

    /// Release `n` blocks back to the pool.
    pub fn free(&mut self, n: u32) {
        assert!(n <= self.used_blocks, "double free: {} > {}", n, self.used_blocks);
        self.used_blocks -= n;
    }

    /// Whether a sequence growing from `tokens` to `tokens + 1` needs a new
    /// block appended.
    pub fn needs_new_block(&self, tokens: u32) -> bool {
        tokens % self.block_size == 0
    }
}

/// One cached prefix: the longest context this session has completed on
/// this instance, and the blocks it pins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PrefixEntry {
    session: u64,
    prefix_tokens: u32,
    blocks: u32,
    /// Logical LRU clock (insert/hit counter, never wall time).
    last_used: u64,
}

/// Deterministic per-instance prefix/KV cache model.
///
/// Holds one `(session_key, prefix_tokens)` entry per session, LRU-evicted
/// under a configurable block budget. A `lookup` hit shortens the effective
/// prefill of the next stage of that session (the engine still allocates
/// the full context's KV blocks — the cache models *recompute* avoidance,
/// not extra residency). Recency is a logical counter, so behavior is
/// bit-identical across drivers and hosts.
#[derive(Debug, Clone)]
pub struct PrefixCache {
    budget_blocks: u32,
    block_size: u32,
    /// Entries in insertion order; scans are linear (entry count is bounded
    /// by the block budget since every entry pins at least one block).
    entries: Vec<PrefixEntry>,
    cached_blocks: u32,
    tick: u64,
    /// Lookups that found a usable prefix for the session.
    pub hits: u64,
    /// Lookups that found nothing for the session.
    pub misses: u64,
    /// Prefill tokens skipped across all hits.
    pub saved_prefill_tokens: u64,
    /// Entries inserted (longest-prefix updates count too).
    pub insertions: u64,
    /// Entries evicted to stay under the block budget.
    pub evictions: u64,
}

impl PrefixCache {
    /// A cache holding at most `budget_blocks` blocks of `block_size`
    /// tokens each.
    pub fn new(budget_blocks: u32, block_size: u32) -> PrefixCache {
        assert!(budget_blocks > 0 && block_size > 0);
        PrefixCache {
            budget_blocks,
            block_size,
            entries: Vec::new(),
            cached_blocks: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            saved_prefill_tokens: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Configured block budget.
    pub fn budget_blocks(&self) -> u32 {
        self.budget_blocks
    }

    /// Blocks currently pinned by cached prefixes (≤ budget, audited).
    pub fn cached_blocks(&self) -> u32 {
        self.cached_blocks
    }

    /// Cached sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Tokens of `prompt_tokens` already held for `session` (0 on miss).
    /// Capped at `prompt_tokens - 1` so at least one token is always
    /// prefilled (the hit invariant `hit ≤ prompt` is audited by
    /// `kairos check`). Refreshes the entry's recency and counts the
    /// hit/miss and saved tokens.
    pub fn lookup(&mut self, session: u64, prompt_tokens: u32) -> u32 {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.iter_mut().find(|e| e.session == session) {
            Some(e) => {
                e.last_used = tick;
                let hit = e.prefix_tokens.min(prompt_tokens.saturating_sub(1));
                if hit > 0 {
                    self.hits += 1;
                    self.saved_prefill_tokens += u64::from(hit);
                } else {
                    self.misses += 1;
                }
                hit
            }
            None => {
                self.misses += 1;
                0
            }
        }
    }

    /// Record that `session` now has `prefix_tokens` of context resident
    /// (called at stage completion with the final context length). Keeps
    /// the longest prefix per session and LRU-evicts other sessions until
    /// the block budget holds; a prefix larger than the whole budget is
    /// not cached.
    pub fn insert(&mut self, session: u64, prefix_tokens: u32) {
        if prefix_tokens == 0 {
            return;
        }
        let blocks = prefix_tokens.div_ceil(self.block_size);
        if blocks > self.budget_blocks {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.entries.iter_mut().find(|e| e.session == session) {
            e.last_used = tick;
            if prefix_tokens <= e.prefix_tokens {
                return;
            }
            self.cached_blocks = self.cached_blocks - e.blocks + blocks;
            e.prefix_tokens = prefix_tokens;
            e.blocks = blocks;
        } else {
            self.entries.push(PrefixEntry {
                session,
                prefix_tokens,
                blocks,
                last_used: tick,
            });
            self.cached_blocks += blocks;
        }
        self.insertions += 1;
        while self.cached_blocks > self.budget_blocks {
            // LRU victim; ties (impossible under the monotone tick, but
            // kept explicit) break toward the smaller session key.
            let victim = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| (e.last_used, e.session))
                .map(|(i, _)| i)
                .expect("cached_blocks > 0 implies entries exist");
            let e = self.entries.remove(victim);
            self.cached_blocks -= e.blocks;
            self.evictions += 1;
        }
    }

    /// Internal-consistency audit: cached blocks within budget, per-entry
    /// block counts matching their token counts, and the running total
    /// matching the entries. Returns human-readable violations (empty =
    /// clean); surfaced through `Coordinator::audit_invariants` and
    /// `kairos check`.
    pub fn audit(&self) -> Vec<String> {
        let mut violations = Vec::new();
        if self.cached_blocks > self.budget_blocks {
            violations.push(format!(
                "prefix cache holds {} blocks over budget {}",
                self.cached_blocks, self.budget_blocks
            ));
        }
        let mut sum = 0u32;
        for e in &self.entries {
            if e.blocks != e.prefix_tokens.div_ceil(self.block_size) {
                violations.push(format!(
                    "session {} pins {} blocks for {} tokens (block_size {})",
                    e.session, e.blocks, e.prefix_tokens, self.block_size
                ));
            }
            sum += e.blocks;
        }
        if sum != self.cached_blocks {
            violations.push(format!(
                "prefix cache accounting drift: entries pin {} blocks, counter says {}",
                sum, self.cached_blocks
            ));
        }
        violations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::testing::forall;

    #[test]
    fn blocks_for_rounds_up() {
        let bm = BlockManager::new(100, 16);
        assert_eq!(bm.blocks_for(0), 0);
        assert_eq!(bm.blocks_for(1), 1);
        assert_eq!(bm.blocks_for(16), 1);
        assert_eq!(bm.blocks_for(17), 2);
    }

    #[test]
    fn alloc_free_cycle() {
        let mut bm = BlockManager::new(10, 16);
        assert!(bm.allocate(4));
        assert_eq!(bm.free_blocks(), 6);
        assert!(bm.allocate(6));
        assert!(!bm.allocate(1));
        assert_eq!(bm.alloc_failures, 1);
        bm.free(10);
        assert_eq!(bm.free_blocks(), 10);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut bm = BlockManager::new(10, 16);
        bm.allocate(2);
        bm.free(3);
    }

    #[test]
    fn needs_new_block_at_boundaries() {
        let bm = BlockManager::new(10, 16);
        assert!(bm.needs_new_block(0));
        assert!(!bm.needs_new_block(1));
        assert!(!bm.needs_new_block(15));
        assert!(bm.needs_new_block(16));
        assert!(bm.needs_new_block(32));
    }

    #[test]
    fn prefix_cache_hit_miss_and_longest_prefix() {
        let mut pc = PrefixCache::new(8, 16);
        assert_eq!(pc.lookup(7, 100), 0, "cold cache misses");
        assert_eq!(pc.misses, 1);
        pc.insert(7, 40); // 3 blocks
        assert_eq!(pc.cached_blocks(), 3);
        assert_eq!(pc.lookup(7, 100), 40);
        assert_eq!(pc.hits, 1);
        assert_eq!(pc.saved_prefill_tokens, 40);
        // Hit is capped below the prompt: one token always prefills.
        assert_eq!(pc.lookup(7, 30), 29);
        // Longest prefix wins; shrinking inserts are ignored.
        pc.insert(7, 64); // 4 blocks
        pc.insert(7, 16);
        assert_eq!(pc.cached_blocks(), 4);
        assert_eq!(pc.lookup(7, 1000), 64);
        assert!(pc.audit().is_empty(), "{:?}", pc.audit());
    }

    #[test]
    fn prefix_cache_lru_eviction_respects_budget() {
        let mut pc = PrefixCache::new(4, 16);
        pc.insert(1, 32); // 2 blocks
        pc.insert(2, 32); // 2 blocks — budget full
        assert_eq!(pc.lookup(1, 100), 31, "refresh session 1");
        pc.insert(3, 16); // 1 block: evicts LRU session 2
        assert_eq!(pc.lookup(2, 100), 0, "session 2 evicted");
        assert_eq!(pc.lookup(1, 100), 31, "session 1 survived");
        assert_eq!(pc.evictions, 1);
        assert!(pc.cached_blocks() <= pc.budget_blocks());
        // An entry larger than the whole budget is refused outright.
        pc.insert(9, 16 * 5);
        assert_eq!(pc.lookup(9, 1000), 0);
        assert!(pc.audit().is_empty(), "{:?}", pc.audit());
    }

    #[test]
    fn prefix_cache_budget_property() {
        // Random lookup/insert streams never exceed the budget and never
        // drift the block accounting.
        forall(
            "prefix-cache-budget",
            200,
            0xCACE,
            |rng: &mut Rng| {
                let ops: Vec<(bool, u64, u32)> = (0..60)
                    .map(|_| {
                        (rng.chance(0.5), rng.below(12), rng.below(200) as u32 + 1)
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut pc = PrefixCache::new(6, 16);
                for &(is_insert, session, tokens) in ops {
                    if is_insert {
                        pc.insert(session, tokens);
                    } else {
                        let hit = pc.lookup(session, tokens);
                        if hit >= tokens.max(1) {
                            return Err(format!("hit {hit} >= prompt {tokens}"));
                        }
                    }
                    let audit = pc.audit();
                    if !audit.is_empty() {
                        return Err(audit.join("; "));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn conservation_property() {
        // Random alloc/free traces never violate used + free == total.
        forall(
            "block-conservation",
            200,
            0xB10C,
            |rng: &mut Rng| {
                let ops: Vec<(bool, u32)> = (0..50)
                    .map(|_| (rng.chance(0.6), rng.below(8) as u32 + 1))
                    .collect();
                ops
            },
            |ops| {
                let mut bm = BlockManager::new(32, 16);
                let mut held: Vec<u32> = vec![];
                for &(is_alloc, n) in ops {
                    if is_alloc {
                        if bm.allocate(n) {
                            held.push(n);
                        }
                    } else if let Some(n) = held.pop() {
                        bm.free(n);
                    }
                    let held_sum: u32 = held.iter().sum();
                    if bm.used_blocks() != held_sum {
                        return Err(format!(
                            "used {} != held {}",
                            bm.used_blocks(),
                            held_sum
                        ));
                    }
                    if bm.used_blocks() + bm.free_blocks() != bm.total_blocks() {
                        return Err("used + free != total".into());
                    }
                }
                Ok(())
            },
        );
    }
}
