//! Continuous-batching engine core (the vLLM iteration loop).
//!
//! One [`EngineCore`] is one LLM instance (one GPU in the paper's testbed).
//! Every call to [`EngineCore::step`] runs one iteration:
//!
//! 1. **Admit** waiting sequences (prefill) while KV blocks and the batch /
//!    prefill-token budgets allow — vLLM's prefill-priority scheduling.
//! 2. **Grow** decoding sequences by one block at block boundaries; if the
//!    pool is exhausted, **preempt** the latest-arrived decoding sequence
//!    (recompute-style: its blocks are freed and it re-enters the waiting
//!    queue to re-prefill prompt + already-generated tokens).
//! 3. **Execute** the iteration through the [`ExecBackend`] (virtual-time
//!    cost model or real PJRT compute) and advance sequence state.
//! 4. **Complete** sequences that reached their output length.

use std::collections::VecDeque;

use super::block_manager::{BlockManager, PrefixCache};
use super::cost_model::{effective_prefill, CostModel, ModelKind};
use super::request::{Request, RequestId, SeqPhase, SeqState};
use crate::Time;

/// Execution backend: advances the actual compute for one iteration and
/// returns its duration in seconds.
pub trait ExecBackend {
    /// `prefill`: (request, tokens to prefill) admitted this step.
    /// `decode`: (request, current context length) generating one token.
    fn run_step(&mut self, prefill: &[(RequestId, u32)], decode: &[(RequestId, u32)]) -> f64;
}

/// Virtual-time backend: the calibrated cost model *is* the execution.
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub cost: CostModel,
}

impl SimBackend {
    pub fn new(cost: CostModel) -> SimBackend {
        SimBackend { cost }
    }
}

impl ExecBackend for SimBackend {
    fn run_step(&mut self, prefill: &[(RequestId, u32)], decode: &[(RequestId, u32)]) -> f64 {
        let prefill_tokens: u32 = prefill.iter().map(|&(_, t)| t).sum();
        let sum_ctx: u64 = decode.iter().map(|&(_, c)| c as u64).sum();
        self.cost.step_time(prefill_tokens, decode.len() as u32, sum_ctx)
    }
}

/// Outcome of one engine iteration.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Iteration duration (seconds; virtual or measured).
    pub duration: f64,
    /// Sequences that finished this step.
    pub completed: Vec<SeqState>,
    /// Sequences preempted this step.
    pub preempted: u32,
    /// Prefill tokens processed.
    pub prefill_tokens: u32,
    /// Decoding sequences advanced.
    pub n_decode: u32,
}

/// Point-in-time view of an instance for the dispatcher / status monitor
/// (the paper's vLLM status APIs).
#[derive(Debug, Clone, Copy)]
pub struct InstanceStatus {
    pub id: usize,
    pub free_blocks: u32,
    pub used_blocks: u32,
    pub total_blocks: u32,
    pub block_size: u32,
    pub n_running: usize,
    pub n_waiting: usize,
    /// Prompt tokens of requests dispatched but not yet admitted.
    pub waiting_tokens: u64,
    /// KV tokens currently committed (running context).
    pub committed_tokens: u64,
    /// Token capacity of the KV pool. Under a co-tenant
    /// [`PressureTrace`](crate::server::pressure::PressureTrace) the
    /// coordinator scales this down from the engine's physical pool, so
    /// dispatchers always pack against the *currently available* budget.
    pub capacity_tokens: u64,
    pub preemptions: u64,
    /// Cumulative KV-block allocation failures (admission attempts the
    /// pool could not satisfy) — the dispatcher-visible preemption-pressure
    /// signal next to the prefix-cache hit rate.
    pub alloc_failures: u64,
    /// Whether the instance accepts new dispatches. The engine itself is
    /// always accepting; the coordinator clears this for instances that are
    /// draining toward retirement or already retired, and every dispatcher
    /// must skip non-accepting instances.
    pub accepting: bool,
    /// Model family this instance serves. Dispatchers must only place a
    /// request on an instance whose model its
    /// [`ModelClass`](crate::engine::cost_model::ModelClass) matches.
    pub model: ModelKind,
}

impl InstanceStatus {
    pub fn utilization(&self) -> f64 {
        self.used_blocks as f64 / self.total_blocks as f64
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Model family this engine serves (reported through
    /// [`InstanceStatus::model`] for group-aware dispatching).
    pub model: ModelKind,
    pub block_size: u32,
    pub total_blocks: u32,
    /// Max sequences resident in a batch (vLLM `max_num_seqs`).
    pub max_batch: usize,
    /// Max prefill tokens admitted per iteration (vLLM
    /// `max_num_batched_tokens`).
    pub max_prefill_tokens: u32,
    /// Prefix-cache block budget; `0` disables the cache (the default).
    pub prefix_cache_blocks: u32,
}

impl EngineConfig {
    /// Config for a GPU instance serving `model`, with the full KV pool of
    /// its calibrated cost model.
    pub fn for_model(model: ModelKind, block_size: u32) -> EngineConfig {
        let cost = CostModel::new(model);
        EngineConfig {
            model,
            block_size,
            total_blocks: cost.total_blocks(block_size),
            max_batch: 256,
            max_prefill_tokens: 2048,
            prefix_cache_blocks: 0,
        }
    }
}

/// One LLM instance: waiting queue + running batch + block pool + backend.
pub struct EngineCore<B: ExecBackend> {
    pub id: usize,
    pub backend: B,
    blocks: BlockManager,
    cfg: EngineConfig,
    waiting: VecDeque<SeqState>,
    running: Vec<SeqState>,
    // counters
    pub preemptions: u64,
    pub steps: u64,
    pub tokens_generated: u64,
    /// Tokens re-prefilled due to preemption (wasted work; §2.2.3 reports
    /// 14.2% of memory wasted under Round-Robin).
    pub recomputed_tokens: u64,
    /// When true, the dispatcher has suspended this instance after an
    /// OOM-suspect (paper §6 adaptive measure).
    pub suspended: bool,
    /// Set when the waiting queue changed since the last policy sort
    /// (avoids re-sorting on every iteration — EXPERIMENTS.md §Perf).
    pub waiting_dirty: bool,
    /// Prefix/KV cache model (None when `prefix_cache_blocks` is 0): a hit
    /// at submit time shortens the sequence's effective prefill.
    prefix_cache: Option<PrefixCache>,
}

impl<B: ExecBackend> EngineCore<B> {
    pub fn new(id: usize, cfg: EngineConfig, backend: B) -> EngineCore<B> {
        let prefix_cache = (cfg.prefix_cache_blocks > 0)
            .then(|| PrefixCache::new(cfg.prefix_cache_blocks, cfg.block_size));
        EngineCore {
            id,
            backend,
            blocks: BlockManager::new(cfg.total_blocks, cfg.block_size),
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            preemptions: 0,
            steps: 0,
            tokens_generated: 0,
            recomputed_tokens: 0,
            suspended: false,
            waiting_dirty: false,
            prefix_cache,
        }
    }

    /// Enqueue a dispatched request. With the prefix cache enabled, a
    /// session hit shortens the effective prefill (the KV-block footprint
    /// is unchanged — the cache models recompute avoidance, not extra
    /// residency). Preempted sequences re-prefill their full context: the
    /// recompute cost of preemption is the phenomenon under study.
    pub fn submit(&mut self, req: Request, now: Time) {
        let mut seq = SeqState::new(req, now);
        if let Some(pc) = self.prefix_cache.as_mut() {
            let hit = pc.lookup(seq.req.session, seq.req.prompt_tokens);
            seq.prefill_tokens = effective_prefill(seq.req.prompt_tokens, hit);
        }
        self.waiting.push_back(seq);
        self.waiting_dirty = true;
    }

    /// The prefix-cache model, when enabled (hit/miss counters and audits).
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix_cache.as_ref()
    }

    /// Mutable access to the prefix-cache model — the coordinator uses
    /// this to fold-and-zero the traffic counters into run metrics.
    pub fn prefix_cache_mut(&mut self) -> Option<&mut PrefixCache> {
        self.prefix_cache.as_mut()
    }

    /// Drain the cumulative KV allocation-failure counter (fold-and-zero;
    /// the coordinator sums it into the run's streaming metrics, so the
    /// sweep stays idempotent across drain-time and end-of-run folds).
    pub fn take_alloc_failures(&mut self) -> u64 {
        std::mem::take(&mut self.blocks.alloc_failures)
    }

    /// Whether the engine has any work.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    pub fn status(&self) -> InstanceStatus {
        InstanceStatus {
            id: self.id,
            free_blocks: self.blocks.free_blocks(),
            used_blocks: self.blocks.used_blocks(),
            total_blocks: self.blocks.total_blocks(),
            block_size: self.blocks.block_size(),
            n_running: self.running.len(),
            n_waiting: self.waiting.len(),
            waiting_tokens: self
                .waiting
                .iter()
                .map(|s| s.prefill_tokens as u64)
                .sum(),
            committed_tokens: self
                .running
                .iter()
                .map(|s| s.context_len() as u64)
                .sum(),
            capacity_tokens: self.blocks.total_blocks() as u64
                * self.blocks.block_size() as u64,
            preemptions: self.preemptions,
            alloc_failures: self.blocks.alloc_failures,
            accepting: true,
            model: self.cfg.model,
        }
    }

    /// Number of sequences currently resident (running batch).
    pub fn batch_len(&self) -> usize {
        self.running.len()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Re-order the waiting queue by a scheduling key (lower = admitted
    /// first). This is how the system's scheduling policy governs the
    /// engine-side queue — vLLM's pluggable scheduling policy; FCFS for
    /// Parrot, topology depth for Ayo, Kairos' agent priority + app start
    /// for Kairos. Preempted sequences compete with their original key (a
    /// preempted request does not lose its place).
    pub fn sort_waiting_by<F: Fn(&Request) -> (f64, f64)>(&mut self, key: F) {
        self.waiting_dirty = false;
        if self.waiting.len() < 2 {
            return;
        }
        let mut v: Vec<SeqState> = self.waiting.drain(..).collect();
        // total_cmp keeps the comparator a total order even under NaN keys
        // (sort_by may panic otherwise).
        v.sort_by(|a, b| {
            let ka = key(&a.req);
            let kb = key(&b.req);
            ka.0.total_cmp(&kb.0)
                .then(ka.1.total_cmp(&kb.1))
                .then(a.req.stage_arrival.total_cmp(&b.req.stage_arrival))
        });
        self.waiting = v.into();
    }

    /// Run one continuous-batching iteration at engine-local time `now`.
    pub fn step(&mut self, now: Time) -> StepOutcome {
        let mut out = StepOutcome::default();

        // --- 1. Admit waiting sequences (prefill-priority) ---------------
        let mut prefill_budget = self.cfg.max_prefill_tokens;
        while let Some(front) = self.waiting.front() {
            if self.running.len() >= self.cfg.max_batch {
                break;
            }
            let need_tokens = front.prefill_tokens;
            if need_tokens > prefill_budget && out.prefill_tokens > 0 {
                break; // token budget exhausted (always admit >= 1 if possible)
            }
            // +1: room for the first generated token of this iteration.
            let need_blocks = self.blocks.blocks_for(front.context_len() + 1);
            // vLLM-style watermark: keep one growth block of headroom per
            // resident sequence so admission does not immediately force
            // decode-time preemption.
            let headroom = self.running.len() as u32 + 1;
            if need_blocks + headroom > self.blocks.free_blocks() {
                self.blocks.alloc_failures += 1;
                break; // no memory: stay queued
            }
            let ok = self.blocks.allocate(need_blocks);
            debug_assert!(ok);
            let mut seq = self.waiting.pop_front().unwrap();
            seq.held_blocks = need_blocks;
            seq.admitted_at = now;
            seq.first_admitted_at.get_or_insert(now);
            prefill_budget = prefill_budget.saturating_sub(need_tokens);
            out.prefill_tokens += need_tokens;
            if seq.preempt_count > 0 {
                self.recomputed_tokens += need_tokens as u64;
            }
            self.running.push(seq);
        }

        // --- 2. Block growth for decoding sequences; preempt on pressure -
        let mut need_growth: Vec<usize> = Vec::new();
        for (i, s) in self.running.iter().enumerate() {
            if s.phase == SeqPhase::Decoding && self.blocks.needs_new_block(s.context_len())
            {
                need_growth.push(i);
            }
        }
        // Preempt latest-arrived decoding sequences until growth fits.
        while (need_growth.len() as u32) > self.blocks.free_blocks() {
            let victim_idx = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| s.phase == SeqPhase::Decoding)
                .max_by(|(_, a), (_, b)| {
                    a.req.stage_arrival.total_cmp(&b.req.stage_arrival)
                })
                .map(|(i, _)| i);
            let Some(vi) = victim_idx else { break };
            let mut victim = self.running.swap_remove(vi);
            self.blocks.free(victim.held_blocks);
            victim.held_blocks = 0;
            victim.preempt_count += 1;
            victim.phase = SeqPhase::NeedsPrefill;
            // Recompute-style: the whole context must be prefilled again.
            victim.prefill_tokens = victim.context_len();
            self.preemptions += 1;
            out.preempted += 1;
            self.waiting.push_front(victim);
            self.waiting_dirty = true;
            // Re-derive growth set (indices shifted by swap_remove).
            need_growth.clear();
            for (i, s) in self.running.iter().enumerate() {
                if s.phase == SeqPhase::Decoding
                    && self.blocks.needs_new_block(s.context_len())
                {
                    need_growth.push(i);
                }
            }
        }
        for &i in &need_growth {
            let ok = self.blocks.allocate(1);
            debug_assert!(ok, "growth allocation must succeed after preemption");
            self.running[i].held_blocks += 1;
        }

        // --- 3. Execute the iteration -------------------------------------
        let prefill: Vec<(RequestId, u32)> = self
            .running
            .iter()
            .filter(|s| s.phase == SeqPhase::NeedsPrefill)
            .map(|s| (s.req.id, s.prefill_tokens))
            .collect();
        let decode: Vec<(RequestId, u32)> = self
            .running
            .iter()
            .filter(|s| s.phase == SeqPhase::Decoding)
            .map(|s| (s.req.id, s.context_len()))
            .collect();
        if prefill.is_empty() && decode.is_empty() {
            return out; // idle
        }
        out.n_decode = decode.len() as u32;
        out.duration = self.backend.run_step(&prefill, &decode);
        self.steps += 1;

        // --- 4. Advance sequence state ------------------------------------
        for s in self.running.iter_mut() {
            match s.phase {
                SeqPhase::NeedsPrefill => {
                    // Prefill iteration also emits the first new token.
                    s.phase = SeqPhase::Decoding;
                    s.prefill_tokens = 0;
                    s.generated += 1;
                    self.tokens_generated += 1;
                }
                SeqPhase::Decoding => {
                    s.generated += 1;
                    self.tokens_generated += 1;
                }
            }
        }

        // --- 5. Collect completions ---------------------------------------
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].is_finished() {
                let seq = self.running.swap_remove(i);
                self.blocks.free(seq.held_blocks);
                if let Some(pc) = self.prefix_cache.as_mut() {
                    // The completed stage's full context becomes the
                    // session's cached prefix for its next stage.
                    pc.insert(seq.req.session, seq.context_len());
                }
                out.completed.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Drain every request (used on shutdown): waiting + running, in order.
    pub fn drain(&mut self) -> Vec<Request> {
        let mut reqs: Vec<Request> = self.waiting.drain(..).map(|s| s.req).collect();
        for s in self.running.drain(..) {
            self.blocks.free(s.held_blocks);
            reqs.push(s.req);
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::orchestrator::ids::AgentId;

    fn mk_req(id: u64, prompt: u32, output: u32, arrival: f64) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session: id,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: prompt,
            true_output_tokens: output,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: arrival,
            stage_arrival: arrival,
        }
    }

    fn small_engine(total_blocks: u32) -> EngineCore<SimBackend> {
        let cfg = EngineConfig {
            model: ModelKind::Llama3_8B,
            block_size: 16,
            total_blocks,
            max_batch: 64,
            max_prefill_tokens: 4096,
            prefix_cache_blocks: 0,
        };
        EngineCore::new(0, cfg, SimBackend::new(CostModel::new(ModelKind::Llama3_8B)))
    }

    #[test]
    fn single_request_runs_to_completion() {
        let mut e = small_engine(1000);
        e.submit(mk_req(1, 100, 10, 0.0), 0.0);
        let mut now = 0.0;
        let mut completed = vec![];
        for _ in 0..100 {
            let out = e.step(now);
            now += out.duration;
            completed.extend(out.completed);
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(completed.len(), 1);
        let s = &completed[0];
        assert_eq!(s.generated, 10);
        assert_eq!(s.preempt_count, 0);
        // All blocks returned.
        assert_eq!(e.status().used_blocks, 0);
        assert!(now > 0.0);
    }

    #[test]
    fn prefill_emits_first_token() {
        let mut e = small_engine(1000);
        e.submit(mk_req(1, 32, 1, 0.0), 0.0);
        let out = e.step(0.0);
        assert_eq!(out.prefill_tokens, 32);
        assert_eq!(out.completed.len(), 1, "output of 1 finishes in the prefill step");
    }

    #[test]
    fn continuous_batching_admits_mid_flight() {
        let mut e = small_engine(1000);
        e.submit(mk_req(1, 50, 100, 0.0), 0.0);
        e.step(0.0);
        assert_eq!(e.batch_len(), 1);
        // Another request arrives mid-generation and joins the batch.
        e.submit(mk_req(2, 50, 100, 1.0), 1.0);
        let out = e.step(1.0);
        assert_eq!(e.batch_len(), 2);
        assert!(out.prefill_tokens > 0 && out.n_decode == 1);
    }

    #[test]
    fn preemption_under_block_pressure() {
        // Pool sized so either sequence fits alone (needs 7 blocks at peak)
        // and both pass admission (3+headroom blocks each), but the two
        // cannot grow to completion concurrently.
        let mut e = small_engine(9);
        e.submit(mk_req(1, 32, 80, 0.0), 0.0);
        e.submit(mk_req(2, 32, 80, 0.5), 0.0);
        let mut preempted_total = 0;
        let mut now = 0.0;
        for _ in 0..1000 {
            let out = e.step(now);
            now += out.duration.max(1e-6);
            preempted_total += out.preempted;
            if !e.has_work() {
                break;
            }
        }
        assert!(preempted_total > 0, "block pressure must trigger preemption");
        // Later arrival (id 2) must be the preemption victim first.
        // Both must eventually complete despite preemption.
        assert!(!e.has_work());
        assert_eq!(e.status().used_blocks, 0);
        assert!(e.recomputed_tokens > 0);
    }

    #[test]
    fn memory_never_overcommitted() {
        let mut e = small_engine(20);
        for i in 0..10 {
            e.submit(mk_req(i, 64, 80, i as f64 * 0.1), 0.0);
        }
        let mut now = 0.0;
        for _ in 0..500 {
            let out = e.step(now);
            now += out.duration.max(1e-6);
            let st = e.status();
            assert!(st.used_blocks <= st.total_blocks);
            if !e.has_work() {
                break;
            }
        }
        assert!(!e.has_work(), "all requests must finish");
        assert_eq!(e.status().used_blocks, 0);
    }

    #[test]
    fn max_batch_respected() {
        let cfg = EngineConfig {
            model: ModelKind::Llama3_8B,
            block_size: 16,
            total_blocks: 10_000,
            max_batch: 4,
            max_prefill_tokens: 1 << 20,
            prefix_cache_blocks: 0,
        };
        let mut e =
            EngineCore::new(0, cfg, SimBackend::new(CostModel::new(ModelKind::Llama3_8B)));
        for i in 0..10 {
            e.submit(mk_req(i, 16, 50, 0.0), 0.0);
        }
        e.step(0.0);
        assert_eq!(e.batch_len(), 4);
        assert_eq!(e.waiting_len(), 6);
    }

    #[test]
    fn prefill_token_budget_limits_admission() {
        let cfg = EngineConfig {
            model: ModelKind::Llama3_8B,
            block_size: 16,
            total_blocks: 10_000,
            max_batch: 256,
            max_prefill_tokens: 100,
            prefix_cache_blocks: 0,
        };
        let mut e =
            EngineCore::new(0, cfg, SimBackend::new(CostModel::new(ModelKind::Llama3_8B)));
        for i in 0..5 {
            e.submit(mk_req(i, 80, 10, 0.0), 0.0);
        }
        let out = e.step(0.0);
        // First request (80 tok) admitted; second would exceed 100.
        assert_eq!(out.prefill_tokens, 80);
        assert_eq!(e.batch_len(), 1);
    }

    #[test]
    fn drain_returns_everything_and_frees() {
        let mut e = small_engine(100);
        e.submit(mk_req(1, 32, 50, 0.0), 0.0);
        e.submit(mk_req(2, 32, 50, 0.0), 0.0);
        e.step(0.0);
        let reqs = e.drain();
        assert_eq!(reqs.len(), 2);
        assert_eq!(e.status().used_blocks, 0);
        assert!(!e.has_work());
    }

    #[test]
    fn prefix_cache_shortens_second_stage_prefill() {
        let cfg = EngineConfig {
            model: ModelKind::Llama3_8B,
            block_size: 16,
            total_blocks: 1000,
            max_batch: 64,
            max_prefill_tokens: 4096,
            prefix_cache_blocks: 64,
        };
        let mut e =
            EngineCore::new(0, cfg, SimBackend::new(CostModel::new(ModelKind::Llama3_8B)));
        // Stage 1 of session 7: full prefill, then its 110-token context is
        // cached on completion.
        let mut r1 = mk_req(1, 100, 10, 0.0);
        r1.session = 7;
        e.submit(r1, 0.0);
        let mut now = 0.0;
        for _ in 0..50 {
            let out = e.step(now);
            now += out.duration.max(1e-6);
            if !e.has_work() {
                break;
            }
        }
        let pc = e.prefix_cache().unwrap();
        assert_eq!(pc.misses, 1, "stage 1 is a cold miss");
        assert!(pc.cached_blocks() > 0);
        // Stage 2 of the same session: 150-token prompt, 110 already held.
        let mut r2 = mk_req(2, 150, 5, now);
        r2.session = 7;
        e.submit(r2, now);
        let out = e.step(now);
        assert_eq!(out.prefill_tokens, 40, "110 of 150 tokens hit the cache");
        let pc = e.prefix_cache().unwrap();
        assert_eq!(pc.hits, 1);
        assert_eq!(pc.saved_prefill_tokens, 110);
        // A different session still prefills in full.
        let mut r3 = mk_req(3, 80, 5, now);
        r3.session = 8;
        e.submit(r3, now);
        let out = e.step(now);
        assert_eq!(out.prefill_tokens, 80);
        assert!(e.prefix_cache().unwrap().audit().is_empty());
        // KV accounting is untouched by the cache model.
        let mut guard = 0;
        while e.has_work() && guard < 200 {
            now += e.step(now).duration.max(1e-6);
            guard += 1;
        }
        assert_eq!(e.status().used_blocks, 0);
    }

    #[test]
    fn status_surfaces_alloc_failures() {
        let mut e = small_engine(4);
        // A prompt whose blocks + watermark can never fit the 4-block pool.
        e.submit(mk_req(1, 200, 4, 0.0), 0.0);
        e.step(0.0);
        assert!(e.status().alloc_failures > 0);
    }

    #[test]
    fn virtual_time_advances_with_cost_model() {
        let mut e = small_engine(1000);
        e.submit(mk_req(1, 100, 20, 0.0), 0.0);
        let out1 = e.step(0.0); // prefill step
        let out2 = e.step(out1.duration); // decode step
        assert!(out1.duration > out2.duration, "prefill step costs more");
        assert!(out2.duration > 0.0);
    }
}
