//! Calibrated engine step-latency and KV-memory model (DESIGN.md §6).
//!
//! The paper's testbed is 4× NVIDIA A40 serving Llama3-8B (and Llama2-13B in
//! §7.5) under vLLM. The virtual-time backend advances the clock by
//!
//! `t_step = c_fix + c_dec·B_dec + c_ctx·Σ context + c_pre·prefill_tokens`
//!
//! which captures the three effects the experiments depend on: decode steps
//! dominate end-to-end latency (Fig 4: ≥96.6%), step time grows with batch
//! width, and prefill admission momentarily stretches the iteration.

/// Which served model's calibration to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    /// Llama3-8B on A40 (the paper's main configuration).
    Llama3_8B,
    /// Llama2-13B on A40 (paper §7.5).
    Llama2_13B,
    /// The tiny PJRT-served model (constants measured on this host by the
    /// quickstart; used only for unit-consistency, not experiments).
    Tiny,
}

impl ModelKind {
    /// Parse a CLI/config model name (the single source of the name set:
    /// `--model`, `--fleet` clauses, `[cluster] model` and affinity specs
    /// all go through here).
    pub fn parse(s: &str) -> Result<ModelKind, String> {
        match s {
            "llama3-8b" => Ok(ModelKind::Llama3_8B),
            "llama2-13b" => Ok(ModelKind::Llama2_13B),
            "tiny" => Ok(ModelKind::Tiny),
            other => Err(format!("unknown model {other:?}")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Llama3_8B => "llama3-8b",
            ModelKind::Llama2_13B => "llama2-13b",
            ModelKind::Tiny => "tiny",
        }
    }
}

/// A request's serving-group requirement: which model family may execute
/// it. Derived from the issuing agent's affinity annotation
/// ([`crate::orchestrator::AffinitySpec`]); `Any` — the default — preserves
/// the unsharded behavior where every instance is a candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelClass {
    /// Any instance may serve the request.
    Any,
    /// Only instances of this model family may serve the request.
    Model(ModelKind),
}

impl ModelClass {
    /// Whether an instance serving `model` can execute a request of this
    /// class.
    pub fn matches(&self, model: ModelKind) -> bool {
        match self {
            ModelClass::Any => true,
            ModelClass::Model(k) => *k == model,
        }
    }

    /// Parse a class name: a model name, or `any`/`*` for the unpinned
    /// class.
    pub fn parse(s: &str) -> Result<ModelClass, String> {
        if s == "any" || s == "*" {
            return Ok(ModelClass::Any);
        }
        ModelKind::parse(s).map(ModelClass::Model)
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelClass::Any => "any",
            ModelClass::Model(k) => k.name(),
        }
    }
}

/// Step-latency and memory constants for one (GPU, model) pair.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed per-iteration overhead (s): kernel launches, scheduler.
    pub c_fix: f64,
    /// Per-decoding-sequence cost (s): one token sampled per seq per step.
    pub c_dec: f64,
    /// Per-context-token attention cost (s/token) summed over the batch.
    pub c_ctx: f64,
    /// Per-prefill-token cost (s/token).
    pub c_pre: f64,
    /// KV-cache bytes per token (all layers, fp16).
    pub kv_bytes_per_token: u64,
    /// GPU memory budget available for KV cache (bytes).
    pub kv_budget_bytes: u64,
}

impl CostModel {
    pub fn new(kind: ModelKind) -> CostModel {
        match kind {
            // A40 (48 GB, ~150 TFLOPs bf16) + Llama3-8B. Decode-dominant:
            // a lone decode step ≈ 7 ms; a 64-wide decode batch ≈ 70 ms.
            ModelKind::Llama3_8B => CostModel {
                c_fix: 6e-3,
                c_dec: 0.9e-3,
                c_ctx: 0.25e-6,
                c_pre: 0.11e-3,
                // 32 layers × 8 KV heads × 128 dim × 2 (K,V) × 2 bytes
                kv_bytes_per_token: 131_072,
                // 48 GB − weights(16 GB) − activations/overheads ≈ 30 GB
                kv_budget_bytes: 30 * (1 << 30),
            },
            // Llama2-13B: ~1.65× compute, denser KV (40 layers × 40 heads,
            // no GQA): 40 × 40 × 128 × 2 × 2 = 819200 B/token; weights 26 GB
            // leave ~19 GB of KV.
            ModelKind::Llama2_13B => CostModel {
                c_fix: 8e-3,
                c_dec: 1.5e-3,
                c_ctx: 0.65e-6,
                c_pre: 0.18e-3,
                kv_bytes_per_token: 819_200,
                kv_budget_bytes: 19 * (1 << 30),
            },
            // Tiny PJRT model on host CPU (orders of magnitude only).
            ModelKind::Tiny => CostModel {
                c_fix: 0.4e-3,
                c_dec: 0.05e-3,
                c_ctx: 0.01e-6,
                c_pre: 0.01e-3,
                // 2 layers × 4 heads × 16 dim × 2 × 4 bytes (fp32)
                kv_bytes_per_token: 1_024,
                kv_budget_bytes: 1 << 20,
            },
        }
    }

    /// Duration of one engine iteration.
    ///
    /// * `prefill_tokens` — total tokens prefilled this step.
    /// * `n_decode` — sequences producing one token this step.
    /// * `sum_context` — total KV context length across decoding sequences.
    pub fn step_time(&self, prefill_tokens: u32, n_decode: u32, sum_context: u64) -> f64 {
        if prefill_tokens == 0 && n_decode == 0 {
            return 0.0;
        }
        self.c_fix
            + self.c_dec * n_decode as f64
            + self.c_ctx * sum_context as f64
            + self.c_pre * prefill_tokens as f64
    }

    /// Total KV blocks an instance with this model can hold.
    pub fn total_blocks(&self, block_size: u32) -> u32 {
        let tokens = self.kv_budget_bytes / self.kv_bytes_per_token;
        (tokens / block_size as u64) as u32
    }

    /// Steady-state decode rate (tokens/s) of one sequence in a batch of
    /// `batch` with average context length `ctx` — the `k` slope of the
    /// dispatcher's linear memory ramp (paper Eq. 1 "determined through
    /// prior hardware profiling").
    pub fn decode_rate(&self, batch: u32, ctx: u64) -> f64 {
        let step = self.step_time(0, batch.max(1), ctx * batch.max(1) as u64);
        1.0 / step
    }

    /// Memory ramp slope: KV bytes per second while decoding.
    pub fn mem_slope(&self, batch: u32, ctx: u64) -> f64 {
        self.decode_rate(batch, ctx) * self.kv_bytes_per_token as f64
    }
}

/// Effective prefill length after a prefix-cache hit of `hit_tokens`.
///
/// The hit is clamped to `prompt_tokens − 1`: at least one token is always
/// prefilled (the step that produces the first output token), and a hit can
/// never exceed the prompt. Shared by the engine's admission path and the
/// time-slot packer's ramp precompute so both sides price a cached session
/// identically.
pub fn effective_prefill(prompt_tokens: u32, hit_tokens: u32) -> u32 {
    prompt_tokens - hit_tokens.min(prompt_tokens.saturating_sub(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_dominates_prefill_share() {
        // Paper Fig 4: decoding is >96.6% of inference latency for typical
        // agent requests (prompt ~200 tok, output ~300 tok).
        let m = CostModel::new(ModelKind::Llama3_8B);
        let prefill = m.step_time(200, 0, 0);
        let decode: f64 =
            (0..300).map(|i| m.step_time(0, 1, 200 + i)).sum();
        let share = decode / (decode + prefill);
        assert!(share > 0.96, "decode share {share}");
    }

    #[test]
    fn step_time_monotone_in_batch() {
        let m = CostModel::new(ModelKind::Llama3_8B);
        let t1 = m.step_time(0, 1, 500);
        let t32 = m.step_time(0, 32, 16_000);
        assert!(t32 > t1);
        // Batched decoding amortizes: 32 tokens in < 32× the single time.
        assert!(t32 < 32.0 * t1);
    }

    #[test]
    fn idle_step_is_free() {
        let m = CostModel::new(ModelKind::Llama3_8B);
        assert_eq!(m.step_time(0, 0, 0), 0.0);
    }

    #[test]
    fn kv_capacity_magnitude() {
        // ~30 GB / 128 KiB/token ≈ 245k tokens ≈ 15.3k blocks of 16.
        let m = CostModel::new(ModelKind::Llama3_8B);
        let blocks = m.total_blocks(16);
        assert!((14_000..17_000).contains(&blocks), "blocks={blocks}");
    }

    #[test]
    fn thirteen_b_slower_and_denser() {
        let a = CostModel::new(ModelKind::Llama3_8B);
        let b = CostModel::new(ModelKind::Llama2_13B);
        assert!(b.step_time(100, 8, 4000) > a.step_time(100, 8, 4000));
        assert!(b.kv_bytes_per_token > a.kv_bytes_per_token);
        assert!(b.total_blocks(16) < a.total_blocks(16));
    }

    #[test]
    fn single_seq_decode_speed_plausible() {
        // A40 + 8B: single-stream decode ≈ 30–150 tok/s.
        let m = CostModel::new(ModelKind::Llama3_8B);
        let rate = m.decode_rate(1, 500);
        assert!((30.0..200.0).contains(&rate), "rate={rate}");
    }

    #[test]
    fn mem_slope_positive() {
        let m = CostModel::new(ModelKind::Llama3_8B);
        assert!(m.mem_slope(16, 600) > 0.0);
    }

    #[test]
    fn model_names_roundtrip() {
        for kind in [ModelKind::Llama3_8B, ModelKind::Llama2_13B, ModelKind::Tiny] {
            assert_eq!(ModelKind::parse(kind.name()), Ok(kind));
        }
        assert!(ModelKind::parse("gpt5").is_err());
    }

    #[test]
    fn model_class_matching() {
        assert!(ModelClass::Any.matches(ModelKind::Llama3_8B));
        assert!(ModelClass::Any.matches(ModelKind::Tiny));
        let pinned = ModelClass::Model(ModelKind::Llama2_13B);
        assert!(pinned.matches(ModelKind::Llama2_13B));
        assert!(!pinned.matches(ModelKind::Llama3_8B));
    }

    #[test]
    fn effective_prefill_clamps_hits() {
        assert_eq!(effective_prefill(100, 0), 100);
        assert_eq!(effective_prefill(100, 40), 60);
        assert_eq!(effective_prefill(100, 99), 1);
        assert_eq!(effective_prefill(100, 100), 1, "one token always prefills");
        assert_eq!(effective_prefill(100, 5000), 1);
        assert_eq!(effective_prefill(0, 10), 0, "empty prompt stays empty");
    }

    #[test]
    fn model_class_parses_any_and_models() {
        assert_eq!(ModelClass::parse("any"), Ok(ModelClass::Any));
        assert_eq!(ModelClass::parse("*"), Ok(ModelClass::Any));
        assert_eq!(
            ModelClass::parse("llama2-13b"),
            Ok(ModelClass::Model(ModelKind::Llama2_13B))
        );
        assert!(ModelClass::parse("gpt5").is_err());
    }
}
