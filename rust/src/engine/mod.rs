//! The vLLM-like LLM engine substrate (DESIGN.md §3).
//!
//! The paper runs on vLLM [31]; nothing in its contribution depends on CUDA
//! kernels, but everything depends on vLLM's *iteration-level* behaviour:
//! continuous batching, paged KV-cache block allocation, and
//! recompute-preemption when blocks run out. This module reproduces that
//! behaviour from scratch:
//!
//! * [`request::Request`] — a single agent LLM call with its ground-truth
//!   sampled output length (visible only to the engine and the Oracle).
//! * [`block_manager::BlockManager`] — paged KV block accounting.
//! * [`cost_model::CostModel`] — calibrated A40 step-latency + KV-memory
//!   model for Llama3-8B / Llama2-13B (virtual-time backend).
//! * [`core::EngineCore`] — the continuous-batching step loop, generic over
//!   the execution backend: [`core::SimBackend`] advances virtual time by
//!   the cost model; `PjrtExecBackend` (in [`pjrt_backend`]) runs the real
//!   tiny model through PJRT with the same batching/block-manager code.

pub mod block_manager;
pub mod core;
pub mod cost_model;
pub mod pjrt_backend;
pub mod request;

pub use block_manager::{BlockManager, PrefixCache};
pub use core::{EngineCore, ExecBackend, InstanceStatus, SimBackend, StepOutcome};
pub use cost_model::{effective_prefill, CostModel, ModelClass, ModelKind};
pub use request::{Request, RequestId, SeqPhase, SeqState};
