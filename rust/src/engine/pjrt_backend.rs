//! Real-compute execution backend: drives the AOT-compiled tiny model
//! through PJRT with the same batching/block-manager code path as the
//! virtual-time backend (DESIGN.md §3).
//!
//! Mapping notes: the tiny model is monomorphic — fixed batch width `B` and
//! a contiguous per-row KV cache of `max_seq`. The backend owns a row-slot
//! table (request ↔ batch row). Admissions and recompute-preemptions
//! rebuild the padded token matrix and re-run **prefill for all live rows**
//! (the lowered prefill rewrites the full cache, so correctness is
//! preserved for bystander rows); pure-decode iterations run the Pallas
//! decode path. Step durations are measured wall-clock.

use std::collections::HashMap;
use std::time::Instant;

use super::core::ExecBackend;
use super::request::RequestId;
use crate::runtime::TinyModel;

/// Per-request generation state visible to the server after completion.
#[derive(Debug, Clone, Default)]
pub struct GenState {
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
}

/// PJRT-backed engine executor.
pub struct PjrtExecBackend {
    model: TinyModel,
    /// Padded (B × S) token matrix mirroring model state.
    tokens: Vec<i32>,
    /// Valid token count per row (prompt + generated so far).
    lens: Vec<i32>,
    /// Flat KV cache threaded between calls.
    kv: Vec<f32>,
    /// row -> occupying request (None = free).
    rows: Vec<Option<RequestId>>,
    /// request -> generation state.
    gen: HashMap<RequestId, GenState>,
    /// Last token fed to decode, per row.
    last_token: Vec<i32>,
    /// Total wall seconds spent inside PJRT execute calls.
    pub compute_seconds: f64,
}

impl PjrtExecBackend {
    pub fn new(model: TinyModel) -> PjrtExecBackend {
        let b = model.manifest.batch;
        let s = model.manifest.max_seq;
        let kv = model.empty_kv();
        PjrtExecBackend {
            model,
            tokens: vec![0; b * s],
            lens: vec![1; b],
            kv,
            rows: vec![None; b],
            gen: HashMap::new(),
            last_token: vec![0; b],
            compute_seconds: 0.0,
        }
    }

    /// Max concurrent sequences this backend can host (engine `max_batch`
    /// must not exceed it).
    pub fn max_batch(&self) -> usize {
        self.model.manifest.batch
    }

    /// Longest admissible request (prompt + output) in tokens.
    pub fn max_tokens(&self) -> usize {
        self.model.manifest.max_seq - 1
    }

    /// Register the prompt text for a request before it is submitted.
    pub fn set_prompt(&mut self, id: RequestId, prompt: Vec<i32>) {
        self.gen.insert(id, GenState { prompt, generated: vec![] });
    }

    /// Fetch (and drop) the generation state of a finished request.
    pub fn take_generation(&mut self, id: RequestId) -> Option<GenState> {
        self.gen.remove(&id)
    }

    fn find_row(&self, id: RequestId) -> Option<usize> {
        self.rows.iter().position(|r| *r == Some(id))
    }

    fn free_rows_of_departed(&mut self, live: &[RequestId]) {
        for r in self.rows.iter_mut() {
            if let Some(id) = *r {
                if !live.contains(&id) {
                    *r = None;
                }
            }
        }
    }
}

impl ExecBackend for PjrtExecBackend {
    fn run_step(&mut self, prefill: &[(RequestId, u32)], decode: &[(RequestId, u32)]) -> f64 {
        let b = self.model.manifest.batch;
        let s = self.model.manifest.max_seq;
        let live: Vec<RequestId> = prefill
            .iter()
            .chain(decode.iter())
            .map(|&(id, _)| id)
            .collect();
        assert!(live.len() <= b, "engine max_batch exceeds model batch width");
        self.free_rows_of_departed(&live);

        // kairos-lint: allow(wall-clock, measures real device-dispatch overhead; never feeds simulated time)
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        if !prefill.is_empty() {
            // Assign rows to newly admitted requests.
            for &(id, _) in prefill {
                if self.find_row(id).is_none() {
                    let row = self.rows.iter().position(|r| r.is_none()).expect("free row");
                    self.rows[row] = Some(id);
                    // (Re)build the row's token prefix: prompt + generated.
                    let st = self.gen.get(&id).expect("set_prompt before submit");
                    let mut prefix = st.prompt.clone();
                    prefix.extend_from_slice(&st.generated);
                    assert!(prefix.len() < s, "sequence exceeds model max_seq");
                    for (i, t) in prefix.iter().enumerate() {
                        self.tokens[row * s + i] = *t;
                    }
                    self.lens[row] = prefix.len() as i32;
                }
            }
            // Full-batch re-prefill (rewrites the cache consistently).
            let out = self
                .model
                .prefill(&self.tokens, &self.lens, &self.kv)
                .expect("pjrt prefill");
            self.kv = out.kv_cache;
            // Every live row receives its next token from the prefill.
            for row in 0..b {
                if let Some(id) = self.rows[row] {
                    let tok = out.next_token[row];
                    self.last_token[row] = tok;
                    if let Some(gs) = self.gen.get_mut(&id) {
                        gs.generated.push(tok);
                        self.tokens[row * s + self.lens[row] as usize] = tok;
                    }
                }
            }
            for row in 0..b {
                if self.rows[row].is_some() {
                    self.lens[row] = (self.lens[row] + 1).min(s as i32 - 1);
                }
            }
        } else if !decode.is_empty() {
            let out = self
                .model
                .decode(&self.last_token, &self.lens, &self.kv)
                .expect("pjrt decode");
            self.kv = out.kv_cache;
            for row in 0..b {
                if let Some(id) = self.rows[row] {
                    let tok = out.next_token[row];
                    self.last_token[row] = tok;
                    if let Some(gs) = self.gen.get_mut(&id) {
                        gs.generated.push(tok);
                        self.tokens[row * s + self.lens[row] as usize] = tok;
                    }
                    self.lens[row] = (self.lens[row] + 1).min(s as i32 - 1);
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        self.compute_seconds += dt;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::core::{EngineConfig, EngineCore};
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::engine::request::Request;
    use crate::orchestrator::ids::AgentId;
    use std::path::{Path, PathBuf};

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn mk_req(id: u64, prompt_tokens: u32, output: u32) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session: id,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens,
            true_output_tokens: output,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn engine_over_pjrt_generates_real_tokens() {
        if !artifacts_dir().join("micro_manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let model = TinyModel::load(&artifacts_dir(), "micro").unwrap();
        let max_batch = model.manifest.batch;
        let mut backend = PjrtExecBackend::new(model);
        backend.set_prompt(1, vec![1, 2, 3]);
        backend.set_prompt(2, vec![4, 5]);

        let cfg = EngineConfig {
            model: ModelKind::Tiny,
            block_size: 4,
            total_blocks: 16, // micro: 2 rows × max 16 tokens
            max_batch,
            max_prefill_tokens: 1 << 20,
            prefix_cache_blocks: 0,
        };
        let mut engine = EngineCore::new(0, cfg, backend);
        engine.submit(mk_req(1, 3, 5), 0.0);
        engine.submit(mk_req(2, 2, 4), 0.0);

        let mut done = vec![];
        let mut now = 0.0;
        for _ in 0..50 {
            let out = engine.step(now);
            now += out.duration;
            done.extend(out.completed);
            if !engine.has_work() {
                break;
            }
        }
        assert_eq!(done.len(), 2);
        let g1 = engine.backend.take_generation(1).unwrap();
        let g2 = engine.backend.take_generation(2).unwrap();
        assert!(g1.generated.len() >= 5);
        assert!(g2.generated.len() >= 4);
        // Real model tokens are in-vocab.
        for t in g1.generated.iter().chain(&g2.generated) {
            assert!((0..64).contains(t));
        }
        assert!(engine.backend.compute_seconds > 0.0);
    }
}
