//! Request and sequence state shared by the engine, the load balancer and
//! the dispatcher.

use crate::engine::cost_model::ModelClass;
use crate::orchestrator::ids::{AgentId, MsgId};
use crate::Time;

/// Unique id of one LLM call (one workflow stage execution).
pub type RequestId = u64;

/// Where a running sequence is in its lifecycle inside an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Admitted; its (effective) prompt has not been computed yet.
    NeedsPrefill,
    /// Prefill done; generating one token per engine step.
    Decoding,
}

/// One LLM request emitted by an agent stage of a workflow.
///
/// `true_output_tokens` is the ground-truth sampled generation length: the
/// engine uses it to decide completion (standing in for the model's EOS).
/// Schedulers must NOT read it — only the Oracle policies do, explicitly.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Workflow instance this stage belongs to.
    pub msg_id: MsgId,
    /// The agent issuing this request.
    pub agent: AgentId,
    /// Prefix-cache session key: stages sharing a session extend the same
    /// evolving context, so a later stage landing on an instance that
    /// already holds the session's prefix skips re-prefilling it. Defaults
    /// to the workflow `msg_id`; trace lines may override it.
    pub session: u64,
    /// Serving-group requirement: which model family may execute this
    /// request (from the agent's affinity annotation; `Any` = every
    /// instance is a candidate, the unsharded behavior).
    pub model_class: ModelClass,
    /// Immediate upstream agent in the workflow (None for the entry stage).
    pub upstream: Option<AgentId>,
    /// Prompt length in tokens (known at dispatch, as in the paper §2.3).
    pub prompt_tokens: u32,
    /// Ground truth output length (engine/Oracle only).
    pub true_output_tokens: u32,
    /// Ground truth remaining *workflow* latency after this stage completes
    /// would start (engine-seconds; Oracle scheduling + Fig 8/16 analyses).
    pub true_remaining_latency: f64,
    /// Number of workflow stages remaining including this one (Ayo's
    /// topology-depth signal).
    pub remaining_stages: u32,
    /// Application-level start time: when the user task entered the system
    /// (Kairos' intra-agent ordering key, §5.2).
    pub app_start: Time,
    /// Arrival time of THIS stage at the load balancer.
    pub stage_arrival: Time,
}

impl Request {
    /// Tokens the sequence will hold in KV cache when complete.
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.true_output_tokens
    }
}

/// A sequence resident in an engine (admitted request + progress).
#[derive(Debug, Clone)]
pub struct SeqState {
    pub req: Request,
    pub phase: SeqPhase,
    /// Tokens generated so far (survives recompute-preemption: vLLM re-runs
    /// prefill over prompt + already-generated tokens).
    pub generated: u32,
    /// Tokens that must be (re)prefilled when next scheduled.
    pub prefill_tokens: u32,
    /// Engine time the request was last admitted.
    pub admitted_at: Time,
    /// Engine time the request was FIRST admitted (LLM execution start for
    /// the orchestrator's timestamps; survives recompute-preemption).
    pub first_admitted_at: Option<Time>,
    /// Times this sequence was preempted.
    pub preempt_count: u32,
    /// KV blocks currently held by this sequence.
    pub held_blocks: u32,
}

impl SeqState {
    pub fn new(req: Request, now: Time) -> SeqState {
        let prefill_tokens = req.prompt_tokens;
        SeqState {
            req,
            phase: SeqPhase::NeedsPrefill,
            generated: 0,
            prefill_tokens,
            admitted_at: now,
            first_admitted_at: None,
            preempt_count: 0,
            held_blocks: 0,
        }
    }

    /// Current context length held in KV cache (after prefill).
    pub fn context_len(&self) -> u32 {
        self.req.prompt_tokens + self.generated
    }

    /// True when generation has reached the sampled output length.
    pub fn is_finished(&self) -> bool {
        self.generated >= self.req.true_output_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::ids::AgentId;

    fn req() -> Request {
        Request {
            id: 1,
            msg_id: 10,
            agent: AgentId(0),
            session: 10,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: 100,
            true_output_tokens: 50,
            true_remaining_latency: 1.0,
            remaining_stages: 2,
            app_start: 0.0,
            stage_arrival: 0.0,
        }
    }

    #[test]
    fn totals() {
        assert_eq!(req().total_tokens(), 150);
    }

    #[test]
    fn seq_lifecycle() {
        let mut s = SeqState::new(req(), 1.0);
        assert_eq!(s.phase, SeqPhase::NeedsPrefill);
        assert_eq!(s.prefill_tokens, 100);
        assert_eq!(s.context_len(), 100);
        s.phase = SeqPhase::Decoding;
        s.generated = 49;
        assert!(!s.is_finished());
        assert_eq!(s.context_len(), 149);
        s.generated = 50;
        assert!(s.is_finished());
    }
}
