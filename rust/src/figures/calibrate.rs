//! Load calibration (paper §7.1): "we adjust the overall load rate so that
//! the average queueing time ratio ranges from 0% to 90%".
//!
//! Finds the request rate at which the FCFS/Round-Robin baseline reaches a
//! target queueing-time ratio, by bisection over short probe runs.

use crate::server::sim::{run_system, SimConfig};
use crate::stats::rng::Rng;
use crate::workload::{TraceGen, WorkloadMix};

/// Probe the baseline queueing ratio at `rate`.
pub fn queue_ratio_at(
    cfg: SimConfig,
    mix: &WorkloadMix,
    rate: f64,
    n_tasks: usize,
    seed: u64,
) -> f64 {
    let arrivals =
        TraceGen::default().generate(mix, rate, n_tasks, &mut Rng::new(seed));
    let res = run_system(cfg, "parrot", "rr", arrivals);
    res.summary.mean_queue_ratio
}

/// Bisection search for the rate achieving `target` queueing ratio under
/// the FCFS/RR baseline (all policies are then compared at that same rate).
pub fn rate_for_queue_ratio(
    cfg: SimConfig,
    mix: &WorkloadMix,
    target: f64,
    n_tasks: usize,
    seed: u64,
) -> f64 {
    // The queueing ratio is regime-dependent on trace length (a finite
    // backlog keeps building under sustained overload), so calibration must
    // probe with the same trace length the experiment will run.
    let mut lo = 0.2;
    let mut hi = 2.0;
    // Grow `hi` until the ratio exceeds the target (or a cap).
    while queue_ratio_at(cfg, mix, hi, n_tasks, seed) < target && hi < 256.0 {
        lo = hi;
        hi *= 2.0;
    }
    for _ in 0..7 {
        let mid = 0.5 * (lo + hi);
        if queue_ratio_at(cfg, mix, mid, n_tasks, seed) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_grows_with_rate() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let mix = WorkloadMix::colocated();
        let low = queue_ratio_at(cfg, &mix, 1.0, 400, 1);
        let high = queue_ratio_at(cfg, &mix, 16.0, 400, 1);
        assert!(high > low, "high={high} low={low}");
    }

    #[test]
    fn calibration_hits_target_roughly() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let mix = WorkloadMix::colocated();
        let rate = rate_for_queue_ratio(cfg, &mix, 0.5, 400, 2);
        let got = queue_ratio_at(cfg, &mix, rate, 400, 3); // different seed
        assert!((got - 0.5).abs() < 0.25, "rate={rate} got={got}");
    }
}
