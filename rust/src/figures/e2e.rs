//! End-to-end comparisons: Fig 14 (per-application), Fig 15 (co-located,
//! Llama3-8B), Fig 17 (co-located, Llama2-13B).
//!
//! Each harness fixes a workload, calibrates the request rate so the
//! FCFS/RR baseline sits at ~50% queueing-time ratio (mid excessive-load,
//! paper §7.1), then runs Parrot, Ayo, and Kairos at the SAME rate and
//! reports program-level token latency (avg + tails) and Kairos' reduction
//! vs each baseline.

use crate::agents::apps::App;
use crate::engine::cost_model::ModelKind;
use crate::figures::calibrate::rate_for_queue_ratio;
use crate::server::sim::{run_system, SimConfig, SimResult};
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::workload::{TraceGen, WorkloadMix};
use crate::Result;

/// The three compared systems as (scheduler, dispatcher) pairs.
pub const SYSTEMS: [(&str, &str, &str); 3] = [
    ("Parrot", "parrot", "rr"),
    ("Ayo", "ayo", "rr"),
    ("Kairos", "kairos", "kairos"),
];

pub struct E2eRow {
    pub system: &'static str,
    pub avg: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub queue_ratio: f64,
}

/// Run the three systems on one workload at the calibrated rate.
pub fn compare(
    cfg: SimConfig,
    mix: &WorkloadMix,
    n_tasks: usize,
    target_qr: f64,
    seed: u64,
) -> (f64, Vec<E2eRow>) {
    let rate = rate_for_queue_ratio(cfg, mix, target_qr, n_tasks, seed);
    let rows = SYSTEMS
        .iter()
        .map(|&(name, sched, disp)| {
            let arrivals = TraceGen::default().generate(
                mix,
                rate,
                n_tasks,
                &mut Rng::new(seed),
            );
            let res: SimResult = run_system(cfg, sched, disp, arrivals);
            E2eRow {
                system: name,
                avg: res.summary.avg_token_latency,
                p90: res.summary.p90_token_latency,
                p95: res.summary.p95_token_latency,
                p99: res.summary.p99_token_latency,
                queue_ratio: res.summary.mean_queue_ratio,
            }
        })
        .collect();
    (rate, rows)
}

fn reduction(baseline: f64, ours: f64) -> String {
    format!("{:+.1}%", (ours - baseline) / baseline * 100.0)
}

fn print_rows(title: &str, rate: f64, rows: &[E2eRow], csv_path: &str) -> Result<()> {
    let mut t = Table::new(&[
        "system", "avg (s/tok)", "P90", "P95", "P99", "queue ratio",
        "avg vs Parrot", "P90 vs Parrot",
    ]);
    let parrot = &rows[0];
    let mut csv = vec![vec![
        "system".to_string(), "avg".into(), "p90".into(), "p95".into(), "p99".into(),
        "queue_ratio".into(),
    ]];
    for r in rows {
        t.row(vec![
            r.system.into(),
            format!("{:.4}", r.avg),
            format!("{:.4}", r.p90),
            format!("{:.4}", r.p95),
            format!("{:.4}", r.p99),
            format!("{:.2}", r.queue_ratio),
            reduction(parrot.avg, r.avg),
            reduction(parrot.p90, r.p90),
        ]);
        csv.push(vec![
            r.system.into(),
            r.avg.to_string(),
            r.p90.to_string(),
            r.p95.to_string(),
            r.p99.to_string(),
            r.queue_ratio.to_string(),
        ]);
    }
    println!("{title} (calibrated rate {rate:.2} req/s):");
    t.print();
    write_csv(csv_path, &csv)?;
    Ok(())
}

/// Fig 14: per-application (3 apps × 3 datasets), avg + P90.
pub fn fig14(out_dir: &str) -> Result<()> {
    println!("Fig 14 — individual applications, Llama3-8B, 4 instances");
    println!("(paper: Kairos avg −17.8%..−28.4% vs Parrot; −5.8%..−10.8% vs Ayo)\n");
    let cfg = SimConfig::default();
    for app in App::all() {
        for ds in app.datasets() {
            let mix = WorkloadMix::single(app, ds);
            let (rate, rows) = compare(cfg, &mix, 1500, 0.5, 14);
            print_rows(
                &format!("{} / {}", app.name(), ds),
                rate,
                &rows,
                &format!("{out_dir}/fig14_{}_{}.csv", app.name(), ds.replace('+', "")),
            )?;
            println!();
        }
    }
    Ok(())
}

/// Fig 15: co-located applications, Llama3-8B, avg/P90/P95/P99.
pub fn fig15(out_dir: &str) -> Result<()> {
    println!("Fig 15 — co-located QA+RG+CG, Llama3-8B, 4 instances");
    println!("(paper: Kairos −45.1..−72.8% avg vs Parrot; −6.1..−37.9% vs Ayo)\n");
    let cfg = SimConfig::default();
    // The co-location scenario spans several load levels in the paper; we
    // report the three characteristic points.
    for (tag, qr) in [("moderate", 0.3), ("high", 0.5), ("excessive", 0.7)] {
        let (rate, rows) = compare(cfg, &WorkloadMix::colocated(), 2000, qr, 15);
        print_rows(
            &format!("co-located, {tag} load"),
            rate,
            &rows,
            &format!("{out_dir}/fig15_{tag}.csv"),
        )?;
        println!();
    }
    Ok(())
}

/// Fig 17: co-located applications on Llama2-13B.
pub fn fig17(out_dir: &str) -> Result<()> {
    println!("Fig 17 — co-located QA+RG+CG, Llama2-13B, 4 instances");
    println!("(paper: Kairos −42.1..−57.4% avg vs Parrot; −21.8..−24.6% vs Ayo)\n");
    let cfg = SimConfig { model: ModelKind::Llama2_13B, ..Default::default() };
    for (tag, qr) in [("high", 0.5), ("excessive", 0.7)] {
        let (rate, rows) = compare(cfg, &WorkloadMix::colocated(), 2000, qr, 17);
        print_rows(
            &format!("co-located 13B, {tag} load"),
            rate,
            &rows,
            &format!("{out_dir}/fig17_{tag}.csv"),
        )?;
        println!();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kairos_wins_colocated_at_high_load() {
        // Smaller/cheaper variant of fig15's high-load point.
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let (_, rows) = compare(cfg, &WorkloadMix::colocated(), 600, 0.5, 150);
        let parrot = rows.iter().find(|r| r.system == "Parrot").unwrap();
        let ayo = rows.iter().find(|r| r.system == "Ayo").unwrap();
        let kairos = rows.iter().find(|r| r.system == "Kairos").unwrap();
        assert!(kairos.avg < parrot.avg, "kairos {} parrot {}", kairos.avg, parrot.avg);
        assert!(kairos.avg < ayo.avg * 1.05, "kairos {} ayo {}", kairos.avg, ayo.avg);
        assert!(kairos.p90 < parrot.p90);
    }
}
