//! Fig 16: pairwise sorting accuracy across 10 scenarios (paper §7.4:
//! Kairos 83.5% avg, Ayo 75.9%, Parrot 50%).
//!
//! For each scenario, historical execution data populates the profiler;
//! then a simulated queue of requests is ordered by each policy and the
//! proportion of correctly ordered request pairs (vs true remaining
//! latency) is measured.

use crate::agents::apps::App;
use crate::lb::policies::{Fcfs, KairosPolicy, SchedulePolicy, Topo};
use crate::lb::queue::RequestQueue;
use crate::server::sim::SimConfig;
use crate::stats::kendall::pairwise_sorting_accuracy_grouped;
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::workload::{TraceGen, WorkloadMix};
use crate::Result;

/// The ten evaluation scenarios: nine single-app and the co-located one.
pub fn scenarios() -> Vec<(String, WorkloadMix)> {
    let mut v = Vec::new();
    for app in App::all() {
        for ds in app.datasets() {
            v.push((format!("{}/{}", app.name(), ds), WorkloadMix::single(app, ds)));
        }
    }
    v.push(("co-located".to_string(), WorkloadMix::colocated()));
    v
}

/// Sorting accuracy of each policy on one scenario.
pub fn accuracy_for(mix: &WorkloadMix, seed: u64) -> (f64, f64, f64) {
    // Phase 1: run the system to collect history (any policy; Kairos learns
    // from completions either way).
    let cfg = SimConfig { n_instances: 2, ..Default::default() };
    let arrivals = TraceGen::default().generate(mix, 6.0, 800, &mut Rng::new(seed));
    let policy = crate::server::sim::make_policy("kairos");
    let disp = crate::server::sim::make_dispatcher("rr", &cfg);
    let server = crate::server::sim::SimServer::new(cfg, policy, disp);
    let res = server.run(arrivals);

    // Phase 2: rebuild an orchestrator's profiles from the run's records and
    // form a fresh queue of unseen requests.
    let mut orch = crate::orchestrator::Orchestrator::new();
    // Intern agents in the same order as the sim (ids must line up with the
    // request records, which carry AgentId from the run).
    for app in App::all() {
        for ds in app.datasets() {
            for a in app.dataset(ds).agents {
                orch.registry.intern(a.agent);
            }
        }
    }
    // The recorded requests carry (agent, true_remaining, exec) — feed the
    // profiler the same signal the online system would have.
    for r in &res.metrics.requests {
        orch.profiler.record_execution(r.agent, r.exec_time());
        orch.profiler.record_remaining(r.agent, r.true_remaining);
    }

    let mut kairos = KairosPolicy::new();
    kairos.refresh(&orch);

    // Queue snapshot: the last 300 recorded requests, re-queued.
    let reqs: Vec<_> = res
        .metrics
        .requests
        .iter()
        .rev()
        .take(300)
        .enumerate()
        .map(|(i, r)| crate::engine::request::Request {
            id: i as u64,
            msg_id: r.msg_id,
            agent: r.agent,
            session: r.msg_id,
            model_class: crate::engine::cost_model::ModelClass::Any,
            upstream: None,
            prompt_tokens: 100,
            true_output_tokens: r.output_tokens,
            true_remaining_latency: r.true_remaining,
            remaining_stages: 1,
            app_start: r.stage_arrival,
            stage_arrival: r.stage_arrival,
        })
        .collect();

    // Paper §7.4: pairs are formed between a request and "all other AGENT
    // requests" — inter-agent pairs (agent-level priority is what is being
    // validated; intra-agent order is a separate mechanism, §5.2).
    let accuracy = |policy: &dyn SchedulePolicy, reqs: &[crate::engine::request::Request]| {
        let mut q = RequestQueue::new();
        for r in reqs {
            q.push(r.clone(), policy);
        }
        let ordered = q.drain_ordered(policy);
        let order: Vec<f64> = (0..ordered.len()).map(|i| i as f64).collect();
        let lat: Vec<f64> = ordered.iter().map(|r| r.true_remaining_latency).collect();
        let group: Vec<u32> = ordered.iter().map(|r| r.agent.0).collect();
        pairwise_sorting_accuracy_grouped(&order, &lat, &group)
    };

    // Parrot = FCFS over *scheduling-time* arrival: for any pair either may
    // arrive first, so expected accuracy is 50% — measured over the
    // arrival-ordered queue it equals the fraction of pairs whose arrival
    // order happens to match latency order.
    let parrot = accuracy(&Fcfs, &reqs);
    let ayo = {
        // Ayo needs remaining_stages: reconstruct from the workflow depth
        // (requests in the tail of a workflow have fewer stages left).
        let mut reqs2 = reqs.clone();
        for r in reqs2.iter_mut() {
            // Approximate: deeper remaining latency ⇒ earlier stage.
            r.remaining_stages = if r.true_remaining_latency > 10.0 { 3 }
                else if r.true_remaining_latency > 4.0 { 2 } else { 1 };
        }
        accuracy(&Topo, &reqs2)
    };
    let kairos_acc = accuracy(&kairos, &reqs);
    (parrot, ayo, kairos_acc)
}

pub fn run(out_dir: &str) -> Result<()> {
    let mut t = Table::new(&["scenario", "Parrot", "Ayo", "Kairos"]);
    let mut csv = vec![vec![
        "scenario".to_string(), "parrot".into(), "ayo".into(), "kairos".into(),
    ]];
    let mut sums = (0.0, 0.0, 0.0);
    let scens = scenarios();
    for (i, (name, mix)) in scens.iter().enumerate() {
        let (p, a, k) = accuracy_for(mix, 160 + i as u64);
        sums = (sums.0 + p, sums.1 + a, sums.2 + k);
        t.row(vec![
            name.clone(),
            format!("{:.1}%", p * 100.0),
            format!("{:.1}%", a * 100.0),
            format!("{:.1}%", k * 100.0),
        ]);
        csv.push(vec![name.clone(), p.to_string(), a.to_string(), k.to_string()]);
    }
    let n = scens.len() as f64;
    t.row(vec![
        "AVERAGE".into(),
        format!("{:.1}%", sums.0 / n * 100.0),
        format!("{:.1}%", sums.1 / n * 100.0),
        format!("{:.1}%", sums.2 / n * 100.0),
    ]);
    println!("Fig 16 — pairwise sorting accuracy");
    println!("(paper averages: Kairos 83.5%, Ayo 75.9%, Parrot 50%)");
    t.print();
    write_csv(format!("{out_dir}/fig16.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kairos_sorts_better_than_fcfs() {
        let (p, _a, k) = accuracy_for(&WorkloadMix::colocated(), 3);
        assert!((p - 0.5).abs() < 0.2, "parrot ~ random: {p}");
        assert!(k > p + 0.1, "kairos {k} vs parrot {p}");
        assert!(k > 0.6, "kairos absolute: {k}");
    }
}
