//! Fig 18: ablation studies (paper §7.6).
//!
//! * **w/o priority** — FCFS scheduling + Kairos packing (paper: priority
//!   scheduling contributes 1.63× at the 50%-queueing point, growing
//!   38.8%→69.6% with request rate).
//! * **w/o packing** — Kairos scheduling + Round-Robin dispatch (paper:
//!   packing contributes 1.12×, a stable 9.5–10.6% across rates).

use crate::figures::calibrate::rate_for_queue_ratio;
use crate::server::sim::{run_system, SimConfig};
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::workload::{TraceGen, WorkloadMix};
use crate::Result;

pub struct AblationRow {
    pub rate: f64,
    pub kairos: f64,
    pub wo_priority: f64,
    pub wo_packing: f64,
}

pub fn sweep(rates: &[f64], n_tasks: usize, seed: u64, kv_scale: f64) -> Vec<AblationRow> {
    let cfg = SimConfig { kv_scale, ..Default::default() };
    rates
        .iter()
        .map(|&rate| {
            let run = |sched: &str, disp: &str| {
                let arrivals = TraceGen::default().generate(
                    &WorkloadMix::colocated(),
                    rate,
                    n_tasks,
                    &mut Rng::new(seed),
                );
                run_system(cfg, sched, disp, arrivals).summary.avg_token_latency
            };
            AblationRow {
                rate,
                kairos: run("kairos", "kairos"),
                wo_priority: run("parrot", "kairos"),
                wo_packing: run("kairos", "rr"),
            }
        })
        .collect()
}

pub fn run(out_dir: &str) -> Result<()> {
    // Anchor the sweep around the 50%-queueing point of the baseline.
    let cfg = SimConfig::default();
    let mid = rate_for_queue_ratio(cfg, &WorkloadMix::colocated(), 0.5, 1500, 18);
    let rates: Vec<f64> = [0.6, 0.8, 1.0, 1.25, 1.5].iter().map(|m| m * mid).collect();
    // Mild memory pressure so the packing ablation has headroom to matter.
    let rows = sweep(&rates, 1500, 18, 0.06);

    let mut t = Table::new(&[
        "rate (req/s)", "Kairos", "w/o priority", "w/o packing",
        "priority gain", "packing gain",
    ]);
    let mut csv = vec![vec![
        "rate".to_string(), "kairos".into(), "wo_priority".into(), "wo_packing".into(),
    ]];
    for r in &rows {
        t.row(vec![
            format!("{:.2}", r.rate),
            format!("{:.4}", r.kairos),
            format!("{:.4}", r.wo_priority),
            format!("{:.4}", r.wo_packing),
            format!("{:.2}x", r.wo_priority / r.kairos),
            format!("{:.2}x", r.wo_packing / r.kairos),
        ]);
        csv.push(vec![
            r.rate.to_string(),
            r.kairos.to_string(),
            r.wo_priority.to_string(),
            r.wo_packing.to_string(),
        ]);
    }
    println!("Fig 18 — ablations on the co-located workload");
    println!("(paper: w/o priority 1.63x @50% queueing, 38.8→69.6% with rate;");
    println!("        w/o packing 1.12x, stable 9.5–10.6%)");
    t.print();
    write_csv(format!("{out_dir}/fig18.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_gain_grows_with_rate() {
        let rows = sweep(&[3.0, 8.0], 500, 4, 0.06);
        let gain_low = rows[0].wo_priority / rows[0].kairos;
        let gain_high = rows[1].wo_priority / rows[1].kairos;
        assert!(gain_high > 1.0, "priority must help at high load: {gain_high}");
        assert!(
            gain_high > gain_low * 0.9,
            "gain should not collapse with load: low {gain_low} high {gain_high}"
        );
    }

    #[test]
    fn packing_helps_under_pressure() {
        let rows = sweep(&[8.0], 500, 5, 0.06);
        let gain = rows[0].wo_packing / rows[0].kairos;
        assert!(gain > 0.95, "packing must not hurt materially: {gain}");
    }
}
