//! Fig 7: the queuing example — FCFS vs Topology-Aware vs Oracle
//! (paper §2.2.2: total waiting 13 / 12 / 7 units).
//!
//! The paper's figure is an illustration over four queued QA-app requests
//! served by one executor. The exact per-request numbers in the published
//! figure are not machine-readable (see EXPERIMENTS.md); this harness uses
//! a faithful reconstruction with the same structure — mixed router/expert
//! requests whose workflow depth disagrees with their true remaining
//! latency — that reproduces the paper's three totals exactly:
//!
//! | req | exec | depth (stages left) | true remaining | arrival |
//! |-----|------|---------------------|----------------|---------|
//! | R1  | 2    | 2                   | 2.0            | 1st     |
//! | M   | 1    | 1                   | 1.0            | 2nd     |
//! | H   | 5    | 2                   | 5.0            | 3rd     |
//! | R2  | 1    | 3                   | 1.5            | 4th     |
//!
//! FCFS runs them in arrival order (13 units of waiting); Ayo's
//! topology-depth order promotes M but still runs the long H before R2
//! (12 units); the Oracle's remaining-latency order yields 7.

use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::Result;

#[derive(Debug, Clone, Copy)]
#[allow(dead_code)] // `name` documents the instance
struct Job {
    name: &'static str,
    exec: f64,
    /// Remaining workflow stages including this one (Ayo's signal).
    depth: u32,
    /// True remaining workflow latency (Oracle's signal).
    remaining: f64,
    /// Arrival order (FCFS's signal).
    arrival: usize,
}

const JOBS: [Job; 4] = [
    Job { name: "R1", exec: 2.0, depth: 2, remaining: 2.0, arrival: 0 },
    Job { name: "M", exec: 1.0, depth: 1, remaining: 1.0, arrival: 1 },
    Job { name: "H", exec: 5.0, depth: 2, remaining: 5.0, arrival: 2 },
    Job { name: "R2", exec: 1.0, depth: 3, remaining: 1.5, arrival: 3 },
];

fn total_waiting(order: &[usize]) -> f64 {
    let mut t = 0.0;
    let mut wait = 0.0;
    for &i in order {
        wait += t;
        t += JOBS[i].exec;
    }
    wait
}

/// Sort job indices by a `(float key, arrival tiebreak)` pair. `total_cmp`
/// gives a total order on the float part (lint rule D3: no `partial_cmp`
/// on float keys).
fn order_by(key: impl Fn(&Job) -> (f64, usize)) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..JOBS.len()).collect();
    idx.sort_by(|&a, &b| {
        let (ka, ia) = key(&JOBS[a]);
        let (kb, ib) = key(&JOBS[b]);
        ka.total_cmp(&kb).then(ia.cmp(&ib))
    });
    idx
}

/// Total waiting under (FCFS, Topo, Oracle).
pub fn waiting_times() -> (f64, f64, f64) {
    let fcfs = total_waiting(&order_by(|j| (j.arrival as f64, j.arrival)));
    // Ayo: fewer remaining stages first, FCFS within a depth.
    let topo = total_waiting(&order_by(|j| (j.depth as f64, j.arrival)));
    // Oracle: true remaining latency.
    let oracle = total_waiting(&order_by(|j| (j.remaining, j.arrival)));
    (fcfs, topo, oracle)
}

pub fn run(out_dir: &str) -> Result<()> {
    let (fcfs, topo, oracle) = waiting_times();
    let mut t = Table::new(&["strategy", "total waiting (units)", "paper"]);
    t.row(vec!["FCFS".into(), format!("{fcfs}"), "13".into()]);
    t.row(vec!["Topo (Ayo)".into(), format!("{topo}"), "12".into()]);
    t.row(vec!["Oracle".into(), format!("{oracle}"), "7".into()]);
    println!("Fig 7 — queuing example (paper §2.2.2):");
    t.print();
    write_csv(
        format!("{out_dir}/fig7.csv"),
        &[
            vec!["strategy".to_string(), "waiting".into()],
            vec!["fcfs".into(), fcfs.to_string()],
            vec!["topo".into(), topo.to_string()],
            vec!["oracle".into(), oracle.to_string()],
        ],
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_totals() {
        let (fcfs, topo, oracle) = waiting_times();
        assert_eq!(fcfs, 13.0, "paper: FCFS = 13 units");
        assert_eq!(topo, 12.0, "paper: Topo = 12 units");
        assert_eq!(oracle, 7.0, "paper: Oracle = 7 units");
    }

    #[test]
    fn oracle_matches_spt_optimum_here() {
        // Enumerate all 24 orders: the Oracle's total equals the optimum
        // (as in the paper's example).
        let idx = [0usize, 1, 2, 3];
        let mut best = f64::MAX;
        for a in idx {
            for b in idx {
                for c in idx {
                    for d in idx {
                        let p = [a, b, c, d];
                        let mut q = p;
                        q.sort_unstable();
                        if q == [0, 1, 2, 3] {
                            best = best.min(total_waiting(&p));
                        }
                    }
                }
            }
        }
        let (_, _, oracle) = waiting_times();
        assert_eq!(best, oracle);
    }

    #[test]
    fn topo_strictly_between() {
        let (fcfs, topo, oracle) = waiting_times();
        assert!(oracle < topo && topo < fcfs);
    }
}
