//! Fig 8: correlation between scheduling order (queue ranking) and true
//! inference latency ranking under FCFS and Topo at 8 req/s (paper §2.2.2:
//! "no obvious correlations").
//!
//! We run the co-located workload, collect per-request (dispatch order,
//! true remaining latency) pairs, and report Kendall-τ rank correlation —
//! FCFS/Topo sit near zero, Kairos and the Oracle are strongly positive.

use crate::server::sim::{run_system, SimConfig};
use crate::stats::kendall::kendall_tau;
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::workload::{TraceGen, WorkloadMix};
use crate::Result;

/// Dispatch-order vs true-latency Kendall tau for one scheduler.
pub fn tau_for(scheduler: &str, rate: f64, seed: u64) -> f64 {
    let cfg = SimConfig::default();
    let arrivals =
        TraceGen::default().generate(&WorkloadMix::colocated(), rate, 1200, &mut Rng::new(seed));
    let res = run_system(cfg, scheduler, "rr", arrivals);
    // Only requests that actually waited tell us anything about ordering.
    let mut rows: Vec<(f64, f64)> = res
        .metrics
        .requests
        .iter()
        .filter(|r| r.queue_time() > 1e-6)
        .map(|r| (r.dispatched_at, r.true_remaining))
        .collect();
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    let order: Vec<f64> = (0..rows.len()).map(|i| i as f64).collect();
    let lat: Vec<f64> = rows.iter().map(|r| r.1).collect();
    kendall_tau(&order, &lat)
}

pub fn run(out_dir: &str) -> Result<()> {
    let rate = 8.0; // the paper's operating point
    let mut t = Table::new(&["scheduler", "kendall tau (order vs latency)", "paper expectation"]);
    let mut csv = vec![vec!["scheduler".to_string(), "tau".into()]];
    for (name, expect) in [
        ("parrot", "~0 (no correlation)"),
        ("ayo", "weak"),
        ("kairos", "positive"),
        ("oracle", "strongly positive"),
    ] {
        let tau = tau_for(name, rate, 88);
        t.row(vec![name.into(), format!("{tau:.3}"), expect.into()]);
        csv.push(vec![name.into(), tau.to_string()]);
    }
    println!("Fig 8 — scheduling order vs inference latency (8 req/s, co-located):");
    t.print();
    write_csv(format!("{out_dir}/fig8.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_uncorrelated_kairos_correlated() {
        let fcfs = tau_for("parrot", 8.0, 5);
        let kairos = tau_for("kairos", 8.0, 5);
        let oracle = tau_for("oracle", 8.0, 5);
        assert!(fcfs.abs() < 0.25, "FCFS tau should be near zero: {fcfs}");
        assert!(kairos > fcfs + 0.1, "kairos {kairos} vs fcfs {fcfs}");
        // Dispatch order also reflects arrival times (requests are not all
        // queued simultaneously), so even the oracle's tau is well below 1.
        assert!(oracle > 0.2 && oracle > fcfs + 0.15, "oracle {oracle} fcfs {fcfs}");
    }
}
