//! Fig 9 / §2.2.3: preemption and memory waste under Round-Robin vs
//! memory-aware dispatching at 8 req/s (paper: 18.4% of requests preempted,
//! 14.2% of memory wasted under RR).
//!
//! KV pressure comes from co-tenant memory (the paper's shared production
//! instances); `kv_scale` shrinks the per-instance pool to the pressure
//! regime where dispatching quality matters.

use crate::server::sim::{run_system, SimConfig};
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::workload::{TraceGen, WorkloadMix};
use crate::Result;

pub struct DispatchOutcome {
    pub dispatcher: &'static str,
    pub preemption_rate: f64,
    pub recompute_waste: f64,
    pub avg_token_latency: f64,
}

pub fn outcome_for(dispatcher: &'static str, rate: f64, seed: u64) -> DispatchOutcome {
    let cfg = SimConfig {
        kv_scale: 0.09, // shared-instance memory pressure regime (§2.2.3)
        ..Default::default()
    };
    let arrivals =
        TraceGen::default().generate(&WorkloadMix::colocated(), rate, 1200, &mut Rng::new(seed));
    let res = run_system(cfg, "parrot", dispatcher, arrivals);
    DispatchOutcome {
        dispatcher,
        preemption_rate: res.summary.preemption_rate,
        recompute_waste: res.summary.recompute_waste,
        avg_token_latency: res.summary.avg_token_latency,
    }
}

pub fn run(out_dir: &str) -> Result<()> {
    let rate = 8.0;
    let mut t = Table::new(&[
        "dispatcher", "preempted reqs", "recompute waste", "avg token latency (s)",
    ]);
    let mut csv = vec![vec![
        "dispatcher".to_string(), "preemption_rate".into(), "recompute_waste".into(),
        "avg_token_latency".into(),
    ]];
    for d in ["rr", "kairos", "oracle"] {
        let o = outcome_for(match d {
            "rr" => "rr",
            "kairos" => "kairos",
            _ => "oracle",
        }, rate, 99);
        t.row(vec![
            o.dispatcher.into(),
            format!("{:.1}%", o.preemption_rate * 100.0),
            format!("{:.1}%", o.recompute_waste * 100.0),
            format!("{:.3}", o.avg_token_latency),
        ]);
        csv.push(vec![
            o.dispatcher.into(),
            o.preemption_rate.to_string(),
            o.recompute_waste.to_string(),
            o.avg_token_latency.to_string(),
        ]);
    }
    println!("Fig 9 / §2.2.3 — dispatching under memory pressure (8 req/s):");
    println!("(paper, RR: 18.4% requests preempted, 14.2% memory wasted)");
    t.print();
    write_csv(format!("{out_dir}/fig9.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rr_preempts_more_than_memory_aware() {
        let rr = outcome_for("rr", 8.0, 7);
        let kairos = outcome_for("kairos", 8.0, 7);
        assert!(rr.preemption_rate > 0.02, "pressure regime: rr {}", rr.preemption_rate);
        assert!(
            kairos.preemption_rate < rr.preemption_rate,
            "kairos {} !< rr {}",
            kairos.preemption_rate,
            rr.preemption_rate
        );
    }
}
