//! Figure/table regeneration harnesses — one per table AND figure of the
//! paper's evaluation (DESIGN.md §5 maps each to its modules).
//!
//! Every harness prints the paper's rows/series as an aligned table and
//! writes the same data as CSV under `results/`. Invoke via
//! `kairos figures <id>` or `kairos figures all`.

pub mod calibrate;
pub mod e2e;
pub mod fig16;
pub mod fig18;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod motivation;
pub mod overhead;

use crate::Result;

/// All known figure ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
    "fig14", "fig15", "fig16", "fig17", "fig18", "overhead",
];

/// Run one harness by id (or "all").
pub fn run(id: &str, out_dir: &str) -> Result<()> {
    match id {
        "table1" => motivation::table1(out_dir),
        "fig3" => motivation::fig3(out_dir),
        "fig4" => motivation::fig4(out_dir),
        "fig5" => motivation::fig5(out_dir),
        "fig6" => motivation::fig6(out_dir),
        "fig7" => fig7::run(out_dir),
        "fig8" => fig8::run(out_dir),
        "fig9" => fig9::run(out_dir),
        "fig14" => e2e::fig14(out_dir),
        "fig15" => e2e::fig15(out_dir),
        "fig16" => fig16::run(out_dir),
        "fig17" => e2e::fig17(out_dir),
        "fig18" => fig18::run(out_dir),
        "overhead" => overhead::run(out_dir),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, out_dir)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure id {other:?}; known: {ALL:?} or all"),
    }
}
