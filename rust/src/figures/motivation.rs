//! Motivation / characterization harnesses: Table 1 and Figures 3–6
//! (paper §2.1).

use crate::agents::apps::App;
use crate::agents::datasets::group_datasets;
use crate::engine::cost_model::{CostModel, ModelKind};
use crate::stats::dist::Dist;
use crate::stats::rng::Rng;
use crate::stats::summary::Summary;
use crate::util::csv::write_csv;
use crate::util::table::{f3, Table};
use crate::Result;

/// Table 1: workflow-type survey statistics (static data from the paper's
/// 30-project GitHub survey).
pub fn table1(out_dir: &str) -> Result<()> {
    let rows = [
        ("Dynamic branching", 19, 63.3),
        ("Sequential execution", 23, 76.6),
        ("Dynamic feedback", 16, 53.3),
    ];
    let mut t = Table::new(&["Workflow Type", "Count", "Proportion"]);
    let mut csv = vec![vec!["workflow_type".to_string(), "count".into(), "proportion".into()]];
    for (name, count, prop) in rows {
        t.row(vec![name.into(), count.to_string(), format!("{prop}%")]);
        csv.push(vec![name.into(), count.to_string(), prop.to_string()]);
    }
    t.print();
    write_csv(format!("{out_dir}/table1.csv"), &csv)?;
    Ok(())
}

/// The ten agents of the Group-1 workloads (QA/G+M, RG/TQ, CG/HE) — the
/// roster Figures 3 and 4 characterize.
fn group1_agents() -> Vec<(App, &'static str, &'static str)> {
    let mut v = Vec::new();
    for (app, ds) in [(App::Qa, "G+M"), (App::Rg, "TQ"), (App::Cg, "HE")] {
        for a in app.dataset(ds).agents {
            v.push((app, ds, a.agent));
        }
    }
    v
}

/// Sample output lengths for one agent of one dataset.
fn output_samples(app: App, ds: &str, agent: &str, n: usize, seed: u64) -> Vec<f64> {
    let profile = app.dataset(ds);
    let p = profile.agent(agent);
    let mut rng = Rng::new(seed);
    (0..n).map(|_| p.sample_output(&mut rng) as f64).collect()
}

/// Isolated inference latency for a sampled request of an agent.
fn latency_samples(app: App, ds: &str, agent: &str, n: usize, seed: u64) -> Vec<f64> {
    let profile = app.dataset(ds);
    let p = profile.agent(agent);
    let cost = CostModel::new(ModelKind::Llama3_8B);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let prompt = p.sample_prompt(&mut rng);
            let output = p.sample_output(&mut rng);
            let prefill = cost.step_time(prompt, 0, 0);
            let decode: f64 = cost.step_time(0, 1, prompt as u64 + output as u64 / 2)
                * output as f64;
            prefill + decode
        })
        .collect()
}

/// Fig 3: output-length distributions of the ten agents (P10/P50/P90).
pub fn fig3(out_dir: &str) -> Result<()> {
    let mut t = Table::new(&["app", "agent", "p10", "median", "p90", "mean"]);
    let mut csv =
        vec![vec!["app".to_string(), "agent".into(), "p10".into(), "p50".into(), "p90".into(), "mean".into()]];
    for (i, (app, ds, agent)) in group1_agents().into_iter().enumerate() {
        let s = Summary::from_samples(&output_samples(app, ds, agent, 4000, 30 + i as u64))
            .unwrap();
        t.row(vec![
            app.name().into(),
            agent.into(),
            f3(s.percentile(10.0)),
            f3(s.p50()),
            f3(s.p90()),
            f3(s.mean()),
        ]);
        csv.push(vec![
            app.name().into(),
            agent.into(),
            s.percentile(10.0).to_string(),
            s.p50().to_string(),
            s.p90().to_string(),
            s.mean().to_string(),
        ]);
    }
    println!("Fig 3 — output length distributions (tokens):");
    t.print();
    write_csv(format!("{out_dir}/fig3.csv"), &csv)?;
    Ok(())
}

/// Fig 4: inference latency distributions + decode share of total latency.
pub fn fig4(out_dir: &str) -> Result<()> {
    let cost = CostModel::new(ModelKind::Llama3_8B);
    let mut t = Table::new(&["app", "agent", "p50 (s)", "p90 (s)", "decode share"]);
    let mut csv = vec![vec![
        "app".to_string(), "agent".into(), "p50".into(), "p90".into(), "decode_share".into(),
    ]];
    let mut min_share: f64 = 1.0;
    for (i, (app, ds, agent)) in group1_agents().into_iter().enumerate() {
        let lats = latency_samples(app, ds, agent, 4000, 60 + i as u64);
        let s = Summary::from_samples(&lats).unwrap();
        // Decode share at the agent's mean operating point.
        let p = app.dataset(ds);
        let prof = p.agent(agent);
        let prompt = prof.prompt.mean();
        let output = prof.output.mean();
        let prefill = cost.step_time(prompt as u32, 0, 0);
        let decode =
            cost.step_time(0, 1, (prompt + output / 2.0) as u64) * output;
        let share = decode / (decode + prefill);
        min_share = min_share.min(share);
        t.row(vec![
            app.name().into(),
            agent.into(),
            f3(s.p50()),
            f3(s.p90()),
            format!("{:.1}%", share * 100.0),
        ]);
        csv.push(vec![
            app.name().into(),
            agent.into(),
            s.p50().to_string(),
            s.p90().to_string(),
            share.to_string(),
        ]);
    }
    println!("Fig 4 — inference latency distributions (A40/Llama3-8B cost model):");
    t.print();
    println!("minimum decode share across agents: {:.1}% (paper: >96.6%)", min_share * 100.0);
    write_csv(format!("{out_dir}/fig4.csv"), &csv)?;
    Ok(())
}

/// Fig 5/6 shared sweep: per (group, app, agent) → (mean output, mean latency).
fn group_sweep() -> Vec<(usize, App, &'static str, f64, f64)> {
    let mut rows = Vec::new();
    for group in 1..=3 {
        let (qa, rg, cg) = group_datasets(group);
        for (app, ds) in [(App::Qa, qa), (App::Rg, rg), (App::Cg, cg)] {
            for a in app.dataset(ds).agents {
                let outs = output_samples(app, ds, a.agent, 3000, group as u64 * 97);
                let lats = latency_samples(app, ds, a.agent, 3000, group as u64 * 131);
                let mean_out = outs.iter().sum::<f64>() / outs.len() as f64;
                let mean_lat = lats.iter().sum::<f64>() / lats.len() as f64;
                rows.push((group, app, a.agent, mean_out, mean_lat));
            }
        }
    }
    rows
}

/// Fig 5: average output lengths across dataset Groups 1–3.
pub fn fig5(out_dir: &str) -> Result<()> {
    let mut t = Table::new(&["group", "app", "agent", "avg output (tok)"]);
    let mut csv =
        vec![vec!["group".to_string(), "app".into(), "agent".into(), "avg_output".into()]];
    for (g, app, agent, out, _) in group_sweep() {
        t.row(vec![g.to_string(), app.name().into(), agent.into(), f3(out)]);
        csv.push(vec![g.to_string(), app.name().into(), agent.into(), out.to_string()]);
    }
    println!("Fig 5 — average output lengths across Groups 1-3:");
    t.print();
    write_csv(format!("{out_dir}/fig5.csv"), &csv)?;
    Ok(())
}

/// Fig 6: average inference latency across dataset Groups 1–3.
pub fn fig6(out_dir: &str) -> Result<()> {
    let mut t = Table::new(&["group", "app", "agent", "avg latency (s)"]);
    let mut csv =
        vec![vec!["group".to_string(), "app".into(), "agent".into(), "avg_latency".into()]];
    for (g, app, agent, _, lat) in group_sweep() {
        t.row(vec![g.to_string(), app.name().into(), agent.into(), f3(lat)]);
        csv.push(vec![g.to_string(), app.name().into(), agent.into(), lat.to_string()]);
    }
    println!("Fig 6 — average inference latency across Groups 1-3:");
    t.print();
    write_csv(format!("{out_dir}/fig6.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_agents_in_group1() {
        assert_eq!(group1_agents().len(), 10);
    }

    #[test]
    fn decode_dominates_aggregate_and_experts() {
        // Fig-4 claim: >96.6% of inference time is decoding. That is an
        // aggregate over requests — short-output agents (Router) sit lower
        // individually, expert agents higher.
        let cost = CostModel::new(ModelKind::Llama3_8B);
        let mut total_prefill = 0.0;
        let mut total_decode = 0.0;
        for (app, ds, agent) in group1_agents() {
            let p = app.dataset(ds);
            let prof = p.agent(agent);
            let prompt = prof.prompt.mean();
            let output = prof.output.mean();
            let prefill = cost.step_time(prompt as u32, 0, 0);
            let decode = cost.step_time(0, 1, (prompt + output / 2.0) as u64) * output;
            total_prefill += prefill;
            total_decode += decode;
            if output > 100.0 {
                let share = decode / (decode + prefill);
                assert!(share > 0.95, "expert {agent}: {share}");
            }
        }
        let agg = total_decode / (total_decode + total_prefill);
        assert!(agg > 0.96, "aggregate decode share {agg} (paper: 0.966)");
    }

    #[test]
    fn agent_behaviour_stable_across_groups() {
        // Fig 5: per-agent means vary < 2x across groups while inter-agent
        // spread within a group is much larger.
        let rows = group_sweep();
        let router: Vec<f64> = rows
            .iter()
            .filter(|(_, _, a, _, _)| *a == "Router")
            .map(|(_, _, _, o, _)| *o)
            .collect();
        let max = router.iter().cloned().fold(f64::MIN, f64::max);
        let min = router.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 2.0, "router across groups: {router:?}");
    }
}
