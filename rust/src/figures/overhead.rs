//! §7.7: Kairos' overheads.
//!
//! * Agent-priority updates: Wasserstein matrix (incremental) + MDS —
//!   quadratic in agents; paper measures ~0.1 s at 10 agents to ~4.3 s at
//!   5000 agents.
//! * Per-request: queue sorting ≈ 3.6 ms, time-slot packing ≈ 4.1 ms.

// This figure *measures* real wall time (that is its whole point), so the
// determinism lint (rule D1) exempts this file and clippy's
// disallowed-methods check is switched off module-wide.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use crate::dispatch::timeslot::{TimeSlotConfig, TimeSlotDispatcher};
use crate::dispatch::DispatchPolicy;
use crate::engine::core::InstanceStatus;
use crate::engine::cost_model::{CostModel, ModelClass, ModelKind};
use crate::engine::request::Request;
use crate::lb::policies::{Fcfs, SchedulePolicy};
use crate::lb::priority::AgentPriorities;
use crate::lb::queue::RequestQueue;
use crate::orchestrator::ids::AgentId;
use crate::stats::dist::{Dist, LogNormal};
use crate::stats::ecdf::Ecdf;
use crate::stats::rng::Rng;
use crate::util::csv::write_csv;
use crate::util::table::Table;
use crate::Result;

fn mk_req(id: u64, agent: u32, rng: &mut Rng) -> Request {
    Request {
        id,
        msg_id: id,
        agent: AgentId(agent),
        session: id,
        model_class: ModelClass::Any,
        upstream: None,
        prompt_tokens: 50 + rng.below(400) as u32,
        true_output_tokens: 50 + rng.below(500) as u32,
        true_remaining_latency: rng.f64() * 30.0,
        remaining_stages: 1 + rng.below(5) as u32,
        app_start: rng.f64() * 100.0,
        stage_arrival: rng.f64() * 100.0,
    }
}

/// MDS priority-update time for `n` agents (seconds).
pub fn mds_time(n: usize, samples_per_agent: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let agents: Vec<AgentId> = (0..n as u32).map(AgentId).collect();
    let ecdfs: Vec<Ecdf> = (0..n)
        .map(|i| {
            let d = LogNormal::from_mean_cv(1.0 + i as f64 * 0.01, 0.5);
            Ecdf::new((0..samples_per_agent).map(|_| d.sample(&mut rng)).collect())
        })
        .collect();
    let t0 = Instant::now();
    let p = AgentPriorities::from_ecdfs(&agents, &ecdfs);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(p.len(), n);
    dt
}

/// Queue-scheduling time: one full priority extraction from `n` queued
/// requests (seconds).
pub fn sort_time(n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let policy = Fcfs;
    let mut q = RequestQueue::new();
    for i in 0..n {
        q.push(mk_req(i as u64, (i % 50) as u32, &mut rng), &policy as &dyn SchedulePolicy);
    }
    // One scheduling decision = a re-key pass (worst case: priorities just
    // refreshed) + a heap pop.
    let t0 = Instant::now();
    q.resort(&policy as &dyn SchedulePolicy);
    let got = q.pop_best();
    let dt = t0.elapsed().as_secs_f64();
    assert!(got.is_some());
    dt
}

/// Time-slot packing decision time across `n_instances` (seconds).
pub fn packing_time(n_instances: usize, live_requests: usize, seed: u64) -> f64 {
    let cost = CostModel::new(ModelKind::Llama3_8B);
    let cfg = TimeSlotConfig::for_cost_model(&cost);
    let mut d = TimeSlotDispatcher::new(n_instances, cfg);
    let mut rng = Rng::new(seed);
    let statuses: Vec<InstanceStatus> = (0..n_instances)
        .map(|id| InstanceStatus {
            id,
            free_blocks: 1000,
            used_blocks: 0,
            total_blocks: 1000,
            block_size: 16,
            n_running: 0,
            n_waiting: 0,
            waiting_tokens: 0,
            committed_tokens: 0,
            capacity_tokens: 1 << 24,
            preemptions: 0,
            alloc_failures: 0,
            accepting: true,
            model: ModelKind::Llama3_8B,
        })
        .collect();
    // Pre-commit a realistic number of live predictions.
    for i in 0..live_requests {
        let r = mk_req(i as u64, (i % 10) as u32, &mut rng);
        let now = i as f64 * 0.01;
        if let Some(j) = d.choose(&r, &statuses, now) {
            d.on_dispatch(&r, j, now);
        }
    }
    let probe = mk_req(u64::MAX, 0, &mut rng);
    let t0 = Instant::now();
    let got = d.choose(&probe, &statuses, live_requests as f64 * 0.01);
    let dt = t0.elapsed().as_secs_f64();
    assert!(got.is_some());
    dt
}

/// One coordinator pump pass — scheduling + dispatching a deep backlog
/// across `n_instances` — in seconds. The per-instance status snapshot is
/// a reusable buffer inside the coordinator (refreshed only for instances
/// whose engine changed), so this measures decision cost, not per-pump
/// allocation.
pub fn pump_time(n_instances: usize, backlog: usize, seed: u64) -> f64 {
    use crate::dispatch::RoundRobin;
    use crate::server::coordinator::{Coordinator, FleetSpec, InstanceSpec};
    let fleet = FleetSpec::homogeneous(
        n_instances,
        InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12),
    );
    let mut coord =
        Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
    let mut rng = Rng::new(seed);
    for i in 0..backlog {
        let r = mk_req(i as u64, (i % 10) as u32, &mut rng);
        coord.submit_external("bench-agent", r.prompt_tokens, r.true_output_tokens, 0.0);
    }
    let t0 = Instant::now();
    let woken = coord.pump(0.0);
    let dt = t0.elapsed().as_secs_f64();
    assert!(!woken.is_empty());
    assert_eq!(coord.dispatch_log.len(), backlog);
    dt
}

pub fn run(out_dir: &str) -> Result<()> {
    println!("§7.7 — overhead of Kairos\n");

    let mut t = Table::new(&["agents", "MDS update (s)", "paper"]);
    let mut csv = vec![vec!["agents".to_string(), "seconds".into()]];
    for (n, paper) in [(10, "~0.1"), (100, ""), (1000, ""), (5000, "~4.3")] {
        let dt = mds_time(n, 64, 7);
        t.row(vec![n.to_string(), format!("{dt:.4}"), paper.into()]);
        csv.push(vec![n.to_string(), dt.to_string()]);
    }
    t.print();
    write_csv(format!("{out_dir}/overhead_mds.csv"), &csv)?;

    let mut t = Table::new(&["queued requests", "schedule pick (ms)", "paper"]);
    let mut csv = vec![vec!["queued".to_string(), "ms".into()]];
    for (n, paper) in [(100, ""), (1000, ""), (10_000, "~3.6 ms"), (100_000, "")] {
        let dt = sort_time(n, 8) * 1e3;
        t.row(vec![n.to_string(), format!("{dt:.3}"), paper.into()]);
        csv.push(vec![n.to_string(), dt.to_string()]);
    }
    println!();
    t.print();
    write_csv(format!("{out_dir}/overhead_sort.csv"), &csv)?;

    let mut t = Table::new(&["instances", "packing decision (ms)", "paper"]);
    let mut csv = vec![vec!["instances".to_string(), "ms".into()]];
    for (n, paper) in [(4, "~4.1 ms"), (8, ""), (16, ""), (64, "")] {
        let dt = packing_time(n, 200, 9) * 1e3;
        t.row(vec![n.to_string(), format!("{dt:.3}"), paper.into()]);
        csv.push(vec![n.to_string(), dt.to_string()]);
    }
    println!();
    t.print();
    write_csv(format!("{out_dir}/overhead_packing.csv"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mds_scales_quadratically_ish() {
        let t10 = mds_time(10, 32, 1).max(1e-6);
        let t100 = mds_time(100, 32, 1);
        // 10x agents should be far more than 2x cost but bounded.
        assert!(t100 > t10, "t100={t100} t10={t10}");
        assert!(t100 / t10 < 100_000.0);
    }

    #[test]
    fn per_request_overheads_are_small() {
        // The paper's overheads (3.6 ms / 4.1 ms) are on python; our rust
        // implementations must be well under.
        assert!(sort_time(10_000, 2) < 3.6e-3);
        assert!(packing_time(4, 200, 3) < 4.1e-3);
    }

    #[test]
    fn pump_dispatches_whole_backlog() {
        // Correctness smoke for the bench helper: every backlogged request
        // gets a dispatch decision in one pump pass.
        let dt = pump_time(4, 1_000, 5);
        assert!(dt >= 0.0);
        assert!(dt < 1.0, "pump of 1k backlog took {dt}s");
    }
}
