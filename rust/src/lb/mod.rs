//! Load-balancer scheduling layer (paper §5 + baselines).
//!
//! All requests enter a single central queue; a [`SchedulePolicy`] defines
//! the total order in which they leave it:
//!
//! * [`policies::Fcfs`] — Parrot's First-Come-First-Serve baseline.
//! * [`policies::Topo`] — Ayo's topology-depth priority (fewer remaining
//!   stages first).
//! * [`policies::KairosPolicy`] — the paper's workflow-aware priority:
//!   agent-level order from the remaining-latency distributions
//!   (Wasserstein → MDS → zero-anchor orientation, [`priority`]) and
//!   intra-agent order by application-level start time (§5.2).
//! * [`policies::Oracle`] — knows each request's true remaining latency
//!   (upper bound used in the §2.2.2 / Fig 7-8 analyses).

pub mod policies;
pub mod priority;
pub mod queue;

pub use policies::{Fcfs, KairosPolicy, Oracle, SchedulePolicy, Topo};
pub use priority::AgentPriorities;
pub use queue::RequestQueue;
