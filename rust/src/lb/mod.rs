//! Load-balancer scheduling layer (paper §5 + baselines).
//!
//! All requests enter the central queue — sharded into model-affine
//! serving groups ([`sharded::ShardedQueue`]): one [`queue::RequestQueue`]
//! per [`sharded::ShardKey`] — a model family pinned by agent affinity, a
//! per-group shard of router-balanced `Any` work, or the shared `Any`
//! shard for unrouted work. A [`SchedulePolicy`] defines the total order
//! in which requests leave it (global across shards; a blocked group only
//! stalls itself):
//!
//! * [`policies::Fcfs`] — Parrot's First-Come-First-Serve baseline.
//! * [`policies::Topo`] — Ayo's topology-depth priority (fewer remaining
//!   stages first).
//! * [`policies::KairosPolicy`] — the paper's workflow-aware priority:
//!   agent-level order from the remaining-latency distributions
//!   (Wasserstein → MDS → zero-anchor orientation, [`priority`]) and
//!   intra-agent order by application-level start time (§5.2).
//! * [`policies::Oracle`] — knows each request's true remaining latency
//!   (upper bound used in the §2.2.2 / Fig 7-8 analyses).

pub mod policies;
pub mod priority;
pub mod queue;
pub mod sharded;

pub use policies::{Fcfs, KairosPolicy, Oracle, SchedulePolicy, Topo};
pub use priority::AgentPriorities;
pub use queue::RequestQueue;
pub use sharded::{ShardKey, ShardedQueue};
