//! The scheduling policies: Kairos and the baselines it is evaluated
//! against (paper §7.1).

use super::priority::AgentPriorities;
use crate::engine::request::Request;
use crate::orchestrator::Orchestrator;

/// A total order over queued requests. Lower key = scheduled earlier.
///
/// Keys are a `(primary, secondary)` pair; ties on the primary fall back to
/// the secondary (and then to arrival order inside the queue).
pub trait SchedulePolicy: Send {
    fn name(&self) -> &'static str;

    /// Ordering key for a queued request.
    fn key(&self, req: &Request) -> (f64, f64);

    /// Refresh internal state from the orchestrator (called periodically;
    /// Kairos recomputes its agent priorities here — §7.7 notes this runs
    /// asynchronously at fixed intervals).
    fn refresh(&mut self, _orch: &Orchestrator) {}
}

/// Parrot: First-Come-First-Serve on stage arrival time.
#[derive(Debug, Default, Clone)]
pub struct Fcfs;

impl SchedulePolicy for Fcfs {
    fn name(&self) -> &'static str {
        "parrot-fcfs"
    }
    fn key(&self, req: &Request) -> (f64, f64) {
        (req.stage_arrival, 0.0)
    }
}

/// Ayo: topology-depth priority — requests with fewer remaining workflow
/// stages first; FCFS within a depth.
#[derive(Debug, Default, Clone)]
pub struct Topo;

impl SchedulePolicy for Topo {
    fn name(&self) -> &'static str {
        "ayo-topo"
    }
    fn key(&self, req: &Request) -> (f64, f64) {
        (req.remaining_stages as f64, req.stage_arrival)
    }
}

/// Kairos: agent-level priority from remaining-latency distributions
/// (Wasserstein + MDS + zero anchor), intra-agent by application-level
/// start time (earlier app start = more accumulated delay = higher
/// priority, §5.2).
#[derive(Debug, Default)]
pub struct KairosPolicy {
    priorities: AgentPriorities,
    refreshes: u64,
}

impl KairosPolicy {
    pub fn new() -> KairosPolicy {
        KairosPolicy::default()
    }

    pub fn priorities(&self) -> &AgentPriorities {
        &self.priorities
    }

    pub fn refresh_count(&self) -> u64 {
        self.refreshes
    }
}

impl SchedulePolicy for KairosPolicy {
    fn name(&self) -> &'static str {
        "kairos"
    }
    fn key(&self, req: &Request) -> (f64, f64) {
        (self.priorities.coord(req.agent), req.app_start)
    }
    fn refresh(&mut self, orch: &Orchestrator) {
        self.priorities = AgentPriorities::compute(&orch.profiler);
        self.refreshes += 1;
    }
}

/// Oracle: schedules by the request's true remaining workflow latency
/// (shortest-remaining-time-first with perfect information).
#[derive(Debug, Default, Clone)]
pub struct Oracle;

impl SchedulePolicy for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn key(&self, req: &Request) -> (f64, f64) {
        (req.true_remaining_latency, req.app_start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::ids::AgentId;

    fn req(agent: u32, arrival: f64, app_start: f64, stages: u32, rem: f64) -> Request {
        Request {
            id: 0,
            msg_id: 0,
            agent: AgentId(agent),
            session: 0,
            model_class: crate::engine::cost_model::ModelClass::Any,
            upstream: None,
            prompt_tokens: 10,
            true_output_tokens: 10,
            true_remaining_latency: rem,
            remaining_stages: stages,
            app_start,
            stage_arrival: arrival,
        }
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let p = Fcfs;
        assert!(p.key(&req(0, 1.0, 0.0, 1, 0.0)) < p.key(&req(1, 2.0, 0.0, 1, 0.0)));
    }

    #[test]
    fn topo_orders_by_depth_then_arrival() {
        let p = Topo;
        let shallow = req(0, 5.0, 0.0, 1, 0.0);
        let deep = req(1, 1.0, 0.0, 3, 0.0);
        assert!(p.key(&shallow) < p.key(&deep), "fewer stages wins despite later arrival");
        let a = req(0, 1.0, 0.0, 2, 0.0);
        let b = req(1, 2.0, 0.0, 2, 0.0);
        assert!(p.key(&a) < p.key(&b), "ties broken FCFS");
    }

    #[test]
    fn oracle_orders_by_true_remaining() {
        let p = Oracle;
        assert!(
            p.key(&req(0, 9.0, 0.0, 5, 1.0)) < p.key(&req(1, 0.0, 0.0, 1, 2.0)),
            "only remaining latency matters"
        );
    }

    #[test]
    fn kairos_intra_agent_prefers_older_app_start() {
        // Same agent: priority coordinate equal, so app_start decides.
        let p = KairosPolicy::new();
        let older = req(0, 5.0, 1.0, 1, 0.0);
        let newer = req(0, 1.0, 8.0, 1, 0.0);
        assert!(p.key(&older) < p.key(&newer));
    }

    #[test]
    fn kairos_refresh_picks_up_profiles() {
        use crate::orchestrator::graph::ExecRecord;
        let mut orch = Orchestrator::new();
        let fast = orch.registry.intern("fast");
        let slow = orch.registry.intern("slow");
        // Build workflows so remaining latency differs 10x.
        for m in 0..64 {
            let msg = m as u64;
            orch.record_execution(ExecRecord {
                msg_id: msg,
                agent: fast,
                upstream: None,
                start: 0.0,
                end: 1.0,
            });
            orch.record_workflow_done(msg, 1.0);
        }
        for m in 100..164 {
            let msg = m as u64;
            orch.record_execution(ExecRecord {
                msg_id: msg,
                agent: slow,
                upstream: None,
                start: 0.0,
                end: 10.0,
            });
            orch.record_workflow_done(msg, 10.0);
        }
        let mut p = KairosPolicy::new();
        p.refresh(&orch);
        assert_eq!(p.refresh_count(), 1);
        let kf = p.key(&Request { agent: fast, ..req(0, 0.0, 0.0, 1, 0.0) });
        let ks = p.key(&Request { agent: slow, ..req(0, 0.0, 0.0, 1, 0.0) });
        assert!(kf < ks, "fast agent must rank before slow: {kf:?} vs {ks:?}");
    }
}
