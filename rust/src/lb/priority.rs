//! Agent-level priority determination (paper §5.1).
//!
//! From each agent's **remaining execution latency distribution**:
//! 1. pairwise Wasserstein-1 distance matrix over all agents **plus** an
//!    ideal "zero latency" anchor distribution,
//! 2. classical MDS embeds the matrix into a 1-D coordinate space,
//! 3. the axis is oriented so the anchor sits lowest: agents closer to the
//!    anchor have shorter remaining latency ⇒ higher scheduling priority.

use std::collections::HashMap;

use crate::orchestrator::ids::AgentId;
use crate::orchestrator::profiler::DistributionProfiler;
use crate::stats::ecdf::{wasserstein1, Ecdf, QuantileSketch};
use crate::stats::mds::{mds_1d_anchored, SymMatrix};


/// The computed agent priority coordinates (lower = schedule earlier).
#[derive(Debug, Clone, Default)]
pub struct AgentPriorities {
    coords: HashMap<AgentId, f64>,
    default_coord: f64,
}

impl AgentPriorities {
    /// Compute priorities from the profiler's remaining-latency ECDFs.
    /// Agents without samples yet get the mean coordinate (neutral).
    pub fn compute(profiler: &DistributionProfiler) -> AgentPriorities {
        let agents = profiler.agents_with_remaining();
        let ecdfs: Vec<Ecdf> = agents
            .iter()
            .filter_map(|&a| profiler.remaining_profile(a).and_then(|p| p.ecdf()))
            .collect();
        Self::from_ecdfs(&agents, &ecdfs)
    }

    /// Core computation, usable directly in tests/figures.
    pub fn from_ecdfs(agents: &[AgentId], ecdfs: &[Ecdf]) -> AgentPriorities {
        assert_eq!(agents.len(), ecdfs.len());
        let n = agents.len();
        if n == 0 {
            return AgentPriorities::default();
        }
        // Distance matrix over agents + anchor (last row/col).
        //
        // §7.7 evaluates up to 5000 agents ⇒ 12.5M pairwise distances per
        // refresh; the exact O(samples) Wasserstein merge per pair would
        // dominate the update. Small agent sets use the exact distance;
        // large ones use the O(K) quantile-sketch approximation — within a
        // few percent of exact, which only has to preserve the *ordering*
        // (EXPERIMENTS.md §Perf).
        let zero = Ecdf::zero();
        let mut m = SymMatrix::zeros(n + 1);
        if n < 64 {
            for i in 0..n {
                for j in (i + 1)..n {
                    m.set(i, j, wasserstein1(&ecdfs[i], &ecdfs[j]));
                }
                m.set(i, n, wasserstein1(&ecdfs[i], &zero));
            }
        } else {
            let k = QuantileSketch::DEFAULT_K;
            let sketches: Vec<QuantileSketch> =
                ecdfs.iter().map(|e| QuantileSketch::of(e, k)).collect();
            let zero_sketch = QuantileSketch::zero(k);
            for i in 0..n {
                for j in (i + 1)..n {
                    m.set(i, j, sketches[i].w1(&sketches[j]));
                }
                m.set(i, n, sketches[i].w1(&zero_sketch));
            }
        }
        let coords_vec = mds_1d_anchored(&m);
        let mean = coords_vec.iter().sum::<f64>() / n as f64;
        let coords = agents.iter().copied().zip(coords_vec).collect();
        AgentPriorities { coords, default_coord: mean }
    }

    /// Priority coordinate for an agent (lower = earlier).
    pub fn coord(&self, agent: AgentId) -> f64 {
        self.coords.get(&agent).copied().unwrap_or(self.default_coord)
    }

    /// Agents ranked by priority (highest priority first). The comparator
    /// is total even if a degenerate MDS embedding yields a NaN coordinate
    /// (no panic in the refresh), and NaN of EITHER sign ranks last —
    /// `total_cmp` alone orders by sign bit, so the negative quiet NaN
    /// that `0.0 / 0.0` actually produces on x86-64 would otherwise rank
    /// first and hand the degenerate agent top scheduling priority.
    pub fn ranking(&self) -> Vec<AgentId> {
        let mut v: Vec<(AgentId, f64)> =
            self.coords.iter().map(|(&a, &c)| (a, c)).collect();
        v.sort_by(|a, b| {
            a.1.is_nan()
                .cmp(&b.1.is_nan())
                .then(a.1.total_cmp(&b.1))
                .then(a.0.cmp(&b.0))
        });
        v.into_iter().map(|(a, _)| a).collect()
    }

    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{Dist, LogNormal};
    use crate::stats::rng::Rng;

    fn ecdf_from(d: &LogNormal, n: usize, rng: &mut Rng) -> Ecdf {
        Ecdf::new((0..n).map(|_| d.sample(rng)).collect())
    }

    #[test]
    fn orders_agents_by_remaining_latency() {
        let mut rng = Rng::new(42);
        let agents = vec![AgentId(0), AgentId(1), AgentId(2)];
        // Remaining latency: agent 1 short, agent 0 medium, agent 2 long.
        let ecdfs = vec![
            ecdf_from(&LogNormal::from_mean_cv(8.0, 0.4), 400, &mut rng),
            ecdf_from(&LogNormal::from_mean_cv(1.0, 0.4), 400, &mut rng),
            ecdf_from(&LogNormal::from_mean_cv(30.0, 0.4), 400, &mut rng),
        ];
        let p = AgentPriorities::from_ecdfs(&agents, &ecdfs);
        assert_eq!(p.ranking(), vec![AgentId(1), AgentId(0), AgentId(2)]);
        assert!(p.coord(AgentId(1)) < p.coord(AgentId(0)));
        assert!(p.coord(AgentId(0)) < p.coord(AgentId(2)));
    }

    #[test]
    fn overlapping_distributions_ranked_by_location() {
        let mut rng = Rng::new(7);
        // Heavily overlapping but shifted distributions must still order.
        let agents = vec![AgentId(0), AgentId(1)];
        let ecdfs = vec![
            ecdf_from(&LogNormal::from_mean_cv(10.0, 1.2), 800, &mut rng),
            ecdf_from(&LogNormal::from_mean_cv(14.0, 1.2), 800, &mut rng),
        ];
        let p = AgentPriorities::from_ecdfs(&agents, &ecdfs);
        assert!(p.coord(AgentId(0)) < p.coord(AgentId(1)));
    }

    #[test]
    fn unknown_agent_gets_neutral_coordinate() {
        let mut rng = Rng::new(9);
        let agents = vec![AgentId(0), AgentId(1)];
        let ecdfs = vec![
            ecdf_from(&LogNormal::from_mean_cv(1.0, 0.3), 200, &mut rng),
            ecdf_from(&LogNormal::from_mean_cv(9.0, 0.3), 200, &mut rng),
        ];
        let p = AgentPriorities::from_ecdfs(&agents, &ecdfs);
        let unknown = p.coord(AgentId(99));
        assert!(unknown > p.coord(AgentId(0)));
        assert!(unknown < p.coord(AgentId(1)));
    }

    #[test]
    fn empty_profiler_is_safe() {
        let p = AgentPriorities::from_ecdfs(&[], &[]);
        assert!(p.is_empty());
        assert_eq!(p.coord(AgentId(0)), 0.0);
    }

    #[test]
    fn ranking_survives_nan_coordinate() {
        // Regression: a NaN coordinate out of a degenerate MDS embedding
        // panicked the scheduler refresh via partial_cmp().unwrap(). Now
        // it ranks last — including the NEGATIVE quiet NaN that real
        // 0.0/0.0 arithmetic produces, which raw total_cmp would rank
        // first (it orders by sign bit).
        let mut p = AgentPriorities::default();
        p.coords.insert(AgentId(0), 1.0);
        p.coords.insert(AgentId(1), f64::NAN);
        p.coords.insert(AgentId(2), 0.5);
        p.coords.insert(AgentId(3), -f64::NAN);
        let r = p.ranking();
        assert_eq!(r[0], AgentId(2));
        assert_eq!(r[1], AgentId(0));
        assert!(r[2..].contains(&AgentId(1)) && r[2..].contains(&AgentId(3)));
    }

    #[test]
    fn many_agents_scale() {
        // §7.7 scalability sanity: 100 agents embed without issue.
        let mut rng = Rng::new(3);
        let agents: Vec<AgentId> = (0..100).map(AgentId).collect();
        let ecdfs: Vec<Ecdf> = (0..100)
            .map(|i| {
                ecdf_from(
                    &LogNormal::from_mean_cv(1.0 + i as f64 * 0.5, 0.4),
                    100,
                    &mut rng,
                )
            })
            .collect();
        let p = AgentPriorities::from_ecdfs(&agents, &ecdfs);
        let ranking = p.ranking();
        assert_eq!(ranking.len(), 100);
        // Ranking should be close to the construction order: check Kendall
        // tau between ranks and means is strongly positive.
        let order: Vec<f64> = ranking.iter().map(|a| a.0 as f64).collect();
        let ideal: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let tau = crate::stats::kendall::kendall_tau(&order, &ideal);
        assert!(tau > 0.9, "tau={tau}");
    }
}
