//! One shard of the central request queue the load balancer schedules
//! from (the coordinator holds one per serving group — see
//! [`super::sharded::ShardedQueue`]; analyses still use it standalone).
//!
//! The queue is a binary heap keyed by the active
//! [`SchedulePolicy`](super::policies::SchedulePolicy)'s ordering key, so a
//! dispatch is O(log n) even under deep backlogs (the §7.7 scheduling
//! overhead). Policy keys are captured at push time; when a refresh moves
//! the agent priorities, [`RequestQueue::resort`] re-keys the heap (the
//! paper's priority updates run at fixed intervals, so re-keying is rare
//! relative to dispatching — EXPERIMENTS.md §Perf).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::policies::SchedulePolicy;
use crate::engine::request::Request;

struct Entry {
    key: (f64, f64),
    seq: u64,
    req: Request,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap; we want the MIN key on top,
        // with arrival sequence as the deterministic tiebreaker. Keys use
        // `f64::total_cmp` so a NaN from a policy (it sorts after +inf)
        // yields a total order instead of silently corrupting the heap.
        other
            .key
            .0
            .total_cmp(&self.key.0)
            .then(other.key.1.total_cmp(&self.key.1))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Priority queue over requests, keyed by the scheduling policy.
#[derive(Default)]
pub struct RequestQueue {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
    /// Peak occupancy (diagnostics).
    pub peak_len: usize,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    pub fn push(&mut self, req: Request, policy: &dyn SchedulePolicy) {
        let seq = self.next_seq;
        self.push_with_seq(req, policy, seq);
    }

    /// Push with an externally allocated insertion sequence. The sharded
    /// queue ([`super::sharded::ShardedQueue`]) allocates one global
    /// sequence across all shards so cross-shard priority ties still break
    /// by arrival order.
    pub fn push_with_seq(&mut self, req: Request, policy: &dyn SchedulePolicy, seq: u64) {
        let key = policy.key(&req);
        self.heap.push(Entry { key, seq, req });
        self.next_seq = self.next_seq.max(seq + 1);
        self.peak_len = self.peak_len.max(self.heap.len());
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Remove and return the highest-priority request.
    pub fn pop_best(&mut self) -> Option<Request> {
        self.heap.pop().map(|e| e.req)
    }

    /// Peek at the highest-priority request without removing it.
    pub fn peek_best(&self) -> Option<&Request> {
        self.heap.peek().map(|e| &e.req)
    }

    /// Priority rank `(key, insertion seq)` of the head entry — what the
    /// sharded queue compares across shards to preserve the global
    /// scheduling order. Lower sorts first.
    pub fn head_rank(&self) -> Option<((f64, f64), u64)> {
        self.heap.peek().map(|e| (e.key, e.seq))
    }

    /// Re-key every queued request against the (refreshed) policy.
    pub fn resort(&mut self, policy: &dyn SchedulePolicy) {
        let entries: Vec<Entry> = std::mem::take(&mut self.heap).into_vec();
        self.heap = entries
            .into_iter()
            .map(|mut e| {
                e.key = policy.key(&e.req);
                e
            })
            .collect();
    }

    /// Snapshot of queued requests in arbitrary order (analysis).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.heap.iter().map(|e| &e.req)
    }

    /// Drain the queue in policy order (used by the Fig 7/8/16 analyses).
    pub fn drain_ordered(&mut self, policy: &dyn SchedulePolicy) -> Vec<Request> {
        self.resort(policy);
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(r) = self.pop_best() {
            out.push(r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::policies::{Fcfs, Oracle};
    use crate::orchestrator::ids::AgentId;

    fn req(id: u64, arrival: f64, rem: f64) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session: id,
            model_class: crate::engine::cost_model::ModelClass::Any,
            upstream: None,
            prompt_tokens: 1,
            true_output_tokens: 1,
            true_remaining_latency: rem,
            remaining_stages: 1,
            app_start: arrival,
            stage_arrival: arrival,
        }
    }

    #[test]
    fn fcfs_pops_in_arrival_order() {
        let mut q = RequestQueue::new();
        for (id, arr) in [(1u64, 3.0), (2, 1.0), (3, 2.0)] {
            q.push(req(id, arr, 0.0), &Fcfs);
        }
        let order: Vec<u64> = q.drain_ordered(&Fcfs).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn oracle_pops_shortest_remaining_first() {
        let mut q = RequestQueue::new();
        for (id, rem) in [(1u64, 9.0), (2, 1.0), (3, 5.0)] {
            q.push(req(id, id as f64, rem), &Oracle);
        }
        let order: Vec<u64> = q.drain_ordered(&Oracle).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn ties_fall_back_to_insertion_order() {
        let mut q = RequestQueue::new();
        q.push(req(1, 5.0, 1.0), &Fcfs);
        q.push(req(2, 5.0, 1.0), &Fcfs);
        let order: Vec<u64> = q.drain_ordered(&Fcfs).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2]);
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = RequestQueue::new();
        q.push(req(1, 1.0, 1.0), &Fcfs);
        assert_eq!(q.peek_best().unwrap().id, 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn resort_applies_new_policy() {
        // Push under FCFS keys, then re-key under Oracle.
        let mut q = RequestQueue::new();
        q.push(req(1, 0.0, 9.0), &Fcfs);
        q.push(req(2, 1.0, 1.0), &Fcfs);
        assert_eq!(q.peek_best().unwrap().id, 1);
        q.resort(&Oracle);
        assert_eq!(q.peek_best().unwrap().id, 2);
    }

    #[test]
    fn peak_len_tracks_high_water() {
        let mut q = RequestQueue::new();
        for i in 0..5 {
            q.push(req(i, i as f64, 0.0), &Fcfs);
        }
        q.pop_best();
        q.push(req(9, 9.0, 0.0), &Fcfs);
        assert_eq!(q.peak_len, 5);
    }

    #[test]
    fn nan_key_sorts_last_and_preserves_order() {
        // Regression: Entry::cmp used partial_cmp(..).unwrap_or(Equal), so
        // one NaN key made the comparator non-total and could silently
        // corrupt heap order for every other element. With total_cmp, NaN
        // sorts after +inf (i.e. last in the min-queue) and all other
        // elements keep their exact order.
        struct NanPolicy;
        impl SchedulePolicy for NanPolicy {
            fn name(&self) -> &'static str {
                "nan-test"
            }
            fn key(&self, r: &Request) -> (f64, f64) {
                if r.id == 99 {
                    (f64::NAN, f64::NAN)
                } else {
                    (r.stage_arrival, 0.0)
                }
            }
        }
        let mut q = RequestQueue::new();
        q.push(req(1, 3.0, 0.0), &NanPolicy);
        q.push(req(99, 0.0, 0.0), &NanPolicy); // NaN key
        q.push(req(2, 1.0, 0.0), &NanPolicy);
        q.push(req(3, 2.0, 0.0), &NanPolicy);
        assert_eq!(q.len(), 4, "nothing lost");
        let order: Vec<u64> = q.drain_ordered(&NanPolicy).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 1, 99]);
    }

    #[test]
    fn heap_pop_is_total_order() {
        use crate::stats::rng::Rng;
        let mut rng = Rng::new(5);
        let mut q = RequestQueue::new();
        for i in 0..500 {
            q.push(req(i, rng.f64() * 100.0, rng.f64()), &Fcfs);
        }
        let drained = q.drain_ordered(&Fcfs);
        for w in drained.windows(2) {
            assert!(w[0].stage_arrival <= w[1].stage_arrival);
        }
    }
}
