//! The central queue, sharded into model-affine serving groups.
//!
//! One [`RequestQueue`] shard per [`ShardKey`] that has seen traffic: a
//! request pinned to a model family waits only behind requests of its own
//! group, plus the `Any` shard for unpinned work. Cross-shard scheduling
//! order is preserved by a single global insertion sequence and a
//! rank comparison over the shard heads ([`ShardedQueue::best_shard`]), so
//! a workload whose requests are all `Any` behaves exactly like the
//! unsharded queue — while a group whose head cannot be placed no longer
//! blocks every other group (per-group head-of-line blocking only).
//!
//! The routing layer ([`crate::orchestrator::router`]) may balance an
//! `Any`-class request into a specific group's queue without constraining
//! its dispatch: such requests go to the group's [`ShardKey::AnyIn`]
//! shard — separate from the family's pinned shard, so a pinned head that
//! defers (e.g. its family is mid-drain) can never starve routed `Any`
//! work queued toward the same group.

use super::policies::SchedulePolicy;
use super::queue::RequestQueue;
use crate::engine::cost_model::{ModelClass, ModelKind};
use crate::engine::request::Request;

/// Which shard of the central queue a request waits in. The key is a pure
/// queueing partition: the request's dispatch constraint is always its own
/// [`Request::model_class`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardKey {
    /// Shard of the request's own class: one per pinned family, plus the
    /// shared `Any` shard (the unrouted behavior).
    Class(ModelClass),
    /// Per-group shard of `Any`-class requests balanced into the group by
    /// the router.
    AnyIn(ModelKind),
}

/// Total order over head ranks: policy key first (NaN-safe via
/// `total_cmp`, like the heap itself), then global insertion sequence.
fn rank_lt(a: ((f64, f64), u64), b: ((f64, f64), u64)) -> bool {
    let ((a1, a2), aseq) = a;
    let ((b1, b2), bseq) = b;
    a1.total_cmp(&b1).then(a2.total_cmp(&b2)).then(aseq.cmp(&bseq)).is_lt()
}

/// Priority queue over requests, partitioned by serving group.
pub struct ShardedQueue {
    /// Shards in creation order (deterministic: same push sequence ⇒ same
    /// shard layout, which the driver-equivalence contract relies on).
    shards: Vec<(ShardKey, RequestQueue)>,
    /// Global insertion sequence shared by all shards.
    next_seq: u64,
    /// Bumped on every depth-changing operation (push or pop). Consumers
    /// that derive state from shard depths (the coordinator's group
    /// pressures) key their caches on this instead of re-walking shards.
    epoch: u64,
    /// Peak total occupancy across shards (diagnostics).
    pub peak_len: usize,
}

impl Default for ShardedQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedQueue {
    /// A queue with the `Any` shard only (today's single-queue behavior
    /// until a pinned request arrives).
    pub fn new() -> ShardedQueue {
        ShardedQueue {
            shards: vec![(ShardKey::Class(ModelClass::Any), RequestQueue::new())],
            next_seq: 0,
            epoch: 0,
            peak_len: 0,
        }
    }

    /// Monotone counter that moves whenever any shard's depth does.
    /// Unchanged epoch ⇒ every `group_len`/`for_each_group_depth` result
    /// is unchanged too.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Index of the shard for `key`, creating it if absent.
    pub fn ensure_shard(&mut self, key: ShardKey) -> usize {
        if let Some(i) = self.shards.iter().position(|(k, _)| *k == key) {
            return i;
        }
        self.shards.push((key, RequestQueue::new()));
        self.shards.len() - 1
    }

    /// Route `req` to its own class's shard (the unrouted behavior).
    pub fn push(&mut self, req: Request, policy: &dyn SchedulePolicy) {
        let key = ShardKey::Class(req.model_class);
        self.push_routed(req, key, policy);
    }

    /// Queue `req` under an explicit shard key — the routing layer's
    /// entry point (e.g. an `Any` request balanced into a group's shard).
    pub fn push_routed(&mut self, req: Request, key: ShardKey, policy: &dyn SchedulePolicy) {
        let i = self.ensure_shard(key);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.epoch += 1;
        self.shards[i].1.push_with_seq(req, policy, seq);
        self.peak_len = self.peak_len.max(self.len());
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The key of shard `i`.
    pub fn key(&self, shard: usize) -> ShardKey {
        self.shards[shard].0
    }

    /// Total queued requests across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|(_, q)| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|(_, q)| q.is_empty())
    }

    /// Queued requests in `key`'s shard (0 when the shard does not exist).
    pub fn shard_len(&self, key: ShardKey) -> usize {
        self.shards
            .iter()
            .find(|(k, _)| *k == key)
            .map_or(0, |(_, q)| q.len())
    }

    /// Requests queued toward family `model`: its pinned shard plus its
    /// routed-`Any` shard — the routing layer's per-group queue depth.
    pub fn group_len(&self, model: ModelKind) -> usize {
        self.shard_len(ShardKey::Class(ModelClass::Model(model)))
            + self.shard_len(ShardKey::AnyIn(model))
    }

    /// Peek at shard `i`'s highest-priority request.
    pub fn peek_shard(&self, shard: usize) -> Option<&Request> {
        self.shards[shard].1.peek_best()
    }

    /// Remove and return shard `i`'s highest-priority request.
    pub fn pop_shard(&mut self, shard: usize) -> Option<Request> {
        let popped = self.shards[shard].1.pop_best();
        if popped.is_some() {
            self.epoch += 1;
        }
        popped
    }

    /// Visit every shard that belongs to a model family's serving group —
    /// `Class(Model(m))` and `AnyIn(m)` both map to `m` — with its depth,
    /// in shard creation order. One pass over the shards replaces G
    /// separate [`Self::group_len`] walks (each of which scans all shards);
    /// callers sum the per-shard depths they receive for the same family.
    pub fn for_each_group_depth(&self, mut f: impl FnMut(ModelKind, usize)) {
        for (key, q) in &self.shards {
            match key {
                ShardKey::Class(ModelClass::Model(m)) | ShardKey::AnyIn(m) => {
                    f(*m, q.len());
                }
                ShardKey::Class(ModelClass::Any) => {}
            }
        }
    }

    /// The shard whose head ranks first globally, skipping shards marked
    /// blocked (a group whose head deferred this scheduling round). Rank is
    /// the policy key with the global insertion sequence as tiebreaker —
    /// exactly the unsharded queue's order.
    pub fn best_shard(&self, blocked: &[bool]) -> Option<usize> {
        let mut best: Option<(usize, ((f64, f64), u64))> = None;
        for (i, (_, q)) in self.shards.iter().enumerate() {
            if blocked.get(i).copied().unwrap_or(false) {
                continue;
            }
            let Some(rank) = q.head_rank() else { continue };
            let better = match best {
                None => true,
                Some((_, b)) => rank_lt(rank, b),
            };
            if better {
                best = Some((i, rank));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Re-key every shard against the (refreshed) policy — the per-shard
    /// priority resort of the periodic refresh.
    pub fn resort(&mut self, policy: &dyn SchedulePolicy) {
        for (_, q) in self.shards.iter_mut() {
            q.resort(policy);
        }
    }

    /// Snapshot of all queued requests in arbitrary order (analysis).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.shards.iter().flat_map(|(_, q)| q.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::{ModelClass, ModelKind};
    use crate::lb::policies::Fcfs;
    use crate::orchestrator::ids::AgentId;

    fn req(id: u64, arrival: f64, class: ModelClass) -> Request {
        Request {
            id,
            msg_id: id,
            agent: AgentId(0),
            session: id,
            model_class: class,
            upstream: None,
            prompt_tokens: 1,
            true_output_tokens: 1,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: arrival,
            stage_arrival: arrival,
        }
    }

    const M8: ModelClass = ModelClass::Model(ModelKind::Llama3_8B);
    const M13: ModelClass = ModelClass::Model(ModelKind::Llama2_13B);

    #[test]
    fn routes_by_model_class() {
        let mut q = ShardedQueue::new();
        q.push(req(1, 0.0, ModelClass::Any), &Fcfs);
        q.push(req(2, 1.0, M8), &Fcfs);
        q.push(req(3, 2.0, M13), &Fcfs);
        q.push(req(4, 3.0, M8), &Fcfs);
        assert_eq!(q.n_shards(), 3);
        assert_eq!(q.len(), 4);
        assert_eq!(q.shard_len(ShardKey::Class(ModelClass::Any)), 1);
        assert_eq!(q.shard_len(ShardKey::Class(M8)), 2);
        assert_eq!(q.shard_len(ShardKey::Class(M13)), 1);
        assert_eq!(q.shard_len(ShardKey::Class(ModelClass::Model(ModelKind::Tiny))), 0);
    }

    #[test]
    fn routed_any_gets_its_own_per_group_shard() {
        let mut q = ShardedQueue::new();
        q.push(req(1, 0.0, M8), &Fcfs);
        // An Any-class request balanced into the 8B group: separate shard,
        // same group accounting.
        q.push_routed(req(2, 1.0, ModelClass::Any), ShardKey::AnyIn(ModelKind::Llama3_8B), &Fcfs);
        q.push_routed(req(3, 2.0, ModelClass::Any), ShardKey::AnyIn(ModelKind::Llama3_8B), &Fcfs);
        assert_eq!(q.n_shards(), 3, "Any + pinned-8B + routed-8B");
        assert_eq!(q.shard_len(ShardKey::Class(M8)), 1);
        assert_eq!(q.shard_len(ShardKey::AnyIn(ModelKind::Llama3_8B)), 2);
        assert_eq!(q.group_len(ModelKind::Llama3_8B), 3, "pinned + routed");
        assert_eq!(q.group_len(ModelKind::Llama2_13B), 0);
        // The routed requests keep their Any class (dispatch constraint).
        let s = q
            .ensure_shard(ShardKey::AnyIn(ModelKind::Llama3_8B));
        assert_eq!(q.peek_shard(s).unwrap().model_class, ModelClass::Any);
        // Cross-shard order is still global arrival order.
        let blocked = vec![false; q.n_shards()];
        let mut order = Vec::new();
        while let Some(i) = q.best_shard(&blocked) {
            order.push(q.pop_shard(i).unwrap().id);
        }
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn best_shard_preserves_global_fcfs_order() {
        let mut q = ShardedQueue::new();
        // Interleave arrivals across three groups; the global pop order
        // must equal plain arrival order.
        let classes = [M8, ModelClass::Any, M13, M8, ModelClass::Any, M13];
        for (i, c) in classes.iter().enumerate() {
            q.push(req(i as u64 + 1, i as f64, *c), &Fcfs);
        }
        let blocked = vec![false; q.n_shards()];
        let mut order = Vec::new();
        while let Some(s) = q.best_shard(&blocked) {
            order.push(q.pop_shard(s).unwrap().id);
        }
        assert_eq!(order, vec![1, 2, 3, 4, 5, 6]);
        assert!(q.is_empty());
    }

    #[test]
    fn blocked_shards_are_skipped() {
        let mut q = ShardedQueue::new();
        q.push(req(1, 0.0, ModelClass::Any), &Fcfs); // shard 0, earliest
        q.push(req(2, 1.0, M8), &Fcfs); // shard 1
        let mut blocked = vec![false; q.n_shards()];
        assert_eq!(q.best_shard(&blocked), Some(0));
        blocked[0] = true;
        assert_eq!(q.best_shard(&blocked), Some(1));
        blocked[1] = true;
        assert_eq!(q.best_shard(&blocked), None);
    }

    #[test]
    fn cross_shard_ties_break_by_arrival_sequence() {
        let mut q = ShardedQueue::new();
        // Identical FCFS keys in two shards: the earlier push wins.
        q.push(req(7, 5.0, M13), &Fcfs);
        q.push(req(8, 5.0, M8), &Fcfs);
        let blocked = vec![false; q.n_shards()];
        let s = q.best_shard(&blocked).unwrap();
        assert_eq!(q.peek_shard(s).unwrap().id, 7);
    }

    #[test]
    fn resort_rekeys_every_shard() {
        use crate::lb::policies::Oracle;
        let mut q = ShardedQueue::new();
        let mut a = req(1, 0.0, M8);
        a.true_remaining_latency = 9.0;
        let mut b = req(2, 1.0, M8);
        b.true_remaining_latency = 1.0;
        q.push(a, &Fcfs);
        q.push(b, &Fcfs);
        let shard = q.n_shards() - 1;
        assert_eq!(q.peek_shard(shard).unwrap().id, 1, "FCFS keys");
        q.resort(&Oracle);
        assert_eq!(q.peek_shard(shard).unwrap().id, 2, "re-keyed to SRTF");
    }

    #[test]
    fn epoch_moves_exactly_with_depth() {
        let mut q = ShardedQueue::new();
        let e0 = q.epoch();
        q.push(req(1, 0.0, M8), &Fcfs);
        assert!(q.epoch() > e0, "push bumps");
        let e1 = q.epoch();
        q.resort(&Fcfs);
        assert_eq!(q.epoch(), e1, "resort leaves depths alone");
        let s = q.best_shard(&vec![false; q.n_shards()]).unwrap();
        assert!(q.pop_shard(s).is_some());
        assert!(q.epoch() > e1, "pop bumps");
        let e2 = q.epoch();
        assert!(q.pop_shard(s).is_none());
        assert_eq!(q.epoch(), e2, "empty pop is depth-neutral");
    }

    #[test]
    fn group_depth_visitor_matches_group_len() {
        let mut q = ShardedQueue::new();
        q.push(req(1, 0.0, ModelClass::Any), &Fcfs);
        q.push(req(2, 1.0, M8), &Fcfs);
        q.push(req(3, 2.0, M13), &Fcfs);
        q.push_routed(req(4, 3.0, ModelClass::Any), ShardKey::AnyIn(ModelKind::Llama3_8B), &Fcfs);
        let mut sums: Vec<(ModelKind, usize)> = Vec::new();
        q.for_each_group_depth(|m, d| match sums.iter_mut().find(|(k, _)| *k == m) {
            Some((_, s)) => *s += d,
            None => sums.push((m, d)),
        });
        for (m, s) in sums {
            assert_eq!(s, q.group_len(m), "{m:?}");
        }
        // The shared Any shard belongs to no group and is never visited.
        let mut visits = 0;
        q.for_each_group_depth(|_, _| visits += 1);
        assert_eq!(visits, 3, "pinned-8B, pinned-13B, routed-8B");
    }

    #[test]
    fn any_only_workload_keeps_single_shard() {
        let mut q = ShardedQueue::new();
        for i in 0..5 {
            q.push(req(i, i as f64, ModelClass::Any), &Fcfs);
        }
        assert_eq!(q.n_shards(), 1, "no pinned traffic, no extra shards");
        assert_eq!(q.iter().count(), 5);
        assert_eq!(q.peak_len, 5);
    }
}
