//! # Kairos — low-latency multi-agent LLM serving
//!
//! A reproduction of *"Kairos: Low-latency Multi-Agent Serving with Shared
//! LLMs and Excessive Loads in the Public Cloud"* (Chen et al., 2025) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: a
//!   [`orchestrator`] that reconstructs multi-agent workflows online, a
//!   workflow-aware priority scheduler ([`lb`]), and a memory-aware
//!   time-slot dispatcher ([`dispatch`]), running over a from-scratch
//!   vLLM-like [`engine`] substrate (continuous batching, paged KV blocks,
//!   recompute-preemption) and a Kafka-like in-process [`bus`].
//! * **Layer 2/1 (python, build time only)** — a tiny Llama-style LM whose
//!   decode hot path goes through Pallas kernels, AOT-lowered to HLO text
//!   that [`runtime`] loads and executes through the PJRT C API.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod agents;
pub mod bench;
pub mod bus;
pub mod cli;
pub mod config;
pub mod dispatch;
pub mod engine;
pub mod figures;
pub mod lb;
pub mod metrics;
pub mod orchestrator;
pub mod runtime;
pub mod server;
pub mod simcore;
pub mod stats;
pub mod testing;
pub mod util;
pub mod workload;

/// Simulation / wall-clock time in seconds.
pub type Time = f64;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
