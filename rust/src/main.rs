//! `kairos` binary — CLI for serving simulations, figure regeneration, and
//! the PJRT quickstart. See `kairos --help` / README.md.
fn main() -> anyhow::Result<()> {
    kairos::cli::run(std::env::args().skip(1).collect())
}
