//! HyperLogLog distinct counting for (agent, family) cardinality.
//!
//! A million-request run touches an unknown number of distinct
//! (agent, serving-family) pairs — the live fan-out the routing layer is
//! actually exercising. Tracking them exactly needs a hash set that grows
//! with the workload; [`Hll`] estimates the cardinality in `2^b` bytes with
//! ~`1.04/sqrt(2^b)` relative error. The hash is a fixed splitmix64
//! finalizer — not `std`'s randomly-seeded default hasher — so estimates
//! are bit-identical across runs, platforms and toolchains: the
//! determinism contract every number in a `BENCH_*.json` carries.

/// The splitmix64 finalizer: a cheap, well-mixed, *fixed* 64-bit hash.
/// Public so callers packing composite keys (e.g. agent id × model family)
/// hash them the same way everywhere.
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// A HyperLogLog sketch with `2^b` one-byte registers.
#[derive(Debug, Clone)]
pub struct Hll {
    registers: Vec<u8>,
    b: u32,
}

impl Default for Hll {
    /// 256 registers (b = 8): ~6.5% standard error in 256 bytes.
    fn default() -> Self {
        Hll::new(8)
    }
}

impl Hll {
    /// `b` index bits, `4 ..= 16` (i.e. 16 to 65536 registers).
    pub fn new(b: u32) -> Hll {
        assert!((4..=16).contains(&b), "HLL precision out of range: {b}");
        Hll { registers: vec![0; 1 << b], b }
    }

    /// Number of registers.
    pub fn m(&self) -> usize {
        self.registers.len()
    }

    /// Insert a key by value; the sketch hashes it with [`mix64`].
    pub fn insert_u64(&mut self, key: u64) {
        self.insert_hash(mix64(key));
    }

    /// Insert an already-hashed key (must be uniformly mixed).
    pub fn insert_hash(&mut self, h: u64) {
        let idx = (h >> (64 - self.b)) as usize;
        // Rank of the first set bit in the remaining 64-b bits, 1-based;
        // an all-zero remainder saturates at 64-b+1.
        let rest = h << self.b;
        let rank = if rest == 0 { 64 - self.b + 1 } else { rest.leading_zeros() + 1 };
        let rank = rank as u8;
        if rank > self.registers[idx] {
            self.registers[idx] = rank;
        }
    }

    /// Estimated distinct-key count (with the standard small-range
    /// linear-counting correction; 64-bit hashes need no large-range one).
    pub fn estimate(&self) -> f64 {
        let m = self.registers.len() as f64;
        let alpha = match self.registers.len() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            _ => 0.7213 / (1.0 + 1.079 / m),
        };
        // Ranks are at most 64-b+1 <= 61, so the shift below cannot
        // overflow a u64.
        let sum: f64 = self
            .registers
            .iter()
            .map(|&r| 1.0 / (1u64 << r) as f64)
            .sum();
        let raw = alpha * m * m / sum;
        if raw <= 2.5 * m {
            let zeros = self.registers.iter().filter(|&&r| r == 0).count();
            if zeros > 0 {
                return m * (m / zeros as f64).ln();
            }
        }
        raw
    }

    /// Merge another sketch of the same precision (register-wise max).
    pub fn merge(&mut self, other: &Hll) {
        assert_eq!(self.b, other.b, "HLL precision mismatch");
        for (a, &b) in self.registers.iter_mut().zip(&other.registers) {
            *a = (*a).max(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_estimates_zero() {
        let h = Hll::new(8);
        assert_eq!(h.estimate(), 0.0);
    }

    #[test]
    fn duplicates_do_not_inflate() {
        let mut h = Hll::new(8);
        for _ in 0..10_000 {
            h.insert_u64(42);
        }
        let est = h.estimate();
        assert!((0.9..=1.5).contains(&est), "one distinct key, estimated {est}");
    }

    #[test]
    fn small_cardinalities_are_near_exact() {
        let mut h = Hll::new(10);
        for k in 0..50u64 {
            h.insert_u64(k);
            h.insert_u64(k); // duplicate inserts are free
        }
        let est = h.estimate();
        assert!((est - 50.0).abs() < 5.0, "estimated {est} for 50 keys");
    }

    #[test]
    fn large_cardinalities_within_error_bound() {
        // b=10 => 1024 registers => ~3.3% standard error; assert 10%.
        let mut h = Hll::new(10);
        let n = 100_000u64;
        for k in 0..n {
            h.insert_u64(k);
        }
        let est = h.estimate();
        let rel = (est - n as f64).abs() / n as f64;
        assert!(rel < 0.10, "estimated {est} for {n} keys (rel err {rel:.3})");
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut h = Hll::new(8);
            for k in 0..1000u64 {
                h.insert_u64(k.wrapping_mul(0x1234_5678_9ABC_DEF1));
            }
            h.estimate()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn merge_matches_union() {
        let mut a = Hll::new(8);
        let mut b = Hll::new(8);
        let mut u = Hll::new(8);
        for k in 0..500u64 {
            a.insert_u64(k);
            u.insert_u64(k);
        }
        for k in 250..750u64 {
            b.insert_u64(k);
            u.insert_u64(k);
        }
        a.merge(&b);
        assert_eq!(a.estimate(), u.estimate(), "merge is register-wise max");
    }
}
