//! Serving metrics (paper §7.1 "Metrics").
//!
//! The headline metric is **program-level token latency** [37]: a
//! workflow's end-to-end response time divided by the total tokens it
//! generated. Averages and P90/P95/P99 tails are reported per run, plus the
//! queueing-time ratio used to calibrate load levels, and per-request
//! records for the Fig. 8 / Fig. 16 ordering analyses.

use crate::agents::apps::App;
use crate::orchestrator::ids::{AgentId, MsgId};
use crate::stats::summary::Summary;
use crate::Time;

/// Per-request (stage-level) record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub msg_id: MsgId,
    pub agent: AgentId,
    pub stage_arrival: Time,
    pub dispatched_at: Time,
    pub finished_at: Time,
    pub output_tokens: u32,
    pub preempt_count: u32,
    /// Ground-truth remaining workflow latency at scheduling time (for the
    /// ordering-accuracy analyses only).
    pub true_remaining: f64,
}

impl RequestRecord {
    pub fn queue_time(&self) -> f64 {
        self.dispatched_at - self.stage_arrival
    }
    pub fn exec_time(&self) -> f64 {
        self.finished_at - self.dispatched_at
    }
}

/// Per-workflow (program-level) record.
#[derive(Debug, Clone)]
pub struct WorkflowRecord {
    pub msg_id: MsgId,
    pub app: App,
    pub app_start: Time,
    pub finished_at: Time,
    pub output_tokens: u64,
    pub queue_time: f64,
}

impl WorkflowRecord {
    pub fn e2e(&self) -> f64 {
        self.finished_at - self.app_start
    }

    /// Program-level token latency: e2e seconds per generated token.
    pub fn token_latency(&self) -> f64 {
        self.e2e() / self.output_tokens.max(1) as f64
    }

    pub fn queue_ratio(&self) -> f64 {
        (self.queue_time / self.e2e().max(1e-9)).clamp(0.0, 1.0)
    }
}

/// Collected metrics of one simulation / serving run.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    pub requests: Vec<RequestRecord>,
    pub workflows: Vec<WorkflowRecord>,
    pub preemptions: u64,
    pub recomputed_tokens: u64,
    pub total_tokens: u64,
}

/// Summary of a run, in the paper's reporting terms.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub n_workflows: usize,
    pub avg_token_latency: f64,
    pub p50_token_latency: f64,
    pub p90_token_latency: f64,
    pub p95_token_latency: f64,
    pub p99_token_latency: f64,
    pub mean_queue_ratio: f64,
    pub preemption_rate: f64,
    pub recompute_waste: f64,
}

impl MetricsCollector {
    pub fn new() -> MetricsCollector {
        MetricsCollector::default()
    }

    pub fn record_request(&mut self, r: RequestRecord) {
        self.total_tokens += r.output_tokens as u64;
        self.requests.push(r);
    }

    pub fn record_workflow(&mut self, w: WorkflowRecord) {
        self.workflows.push(w);
    }

    /// Summarize workflows finishing at or after `from_time` (warmup skip).
    pub fn summary_from(&self, from_time: Time) -> Option<RunSummary> {
        let lats: Vec<f64> = self
            .workflows
            .iter()
            .filter(|w| w.app_start >= from_time)
            .map(|w| w.token_latency())
            .collect();
        let s = Summary::from_samples(&lats)?;
        let qr: Vec<f64> = self
            .workflows
            .iter()
            .filter(|w| w.app_start >= from_time)
            .map(|w| w.queue_ratio())
            .collect();
        let mean_queue_ratio = qr.iter().sum::<f64>() / qr.len() as f64;
        let preempted = self.requests.iter().filter(|r| r.preempt_count > 0).count();
        Some(RunSummary {
            n_workflows: lats.len(),
            avg_token_latency: s.mean(),
            p50_token_latency: s.p50(),
            p90_token_latency: s.p90(),
            p95_token_latency: s.p95(),
            p99_token_latency: s.p99(),
            mean_queue_ratio,
            preemption_rate: preempted as f64 / self.requests.len().max(1) as f64,
            recompute_waste: self.recomputed_tokens as f64
                / self.total_tokens.max(1) as f64,
        })
    }

    pub fn summary(&self) -> Option<RunSummary> {
        self.summary_from(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wf(msg: u64, start: f64, end: f64, tokens: u64, queue: f64) -> WorkflowRecord {
        WorkflowRecord {
            msg_id: msg,
            app: App::Qa,
            app_start: start,
            finished_at: end,
            output_tokens: tokens,
            queue_time: queue,
        }
    }

    #[test]
    fn token_latency_definition() {
        let w = wf(1, 0.0, 10.0, 100, 2.0);
        assert!((w.token_latency() - 0.1).abs() < 1e-12);
        assert!((w.queue_ratio() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut m = MetricsCollector::new();
        for i in 1..=100u64 {
            m.record_workflow(wf(i, 0.0, i as f64, 100, 0.0));
        }
        let s = m.summary().unwrap();
        assert_eq!(s.n_workflows, 100);
        assert!((s.avg_token_latency - 0.505).abs() < 1e-9);
        assert!(s.p99_token_latency > s.p90_token_latency);
        assert!(s.p90_token_latency > s.avg_token_latency);
    }

    #[test]
    fn warmup_filtering() {
        let mut m = MetricsCollector::new();
        m.record_workflow(wf(1, 0.0, 100.0, 1, 0.0)); // warmup straggler
        m.record_workflow(wf(2, 50.0, 60.0, 10, 0.0));
        let s = m.summary_from(10.0).unwrap();
        assert_eq!(s.n_workflows, 1);
        assert!((s.avg_token_latency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_none() {
        assert!(MetricsCollector::new().summary().is_none());
    }

    #[test]
    fn preemption_rate() {
        let mut m = MetricsCollector::new();
        for i in 0..4 {
            m.record_request(RequestRecord {
                msg_id: i,
                agent: AgentId(0),
                stage_arrival: 0.0,
                dispatched_at: 1.0,
                finished_at: 2.0,
                output_tokens: 10,
                preempt_count: u32::from(i == 0),
                true_remaining: 0.0,
            });
        }
        m.record_workflow(wf(1, 0.0, 1.0, 1, 0.0));
        let s = m.summary().unwrap();
        assert!((s.preemption_rate - 0.25).abs() < 1e-12);
    }
}
