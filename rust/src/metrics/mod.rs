//! Serving metrics (paper §7.1 "Metrics").
//!
//! The headline metric is **program-level token latency** [37]: a
//! workflow's end-to-end response time divided by the total tokens it
//! generated. Averages and P90/P95/P99 tails are reported per run, plus the
//! queueing-time ratio used to calibrate load levels, and per-request
//! records for the Fig. 8 / Fig. 16 ordering analyses.
//!
//! Two accumulation modes coexist. The default retains every
//! [`RequestRecord`] / [`WorkflowRecord`] — exact summaries, warmup
//! filtering, and the per-request analyses all read those vectors. **Lean
//! mode** ([`MetricsCollector::lean`]) drops the vectors and feeds the
//! [`StreamingMetrics`] sketches instead: O(1) memory per million requests
//! at the cost of approximate percentiles and no warmup filtering. The
//! bench harness runs lean; everything else defaults to exact.

pub mod hll;
pub mod sketch;

use crate::agents::apps::App;
use crate::engine::cost_model::ModelKind;
use crate::metrics::hll::Hll;
use crate::metrics::sketch::QuantileSketch;
use crate::orchestrator::ids::{AgentId, MsgId};
use crate::stats::summary::{OnlineStats, Summary};
use crate::Time;

/// Per-request (stage-level) record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub msg_id: MsgId,
    pub agent: AgentId,
    pub stage_arrival: Time,
    pub dispatched_at: Time,
    pub finished_at: Time,
    pub output_tokens: u32,
    pub preempt_count: u32,
    /// Ground-truth remaining workflow latency at scheduling time (for the
    /// ordering-accuracy analyses only).
    pub true_remaining: f64,
}

impl RequestRecord {
    /// Seconds the stage waited: arrival at the load balancer to first
    /// admission into a running batch.
    pub fn queue_time(&self) -> f64 {
        self.dispatched_at - self.stage_arrival
    }

    /// Seconds the stage executed: first admission to completion.
    pub fn exec_time(&self) -> f64 {
        self.finished_at - self.dispatched_at
    }
}

/// Per-workflow (program-level) record.
#[derive(Debug, Clone)]
pub struct WorkflowRecord {
    pub msg_id: MsgId,
    pub app: App,
    pub app_start: Time,
    pub finished_at: Time,
    pub output_tokens: u64,
    pub queue_time: f64,
}

impl WorkflowRecord {
    /// End-to-end workflow latency in seconds (submission to last stage's
    /// completion).
    pub fn e2e(&self) -> f64 {
        self.finished_at - self.app_start
    }

    /// Program-level token latency: e2e seconds per generated token.
    pub fn token_latency(&self) -> f64 {
        self.e2e() / self.output_tokens.max(1) as f64
    }

    /// Share of the end-to-end latency spent queueing, clamped to `[0, 1]`
    /// (the paper's load-calibration metric).
    pub fn queue_ratio(&self) -> f64 {
        (self.queue_time / self.e2e().max(1e-9)).clamp(0.0, 1.0)
    }
}

/// Aggregate prefix-cache counters summed across the fleet's engines
/// ([`crate::engine::block_manager::PrefixCache`] per instance). All
/// counters are monotone totals over the run; the bench summary reports
/// `hits / (hits + misses)` as the hit rate.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Prefix lookups that found a usable cached prefix.
    pub hits: u64,
    /// Prefix lookups that found nothing for the session.
    pub misses: u64,
    /// Prefill tokens skipped thanks to cache hits (the recompute the
    /// cache avoided).
    pub saved_prefill_tokens: u64,
    /// Prefix entries inserted (longest-prefix updates included).
    pub insertions: u64,
    /// Prefix entries evicted by the LRU budget.
    pub evictions: u64,
}

impl CacheStats {
    /// Hit rate over all lookups; 0.0 before the first lookup.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }
}

/// Constant-memory accumulators fed on every record regardless of mode:
/// P² sketches for the latency distributions, running moments for the
/// queue ratio, and an HLL counting distinct (agent, serving-family)
/// pairs — the live routing fan-out of the run.
#[derive(Debug, Default)]
pub struct StreamingMetrics {
    /// Program-level token latency of completed workflows.
    pub token_latency: QuantileSketch,
    /// Per-stage queueing time (arrival → first admission).
    pub queue_time: QuantileSketch,
    /// Per-workflow queueing-time ratio.
    pub queue_ratio: OnlineStats,
    /// Distinct (agent, model-family) pairs that actually served.
    pub agent_families: Hll,
    /// Latest snapshot of the dispatcher's decision counters
    /// ([`crate::dispatch::DispatchStats`]): candidates offered vs.
    /// evaluated, fast-path accepts/rejects, rejected rounds and
    /// OOM-suspect suspensions. Synced by the coordinator on every refresh
    /// and at end of run; printed by the bench summary and `kairos check`.
    pub packer: crate::dispatch::DispatchStats,
    /// Fleet-wide prefix-cache counters, folded from every engine's
    /// [`crate::engine::block_manager::PrefixCache`] at end of run. All
    /// zeros when the cache is disabled.
    pub cache: CacheStats,
    /// KV block-allocation failures summed across engines (admission
    /// attempts refused by the watermark); folded at end of run.
    pub alloc_failures: u64,
}

impl StreamingMetrics {
    /// Estimated number of distinct (agent, family) serving pairs.
    pub fn distinct_agent_families(&self) -> f64 {
        self.agent_families.estimate()
    }
}

/// Collected metrics of one simulation / serving run.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    pub requests: Vec<RequestRecord>,
    pub workflows: Vec<WorkflowRecord>,
    pub preemptions: u64,
    pub recomputed_tokens: u64,
    pub total_tokens: u64,
    /// Streaming sketches, fed on every record in both modes.
    pub stream: StreamingMetrics,
    /// When set, per-record vectors stay empty (counters and sketches
    /// still accumulate). Set it before the run starts: flipping it
    /// mid-run leaves the vectors truncated, not re-filtered.
    pub lean: bool,
    /// Requests recorded, retained or not (`requests.len()` in exact mode).
    pub total_requests: u64,
    /// Workflows recorded, retained or not.
    pub total_workflows: u64,
    /// Requests recorded with at least one preemption.
    pub preempted_requests: u64,
    recent_qr_sum: f64,
    recent_qr_n: u64,
}

/// Summary of a run, in the paper's reporting terms. The `Default` value
/// (all zeros) is what a run where no workflow completed reports.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub n_workflows: usize,
    pub avg_token_latency: f64,
    pub p50_token_latency: f64,
    pub p90_token_latency: f64,
    pub p95_token_latency: f64,
    pub p99_token_latency: f64,
    pub mean_queue_ratio: f64,
    pub preemption_rate: f64,
    pub recompute_waste: f64,
}

impl MetricsCollector {
    /// An empty collector in exact (record-retaining) mode.
    pub fn new() -> MetricsCollector {
        MetricsCollector::default()
    }

    /// Record one completed request stage: counters and streaming sketches
    /// always accumulate; the per-record vector only outside lean mode.
    pub fn record_request(&mut self, r: RequestRecord) {
        self.total_tokens += r.output_tokens as u64;
        self.total_requests += 1;
        self.preempted_requests += u64::from(r.preempt_count > 0);
        // The autoscaler's load-calibration window, accumulated at record
        // time in record order so the windowed mean is bit-identical to
        // summing a retained slice.
        let e2e = (r.finished_at - r.stage_arrival).max(1e-9);
        self.recent_qr_sum += (r.queue_time() / e2e).clamp(0.0, 1.0);
        self.recent_qr_n += 1;
        self.stream.queue_time.observe(r.queue_time());
        if !self.lean {
            self.requests.push(r);
        }
    }

    /// Record one completed workflow (program-level metrics; same
    /// lean-mode retention rule as [`Self::record_request`]).
    pub fn record_workflow(&mut self, w: WorkflowRecord) {
        self.total_workflows += 1;
        self.stream.token_latency.observe(w.token_latency());
        self.stream.queue_ratio.push(w.queue_ratio());
        if !self.lean {
            self.workflows.push(w);
        }
    }

    /// Feed the (agent, serving family) pair of one completed request into
    /// the distinct-pair counter.
    pub fn record_served(&mut self, agent: AgentId, model: ModelKind) {
        let key = (u64::from(agent.0) << 8) | model as u64;
        self.stream.agent_families.insert_u64(key);
    }

    /// Mean queueing-time ratio of requests recorded since the previous
    /// call, then reset the window (the autoscaler's scale-up pressure
    /// signal). 0.0 for an empty window.
    pub fn take_recent_queue_ratio(&mut self) -> f64 {
        let out = if self.recent_qr_n == 0 {
            0.0
        } else {
            self.recent_qr_sum / self.recent_qr_n as f64
        };
        self.recent_qr_sum = 0.0;
        self.recent_qr_n = 0;
        out
    }

    /// Summarize workflows finishing at or after `from_time` (warmup skip).
    /// Exact-mode only: lean runs retain no records and get `None` (fall
    /// back to [`Self::streaming_summary`]).
    pub fn summary_from(&self, from_time: Time) -> Option<RunSummary> {
        let lats: Vec<f64> = self
            .workflows
            .iter()
            .filter(|w| w.app_start >= from_time)
            .map(|w| w.token_latency())
            .collect();
        let s = Summary::from_samples(&lats)?;
        let qr: Vec<f64> = self
            .workflows
            .iter()
            .filter(|w| w.app_start >= from_time)
            .map(|w| w.queue_ratio())
            .collect();
        let mean_queue_ratio = qr.iter().sum::<f64>() / qr.len() as f64;
        let preempted = self.requests.iter().filter(|r| r.preempt_count > 0).count();
        Some(RunSummary {
            n_workflows: lats.len(),
            avg_token_latency: s.mean(),
            p50_token_latency: s.p50(),
            p90_token_latency: s.p90(),
            p95_token_latency: s.p95(),
            p99_token_latency: s.p99(),
            mean_queue_ratio,
            preemption_rate: preempted as f64 / self.requests.len().max(1) as f64,
            recompute_waste: self.recomputed_tokens as f64
                / self.total_tokens.max(1) as f64,
        })
    }

    /// Summarize every retained workflow (no warmup skip); `None` when no
    /// workflow record is retained.
    pub fn summary(&self) -> Option<RunSummary> {
        self.summary_from(0.0)
    }

    /// Summary from the streaming sketches alone: approximate percentiles,
    /// no warmup filtering. `None` until a workflow completes.
    pub fn streaming_summary(&self) -> Option<RunSummary> {
        if self.total_workflows == 0 {
            return None;
        }
        let tl = &self.stream.token_latency;
        Some(RunSummary {
            n_workflows: self.total_workflows as usize,
            avg_token_latency: tl.mean(),
            p50_token_latency: tl.p50(),
            p90_token_latency: tl.p90(),
            p95_token_latency: tl.p95(),
            p99_token_latency: tl.p99(),
            mean_queue_ratio: self.stream.queue_ratio.mean(),
            preemption_rate: self.preempted_requests as f64
                / self.total_requests.max(1) as f64,
            recompute_waste: self.recomputed_tokens as f64
                / self.total_tokens.max(1) as f64,
        })
    }

    /// Requests recorded, independent of retention mode.
    pub fn n_requests(&self) -> u64 {
        self.total_requests
    }

    /// Workflows recorded, independent of retention mode.
    pub fn n_workflows(&self) -> u64 {
        self.total_workflows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wf(msg: u64, start: f64, end: f64, tokens: u64, queue: f64) -> WorkflowRecord {
        WorkflowRecord {
            msg_id: msg,
            app: App::Qa,
            app_start: start,
            finished_at: end,
            output_tokens: tokens,
            queue_time: queue,
        }
    }

    fn req(msg: u64, queue: f64, total: f64, preempts: u32) -> RequestRecord {
        RequestRecord {
            msg_id: msg,
            agent: AgentId(0),
            stage_arrival: 0.0,
            dispatched_at: queue,
            finished_at: total,
            output_tokens: 10,
            preempt_count: preempts,
            true_remaining: 0.0,
        }
    }

    #[test]
    fn token_latency_definition() {
        let w = wf(1, 0.0, 10.0, 100, 2.0);
        assert!((w.token_latency() - 0.1).abs() < 1e-12);
        assert!((w.queue_ratio() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut m = MetricsCollector::new();
        for i in 1..=100u64 {
            m.record_workflow(wf(i, 0.0, i as f64, 100, 0.0));
        }
        let s = m.summary().unwrap();
        assert_eq!(s.n_workflows, 100);
        assert!((s.avg_token_latency - 0.505).abs() < 1e-9);
        assert!(s.p99_token_latency > s.p90_token_latency);
        assert!(s.p90_token_latency > s.avg_token_latency);
    }

    #[test]
    fn warmup_filtering() {
        let mut m = MetricsCollector::new();
        m.record_workflow(wf(1, 0.0, 100.0, 1, 0.0)); // warmup straggler
        m.record_workflow(wf(2, 50.0, 60.0, 10, 0.0));
        let s = m.summary_from(10.0).unwrap();
        assert_eq!(s.n_workflows, 1);
        assert!((s.avg_token_latency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_none() {
        assert!(MetricsCollector::new().summary().is_none());
        assert!(MetricsCollector::new().streaming_summary().is_none());
    }

    #[test]
    fn preemption_rate() {
        let mut m = MetricsCollector::new();
        for i in 0..4 {
            m.record_request(RequestRecord {
                msg_id: i,
                agent: AgentId(0),
                stage_arrival: 0.0,
                dispatched_at: 1.0,
                finished_at: 2.0,
                output_tokens: 10,
                preempt_count: u32::from(i == 0),
                true_remaining: 0.0,
            });
        }
        m.record_workflow(wf(1, 0.0, 1.0, 1, 0.0));
        let s = m.summary().unwrap();
        assert!((s.preemption_rate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lean_mode_retains_nothing_but_counts_everything() {
        let mut m = MetricsCollector::new();
        m.lean = true;
        for i in 0..8 {
            m.record_request(req(i, 1.0, 2.0, u32::from(i < 2)));
        }
        for i in 1..=4u64 {
            m.record_workflow(wf(i, 0.0, i as f64, 10, 0.0));
        }
        assert!(m.requests.is_empty() && m.workflows.is_empty());
        assert_eq!(m.n_requests(), 8);
        assert_eq!(m.n_workflows(), 4);
        assert!(m.summary().is_none(), "exact summary needs retained records");
        let s = m.streaming_summary().unwrap();
        assert_eq!(s.n_workflows, 4);
        assert!((s.preemption_rate - 0.25).abs() < 1e-12);
        // Token latencies are 0.1, 0.2, 0.3, 0.4: exact small-sample path.
        assert!((s.avg_token_latency - 0.25).abs() < 1e-12);
    }

    #[test]
    fn streaming_summary_tracks_exact_summary() {
        let mut exact = MetricsCollector::new();
        for i in 1..=100u64 {
            exact.record_workflow(wf(i, 0.0, i as f64, 100, 0.0));
        }
        let e = exact.summary().unwrap();
        let s = exact.streaming_summary().unwrap();
        assert_eq!(s.n_workflows, e.n_workflows);
        assert!((s.avg_token_latency - e.avg_token_latency).abs() < 1e-9);
        // P² on a 100-sample sorted uniform stream: within a few
        // percentile ranks of exact (rank spacing is 0.01 here).
        assert!((s.p50_token_latency - e.p50_token_latency).abs() < 0.05);
        assert!((s.p90_token_latency - e.p90_token_latency).abs() < 0.05);
        assert!((s.mean_queue_ratio - e.mean_queue_ratio).abs() < 1e-12);
    }

    #[test]
    fn recent_queue_ratio_window_resets_on_take() {
        let mut m = MetricsCollector::new();
        // queue ratios: 0.5 and 0.25.
        m.record_request(req(1, 1.0, 2.0, 0));
        m.record_request(req(2, 1.0, 4.0, 0));
        assert!((m.take_recent_queue_ratio() - 0.375).abs() < 1e-12);
        assert_eq!(m.take_recent_queue_ratio(), 0.0, "window consumed");
        m.record_request(req(3, 3.0, 4.0, 0));
        assert!((m.take_recent_queue_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cache_hit_rate_handles_empty_and_mixed_streams() {
        let z = CacheStats::default();
        assert_eq!(z.hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn served_pairs_count_distinct_agent_family_combinations() {
        let mut m = MetricsCollector::new();
        for a in 0..10u32 {
            for model in [ModelKind::Llama3_8B, ModelKind::Llama2_13B, ModelKind::Tiny] {
                m.record_served(AgentId(a), model);
                m.record_served(AgentId(a), model); // duplicates are free
            }
        }
        let est = m.stream.distinct_agent_families();
        assert!((est - 30.0).abs() < 3.0, "30 distinct pairs, estimated {est}");
    }
}
