//! Streaming quantile estimation: the P² algorithm (Jain & Chlamtac 1985).
//!
//! The sweeps' summary path collects every per-workflow token latency into
//! a `Vec<f64>` and sorts it once at the end — exact, but O(n) memory and
//! useless for a million-request bench run whose only reader wants five
//! percentiles. [`P2Quantile`] tracks one quantile with five markers in
//! O(1) memory and deterministic arithmetic (no randomness, no hashing),
//! so two runs over the same stream report bit-identical estimates.
//! [`QuantileSketch`] bundles the four percentiles the paper reports
//! (P50/P90/P95/P99) with streaming min/max/mean.
//!
//! Accuracy is rank-bounded, not value-bounded: the estimate converges to
//! a value whose *rank* is near `p`, which is what the property tests in
//! this module pin (against the exact [`Summary`](crate::stats::summary::Summary)
//! on sorted, reversed, constant and mixed adversarial streams).

use crate::stats::summary::OnlineStats;

/// One streaming quantile estimator (the P² five-marker algorithm).
///
/// Exact for fewer than five observations (it just sorts them); afterwards
/// the five markers approximate the min, the p/2, p, (1+p)/2 quantiles and
/// the max, nudged toward their desired ranks on every observation.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights (estimated quantile values).
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Per-observation increments of the desired positions.
    dn: [f64; 5],
    count: u64,
    /// The first five observations (exact small-sample path).
    init: [f64; 5],
}

impl P2Quantile {
    /// Estimator for quantile `p` in `[0, 1]` (e.g. `0.99` for P99).
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile out of range: {p}");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: [0.0; 5],
        }
    }

    /// The tracked quantile in `[0, 1]`.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Observations fed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Feed one observation. Non-finite samples are rejected by the
    /// caller-facing [`QuantileSketch`]; feeding one here corrupts the
    /// marker invariants, so don't.
    pub fn observe(&mut self, x: f64) {
        if self.count < 5 {
            self.init[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                let mut s = self.init;
                s.sort_by(f64::total_cmp);
                self.q = s;
            }
            return;
        }
        self.count += 1;
        // Locate the cell k with q[k] <= x < q[k+1], extending the extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 1..4 {
                if x >= self.q[i] {
                    k = i;
                } else {
                    break;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Nudge each interior marker toward its desired rank, preferring
        // the parabolic (P²) height when it stays between its neighbors.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let parabolic = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < parabolic && parabolic < self.q[i + 1] {
                    parabolic
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// The current estimate: NaN before any observation, exact (same
    /// linear-interpolation convention as
    /// [`Summary::percentile`](crate::stats::summary::Summary::percentile))
    /// below five observations, the center marker afterwards.
    pub fn value(&self) -> f64 {
        let c = self.count as usize;
        if c == 0 {
            return f64::NAN;
        }
        if c < 5 {
            let mut s = self.init[..c].to_vec();
            s.sort_by(f64::total_cmp);
            if c == 1 {
                return s[0];
            }
            let rank = self.p * (c - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            return s[lo] * (1.0 - frac) + s[hi] * frac;
        }
        self.q[2]
    }
}

/// The percentile set the paper reports, streamed: P50/P90/P95/P99 markers
/// plus exact streaming min/max/mean (Welford). O(1) memory per stream.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    p50: P2Quantile,
    p90: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
    stats: OnlineStats,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new()
    }
}

impl QuantileSketch {
    /// An empty sketch (P50/P90/P95/P99 markers plus streaming moments).
    pub fn new() -> QuantileSketch {
        QuantileSketch {
            p50: P2Quantile::new(0.50),
            p90: P2Quantile::new(0.90),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
            stats: OnlineStats::new(),
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Feed one observation. Non-finite samples are dropped (they would
    /// corrupt the marker invariants; the exact-path `Summary` tolerates
    /// them by sorting last, which the count-based contract here mirrors
    /// by excluding them from [`QuantileSketch::count`]).
    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.p50.observe(x);
        self.p90.observe(x);
        self.p95.observe(x);
        self.p99.observe(x);
        self.stats.push(x);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Finite observations accepted so far.
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Exact streaming mean (Welford).
    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    /// Exact streaming sample standard deviation.
    pub fn std(&self) -> f64 {
        self.stats.std()
    }

    /// Exact minimum observed (`+inf` before any observation).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum observed (`-inf` before any observation).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Estimated median (see [`P2Quantile::value`] for exactness rules).
    pub fn p50(&self) -> f64 {
        self.p50.value()
    }

    /// Estimated 90th percentile.
    pub fn p90(&self) -> f64 {
        self.p90.value()
    }

    /// Estimated 95th percentile.
    pub fn p95(&self) -> f64 {
        self.p95.value()
    }

    /// Estimated 99th percentile.
    pub fn p99(&self) -> f64 {
        self.p99.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::stats::summary::Summary;
    use crate::testing::forall;

    /// The P² accuracy contract, robust to both failure shapes: the
    /// estimate must land inside the exact values at ranks `p ± tol_rank`
    /// (percent), OR within a small *value* distance of the exact
    /// percentile (for distributions with atoms/clusters, where a tiny
    /// value error translates to a large rank error and vice versa).
    fn assert_close(
        exact: &Summary,
        estimate: f64,
        p: f64,
        tol_rank: f64,
    ) -> Result<(), String> {
        let lo = exact.percentile((p - tol_rank).max(0.0));
        let hi = exact.percentile((p + tol_rank).min(100.0));
        let eps = 1e-9 + (exact.max() - exact.min()).abs() * 1e-9;
        if (lo - eps..=hi + eps).contains(&estimate) {
            return Ok(());
        }
        let target = exact.percentile(p);
        let spread = exact.percentile(95.0) - exact.percentile(5.0);
        if (estimate - target).abs() <= 0.05 * (target.abs() + spread) {
            return Ok(());
        }
        Err(format!(
            "P{p} estimate {estimate} outside rank window [{lo}, {hi}] and \
             not value-close to exact {target} (n={})",
            exact.len()
        ))
    }

    fn check_all_percentiles(samples: &[f64], tol_rank: f64) -> Result<(), String> {
        let mut sk = QuantileSketch::new();
        for &x in samples {
            sk.observe(x);
        }
        let exact = Summary::from_samples(samples).unwrap();
        assert_close(&exact, sk.p50(), 50.0, tol_rank)?;
        assert_close(&exact, sk.p90(), 90.0, tol_rank)?;
        assert_close(&exact, sk.p95(), 95.0, tol_rank)?;
        assert_close(&exact, sk.p99(), 99.0, tol_rank)?;
        if (sk.mean() - exact.mean()).abs() > 1e-9 * (1.0 + exact.mean().abs()) {
            return Err(format!("mean {} != exact {}", sk.mean(), exact.mean()));
        }
        if sk.min() != exact.min() || sk.max() != exact.max() {
            return Err("min/max not exact".into());
        }
        Ok(())
    }

    #[test]
    fn exact_below_five_samples() {
        let mut q = P2Quantile::new(0.5);
        assert!(q.value().is_nan());
        q.observe(3.0);
        assert_eq!(q.value(), 3.0);
        q.observe(1.0);
        assert_eq!(q.value(), 2.0); // interpolated median of {1, 3}
        q.observe(2.0);
        assert_eq!(q.value(), 2.0);
    }

    #[test]
    fn constant_stream_is_exact() {
        let xs = vec![7.25; 5000];
        let mut sk = QuantileSketch::new();
        for &x in &xs {
            sk.observe(x);
        }
        assert_eq!(sk.p50(), 7.25);
        assert_eq!(sk.p99(), 7.25);
        assert_eq!(sk.min(), 7.25);
        assert_eq!(sk.max(), 7.25);
        assert_eq!(sk.count(), 5000);
    }

    #[test]
    fn sorted_stream_tracks_exact_quantiles() {
        // Adversarial for marker trackers: every observation lands in the
        // top cell.
        let xs: Vec<f64> = (0..8000).map(|i| i as f64).collect();
        check_all_percentiles(&xs, 4.0).unwrap();
    }

    #[test]
    fn reversed_stream_tracks_exact_quantiles() {
        // The mirror attack: every observation lands in the bottom cell.
        let xs: Vec<f64> = (0..8000).rev().map(|i| i as f64).collect();
        check_all_percentiles(&xs, 4.0).unwrap();
    }

    #[test]
    fn mixed_random_streams_stay_rank_bounded() {
        forall(
            "p2-rank-error",
            25,
            0xBEEF,
            |rng| {
                let n = 500 + rng.below(4000);
                // A mix of uniform, heavy-tail and clustered samples
                // (NaN-free by construction).
                (0..n)
                    .map(|_| match rng.below(3) {
                        0 => rng.f64() * 10.0,
                        1 => 1.0 / rng.f64_open().max(1e-3).sqrt(), // heavy tail
                        _ => 5.0 + rng.f64() * 0.5,                 // cluster
                    })
                    .collect::<Vec<f64>>()
            },
            |xs| check_all_percentiles(xs, 6.0),
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let xs: Vec<f64> = {
            let mut rng = Rng::new(99);
            (0..2000).map(|_| rng.f64() * 100.0).collect()
        };
        let run = |xs: &[f64]| {
            let mut sk = QuantileSketch::new();
            for &x in xs {
                sk.observe(x);
            }
            (sk.p50(), sk.p90(), sk.p95(), sk.p99())
        };
        assert_eq!(run(&xs), run(&xs), "same stream, bit-identical estimates");
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        let mut sk = QuantileSketch::new();
        for &x in &[1.0, f64::NAN, 2.0, f64::INFINITY, 3.0, f64::NEG_INFINITY] {
            sk.observe(x);
        }
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.min(), 1.0);
        assert_eq!(sk.max(), 3.0);
        assert_eq!(sk.p50(), 2.0);
    }
}
