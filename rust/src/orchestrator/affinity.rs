//! Agent → model-class affinity annotations.
//!
//! Kairos assumes one shared LLM; a heterogeneous fleet serves several
//! model families at once, so each agent's profile carries the family that
//! may execute its requests. The orchestrator owns the annotation (it owns
//! everything agent-level); the coordinator stamps each request's
//! [`ModelClass`] from it at submission, and the sharded queue routes on
//! that stamp. Unpinned agents default to `Any` — the unsharded behavior.

use crate::engine::cost_model::ModelClass;

/// A parsed affinity specification: per-agent pins plus the default class
/// for unpinned agents.
#[derive(Debug, Clone, PartialEq)]
pub struct AffinitySpec {
    /// Class of agents without an explicit pin.
    pub default: ModelClass,
    /// `(agent name, class)` pins, in spec order.
    pub pins: Vec<(String, ModelClass)>,
}

impl Default for AffinitySpec {
    fn default() -> Self {
        AffinitySpec { default: ModelClass::Any, pins: Vec::new() }
    }
}

impl AffinitySpec {
    /// Parse a compact CLI/config string.
    ///
    /// Grammar: comma-separated `AGENT=CLASS` with classes `llama3-8b`,
    /// `llama2-13b`, `tiny`, `any`; the agent `*` sets the default class
    /// for unpinned agents. Examples:
    ///
    /// * `Engineer=llama2-13b,QAEngineer=llama2-13b` — pin the code
    ///   agents to the 13B group, everything else goes anywhere.
    /// * `*=llama3-8b` — pin every agent to the 8B group.
    pub fn parse(s: &str) -> Result<AffinitySpec, String> {
        if s.trim().is_empty() {
            return Err("empty affinity spec".to_string());
        }
        let mut spec = AffinitySpec::default();
        let mut saw_default = false;
        for raw in s.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                return Err(format!("empty affinity entry in {s:?}"));
            }
            let (agent, class) = entry
                .split_once('=')
                .ok_or_else(|| format!("expected AGENT=CLASS in {entry:?}"))?;
            let class = ModelClass::parse(class.trim())
                .map_err(|e| format!("{e} in {entry:?}"))?;
            let agent = agent.trim();
            if agent.is_empty() {
                return Err(format!("empty agent name in {entry:?}"));
            }
            if agent == "*" {
                // Same contract as duplicate agent pins: a conflicting
                // spec must error at parse naming the offending clause,
                // not silently last-win.
                if saw_default {
                    return Err(format!("duplicate default pin in clause {entry:?}"));
                }
                saw_default = true;
                spec.default = class;
            } else {
                if spec.pins.iter().any(|(a, _)| a == agent) {
                    return Err(format!(
                        "duplicate pin for agent {agent:?} in clause {entry:?}"
                    ));
                }
                spec.pins.push((agent.to_string(), class));
            }
        }
        Ok(spec)
    }

    /// The class `agent` resolves to under this spec.
    pub fn class_for(&self, agent: &str) -> ModelClass {
        self.pins
            .iter()
            .find(|(a, _)| a == agent)
            .map(|(_, c)| *c)
            .unwrap_or(self.default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::ModelKind;

    #[test]
    fn parses_pins_and_default() {
        let s = AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,Router=any").unwrap();
        assert_eq!(s.default, ModelClass::Model(ModelKind::Llama3_8B));
        assert_eq!(s.class_for("Engineer"), ModelClass::Model(ModelKind::Llama2_13B));
        assert_eq!(s.class_for("Router"), ModelClass::Any);
        assert_eq!(
            s.class_for("WriterAgent"),
            ModelClass::Model(ModelKind::Llama3_8B),
            "unpinned agents take the default"
        );
    }

    #[test]
    fn default_spec_is_all_any() {
        let s = AffinitySpec::default();
        assert_eq!(s.class_for("anything"), ModelClass::Any);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(AffinitySpec::parse("").is_err());
        assert!(AffinitySpec::parse("   ").is_err());
        assert!(AffinitySpec::parse("Engineer").is_err(), "missing =CLASS");
        assert!(AffinitySpec::parse("Engineer=gpt5").is_err(), "unknown model");
        assert!(AffinitySpec::parse("=llama3-8b").is_err(), "empty agent");
        assert!(AffinitySpec::parse("A=tiny,,B=tiny").is_err(), "empty entry");
        assert!(AffinitySpec::parse("A=tiny,A=any").is_err(), "duplicate pin");
        assert!(
            AffinitySpec::parse("*=llama3-8b,A=any,*=llama2-13b").is_err(),
            "duplicate default pin"
        );
    }

    #[test]
    fn duplicate_pins_name_the_offending_clause() {
        // The SECOND occurrence is the offending clause: the error must
        // point the user at it, not just the agent name or the whole spec.
        let err = AffinitySpec::parse("A=tiny,B=any,A=llama3-8b").unwrap_err();
        assert!(err.contains("\"A\""), "names the agent: {err}");
        assert!(err.contains("A=llama3-8b"), "names the clause: {err}");
        let err = AffinitySpec::parse("*=llama3-8b,A=any,*=llama2-13b").unwrap_err();
        assert!(err.contains("*=llama2-13b"), "names the clause: {err}");
    }
}
