//! Automated workflow analysis (paper §4.2).
//!
//! Kairos reconstructs the application call graph at runtime from two
//! signals carried by the system identifiers:
//!
//! * **Upstream names** give direct caller→callee edges.
//! * **Execution timestamps** disambiguate whether a node's multiple
//!   downstream calls run in *parallel* or *sequentially* — a sweep-line
//!   over the downstream execution spans: overlapping spans ⇒ parallel
//!   fan-out (Fig. 11a/b), disjoint spans ⇒ sequential re-invocations
//!   (Fig. 11c/d).
//!
//! The graph also maintains per-agent *remaining stage depth* (the longest
//! downstream path), which is exactly the signal the Ayo baseline schedules
//! on.

use std::collections::{BTreeMap, HashMap};

use super::ids::{AgentId, MsgId};
use crate::Time;

/// One completed agent-stage execution (ingest unit).
#[derive(Debug, Clone)]
pub struct ExecRecord {
    pub msg_id: MsgId,
    pub agent: AgentId,
    pub upstream: Option<AgentId>,
    /// LLM execution start / completion timestamps (paper §4.1).
    pub start: Time,
    pub end: Time,
}

/// How a parent invokes multiple downstream agents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Only downstream call observed from this parent in an instance.
    Simple,
    /// Downstream spans overlap in time: parallel fan-out.
    Parallel,
    /// Downstream spans are disjoint: sequential calls from the parent.
    Sequential,
}

/// Aggregated edge statistics.
#[derive(Debug, Clone)]
pub struct EdgeStats {
    pub kind: EdgeKind,
    /// Observation count (edge traversals across instances).
    pub count: u64,
}

/// The reconstructed workflow call graph, aggregated across instances.
#[derive(Debug, Default)]
pub struct WorkflowGraph {
    /// (upstream, downstream) -> stats. Ordered so [`WorkflowGraph::edges`]
    /// and [`WorkflowGraph::successors`] iterate deterministically (lint
    /// rule D2).
    edges: BTreeMap<(AgentId, AgentId), EdgeStats>,
    /// Per-instance execution records awaiting workflow completion.
    instances: HashMap<MsgId, Vec<ExecRecord>>,
    /// Agents observed as workflow entry points (no upstream).
    entries: HashMap<AgentId, u64>,
}

impl WorkflowGraph {
    pub fn new() -> WorkflowGraph {
        WorkflowGraph::default()
    }

    /// Ingest one execution record; updates edges incrementally.
    pub fn ingest(&mut self, rec: ExecRecord) {
        match rec.upstream {
            None => *self.entries.entry(rec.agent).or_insert(0) += 1,
            Some(up) => {
                let e = self
                    .edges
                    .entry((up, rec.agent))
                    .or_insert(EdgeStats { kind: EdgeKind::Simple, count: 0 });
                e.count += 1;
            }
        }
        let msg_id = rec.msg_id;
        self.instances.entry(msg_id).or_default().push(rec);
        // Re-classify the parent's outgoing calls within this instance.
        self.classify_instance_edges(msg_id);
    }

    /// Sweep-line classification of multi-downstream call patterns for one
    /// instance (paper Fig. 11b/d).
    fn classify_instance_edges(&mut self, msg_id: MsgId) {
        let Some(records) = self.instances.get(&msg_id) else { return };
        // Group downstream spans by parent (ordered: the loop below mutates
        // edge kinds, so parent visit order must be deterministic).
        let mut by_parent: BTreeMap<AgentId, Vec<&ExecRecord>> = BTreeMap::new();
        for r in records {
            if let Some(up) = r.upstream {
                by_parent.entry(up).or_default().push(r);
            }
        }
        for (parent, spans) in by_parent {
            if spans.len() < 2 {
                continue;
            }
            // Sweep line: sort by start; any span starting before the
            // previous maximum end overlaps ⇒ parallel.
            let mut sorted: Vec<&ExecRecord> = spans.clone();
            sorted.sort_by(|a, b| a.start.total_cmp(&b.start));
            let mut overlap = false;
            let mut max_end = sorted[0].end;
            for r in &sorted[1..] {
                if r.start < max_end {
                    overlap = true;
                    break;
                }
                max_end = max_end.max(r.end);
            }
            let kind = if overlap { EdgeKind::Parallel } else { EdgeKind::Sequential };
            for r in spans {
                if let Some(e) = self.edges.get_mut(&(parent, r.agent)) {
                    e.kind = kind;
                }
            }
        }
    }

    /// Remove and return the execution records of a finished instance.
    pub fn take_instance(&mut self, msg_id: MsgId) -> Option<Vec<ExecRecord>> {
        self.instances.remove(&msg_id)
    }

    /// Number of instances still being tracked.
    pub fn open_instances(&self) -> usize {
        self.instances.len()
    }

    pub fn edge(&self, up: AgentId, down: AgentId) -> Option<&EdgeStats> {
        self.edges.get(&(up, down))
    }

    pub fn edges(&self) -> impl Iterator<Item = (&(AgentId, AgentId), &EdgeStats)> {
        self.edges.iter()
    }

    /// Downstream successors of `agent` with traversal counts.
    pub fn successors(&self, agent: AgentId) -> Vec<(AgentId, u64)> {
        self.edges
            .iter()
            .filter(|((up, _), _)| *up == agent)
            .map(|((_, down), st)| (*down, st.count))
            .collect()
    }

    /// Remaining stage depth of `agent`: the longest downstream path length
    /// including the agent's own stage (≥ 1 for any observed agent). This
    /// is the Ayo baseline's priority signal. Cycles (dynamic feedback
    /// loops, Fig. 2c) are cut by visit marking.
    pub fn remaining_depth(&self, agent: AgentId) -> u32 {
        let mut memo: HashMap<AgentId, u32> = HashMap::new();
        let mut visiting: Vec<AgentId> = Vec::new();
        self.depth_rec(agent, &mut memo, &mut visiting)
    }

    fn depth_rec(
        &self,
        agent: AgentId,
        memo: &mut HashMap<AgentId, u32>,
        visiting: &mut Vec<AgentId>,
    ) -> u32 {
        if let Some(&d) = memo.get(&agent) {
            return d;
        }
        if visiting.contains(&agent) {
            return 1; // feedback loop: cut the cycle
        }
        visiting.push(agent);
        let best_down = self
            .successors(agent)
            .into_iter()
            .map(|(down, _)| self.depth_rec(down, memo, visiting))
            .max()
            .unwrap_or(0);
        visiting.pop();
        let d = 1 + best_down;
        memo.insert(agent, d);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: AgentId = AgentId(0);
    const B: AgentId = AgentId(1);
    const C: AgentId = AgentId(2);
    const D: AgentId = AgentId(3);

    fn rec(msg: MsgId, agent: AgentId, up: Option<AgentId>, start: f64, end: f64) -> ExecRecord {
        ExecRecord { msg_id: msg, agent, upstream: up, start, end }
    }

    #[test]
    fn linear_chain_reconstruction() {
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 2.0));
        g.ingest(rec(1, C, Some(B), 2.0, 3.0));
        assert!(g.edge(A, B).is_some());
        assert!(g.edge(B, C).is_some());
        assert!(g.edge(A, C).is_none());
        assert_eq!(g.remaining_depth(A), 3);
        assert_eq!(g.remaining_depth(B), 2);
        assert_eq!(g.remaining_depth(C), 1);
    }

    #[test]
    fn parallel_fanout_detected_by_overlap() {
        // Fig 11a: A calls B, C, D which execute concurrently.
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 3.0));
        g.ingest(rec(1, C, Some(A), 1.2, 2.5));
        g.ingest(rec(1, D, Some(A), 1.1, 4.0));
        assert_eq!(g.edge(A, B).unwrap().kind, EdgeKind::Parallel);
        assert_eq!(g.edge(A, C).unwrap().kind, EdgeKind::Parallel);
        assert_eq!(g.edge(A, D).unwrap().kind, EdgeKind::Parallel);
    }

    #[test]
    fn sequential_fanout_detected_by_disjoint_spans() {
        // Fig 11c: A calls B, then C, then D — same upstream, disjoint
        // spans. Pure-timestamp ordering would misread this as A→B→C→D.
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 2.0));
        g.ingest(rec(1, C, Some(A), 2.5, 3.5));
        g.ingest(rec(1, D, Some(A), 4.0, 5.0));
        assert_eq!(g.edge(A, B).unwrap().kind, EdgeKind::Sequential);
        // The upstream signal prevents the A→B→C chain misinterpretation:
        assert!(g.edge(B, C).is_none());
        // Sequential fan-out still counts each stage for depth: A has 3
        // one-hop children, so depth(A) = 2.
        assert_eq!(g.remaining_depth(A), 2);
    }

    #[test]
    fn branching_takes_longest_path() {
        // A -> B (leaf), A -> C -> D.
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 2.0));
        g.ingest(rec(2, A, None, 0.0, 1.0));
        g.ingest(rec(2, C, Some(A), 1.0, 2.0));
        g.ingest(rec(2, D, Some(C), 2.0, 3.0));
        assert_eq!(g.remaining_depth(A), 3);
    }

    #[test]
    fn feedback_cycle_does_not_hang() {
        // CG-style loop: Engineer -> QA -> Engineer.
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 2.0)); // engineer
        g.ingest(rec(1, C, Some(B), 2.0, 3.0)); // qa
        g.ingest(rec(1, B, Some(C), 3.0, 4.0)); // redevelopment
        let d = g.remaining_depth(A);
        assert!(d >= 3, "depth accounts for the loop body once, got {d}");
    }

    #[test]
    fn instance_take_removes_tracking() {
        let mut g = WorkflowGraph::new();
        g.ingest(rec(1, A, None, 0.0, 1.0));
        g.ingest(rec(1, B, Some(A), 1.0, 2.0));
        assert_eq!(g.open_instances(), 1);
        let recs = g.take_instance(1).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(g.open_instances(), 0);
        assert!(g.take_instance(1).is_none());
    }

    #[test]
    fn edge_counts_accumulate_across_instances() {
        let mut g = WorkflowGraph::new();
        for msg in 0..5 {
            g.ingest(rec(msg, A, None, 0.0, 1.0));
            g.ingest(rec(msg, B, Some(A), 1.0, 2.0));
        }
        assert_eq!(g.edge(A, B).unwrap().count, 5);
        assert_eq!(g.successors(A), vec![(B, 5)]);
    }
}
