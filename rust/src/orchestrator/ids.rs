//! System identifiers (paper §4.1): agent names, message ids, upstream
//! names and execution timestamps — the contextual information Kairos
//! propagates transparently through the communication layer.

use std::collections::HashMap;

/// Globally unique id of one user task / workflow instance ("Message ID").
pub type MsgId = u64;

/// Interned agent identity ("Agent Name"). Cheap to copy through the hot
/// path; resolved to names via [`AgentRegistry`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentId(pub u32);

/// Bidirectional agent-name interner.
///
/// Name storage is the process-wide pool ([`crate::util::intern()`]): the
/// registry maps names to dense ids but owns no string allocations, so
/// cloning it (e.g. snapshotting orchestrator state) copies only pointers
/// and a name shared with the trace recorder is leaked exactly once.
#[derive(Debug, Default, Clone)]
pub struct AgentRegistry {
    names: Vec<&'static str>,
    by_name: HashMap<&'static str, AgentId>,
}

impl AgentRegistry {
    pub fn new() -> AgentRegistry {
        AgentRegistry::default()
    }

    /// Intern `name`, returning its stable id.
    pub fn intern(&mut self, name: &str) -> AgentId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let name = crate::util::intern(name);
        let id = AgentId(self.names.len() as u32);
        self.names.push(name);
        self.by_name.insert(name, id);
        id
    }

    pub fn get(&self, name: &str) -> Option<AgentId> {
        self.by_name.get(name).copied()
    }

    pub fn name(&self, id: AgentId) -> &'static str {
        self.names[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn all(&self) -> impl Iterator<Item = AgentId> + '_ {
        (0..self.names.len() as u32).map(AgentId)
    }
}

/// Monotonic message-id generator (frontend-assigned).
#[derive(Debug, Default)]
pub struct MsgIdGen {
    next: MsgId,
}

impl MsgIdGen {
    pub fn new() -> MsgIdGen {
        MsgIdGen { next: 1 }
    }

    pub fn next(&mut self) -> MsgId {
        let id = self.next;
        self.next += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_stable() {
        let mut r = AgentRegistry::new();
        let a = r.intern("Router");
        let b = r.intern("MathAgent");
        assert_eq!(r.intern("Router"), a);
        assert_ne!(a, b);
        assert_eq!(r.name(a), "Router");
        assert_eq!(r.get("MathAgent"), Some(b));
        assert_eq!(r.get("Nope"), None);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn msg_ids_unique_and_monotonic() {
        let mut g = MsgIdGen::new();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }

    #[test]
    fn registry_shares_the_global_pool() {
        let mut r = AgentRegistry::new();
        let id = r.intern("SharedPoolAgent");
        // The registry stores the pool's allocation, not a private copy.
        assert!(std::ptr::eq(r.name(id), crate::util::intern("SharedPoolAgent")));
        let clone = r.clone();
        assert!(std::ptr::eq(clone.name(id), r.name(id)));
    }

    #[test]
    fn all_iterates_in_intern_order() {
        let mut r = AgentRegistry::new();
        r.intern("A");
        r.intern("B");
        let ids: Vec<AgentId> = r.all().collect();
        assert_eq!(ids, vec![AgentId(0), AgentId(1)]);
    }
}
