//! The Workflow Orchestrator (paper §4).
//!
//! Collects the system identifiers riding on every agent request
//! ([`ids`]), reconstructs the application call graph online from
//! upstream/downstream causality + execution-span overlap ([`graph`]), and
//! maintains per-agent latency distributions — single-request execution and
//! remaining-workflow — with the doubling/Wasserstein convergence test
//! ([`profiler`]).

pub mod graph;
pub mod ids;
pub mod profiler;

pub use graph::{EdgeKind, ExecRecord, WorkflowGraph};
pub use ids::{AgentId, AgentRegistry, MsgId};
pub use profiler::{DistributionProfiler, LatencyProfile};

use crate::Time;

/// The orchestrator facade: ingest completion records, expose workflow
/// structure and latency profiles to the scheduler and dispatcher.
pub struct Orchestrator {
    pub registry: AgentRegistry,
    pub graph: WorkflowGraph,
    pub profiler: DistributionProfiler,
}

impl Default for Orchestrator {
    fn default() -> Self {
        Self::new()
    }
}

impl Orchestrator {
    pub fn new() -> Orchestrator {
        Orchestrator {
            registry: AgentRegistry::new(),
            graph: WorkflowGraph::new(),
            profiler: DistributionProfiler::new(),
        }
    }

    /// Record one completed agent-stage execution (paper step ④: "once a
    /// request is completed, the Workflow Orchestrator collects its
    /// execution information and incrementally updates the Workflow
    /// Analyzer and the Distribution Profiler").
    pub fn record_execution(&mut self, rec: ExecRecord) {
        self.profiler.record_execution(rec.agent, rec.end - rec.start);
        self.graph.ingest(rec);
    }

    /// Record the completion of an entire workflow instance: back-fills the
    /// remaining-latency samples for every stage of that instance.
    pub fn record_workflow_done(&mut self, msg_id: MsgId, done_at: Time) {
        if let Some(stages) = self.graph.take_instance(msg_id) {
            for rec in &stages {
                // Remaining latency measured from the START of the stage's
                // execution to the end of the workflow: the quantity the
                // scheduler wants to minimize queueing against.
                self.profiler
                    .record_remaining(rec.agent, (done_at - rec.start).max(0.0));
            }
        }
    }
}
