//! The Workflow Orchestrator (paper §4).
//!
//! Collects the system identifiers riding on every agent request
//! ([`ids`]), reconstructs the application call graph online from
//! upstream/downstream causality + execution-span overlap ([`graph`]),
//! maintains per-agent latency distributions — single-request execution and
//! remaining-workflow — with the doubling/Wasserstein convergence test
//! ([`profiler`]), carries each agent's model-class affinity
//! annotation for serving-group routing ([`affinity`]), and owns the
//! profile-driven routing layer ([`router`]) that turns those annotations
//! plus the measured per-family latency profiles into per-request
//! serving-group placements.

pub mod affinity;
pub mod graph;
pub mod ids;
pub mod profiler;
pub mod router;

pub use affinity::AffinitySpec;
pub use graph::{EdgeKind, ExecRecord, WorkflowGraph};
pub use ids::{AgentId, AgentRegistry, MsgId};
pub use profiler::{DistributionProfiler, LatencyProfile};
pub use router::{GroupPressure, RouteDecision, RoutePolicy, RouteReason, Router};

use std::collections::HashMap;

use crate::engine::cost_model::ModelClass;
use crate::Time;

/// The orchestrator facade: ingest completion records, expose workflow
/// structure, latency profiles and model-affinity annotations to the
/// scheduler and dispatcher.
pub struct Orchestrator {
    pub registry: AgentRegistry,
    pub graph: WorkflowGraph,
    pub profiler: DistributionProfiler,
    /// Agent → serving-group requirement (explicit pins).
    model_class: HashMap<AgentId, ModelClass>,
    /// Class of agents without an explicit pin.
    default_class: ModelClass,
}

impl Default for Orchestrator {
    fn default() -> Self {
        Self::new()
    }
}

impl Orchestrator {
    pub fn new() -> Orchestrator {
        Orchestrator {
            registry: AgentRegistry::new(),
            graph: WorkflowGraph::new(),
            profiler: DistributionProfiler::new(),
            model_class: HashMap::new(),
            default_class: ModelClass::Any,
        }
    }

    /// Install an affinity spec: interns every pinned agent and records the
    /// default class for unpinned ones. REPLACES any previously installed
    /// spec — pins absent from the new spec fall back to its default.
    pub fn apply_affinity(&mut self, spec: &AffinitySpec) {
        self.model_class.clear();
        self.default_class = spec.default;
        for (name, class) in &spec.pins {
            let id = self.registry.intern(name);
            self.model_class.insert(id, *class);
        }
    }

    /// Pin one agent's serving group directly.
    pub fn set_model_class(&mut self, agent: AgentId, class: ModelClass) {
        self.model_class.insert(agent, class);
    }

    /// The serving group `agent`'s requests require.
    pub fn model_class(&self, agent: AgentId) -> ModelClass {
        self.model_class.get(&agent).copied().unwrap_or(self.default_class)
    }

    /// [`Self::model_class`] by agent name, without interning: agents the
    /// registry has never seen get the default class. The trace-recording
    /// path reads this so capturing a plan never perturbs id assignment.
    pub fn class_of_name(&self, name: &str) -> ModelClass {
        self.registry
            .get(name)
            .map(|id| self.model_class(id))
            .unwrap_or(self.default_class)
    }

    /// Record one completed agent-stage execution (paper step ④: "once a
    /// request is completed, the Workflow Orchestrator collects its
    /// execution information and incrementally updates the Workflow
    /// Analyzer and the Distribution Profiler").
    pub fn record_execution(&mut self, rec: ExecRecord) {
        self.profiler.record_execution(rec.agent, rec.end - rec.start);
        self.graph.ingest(rec);
    }

    /// Record one completed execution with its serving context: which
    /// model family served it, how long it ran there, and how many KV
    /// tokens the request held — the routing layer's learning signal and
    /// the dispatcher's demand prediction, fed from the coordinator's
    /// completion path. `now` (the completion time) drives the profile
    /// half-life for non-stationary workloads.
    pub fn record_serving_feedback(
        &mut self,
        agent: AgentId,
        model: crate::engine::cost_model::ModelKind,
        exec_latency: f64,
        kv_tokens: f64,
        now: Time,
    ) {
        self.profiler
            .record_family_execution_at(agent, model, exec_latency.max(0.0), now);
        self.profiler.record_kv_demand(agent, kv_tokens.max(0.0));
    }

    /// Record the completion of an entire workflow instance: back-fills the
    /// remaining-latency samples for every stage of that instance.
    pub fn record_workflow_done(&mut self, msg_id: MsgId, done_at: Time) {
        if let Some(stages) = self.graph.take_instance(msg_id) {
            for rec in &stages {
                // Remaining latency measured from the START of the stage's
                // execution to the end of the workflow: the quantity the
                // scheduler wants to minimize queueing against.
                self.profiler
                    .record_remaining(rec.agent, (done_at - rec.start).max(0.0));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost_model::ModelKind;

    #[test]
    fn affinity_resolves_through_the_registry() {
        let mut orch = Orchestrator::new();
        let spec = AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b").unwrap();
        orch.apply_affinity(&spec);
        let eng = orch.registry.intern("Engineer");
        let other = orch.registry.intern("Router");
        assert_eq!(orch.model_class(eng), ModelClass::Model(ModelKind::Llama2_13B));
        assert_eq!(orch.model_class(other), ModelClass::Model(ModelKind::Llama3_8B));
        orch.set_model_class(other, ModelClass::Any);
        assert_eq!(orch.model_class(other), ModelClass::Any);
    }

    #[test]
    fn unannotated_orchestrator_defaults_to_any() {
        let mut orch = Orchestrator::new();
        let a = orch.registry.intern("A");
        assert_eq!(orch.model_class(a), ModelClass::Any);
    }
}
