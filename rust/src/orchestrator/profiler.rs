//! Latency distribution analysis (paper §4.3).
//!
//! Per agent, two empirical distributions are maintained online:
//!
//! 1. **Single-request execution latency** — drives the dispatcher's
//!    expected execution time (mode of the distribution, §6).
//! 2. **Remaining execution latency** — time from a stage's execution start
//!    to the end of its workflow; drives the scheduler's agent priorities
//!    (§5.1). Multi-path agents (e.g. QA's Router) naturally merge samples
//!    from all downstream paths in their historical frequency proportions.
//!
//! Convergence uses the paper's exponentially-increasing sampling strategy:
//! each time the sample count doubles, the Wasserstein distance between the
//! current and previous snapshot is compared to a threshold.
//!
//! Since the routing layer ([`super::router`]), two more profile families
//! are maintained from the coordinator's completion feedback:
//!
//! 3. **Per-(agent, model-family) execution latency** — what the agent's
//!    requests actually cost on each serving group; the learned
//!    [`super::router::RoutePolicy`] picks the family with the lowest
//!    measured mean.
//! 4. **Per-agent KV demand** — total KV tokens (prompt + generated) a
//!    request of the agent ends up holding; the time-slot dispatcher's
//!    demand-prediction hook reads its mode instead of the slope-based
//!    guess once samples exist.

use std::collections::HashMap;

use super::ids::AgentId;
use crate::engine::cost_model::ModelKind;
use crate::stats::ecdf::{wasserstein1, Ecdf};
use crate::Time;

/// Relative Wasserstein threshold for declaring convergence.
const CONVERGENCE_REL_THRESHOLD: f64 = 0.08;
/// Minimum samples before any convergence claim.
const MIN_SAMPLES: usize = 8;

/// One agent's evolving latency distribution with doubling-based
/// convergence detection.
#[derive(Debug, Clone)]
pub struct LatencyProfile {
    samples: Vec<f64>,
    /// Snapshot taken at the last doubling checkpoint.
    last_snapshot: Option<Ecdf>,
    next_checkpoint: usize,
    converged: bool,
}

impl Default for LatencyProfile {
    fn default() -> Self {
        LatencyProfile {
            samples: Vec::new(),
            last_snapshot: None,
            next_checkpoint: MIN_SAMPLES,
            converged: false,
        }
    }
}

impl LatencyProfile {
    pub fn record(&mut self, latency: f64) {
        debug_assert!(latency.is_finite() && latency >= 0.0);
        self.samples.push(latency);
        if self.samples.len() >= self.next_checkpoint {
            let current = Ecdf::new(self.samples.clone());
            if let Some(prev) = &self.last_snapshot {
                let d = wasserstein1(prev, &current);
                let scale = current.mean().max(1e-9);
                self.converged = d / scale < CONVERGENCE_REL_THRESHOLD;
            }
            self.last_snapshot = Some(current);
            self.next_checkpoint *= 2; // exponentially increasing sampling
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Whether the doubling test has declared the distribution stable.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// Current ECDF (None if no samples yet).
    pub fn ecdf(&self) -> Option<Ecdf> {
        if self.samples.is_empty() {
            None
        } else {
            Some(Ecdf::new(self.samples.clone()))
        }
    }

    /// Mode of the distribution — the dispatcher's expected execution time.
    pub fn mode(&self) -> Option<f64> {
        self.ecdf().map(|e| e.mode())
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }
}

/// An exponentially decayed running mean: each recorded sample enters with
/// weight 1, and all accumulated weight halves every `half_life` seconds.
/// The non-stationary view of a latency stream — old regimes fade instead
/// of anchoring the average forever.
#[derive(Debug, Clone, Copy)]
struct DecayedMean {
    mean: f64,
    weight: f64,
    last: Time,
}

impl DecayedMean {
    fn new(value: f64, now: Time) -> DecayedMean {
        DecayedMean { mean: value, weight: 1.0, last: now }
    }

    fn update(&mut self, value: f64, now: Time, half_life: f64) {
        let dt = (now - self.last).max(0.0);
        let kept = self.weight * 0.5f64.powf(dt / half_life);
        self.weight = kept + 1.0;
        self.mean = (self.mean * kept + value) / self.weight;
        self.last = self.last.max(now);
    }
}

/// All agents' profiles: execution latency + remaining workflow latency,
/// plus the routing layer's per-family execution and KV-demand profiles.
#[derive(Debug, Default)]
pub struct DistributionProfiler {
    exec: HashMap<AgentId, LatencyProfile>,
    remaining: HashMap<AgentId, LatencyProfile>,
    /// Execution latency of the agent's requests on one model family —
    /// what the learned route policy compares across serving groups.
    family_exec: HashMap<(AgentId, ModelKind), LatencyProfile>,
    /// Total KV tokens (prompt + generated) held by the agent's requests
    /// at completion — the dispatcher's learned demand prediction.
    kv_demand: HashMap<AgentId, LatencyProfile>,
    /// Half-life (seconds) of the per-family execution means. `None` (the
    /// default) keeps the stationary behavior: means average forever.
    half_life: Option<f64>,
    /// Decayed per-family means, maintained alongside the raw profiles
    /// whenever a half-life is configured.
    family_decayed: HashMap<(AgentId, ModelKind), DecayedMean>,
}

impl DistributionProfiler {
    pub fn new() -> DistributionProfiler {
        DistributionProfiler::default()
    }

    pub fn record_execution(&mut self, agent: AgentId, latency: f64) {
        self.exec.entry(agent).or_default().record(latency);
    }

    pub fn record_remaining(&mut self, agent: AgentId, latency: f64) {
        self.remaining.entry(agent).or_default().record(latency);
    }

    /// Configure the per-family profile half-life for non-stationary
    /// workloads: with `Some(h)`, [`Self::family_mean_exec`] reports an
    /// exponentially decayed mean (half-life `h` seconds) so learned
    /// routing tracks drifting agent latencies instead of averaging
    /// forever. `None` restores the stationary behavior. Callers validate
    /// (`h` must be positive and finite — see `[policy]
    /// profile_half_life`).
    pub fn set_half_life(&mut self, half_life: Option<f64>) {
        if let Some(h) = half_life {
            debug_assert!(
                h.is_finite() && h > 0.0,
                "half-life must be validated by the caller: {h}"
            );
        }
        self.half_life = half_life;
    }

    /// The configured per-family profile half-life, if any.
    pub fn half_life(&self) -> Option<f64> {
        self.half_life
    }

    /// Record one completed execution on the family that actually served
    /// it (the coordinator knows the instance, hence the family).
    /// Timeless form: feeds only the raw profile — equivalent to
    /// [`Self::record_family_execution_at`] when no half-life is set.
    pub fn record_family_execution(
        &mut self,
        agent: AgentId,
        model: ModelKind,
        latency: f64,
    ) {
        self.record_family_execution_at(agent, model, latency, 0.0);
    }

    /// Record one completed execution on the family that served it, at
    /// completion time `now` — the timestamp drives the decayed mean when
    /// a half-life is configured.
    pub fn record_family_execution_at(
        &mut self,
        agent: AgentId,
        model: ModelKind,
        latency: f64,
        now: Time,
    ) {
        self.family_exec.entry((agent, model)).or_default().record(latency);
        if let Some(h) = self.half_life {
            self.family_decayed
                .entry((agent, model))
                .and_modify(|d| d.update(latency, now, h))
                .or_insert_with(|| DecayedMean::new(latency, now));
        }
    }

    /// Record the total KV tokens a completed request of `agent` held.
    pub fn record_kv_demand(&mut self, agent: AgentId, tokens: f64) {
        self.kv_demand.entry(agent).or_default().record(tokens);
    }

    pub fn exec_profile(&self, agent: AgentId) -> Option<&LatencyProfile> {
        self.exec.get(&agent)
    }

    pub fn remaining_profile(&self, agent: AgentId) -> Option<&LatencyProfile> {
        self.remaining.get(&agent)
    }

    /// The agent's execution-latency profile on one model family.
    pub fn family_exec_profile(
        &self,
        agent: AgentId,
        model: ModelKind,
    ) -> Option<&LatencyProfile> {
        self.family_exec.get(&(agent, model))
    }

    /// Execution samples collected for `agent` on `model` (0 when none).
    pub fn family_samples(&self, agent: AgentId, model: ModelKind) -> usize {
        self.family_exec.get(&(agent, model)).map_or(0, |p| p.len())
    }

    /// Measured mean execution latency of `agent` on `model`, if sampled:
    /// the exponentially decayed mean when a half-life is configured
    /// (recent regime dominates), the all-time mean otherwise.
    pub fn family_mean_exec(&self, agent: AgentId, model: ModelKind) -> Option<f64> {
        if self.half_life.is_some() {
            if let Some(d) = self.family_decayed.get(&(agent, model)) {
                return Some(d.mean);
            }
        }
        self.family_exec.get(&(agent, model)).and_then(|p| p.mean())
    }

    /// Expected total KV tokens (mode of the demand distribution) one
    /// request of `agent` will hold, if profiled.
    pub fn expected_kv_demand(&self, agent: AgentId) -> Option<f64> {
        self.kv_demand.get(&agent).and_then(|p| p.mode())
    }

    /// Agents with at least one remaining-latency sample.
    pub fn agents_with_remaining(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.remaining.keys().copied().collect();
        v.sort();
        v
    }

    /// Expected execution latency (mode) for an agent, if profiled.
    pub fn expected_exec(&self, agent: AgentId) -> Option<f64> {
        self.exec.get(&agent).and_then(|p| p.mode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{Dist, LogNormal};
    use crate::stats::rng::Rng;

    #[test]
    fn stationary_stream_converges() {
        let mut p = LatencyProfile::default();
        let d = LogNormal::from_mean_cv(5.0, 0.4);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            p.record(d.sample(&mut rng));
        }
        assert!(p.converged(), "stationary distribution must converge");
    }

    #[test]
    fn shifting_stream_resets_convergence() {
        let mut p = LatencyProfile::default();
        let mut rng = Rng::new(2);
        let d1 = LogNormal::from_mean_cv(5.0, 0.3);
        for _ in 0..512 {
            p.record(d1.sample(&mut rng));
        }
        // Drastic regime change: new samples 20x larger.
        let d2 = LogNormal::from_mean_cv(100.0, 0.3);
        for _ in 0..4096 {
            p.record(d2.sample(&mut rng));
        }
        // At some point during the shift the doubling check must have seen
        // a large Wasserstein gap; after enough new samples it re-settles.
        assert!(p.len() > 4000);
    }

    #[test]
    fn few_samples_not_converged() {
        let mut p = LatencyProfile::default();
        for _ in 0..4 {
            p.record(1.0);
        }
        assert!(!p.converged());
    }

    #[test]
    fn mode_tracks_lognormal() {
        let mut p = LatencyProfile::default();
        let d = LogNormal::from_mean_cv(10.0, 0.5);
        let mut rng = Rng::new(3);
        for _ in 0..20_000 {
            p.record(d.sample(&mut rng));
        }
        let mode = p.mode().unwrap();
        let want = d.mode();
        assert!((mode - want).abs() / want < 0.4, "mode={mode} want={want}");
    }

    #[test]
    fn profiler_tracks_agents_separately() {
        let mut pr = DistributionProfiler::new();
        let a = AgentId(0);
        let b = AgentId(1);
        pr.record_execution(a, 1.0);
        pr.record_execution(b, 100.0);
        pr.record_remaining(a, 2.0);
        assert_eq!(pr.exec_profile(a).unwrap().len(), 1);
        assert_eq!(pr.exec_profile(b).unwrap().len(), 1);
        assert_eq!(pr.agents_with_remaining(), vec![a]);
        assert!(pr.remaining_profile(b).is_none());
    }

    #[test]
    fn family_profiles_tracked_per_model() {
        let mut pr = DistributionProfiler::new();
        let a = AgentId(0);
        pr.record_family_execution(a, ModelKind::Llama3_8B, 1.0);
        pr.record_family_execution(a, ModelKind::Llama3_8B, 3.0);
        pr.record_family_execution(a, ModelKind::Llama2_13B, 10.0);
        assert_eq!(pr.family_samples(a, ModelKind::Llama3_8B), 2);
        assert_eq!(pr.family_samples(a, ModelKind::Llama2_13B), 1);
        assert_eq!(pr.family_samples(a, ModelKind::Tiny), 0);
        assert!((pr.family_mean_exec(a, ModelKind::Llama3_8B).unwrap() - 2.0).abs() < 1e-9);
        assert!(pr.family_mean_exec(AgentId(1), ModelKind::Llama3_8B).is_none());
        assert!(pr.family_exec_profile(a, ModelKind::Llama2_13B).is_some());
    }

    #[test]
    fn kv_demand_mode_tracks_samples() {
        let mut pr = DistributionProfiler::new();
        let a = AgentId(2);
        assert!(pr.expected_kv_demand(a).is_none());
        for _ in 0..10 {
            pr.record_kv_demand(a, 300.0);
        }
        pr.record_kv_demand(a, 1200.0);
        // Histogram-mode estimate: lands in the dense cluster's bin, far
        // from the single outlier.
        let kv = pr.expected_kv_demand(a).unwrap();
        assert!((300.0..600.0).contains(&kv), "mode near the majority: {kv}");
    }

    #[test]
    fn decayed_family_mean_tracks_a_regime_shift() {
        let a = AgentId(0);
        let m = ModelKind::Llama2_13B;
        // Without a half-life: 100 fast samples anchor the mean forever —
        // 5 slow late samples barely move it.
        let mut stationary = DistributionProfiler::new();
        for i in 0..100 {
            stationary.record_family_execution_at(a, m, 0.5, i as f64 * 0.1);
        }
        for i in 0..5 {
            stationary.record_family_execution_at(a, m, 10.0, 200.0 + i as f64);
        }
        let anchored = stationary.family_mean_exec(a, m).unwrap();
        assert!(anchored < 1.5, "all-time mean stays anchored: {anchored}");
        // With a 10 s half-life: by t=200 the fast-era weight has halved
        // ~19 times, so the mean follows the new slow regime.
        let mut decayed = DistributionProfiler::new();
        decayed.set_half_life(Some(10.0));
        assert_eq!(decayed.half_life(), Some(10.0));
        for i in 0..100 {
            decayed.record_family_execution_at(a, m, 0.5, i as f64 * 0.1);
        }
        for i in 0..5 {
            decayed.record_family_execution_at(a, m, 10.0, 200.0 + i as f64);
        }
        let tracked = decayed.family_mean_exec(a, m).unwrap();
        assert!(tracked > 9.0, "decayed mean follows the shift: {tracked}");
        // The raw sample count is untouched (min_samples gates still
        // work), and clearing the half-life restores the all-time mean.
        assert_eq!(decayed.family_samples(a, m), 105);
        decayed.set_half_life(None);
        let raw = decayed.family_mean_exec(a, m).unwrap();
        assert!((raw - anchored).abs() < 1e-9);
    }

    #[test]
    fn timeless_recording_matches_old_behavior_without_half_life() {
        let mut pr = DistributionProfiler::new();
        let a = AgentId(3);
        pr.record_family_execution(a, ModelKind::Llama3_8B, 1.0);
        pr.record_family_execution(a, ModelKind::Llama3_8B, 3.0);
        assert!((pr.family_mean_exec(a, ModelKind::Llama3_8B).unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(pr.half_life(), None);
    }

    #[test]
    fn multi_path_merge_reflects_frequencies() {
        // Router goes to Math (fast path) 80% and Humanities (slow) 20%:
        // the merged remaining distribution leans toward the fast path.
        let mut pr = DistributionProfiler::new();
        let router = AgentId(0);
        for _ in 0..80 {
            pr.record_remaining(router, 1.0);
        }
        for _ in 0..20 {
            pr.record_remaining(router, 10.0);
        }
        let e = pr.remaining_profile(router).unwrap().ecdf().unwrap();
        assert!((e.quantile(0.5) - 1.0).abs() < 1e-9, "median follows majority path");
        assert!((e.mean() - 2.8).abs() < 1e-9);
    }
}
