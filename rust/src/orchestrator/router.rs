//! Profile-driven request routing: which serving group a request lands in.
//!
//! PR 3 made the routing decision a *static* stamp — each agent's
//! [`ModelClass`] came straight from its affinity annotation, and every
//! unpinned (`Any`) request fell into one undifferentiated shard. This
//! module turns that stamp into an explicit routing layer, following the
//! paper's orchestrator ("collects agent-specific information for online
//! workflow analysis") plus the workload-aware routing of Maestro and the
//! latency-aware heterogeneous routing of Chimera:
//!
//! * Under [`RoutePolicy::Pinned`] the router reproduces the static
//!   behavior exactly: pins stamp their family, unpinned requests share
//!   the `Any` shard. This is the default.
//! * Under [`RoutePolicy::Learned`] the affinity pin becomes a *prior*:
//!   once the [`DistributionProfiler`]'s per-(agent, family) execution
//!   profiles — fed back from the coordinator's completion path — hold at
//!   least `min_samples` on some family, the router stamps the family
//!   with the lowest measured mean latency. Until then pinned agents fall
//!   back to their pin, and `Any` agents are balanced to the
//!   least-pressured serving group ([`GroupPressure`]) while keeping
//!   their `Any` class, so dispatch stays work-conserving. A
//!   deterministic exploration schedule (every ⌈1/explore_rate⌉-th
//!   decision per agent routes to the least-sampled live family) keeps
//!   every group's profile fresh without any randomness — the
//!   driver-equivalence seam extends to the per-request
//!   [`RouteDecision`] log.
//!
//! The router never chooses a family with zero accepting instances, so a
//! learned stamp can defer behind a transient drain but never targets a
//! group that cannot currently serve. Note the scope of the
//! work-conservation guarantee: it covers *pressure-balanced* `Any`
//! requests ([`RouteReason::LeastPressured`] — class stays `Any`).
//! Explored and learned-best requests are hard-stamped to their target
//! family on purpose (a latency sample is only attributable to a family
//! the request was constrained to), and so adopt exactly the static
//! pin's semantics: if the stamped family later drains away entirely,
//! the request defers until scaling revives it — no worse than a PR 3
//! affinity pin, but not work-conserving either.

use std::collections::HashMap;

use super::ids::AgentId;
use super::profiler::DistributionProfiler;
use crate::engine::cost_model::{ModelClass, ModelKind};
use crate::engine::request::RequestId;

/// How the router picks a serving group for each submitted request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoutePolicy {
    /// The static behavior: the affinity stamp is the route.
    Pinned,
    /// Learn each agent's best family online from measured per-family
    /// execution latency, falling back to the pin until enough samples
    /// exist.
    Learned {
        /// Fraction of decisions spent exploring the least-sampled
        /// family (deterministically: every ⌈1/rate⌉-th decision per
        /// agent). 0 disables exploration.
        explore_rate: f64,
        /// Samples a family needs before it can be chosen as "best".
        min_samples: usize,
    },
}

impl RoutePolicy {
    /// Default learned-policy parameters.
    pub fn learned_default() -> RoutePolicy {
        RoutePolicy::Learned { explore_rate: 0.125, min_samples: 8 }
    }

    /// Parse a CLI/config route policy.
    ///
    /// Grammar: `pinned`, `learned`, or `learned:KEY=VAL[,KEY=VAL]` with
    /// keys `explore` (in `[0, 1)`) and `min_samples` (positive integer).
    /// Examples: `learned`, `learned:explore=0.2`,
    /// `learned:explore=0.1,min_samples=16`.
    pub fn parse(s: &str) -> Result<RoutePolicy, String> {
        let s = s.trim();
        if s == "pinned" {
            return Ok(RoutePolicy::Pinned);
        }
        let Some(rest) = s.strip_prefix("learned") else {
            return Err(format!("unknown route policy {s:?} (pinned|learned[:...])"));
        };
        let RoutePolicy::Learned { mut explore_rate, mut min_samples } =
            RoutePolicy::learned_default()
        else {
            unreachable!()
        };
        if rest.is_empty() {
            return Ok(RoutePolicy::Learned { explore_rate, min_samples });
        }
        let Some(params) = rest.strip_prefix(':') else {
            return Err(format!("unknown route policy {s:?} (pinned|learned[:...])"));
        };
        for clause in params.split(',') {
            let clause = clause.trim();
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("expected KEY=VAL in route-policy clause {clause:?}"))?;
            match key.trim() {
                "explore" => {
                    let r: f64 = val.trim().parse().map_err(|_| {
                        format!("bad explore rate in route-policy clause {clause:?}")
                    })?;
                    if !r.is_finite() || !(0.0..1.0).contains(&r) {
                        return Err(format!(
                            "explore rate must be in [0, 1) in route-policy clause {clause:?}"
                        ));
                    }
                    explore_rate = r;
                }
                "min_samples" => {
                    let n: usize = val.trim().parse().map_err(|_| {
                        format!("bad min_samples in route-policy clause {clause:?}")
                    })?;
                    if n == 0 {
                        return Err(format!(
                            "min_samples must be positive in route-policy clause {clause:?}"
                        ));
                    }
                    min_samples = n;
                }
                other => {
                    return Err(format!(
                        "unknown route-policy key {other:?} in clause {clause:?}"
                    ))
                }
            }
        }
        Ok(RoutePolicy::Learned { explore_rate, min_samples })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Pinned => "pinned",
            RoutePolicy::Learned { .. } => "learned",
        }
    }
}

/// Why the router put a request where it did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteReason {
    /// Static pin honored (Pinned policy, or a pinned fallback would be
    /// identical).
    Pinned,
    /// Unpinned request in the shared `Any` shard (static behavior).
    AnyShared,
    /// Learned best family by measured mean execution latency.
    LearnedBest,
    /// Deterministic exploration of the least-sampled family.
    Explore,
    /// Not enough samples yet: fell back to the agent's static pin.
    FallbackPin,
    /// `Any`-class request balanced into the least-pressured group.
    LeastPressured,
}

/// One routing decision, logged per submitted request — part of the
/// driver-equivalence seam contract alongside the dispatch and group logs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    pub req: RequestId,
    pub agent: AgentId,
    /// The static class from the affinity annotation.
    pub class: ModelClass,
    /// The class actually stamped on the request (the dispatch
    /// constraint). Equals `class` unless learning overrode the pin.
    pub chosen: ModelClass,
    /// The group whose queue shard holds the request when an `Any`-class
    /// request was balanced (its dispatch constraint stays `Any`).
    pub group: Option<ModelKind>,
    pub reason: RouteReason,
}

/// Live pressure signal of one serving group, computed by the coordinator
/// at submission time (fleet-index first-seen order, deterministic).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupPressure {
    pub model: ModelKind,
    /// Requests queued toward this group (pinned shard + routed-Any shard).
    pub queued: usize,
    /// Instances of the family currently accepting dispatches.
    pub active: usize,
    /// Requests resident in the family's accepting engines (running +
    /// engine-queued).
    pub inflight: usize,
    /// Uncommitted KV tokens across the family's accepting instances —
    /// the fleet-headroom tiebreaker.
    pub free_tokens: u64,
}

impl GroupPressure {
    /// Backlog per accepting instance; dead groups are infinitely
    /// pressured.
    pub fn score(&self) -> f64 {
        if self.active == 0 {
            return f64::INFINITY;
        }
        (self.queued + self.inflight) as f64 / self.active as f64
    }
}

/// The least-pressured group: lowest score, then most free KV tokens,
/// then fleet order. `None` when no group has an accepting instance.
pub fn least_pressured(groups: &[GroupPressure]) -> Option<ModelKind> {
    let mut best: Option<&GroupPressure> = None;
    for g in groups {
        if g.active == 0 {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => {
                let (s, bs) = (g.score(), b.score());
                s < bs || (s == bs && g.free_tokens > b.free_tokens)
            }
        };
        if better {
            best = Some(g);
        }
    }
    best.map(|g| g.model)
}

/// The routing layer's state: the policy plus per-agent decision counters
/// driving the deterministic exploration schedule.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    decisions: HashMap<AgentId, u64>,
}

impl Default for Router {
    fn default() -> Self {
        Router::new(RoutePolicy::Pinned)
    }
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, decisions: HashMap::new() }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Whether routing needs the coordinator's group-pressure snapshot
    /// (only the learned policy reads it).
    pub fn wants_pressure(&self) -> bool {
        matches!(self.policy, RoutePolicy::Learned { .. })
    }

    /// Route one request: `static_class` is the affinity stamp, `groups`
    /// the live per-group pressure snapshot (fleet first-seen order).
    pub fn route(
        &mut self,
        req: RequestId,
        agent: AgentId,
        static_class: ModelClass,
        profiler: &DistributionProfiler,
        groups: &[GroupPressure],
    ) -> RouteDecision {
        let RoutePolicy::Learned { explore_rate, min_samples } = self.policy else {
            let reason = match static_class {
                ModelClass::Any => RouteReason::AnyShared,
                ModelClass::Model(_) => RouteReason::Pinned,
            };
            return RouteDecision {
                req,
                agent,
                class: static_class,
                chosen: static_class,
                group: None,
                reason,
            };
        };
        let count = self.decisions.entry(agent).or_insert(0);
        let n = *count;
        *count += 1;
        // Deterministic exploration: every period-th decision (starting
        // with the first, to jump-start sampling) goes to the live family
        // with the fewest samples for this agent.
        if explore_rate > 0.0 {
            let period = (1.0 / explore_rate).ceil().max(1.0) as u64;
            if n % period == 0 {
                if let Some(target) = groups
                    .iter()
                    .filter(|g| g.active > 0)
                    .min_by_key(|g| profiler.family_samples(agent, g.model))
                {
                    return RouteDecision {
                        req,
                        agent,
                        class: static_class,
                        chosen: ModelClass::Model(target.model),
                        group: None,
                        reason: RouteReason::Explore,
                    };
                }
            }
        }
        // Exploit: the live family with the lowest measured mean, among
        // families that have reached min_samples.
        let mut best: Option<(f64, ModelKind)> = None;
        for g in groups {
            if g.active == 0 || profiler.family_samples(agent, g.model) < min_samples {
                continue;
            }
            let Some(mean) = profiler.family_mean_exec(agent, g.model) else { continue };
            // Strict `<` keeps ties deterministic (fleet order wins).
            if best.map(|(b, _)| mean < b).unwrap_or(true) {
                best = Some((mean, g.model));
            }
        }
        if let Some((_, model)) = best {
            return RouteDecision {
                req,
                agent,
                class: static_class,
                chosen: ModelClass::Model(model),
                group: None,
                reason: RouteReason::LearnedBest,
            };
        }
        // Not converged: pinned agents keep their pin; Any agents are
        // balanced into the least-pressured group's shard (class stays
        // Any, so dispatch remains work-conserving).
        match static_class {
            ModelClass::Model(_) => RouteDecision {
                req,
                agent,
                class: static_class,
                chosen: static_class,
                group: None,
                reason: RouteReason::FallbackPin,
            },
            ModelClass::Any => {
                let group = least_pressured(groups);
                let reason = if group.is_some() {
                    RouteReason::LeastPressured
                } else {
                    RouteReason::AnyShared
                };
                RouteDecision {
                    req,
                    agent,
                    class: ModelClass::Any,
                    chosen: ModelClass::Any,
                    group,
                    reason,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M8: ModelKind = ModelKind::Llama3_8B;
    const M13: ModelKind = ModelKind::Llama2_13B;

    fn groups() -> Vec<GroupPressure> {
        vec![
            GroupPressure { model: M8, queued: 0, active: 2, inflight: 0, free_tokens: 100 },
            GroupPressure { model: M13, queued: 0, active: 1, inflight: 0, free_tokens: 50 },
        ]
    }

    #[test]
    fn parse_accepts_both_policies_and_params() {
        assert_eq!(RoutePolicy::parse("pinned").unwrap(), RoutePolicy::Pinned);
        assert_eq!(
            RoutePolicy::parse("learned").unwrap(),
            RoutePolicy::learned_default()
        );
        assert_eq!(
            RoutePolicy::parse("learned:explore=0.2,min_samples=16").unwrap(),
            RoutePolicy::Learned { explore_rate: 0.2, min_samples: 16 }
        );
        assert_eq!(
            RoutePolicy::parse(" learned:min_samples=4 ").unwrap(),
            RoutePolicy::Learned { explore_rate: 0.125, min_samples: 4 }
        );
    }

    #[test]
    fn parse_rejects_garbage_naming_the_clause() {
        assert!(RoutePolicy::parse("").is_err());
        assert!(RoutePolicy::parse("greedy").is_err());
        assert!(RoutePolicy::parse("learnedX").is_err());
        let err = RoutePolicy::parse("learned:explore=2.0").unwrap_err();
        assert!(err.contains("explore=2.0"), "{err}");
        let err = RoutePolicy::parse("learned:min_samples=0").unwrap_err();
        assert!(err.contains("min_samples=0"), "{err}");
        let err = RoutePolicy::parse("learned:banana=1").unwrap_err();
        assert!(err.contains("banana"), "{err}");
        assert!(RoutePolicy::parse("learned:explore=NaN").is_err());
        assert!(RoutePolicy::parse("learned:explore").is_err());
    }

    #[test]
    fn pinned_policy_reproduces_static_stamps() {
        let mut r = Router::new(RoutePolicy::Pinned);
        let pr = DistributionProfiler::new();
        let d = r.route(1, AgentId(0), ModelClass::Model(M13), &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13));
        assert_eq!(d.group, None);
        assert_eq!(d.reason, RouteReason::Pinned);
        let d = r.route(2, AgentId(1), ModelClass::Any, &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Any);
        assert_eq!(d.group, None);
        assert_eq!(d.reason, RouteReason::AnyShared);
    }

    #[test]
    fn learned_falls_back_to_pin_until_sampled() {
        // explore disabled: pure fallback behavior.
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 4 });
        let pr = DistributionProfiler::new();
        let d = r.route(1, AgentId(0), ModelClass::Model(M13), &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13));
        assert_eq!(d.reason, RouteReason::FallbackPin);
    }

    #[test]
    fn learned_picks_the_measured_best_family() {
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 2 });
        let mut pr = DistributionProfiler::new();
        let a = AgentId(0);
        for _ in 0..3 {
            pr.record_family_execution(a, M13, 1.0); // 13B measured faster
            pr.record_family_execution(a, M8, 5.0);
        }
        let d = r.route(1, a, ModelClass::Model(M8), &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13), "pin overridden by data");
        assert_eq!(d.reason, RouteReason::LearnedBest);
        // A family short of min_samples is not eligible even when faster.
        let b = AgentId(1);
        pr.record_family_execution(b, M13, 0.1);
        for _ in 0..2 {
            pr.record_family_execution(b, M8, 5.0);
        }
        let d = r.route(2, b, ModelClass::Any, &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M8));
    }

    #[test]
    fn learned_never_routes_to_a_dead_family() {
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.5, min_samples: 1 });
        let mut pr = DistributionProfiler::new();
        let a = AgentId(0);
        pr.record_family_execution(a, M13, 0.01); // best on paper, but...
        let mut gs = groups();
        gs[1].active = 0; // ...the 13B group has drained away
        for i in 0..6 {
            let d = r.route(i, a, ModelClass::Any, &pr, &gs);
            assert_ne!(d.chosen, ModelClass::Model(M13), "routed to a dead family");
            if let Some(g) = d.group {
                assert_ne!(g, M13);
            }
        }
    }

    #[test]
    fn exploration_fires_on_the_deterministic_schedule() {
        // explore_rate 0.25 => every 4th decision (0, 4, 8, ...) explores.
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.25, min_samples: 99 });
        let pr = DistributionProfiler::new();
        let a = AgentId(0);
        let reasons: Vec<RouteReason> = (0..8)
            .map(|i| r.route(i, a, ModelClass::Model(M8), &pr, &groups()).reason)
            .collect();
        assert_eq!(reasons[0], RouteReason::Explore);
        assert_eq!(reasons[4], RouteReason::Explore);
        assert!(reasons[1..4].iter().all(|&x| x == RouteReason::FallbackPin));
        // Exploration targets the least-sampled live family.
        let mut pr2 = DistributionProfiler::new();
        pr2.record_family_execution(a, M8, 1.0);
        let mut r2 =
            Router::new(RoutePolicy::Learned { explore_rate: 0.9, min_samples: 99 });
        let d = r2.route(0, a, ModelClass::Any, &pr2, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13), "least-sampled family explored");
    }

    #[test]
    fn routing_follows_a_mid_trace_latency_regime_shift_under_decay() {
        // The 13B family serves agent A fast for a long stretch, then its
        // latency regime shifts (co-tenant pressure, model swap) while 8B
        // stays moderate. With a profile half-life the learned stamp must
        // FOLLOW the shift; the all-time mean would keep routing to 13B.
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 4 });
        let a = AgentId(0);
        let mut pr = DistributionProfiler::new();
        pr.set_half_life(Some(10.0));
        for i in 0..100 {
            let t = i as f64 * 0.1;
            pr.record_family_execution_at(a, M13, 0.5, t); // fast era
            pr.record_family_execution_at(a, M8, 2.0, t);
        }
        let d = r.route(1, a, ModelClass::Any, &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13), "pre-shift: 13B measured best");
        // Regime shift: a handful of slow 13B samples, far past the fast
        // era's half-life horizon.
        for i in 0..5 {
            let t = 200.0 + i as f64;
            pr.record_family_execution_at(a, M13, 10.0, t);
            pr.record_family_execution_at(a, M8, 2.0, t);
        }
        let d = r.route(2, a, ModelClass::Any, &pr, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M8), "post-shift: routing followed");
        assert_eq!(d.reason, RouteReason::LearnedBest);
        // Control: the same sample stream WITHOUT decay stays anchored on
        // the stale 13B average (the bug this satellite fixes).
        let mut anchored = DistributionProfiler::new();
        for i in 0..100 {
            let t = i as f64 * 0.1;
            anchored.record_family_execution_at(a, M13, 0.5, t);
            anchored.record_family_execution_at(a, M8, 2.0, t);
        }
        for i in 0..5 {
            let t = 200.0 + i as f64;
            anchored.record_family_execution_at(a, M13, 10.0, t);
            anchored.record_family_execution_at(a, M8, 2.0, t);
        }
        let d = r.route(3, a, ModelClass::Any, &anchored, &groups());
        assert_eq!(d.chosen, ModelClass::Model(M13), "no decay: stale pin persists");
    }

    #[test]
    fn any_balances_to_the_least_pressured_group() {
        let mut r = Router::new(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 9 });
        let pr = DistributionProfiler::new();
        let mut gs = groups();
        gs[0].queued = 10; // 8B backlog: 5 per instance
        gs[1].queued = 1; // 13B backlog: 1 per instance
        let d = r.route(1, AgentId(0), ModelClass::Any, &pr, &gs);
        assert_eq!(d.chosen, ModelClass::Any, "dispatch constraint stays Any");
        assert_eq!(d.group, Some(M13));
        assert_eq!(d.reason, RouteReason::LeastPressured);
        // Ties break toward headroom, then fleet order.
        let gs2 = groups(); // equal scores, 8B has more free tokens
        let d2 = r.route(2, AgentId(0), ModelClass::Any, &pr, &gs2);
        assert_eq!(d2.group, Some(M8));
        // No live group at all: the shared Any shard.
        let dead: Vec<GroupPressure> = groups()
            .into_iter()
            .map(|mut g| {
                g.active = 0;
                g
            })
            .collect();
        let d3 = r.route(3, AgentId(0), ModelClass::Any, &pr, &dead);
        assert_eq!(d3.group, None);
        assert_eq!(d3.reason, RouteReason::AnyShared);
    }
}
