//! AOT artifact manifest: the static shapes the rust runtime validates
//! against before compiling the HLO.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;

/// Parsed `<name>_manifest.json` emitted by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub kv_cache_shape: Vec<usize>,
    pub prefill_hlo: PathBuf,
    pub decode_hlo: PathBuf,
}

impl Manifest {
    /// Load `<dir>/<name>_manifest.json`.
    pub fn load(dir: &Path, name: &str) -> crate::Result<Manifest> {
        let path = dir.join(format!("{name}_manifest.json"));
        // kairos-lint: allow(no-env-fs, manifest loading is this type's contract; callers pass explicit dirs)
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest json: {e}"))?;

        let field = |k: &str| -> crate::Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing numeric field {k:?}"))
        };
        let sfield = |k: &str| -> crate::Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing string field {k:?}"))?
                .to_string())
        };

        let kv_cache_shape: Vec<usize> = j
            .get("kv_cache_shape")
            .and_then(Json::as_arr)
            .context("manifest missing kv_cache_shape")?
            .iter()
            .map(|v| v.as_usize().context("bad kv shape entry"))
            .collect::<crate::Result<_>>()?;

        let m = Manifest {
            name: sfield("name")?,
            vocab_size: field("vocab_size")?,
            d_model: field("d_model")?,
            n_layers: field("n_layers")?,
            n_heads: field("n_heads")?,
            head_dim: field("head_dim")?,
            d_ff: field("d_ff")?,
            max_seq: field("max_seq")?,
            batch: field("batch")?,
            kv_cache_shape,
            prefill_hlo: dir.join(sfield("prefill_hlo")?),
            decode_hlo: dir.join(sfield("decode_hlo")?),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> crate::Result<()> {
        let want = vec![
            self.n_layers, 2, self.batch, self.max_seq, self.n_heads, self.head_dim,
        ];
        if self.kv_cache_shape != want {
            bail!(
                "kv_cache_shape {:?} inconsistent with scalar fields (want {:?})",
                self.kv_cache_shape,
                want
            );
        }
        if !self.prefill_hlo.exists() || !self.decode_hlo.exists() {
            bail!("HLO artifacts missing next to manifest (run `make artifacts`)");
        }
        Ok(())
    }

    /// Flat element count of the KV cache.
    pub fn kv_elems(&self) -> usize {
        self.kv_cache_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_built_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("tiny_manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir, "tiny").unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.kv_cache_shape.len(), 6);
        assert_eq!(m.kv_elems() % m.batch, 0);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir();
        assert!(Manifest::load(&dir, "no_such_model").is_err());
    }

    #[test]
    fn inconsistent_shape_rejected() {
        let dir = std::env::temp_dir().join("kairos_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad_prefill.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("bad_decode.hlo.txt"), "x").unwrap();
        std::fs::write(
            dir.join("bad_manifest.json"),
            r#"{"name":"bad","vocab_size":8,"d_model":4,"n_layers":1,"n_heads":1,
                "head_dim":4,"d_ff":8,"max_seq":4,"batch":1,
                "kv_cache_shape":[9,9,9,9,9,9],
                "prefill_hlo":"bad_prefill.hlo.txt","decode_hlo":"bad_decode.hlo.txt"}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir, "bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
