//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! The python side (`python/compile/aot.py`) lowers the tiny served LM to
//! HLO **text** once at build time; this module loads that text, compiles it
//! on the PJRT CPU client, and drives prefill/decode from the rust hot path.
//! Python never runs at serving time.

pub mod manifest;
pub mod model;
pub mod tokenizer;

pub use manifest::Manifest;
pub use model::{DecodeOut, PrefillOut, TinyModel};
pub use tokenizer::ByteTokenizer;
