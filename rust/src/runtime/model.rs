//! The served model: compiled prefill/decode executables over PJRT.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Both entry points return a 3-tuple
//! `(logits, next_token, kv_cache)`; the KV cache is threaded functionally
//! by the caller between calls.

use std::path::Path;

use anyhow::Context;

use super::manifest::Manifest;

/// Output of a prefill call.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// (B, V) logits for the next token of every row.
    pub logits: Vec<f32>,
    /// (B,) greedy next token per row.
    pub next_token: Vec<i32>,
    /// Flat KV cache to thread into the next decode call.
    pub kv_cache: Vec<f32>,
}

/// Output of a decode step.
pub type DecodeOut = PrefillOut;

/// A loaded, compiled tiny LM bound to a PJRT client.
pub struct TinyModel {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
}

impl TinyModel {
    /// Load artifacts `<dir>/<name>_{prefill,decode}.hlo.txt` and compile.
    pub fn load(dir: &Path, name: &str) -> crate::Result<TinyModel> {
        let manifest = Manifest::load(dir, name)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &Path| -> crate::Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp).with_context(|| format!("compiling {path:?}"))?)
        };
        let prefill_exe = compile(&manifest.prefill_hlo)?;
        let decode_exe = compile(&manifest.decode_hlo)?;
        Ok(TinyModel { manifest, client, prefill_exe, decode_exe })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fresh zeroed flat KV cache.
    pub fn empty_kv(&self) -> Vec<f32> {
        vec![0.0; self.manifest.kv_elems()]
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        tokens: xla::Literal,
        seq_lens: &[i32],
        kv_cache: &[f32],
    ) -> crate::Result<PrefillOut> {
        let m = &self.manifest;
        anyhow::ensure!(seq_lens.len() == m.batch, "seq_lens must be (batch,)");
        anyhow::ensure!(kv_cache.len() == m.kv_elems(), "kv cache size mismatch");
        let lens = xla::Literal::vec1(seq_lens);
        let kv_dims: Vec<i64> = m.kv_cache_shape.iter().map(|&d| d as i64).collect();
        let kv = xla::Literal::vec1(kv_cache).reshape(&kv_dims)?;

        let result = exe.execute::<xla::Literal>(&[tokens, lens, kv])?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True: (logits, next_token, kv_cache).
        let (logits_l, next_l, kv_l) = result.to_tuple3()?;
        Ok(PrefillOut {
            logits: logits_l.to_vec::<f32>()?,
            next_token: next_l.to_vec::<i32>()?,
            kv_cache: kv_l.to_vec::<f32>()?,
        })
    }

    /// Prefill: `tokens` is (B * S) row-major padded prompts.
    pub fn prefill(
        &self,
        tokens: &[i32],
        seq_lens: &[i32],
        kv_cache: &[f32],
    ) -> crate::Result<PrefillOut> {
        let m = &self.manifest;
        anyhow::ensure!(
            tokens.len() == m.batch * m.max_seq,
            "prefill tokens must be (batch * max_seq)"
        );
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[m.batch as i64, m.max_seq as i64])?;
        self.run(&self.prefill_exe, lit, seq_lens, kv_cache)
    }

    /// Decode one token per row. `seq_lens[b]` = valid cache rows before
    /// this token (the position the token is written to).
    pub fn decode(
        &self,
        tokens: &[i32],
        seq_lens: &[i32],
        kv_cache: &[f32],
    ) -> crate::Result<DecodeOut> {
        let m = &self.manifest;
        anyhow::ensure!(tokens.len() == m.batch, "decode tokens must be (batch,)");
        let lit = xla::Literal::vec1(tokens);
        self.run(&self.decode_exe, lit, seq_lens, kv_cache)
    }

    /// Greedy-generate `steps` tokens after prefilling `prompts` (one vec of
    /// tokens per row; rows beyond `prompts.len()` are padded). Returns the
    /// generated tokens per row. Convenience for examples/tests.
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> crate::Result<Vec<Vec<i32>>> {
        let m = &self.manifest;
        anyhow::ensure!(prompts.len() <= m.batch, "too many prompts for batch");
        anyhow::ensure!(
            prompts.iter().all(|p| !p.is_empty() && p.len() <= m.max_seq / 2),
            "prompts must be non-empty and fit half the context"
        );
        let mut tokens = vec![0i32; m.batch * m.max_seq];
        let mut lens = vec![1i32; m.batch]; // padded rows run with len 1
        for (b, p) in prompts.iter().enumerate() {
            tokens[b * m.max_seq..b * m.max_seq + p.len()].copy_from_slice(p);
            lens[b] = p.len() as i32;
        }
        let out = self.prefill(&tokens, &lens, &self.empty_kv())?;
        let mut kv = out.kv_cache;
        let mut cur = out.next_token;
        let mut generated: Vec<Vec<i32>> = vec![vec![]; prompts.len()];
        for (b, g) in generated.iter_mut().enumerate() {
            g.push(cur[b]);
        }
        for _ in 1..steps {
            let out = self.decode(&cur, &lens, &kv)?;
            kv = out.kv_cache;
            cur = out.next_token;
            for l in lens.iter_mut() {
                *l = (*l + 1).min(m.max_seq as i32 - 1);
            }
            for (b, g) in generated.iter_mut().enumerate() {
                g.push(cur[b]);
            }
        }
        Ok(generated)
    }
}
