//! Byte-level tokenizer for the tiny served model (vocab = 256 bytes).
//!
//! Real deployments use BPE; the serving experiments only care about token
//! *counts*, so bytes are the faithful minimal choice and keep the runtime
//! dependency-free.

/// Maps text to byte tokens and back, clamping to the model vocabulary.
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    vocab_size: usize,
}

impl ByteTokenizer {
    pub fn new(vocab_size: usize) -> ByteTokenizer {
        assert!(vocab_size >= 2);
        ByteTokenizer { vocab_size }
    }

    /// Encode text; bytes outside the vocab are folded into range.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| (b as usize % self.vocab_size) as i32).collect()
    }

    /// Decode tokens to a lossy string (non-printable bytes become '?').
    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                let b = (t.max(0) as usize % self.vocab_size) as u8;
                if (0x20..0x7f).contains(&b) {
                    b as char
                } else {
                    '?'
                }
            })
            .collect()
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trips() {
        let t = ByteTokenizer::new(256);
        let s = "Solve 17 * 23 step by step.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn folds_into_small_vocab() {
        let t = ByteTokenizer::new(64);
        for tok in t.encode("hello, world ΩΩ") {
            assert!((0..64).contains(&tok));
        }
    }

    #[test]
    fn length_preserved() {
        let t = ByteTokenizer::new(256);
        assert_eq!(t.encode("abcd").len(), 4);
    }
}
