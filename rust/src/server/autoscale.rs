//! Elastic fleet autoscaling policy.
//!
//! The paper's public-cloud setting pairs excessive, bursty loads with
//! capacity that is *rented*, not fixed: when the central queue deepens
//! past what the active instances can drain, the operator adds instances;
//! when the burst passes, surplus instances are drained and released. This
//! module is the pure decision layer — queue-depth and queuing-ratio
//! thresholds with hysteresis, min/max fleet bounds and a cooldown — while
//! the mechanics (registering engines live, draining in-flight work) live
//! in [`super::coordinator::Coordinator::add_instance`] /
//! [`retire_instance`](super::coordinator::Coordinator::retire_instance).
//! The coordinator consults the autoscaler on every periodic
//! [`refresh`](super::coordinator::Coordinator::refresh), so decisions are
//! deterministic functions of the observed serving state — the
//! driver-equivalence contract (`tests/runtime_seam.rs`) extends to scale
//! events.

use super::coordinator::InstanceSpec;
use crate::engine::cost_model::ModelKind;
use crate::Time;

/// Thresholds and bounds of the autoscaling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Never drain below this many active instances.
    pub min_instances: usize,
    /// Never grow above this many active instances.
    pub max_instances: usize,
    /// Scale up when queued requests per active instance exceed this.
    pub queue_high: f64,
    /// Scale down only when queued requests per active instance fall
    /// below this (kept well under `queue_high`: the gap is the hysteresis
    /// band that stops grow/shrink flapping on a noisy queue).
    pub queue_low: f64,
    /// Queuing-time ratio (queue wait share of stage e2e, the paper's load
    /// calibration metric) that also triggers scale-up.
    pub ratio_high: f64,
    /// Consecutive hot observations required before growing.
    pub up_after: u32,
    /// Consecutive cold observations required before shrinking (higher
    /// than `up_after` by default: growing is urgent, shrinking is not).
    pub down_after: u32,
    /// Minimum time between scale actions (seconds).
    pub cooldown: f64,
    /// Spec for newly added instances.
    pub template: InstanceSpec,
}

impl AutoscaleConfig {
    /// Conservative defaults around `template` for new instances.
    pub fn for_template(template: InstanceSpec) -> AutoscaleConfig {
        AutoscaleConfig {
            min_instances: 1,
            max_instances: 8,
            queue_high: 8.0,
            queue_low: 1.0,
            ratio_high: 0.5,
            up_after: 1,
            down_after: 3,
            cooldown: 10.0,
            template,
        }
    }
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig::for_template(
            InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12),
        )
    }
}

/// Load of one model-affine serving group (all instances of one family).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupLoad {
    pub model: ModelKind,
    /// Requests queued in this group's shard (pinned to this family; the
    /// `Any` shard is accounted only in the aggregate queue depth).
    pub queue_len: usize,
    /// Instances of this family currently accepting dispatches.
    pub active_instances: usize,
}

/// What the autoscaler sees at one observation point.
#[derive(Debug, Clone, Default)]
pub struct FleetObservation {
    /// Depth of the central scheduling queue (all shards).
    pub queue_len: usize,
    /// Instances currently accepting dispatches.
    pub active_instances: usize,
    /// Instances draining toward retirement. Capacity that is already on
    /// its way out: while any drain is in flight, `Shrink` is withheld so
    /// the fleet sheds at most one instance per completed drain.
    pub draining_instances: usize,
    /// Mean queuing-time ratio of requests finished since the previous
    /// observation (0 when none finished).
    pub recent_queue_ratio: f64,
    /// Whether the fleet can actually grow (it has a backend factory).
    /// When false, `Grow` is never emitted — otherwise a factory-less
    /// fleet would record phantom grows and burn the cooldown on actions
    /// that cannot be applied.
    pub can_grow: bool,
    /// Per-group queue-depth signals, in fleet-index first-seen order.
    /// When a grow fires, the most-starved group's model is grown.
    pub groups: Vec<GroupLoad>,
}

/// A scale decision. The coordinator maps `Grow` to a concrete instance
/// spec for the named model family, and `Shrink` to a concrete instance
/// (the highest-index active one, deterministically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add an instance serving this model family.
    Grow(ModelKind),
    Shrink,
}

/// Threshold-with-hysteresis autoscaler.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    hot_streak: u32,
    cold_streak: u32,
    last_action: Time,
    /// Diagnostics.
    pub grows: u64,
    pub shrinks: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            hot_streak: 0,
            cold_streak: 0,
            last_action: f64::NEG_INFINITY,
            grows: 0,
            shrinks: 0,
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// The model family to grow: the group with the deepest pinned backlog
    /// per active instance. Any-only workloads (no per-group backlog) fall
    /// back to the template's model — the homogeneous behavior. Strict
    /// `>` keeps ties deterministic (first group in fleet order wins).
    fn starved_group(&self, obs: &FleetObservation) -> ModelKind {
        let mut best: Option<(f64, ModelKind)> = None;
        for g in &obs.groups {
            if g.queue_len == 0 {
                continue;
            }
            let pressure = g.queue_len as f64 / g.active_instances.max(1) as f64;
            let better = match best {
                None => true,
                Some((bp, _)) => pressure > bp,
            };
            if better {
                best = Some((pressure, g.model));
            }
        }
        best.map(|(_, m)| m).unwrap_or(self.cfg.template.model)
    }

    /// Feed one observation; returns the action to take now, if any.
    pub fn observe(&mut self, obs: &FleetObservation, now: Time) -> Option<ScaleAction> {
        let per_instance = obs.queue_len as f64 / obs.active_instances.max(1) as f64;
        let hot =
            per_instance > self.cfg.queue_high || obs.recent_queue_ratio > self.cfg.ratio_high;
        let cold = per_instance < self.cfg.queue_low
            && obs.recent_queue_ratio < self.cfg.ratio_high * 0.5;
        if hot {
            self.hot_streak += 1;
            self.cold_streak = 0;
        } else if cold {
            self.cold_streak += 1;
            self.hot_streak = 0;
        } else {
            // Inside the hysteresis band: hold position.
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
        if now - self.last_action < self.cfg.cooldown {
            return None;
        }
        if self.hot_streak >= self.cfg.up_after
            && obs.can_grow
            && obs.active_instances < self.cfg.max_instances
        {
            self.last_action = now;
            self.hot_streak = 0;
            self.grows += 1;
            return Some(ScaleAction::Grow(self.starved_group(obs)));
        }
        if self.cold_streak >= self.cfg.down_after
            && obs.active_instances > self.cfg.min_instances
            && obs.draining_instances == 0
        {
            self.last_action = now;
            self.cold_streak = 0;
            self.shrinks += 1;
            return Some(ScaleAction::Shrink);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_instances: 1,
            max_instances: 4,
            queue_high: 8.0,
            queue_low: 1.0,
            ratio_high: 0.5,
            up_after: 1,
            down_after: 2,
            cooldown: 10.0,
            template: InstanceSpec::new(ModelKind::Llama3_8B),
        }
    }

    fn obs(queue: usize, active: usize, ratio: f64) -> FleetObservation {
        FleetObservation {
            queue_len: queue,
            active_instances: active,
            draining_instances: 0,
            recent_queue_ratio: ratio,
            can_grow: true,
            groups: Vec::new(),
        }
    }

    const GROW_8B: ScaleAction = ScaleAction::Grow(ModelKind::Llama3_8B);

    #[test]
    fn grows_on_deep_queue_and_respects_max() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(40, 2, 0.0), 0.0), Some(GROW_8B));
        // At the max bound a hot fleet cannot grow further.
        assert_eq!(a.observe(&obs(80, 4, 0.9), 100.0), None);
        assert_eq!(a.grows, 1);
    }

    #[test]
    fn queue_ratio_alone_triggers_growth() {
        let mut a = Autoscaler::new(cfg());
        // Shallow queue but requests spend 80% of their life queued.
        assert_eq!(a.observe(&obs(2, 2, 0.8), 0.0), Some(GROW_8B));
    }

    #[test]
    fn grow_targets_the_starved_group() {
        let mut a = Autoscaler::new(cfg());
        let mut o = obs(40, 2, 0.0);
        o.groups = vec![
            GroupLoad { model: ModelKind::Llama3_8B, queue_len: 2, active_instances: 1 },
            GroupLoad { model: ModelKind::Llama2_13B, queue_len: 30, active_instances: 1 },
        ];
        assert_eq!(
            a.observe(&o, 0.0),
            Some(ScaleAction::Grow(ModelKind::Llama2_13B)),
            "the deepest pinned backlog picks the family to grow"
        );
        // An Any-only workload (no pinned backlog) grows the template.
        let mut b = Autoscaler::new(cfg());
        let mut o2 = obs(40, 2, 0.0);
        o2.groups = vec![
            GroupLoad { model: ModelKind::Llama3_8B, queue_len: 0, active_instances: 2 },
            GroupLoad { model: ModelKind::Llama2_13B, queue_len: 0, active_instances: 1 },
        ];
        assert_eq!(b.observe(&o2, 0.0), Some(GROW_8B));
    }

    #[test]
    fn cooldown_blocks_back_to_back_actions() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(40, 2, 0.0), 0.0), Some(GROW_8B));
        assert_eq!(a.observe(&obs(40, 3, 0.0), 5.0), None, "inside cooldown");
        assert_eq!(a.observe(&obs(40, 3, 0.0), 10.0), Some(GROW_8B));
    }

    #[test]
    fn shrink_needs_a_cold_streak_and_respects_min() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(0, 3, 0.0), 0.0), None, "one cold tick is not enough");
        assert_eq!(a.observe(&obs(0, 3, 0.0), 5.0), Some(ScaleAction::Shrink));
        // A fleet already at the min bound never shrinks.
        let mut b = Autoscaler::new(cfg());
        assert_eq!(b.observe(&obs(0, 1, 0.0), 0.0), None);
        assert_eq!(b.observe(&obs(0, 1, 0.0), 5.0), None);
        assert_eq!(b.observe(&obs(0, 1, 0.0), 15.0), None);
    }

    #[test]
    fn shrink_waits_for_the_previous_drain_to_finish() {
        let mut a = Autoscaler::new(cfg());
        let mut cold = obs(0, 3, 0.0);
        cold.draining_instances = 1;
        assert_eq!(a.observe(&cold, 0.0), None);
        assert_eq!(a.observe(&cold, 5.0), None, "drain in flight blocks shrink");
        // Drain completed: the (still accumulated) cold streak fires.
        assert_eq!(a.observe(&obs(0, 3, 0.0), 10.0), Some(ScaleAction::Shrink));
    }

    #[test]
    fn factory_less_fleet_never_emits_grow_or_burns_cooldown() {
        let mut a = Autoscaler::new(cfg());
        let hot = FleetObservation { can_grow: false, ..obs(40, 2, 0.9) };
        assert_eq!(a.observe(&hot, 0.0), None);
        assert_eq!(a.observe(&hot, 20.0), None);
        assert_eq!(a.grows, 0, "no phantom grows recorded");
        // The cooldown was never consumed: a later genuine shrink signal
        // fires as soon as its streak completes.
        assert_eq!(a.observe(&obs(0, 3, 0.0), 25.0), None);
        assert_eq!(a.observe(&obs(0, 3, 0.0), 30.0), Some(ScaleAction::Shrink));
    }

    #[test]
    fn hysteresis_band_holds_position() {
        let mut a = Autoscaler::new(cfg());
        // Between queue_low and queue_high: neither streak accumulates.
        for t in 0..10 {
            assert_eq!(a.observe(&obs(4, 2, 0.1), t as f64 * 5.0), None);
        }
        assert_eq!(a.grows + a.shrinks, 0);
    }

    #[test]
    fn mid_band_observation_resets_cold_streak() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(0, 3, 0.0), 0.0), None); // cold 1
        assert_eq!(a.observe(&obs(6, 3, 0.1), 5.0), None); // band: reset
        assert_eq!(a.observe(&obs(0, 3, 0.0), 10.0), None, "cold streak restarted");
        assert_eq!(a.observe(&obs(0, 3, 0.0), 15.0), Some(ScaleAction::Shrink));
    }
}
