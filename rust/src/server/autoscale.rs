//! Elastic fleet autoscaling policy.
//!
//! The paper's public-cloud setting pairs excessive, bursty loads with
//! capacity that is *rented*, not fixed: when the central queue deepens
//! past what the active instances can drain, the operator adds instances;
//! when the burst passes, surplus instances are drained and released. This
//! module is the pure decision layer — queue-depth and queuing-ratio
//! thresholds with hysteresis, min/max fleet bounds and a cooldown — while
//! the mechanics (registering engines live, draining in-flight work) live
//! in [`super::coordinator::Coordinator::add_instance`] /
//! [`retire_instance`](super::coordinator::Coordinator::retire_instance).
//! The coordinator consults the autoscaler on every periodic
//! [`refresh`](super::coordinator::Coordinator::refresh), so decisions are
//! deterministic functions of the observed serving state — the
//! driver-equivalence contract (`tests/runtime_seam.rs`) extends to scale
//! events.

use super::coordinator::InstanceSpec;
use crate::engine::cost_model::ModelKind;
use crate::Time;

/// Per-family instance bounds: a learned-hot family must not starve the
/// other serving groups of slots, and a family the operator wants warm
/// must keep a floor. Families absent from the list are unbounded (within
/// the fleet-wide bounds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupBounds {
    pub model: ModelKind,
    /// Never drain the family below this many active instances.
    pub min_instances: usize,
    /// Never grow the family above this many active + booting instances.
    pub max_instances: usize,
}

/// Parse per-group bounds from a compact config string.
///
/// Grammar: comma-separated `MODEL=MIN..MAX`, e.g.
/// `llama3-8b=1..4,llama2-13b=0..2`. `MODEL=0..0` freezes a family (it
/// can drain away and never grows back).
pub fn parse_per_group(s: &str) -> Result<Vec<GroupBounds>, String> {
    if s.trim().is_empty() {
        return Err("empty per-group bounds spec".to_string());
    }
    let mut out: Vec<GroupBounds> = Vec::new();
    for raw in s.split(',') {
        let clause = raw.trim();
        if clause.is_empty() {
            return Err(format!("empty per-group clause in {s:?}"));
        }
        let (m, range) = clause
            .split_once('=')
            .ok_or_else(|| format!("expected MODEL=MIN..MAX in {clause:?}"))?;
        let model = ModelKind::parse(m.trim())
            .map_err(|e| format!("{e} in per-group clause {clause:?}"))?;
        let (lo, hi) = range
            .split_once("..")
            .ok_or_else(|| format!("expected MIN..MAX in {clause:?}"))?;
        let min: usize = lo
            .trim()
            .parse()
            .map_err(|_| format!("bad min in per-group clause {clause:?}"))?;
        let max: usize = hi
            .trim()
            .parse()
            .map_err(|_| format!("bad max in per-group clause {clause:?}"))?;
        if min > max {
            return Err(format!("min exceeds max in per-group clause {clause:?}"));
        }
        if out.iter().any(|b| b.model == model) {
            return Err(format!(
                "duplicate per-group bounds for {} in clause {clause:?}",
                model.name()
            ));
        }
        out.push(GroupBounds { model, min_instances: min, max_instances: max });
    }
    Ok(out)
}

/// Parse per-family boot delays from a compact config string.
///
/// Grammar: comma-separated `MODEL=SECS`, e.g.
/// `llama3-8b=2,llama2-13b=12.5` — big-model families provision slower
/// than small ones. Families absent from the list fall back to the global
/// scalar [`AutoscaleConfig::boot_delay`].
pub fn parse_boot_delays(s: &str) -> Result<Vec<(ModelKind, f64)>, String> {
    if s.trim().is_empty() {
        return Err("empty boot-delay spec".to_string());
    }
    let mut out: Vec<(ModelKind, f64)> = Vec::new();
    for raw in s.split(',') {
        let clause = raw.trim();
        if clause.is_empty() {
            return Err(format!("empty boot-delay clause in {s:?}"));
        }
        let (m, secs) = clause
            .split_once('=')
            .ok_or_else(|| format!("expected MODEL=SECS in {clause:?}"))?;
        let model = ModelKind::parse(m.trim())
            .map_err(|e| format!("{e} in boot-delay clause {clause:?}"))?;
        let secs: f64 = secs
            .trim()
            .parse()
            .map_err(|_| format!("bad seconds in boot-delay clause {clause:?}"))?;
        if !secs.is_finite() || secs < 0.0 {
            return Err(format!(
                "boot delay must be a non-negative finite number in {clause:?}"
            ));
        }
        if out.iter().any(|(b, _)| *b == model) {
            return Err(format!(
                "duplicate boot delay for {} in clause {clause:?}",
                model.name()
            ));
        }
        out.push((model, secs));
    }
    Ok(out)
}

/// Thresholds and bounds of the autoscaling policy.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Never drain below this many active instances.
    pub min_instances: usize,
    /// Never grow above this many active instances.
    pub max_instances: usize,
    /// Scale up when queued requests per active instance exceed this.
    pub queue_high: f64,
    /// Scale down only when queued requests per active instance fall
    /// below this (kept well under `queue_high`: the gap is the hysteresis
    /// band that stops grow/shrink flapping on a noisy queue).
    pub queue_low: f64,
    /// Queuing-time ratio (queue wait share of stage e2e, the paper's load
    /// calibration metric) that also triggers scale-up.
    pub ratio_high: f64,
    /// Consecutive hot observations required before growing.
    pub up_after: u32,
    /// Consecutive cold observations required before shrinking (higher
    /// than `up_after` by default: growing is urgent, shrinking is not).
    pub down_after: u32,
    /// Minimum time between scale actions (seconds).
    pub cooldown: f64,
    /// Boot latency of a grown instance (seconds): a `Grow` action only
    /// *provisions* the slot; the coordinator registers it live once the
    /// delay elapses. 0 = instant registration (the pre-boot-model
    /// behavior). Per-family overrides in [`Self::boot_delay_per_group`]
    /// win; this scalar is the fallback.
    pub boot_delay: f64,
    /// Per-family boot delays (`MODEL=SECS,...` via
    /// [`parse_boot_delays`]): big-model families provision slower than
    /// small ones. Families absent here use the scalar `boot_delay`.
    pub boot_delay_per_group: Vec<(ModelKind, f64)>,
    /// Per-family min/max bounds (empty = every family unbounded within
    /// the fleet-wide bounds above).
    pub per_group: Vec<GroupBounds>,
    /// Spec for newly added instances.
    pub template: InstanceSpec,
}

impl AutoscaleConfig {
    /// Conservative defaults around `template` for new instances.
    pub fn for_template(template: InstanceSpec) -> AutoscaleConfig {
        AutoscaleConfig {
            min_instances: 1,
            max_instances: 8,
            queue_high: 8.0,
            queue_low: 1.0,
            ratio_high: 0.5,
            up_after: 1,
            down_after: 3,
            cooldown: 10.0,
            boot_delay: 0.0,
            boot_delay_per_group: Vec::new(),
            per_group: Vec::new(),
            template,
        }
    }

    /// The boot delay for growing one instance of `model`: the family's
    /// own entry when configured, the global scalar otherwise.
    pub fn boot_delay_for(&self, model: ModelKind) -> f64 {
        self.boot_delay_per_group
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(self.boot_delay, |(_, secs)| *secs)
    }

    /// The family's active-instance floor (0 when unbounded).
    pub fn family_min(&self, model: ModelKind) -> usize {
        self.per_group
            .iter()
            .find(|b| b.model == model)
            .map_or(0, |b| b.min_instances)
    }

    /// The family's instance ceiling (`usize::MAX` when unbounded).
    pub fn family_max(&self, model: ModelKind) -> usize {
        self.per_group
            .iter()
            .find(|b| b.model == model)
            .map_or(usize::MAX, |b| b.max_instances)
    }
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig::for_template(
            InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12),
        )
    }
}

/// Load of one model-affine serving group (all instances of one family).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupLoad {
    pub model: ModelKind,
    /// Requests queued toward this group: its pinned shard plus its
    /// routed-`Any` shard (the shared `Any` shard is accounted only in
    /// the aggregate queue depth).
    pub queue_len: usize,
    /// Instances of this family currently accepting dispatches.
    pub active_instances: usize,
    /// Instances of this family provisioned but still booting
    /// (`boot_delay`): capacity already on its way, counted against the
    /// family's ceiling.
    pub pending_instances: usize,
}

/// What the autoscaler sees at one observation point.
#[derive(Debug, Clone, Default)]
pub struct FleetObservation {
    /// Depth of the central scheduling queue (all shards).
    pub queue_len: usize,
    /// Instances currently accepting dispatches.
    pub active_instances: usize,
    /// Instances draining toward retirement. Capacity that is already on
    /// its way out: while any drain is in flight, `Shrink` is withheld so
    /// the fleet sheds at most one instance per completed drain.
    pub draining_instances: usize,
    /// Instances provisioned but still booting (`boot_delay`): capacity
    /// already on its way in, counted against `max_instances` so the
    /// scaler does not over-provision during the boot window.
    pub pending_instances: usize,
    /// Mean queuing-time ratio of requests finished since the previous
    /// observation (0 when none finished).
    pub recent_queue_ratio: f64,
    /// Whether the fleet can actually grow (it has a backend factory).
    /// When false, `Grow` is never emitted — otherwise a factory-less
    /// fleet would record phantom grows and burn the cooldown on actions
    /// that cannot be applied.
    pub can_grow: bool,
    /// Per-group queue-depth signals, in fleet-index first-seen order.
    /// When a grow fires, the most-starved group's model is grown.
    pub groups: Vec<GroupLoad>,
}

/// A scale decision. The coordinator maps `Grow` to a concrete instance
/// spec for the named model family, and `Shrink` to a concrete instance
/// (the highest-index active one, deterministically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add an instance serving this model family.
    Grow(ModelKind),
    Shrink,
}

/// Threshold-with-hysteresis autoscaler.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    hot_streak: u32,
    cold_streak: u32,
    last_action: Time,
    /// Diagnostics.
    pub grows: u64,
    pub shrinks: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            hot_streak: 0,
            cold_streak: 0,
            last_action: f64::NEG_INFINITY,
            grows: 0,
            shrinks: 0,
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Whether family `model` may still grow under its per-group ceiling
    /// (active + booting instances count against it).
    fn family_can_grow(&self, obs: &FleetObservation, model: ModelKind) -> bool {
        let (active, pending) = obs
            .groups
            .iter()
            .find(|g| g.model == model)
            .map(|g| (g.active_instances, g.pending_instances))
            .unwrap_or((0, 0));
        active + pending < self.cfg.family_max(model)
    }

    /// The model family to grow: the group with the deepest pinned backlog
    /// per active instance, among families below their per-group ceiling.
    /// Any-only workloads (no per-group backlog) fall back to the
    /// template's model — the homogeneous behavior. Strict `>` keeps ties
    /// deterministic (first group in fleet order wins). `None` when every
    /// candidate family is at its ceiling.
    fn starved_group(&self, obs: &FleetObservation) -> Option<ModelKind> {
        let mut best: Option<(f64, ModelKind)> = None;
        for g in &obs.groups {
            if g.queue_len == 0 || !self.family_can_grow(obs, g.model) {
                continue;
            }
            let pressure = g.queue_len as f64 / g.active_instances.max(1) as f64;
            let better = match best {
                None => true,
                Some((bp, _)) => pressure > bp,
            };
            if better {
                best = Some((pressure, g.model));
            }
        }
        if let Some((_, m)) = best {
            return Some(m);
        }
        self.family_can_grow(obs, self.cfg.template.model)
            .then_some(self.cfg.template.model)
    }

    /// Whether any family still sits above its per-group floor — a
    /// `Shrink` the coordinator could not map to a victim must not fire
    /// (it would burn the cooldown on a no-op).
    fn any_family_shrinkable(&self, obs: &FleetObservation) -> bool {
        if self.cfg.per_group.is_empty() || obs.groups.is_empty() {
            return true;
        }
        obs.groups
            .iter()
            .any(|g| g.active_instances > self.cfg.family_min(g.model))
    }

    /// Feed one observation; returns the action to take now, if any.
    pub fn observe(&mut self, obs: &FleetObservation, now: Time) -> Option<ScaleAction> {
        let per_instance = obs.queue_len as f64 / obs.active_instances.max(1) as f64;
        let hot =
            per_instance > self.cfg.queue_high || obs.recent_queue_ratio > self.cfg.ratio_high;
        let cold = per_instance < self.cfg.queue_low
            && obs.recent_queue_ratio < self.cfg.ratio_high * 0.5;
        if hot {
            self.hot_streak += 1;
            self.cold_streak = 0;
        } else if cold {
            self.cold_streak += 1;
            self.hot_streak = 0;
        } else {
            // Inside the hysteresis band: hold position.
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
        if now - self.last_action < self.cfg.cooldown {
            return None;
        }
        if self.hot_streak >= self.cfg.up_after
            && obs.can_grow
            && obs.active_instances + obs.pending_instances < self.cfg.max_instances
        {
            // When every candidate family is at its per-group ceiling the
            // grow is withheld WITHOUT burning the cooldown or the streak's
            // history (same contract as `can_grow: false`).
            if let Some(model) = self.starved_group(obs) {
                self.last_action = now;
                self.hot_streak = 0;
                self.grows += 1;
                return Some(ScaleAction::Grow(model));
            }
        }
        if self.cold_streak >= self.cfg.down_after
            && obs.active_instances > self.cfg.min_instances
            && obs.draining_instances == 0
            && self.any_family_shrinkable(obs)
        {
            self.last_action = now;
            self.cold_streak = 0;
            self.shrinks += 1;
            return Some(ScaleAction::Shrink);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_instances: 1,
            max_instances: 4,
            queue_high: 8.0,
            queue_low: 1.0,
            ratio_high: 0.5,
            up_after: 1,
            down_after: 2,
            cooldown: 10.0,
            boot_delay: 0.0,
            boot_delay_per_group: Vec::new(),
            per_group: Vec::new(),
            template: InstanceSpec::new(ModelKind::Llama3_8B),
        }
    }

    fn obs(queue: usize, active: usize, ratio: f64) -> FleetObservation {
        FleetObservation {
            queue_len: queue,
            active_instances: active,
            draining_instances: 0,
            pending_instances: 0,
            recent_queue_ratio: ratio,
            can_grow: true,
            groups: Vec::new(),
        }
    }

    fn gl(model: ModelKind, queue_len: usize, active: usize) -> GroupLoad {
        GroupLoad { model, queue_len, active_instances: active, pending_instances: 0 }
    }

    const GROW_8B: ScaleAction = ScaleAction::Grow(ModelKind::Llama3_8B);

    #[test]
    fn grows_on_deep_queue_and_respects_max() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(40, 2, 0.0), 0.0), Some(GROW_8B));
        // At the max bound a hot fleet cannot grow further.
        assert_eq!(a.observe(&obs(80, 4, 0.9), 100.0), None);
        assert_eq!(a.grows, 1);
    }

    #[test]
    fn queue_ratio_alone_triggers_growth() {
        let mut a = Autoscaler::new(cfg());
        // Shallow queue but requests spend 80% of their life queued.
        assert_eq!(a.observe(&obs(2, 2, 0.8), 0.0), Some(GROW_8B));
    }

    #[test]
    fn grow_targets_the_starved_group() {
        let mut a = Autoscaler::new(cfg());
        let mut o = obs(40, 2, 0.0);
        o.groups = vec![
            gl(ModelKind::Llama3_8B, 2, 1),
            gl(ModelKind::Llama2_13B, 30, 1),
        ];
        assert_eq!(
            a.observe(&o, 0.0),
            Some(ScaleAction::Grow(ModelKind::Llama2_13B)),
            "the deepest pinned backlog picks the family to grow"
        );
        // An Any-only workload (no pinned backlog) grows the template.
        let mut b = Autoscaler::new(cfg());
        let mut o2 = obs(40, 2, 0.0);
        o2.groups = vec![
            gl(ModelKind::Llama3_8B, 0, 2),
            gl(ModelKind::Llama2_13B, 0, 1),
        ];
        assert_eq!(b.observe(&o2, 0.0), Some(GROW_8B));
    }

    #[test]
    fn per_group_spec_parses_and_rejects_garbage() {
        let b = parse_per_group("llama3-8b=1..4, llama2-13b=0..2").unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].model, ModelKind::Llama3_8B);
        assert_eq!((b[0].min_instances, b[0].max_instances), (1, 4));
        assert_eq!((b[1].min_instances, b[1].max_instances), (0, 2));
        assert!(parse_per_group("").is_err());
        assert!(parse_per_group("llama3-8b").is_err(), "missing bounds");
        assert!(parse_per_group("gpt5=1..2").is_err(), "unknown model");
        assert!(parse_per_group("llama3-8b=1..2,,tiny=0..1").is_err());
        let err = parse_per_group("llama3-8b=4..1").unwrap_err();
        assert!(err.contains("llama3-8b=4..1"), "error names the clause: {err}");
        let err = parse_per_group("tiny=0..1,tiny=1..2").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        assert!(parse_per_group("llama3-8b=x..2").is_err());
    }

    #[test]
    fn boot_delay_spec_parses_and_rejects_garbage() {
        let b = parse_boot_delays("llama3-8b=2, llama2-13b=12.5").unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], (ModelKind::Llama3_8B, 2.0));
        assert_eq!(b[1], (ModelKind::Llama2_13B, 12.5));
        assert!(parse_boot_delays("").is_err());
        assert!(parse_boot_delays("llama3-8b").is_err(), "missing seconds");
        assert!(parse_boot_delays("gpt5=1").is_err(), "unknown model");
        assert!(parse_boot_delays("llama3-8b=1,,tiny=2").is_err());
        let err = parse_boot_delays("llama3-8b=-1").unwrap_err();
        assert!(err.contains("llama3-8b=-1"), "error names the clause: {err}");
        assert!(parse_boot_delays("llama3-8b=NaN").is_err());
        assert!(parse_boot_delays("llama3-8b=inf").is_err());
        let err = parse_boot_delays("tiny=1,tiny=2").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn boot_delay_falls_back_to_the_scalar_per_family() {
        let mut c = cfg();
        c.boot_delay = 3.0;
        assert_eq!(c.boot_delay_for(ModelKind::Llama2_13B), 3.0, "scalar fallback");
        c.boot_delay_per_group = parse_boot_delays("llama2-13b=12").unwrap();
        assert_eq!(c.boot_delay_for(ModelKind::Llama2_13B), 12.0, "family override");
        assert_eq!(c.boot_delay_for(ModelKind::Llama3_8B), 3.0, "others keep scalar");
        // A family may even opt OUT of the global delay (instant boot).
        c.boot_delay_per_group = parse_boot_delays("tiny=0").unwrap();
        assert_eq!(c.boot_delay_for(ModelKind::Tiny), 0.0);
    }

    #[test]
    fn family_bounds_default_to_unbounded() {
        let c = cfg();
        assert_eq!(c.family_min(ModelKind::Tiny), 0);
        assert_eq!(c.family_max(ModelKind::Tiny), usize::MAX);
        let mut c = cfg();
        c.per_group = parse_per_group("llama2-13b=1..2").unwrap();
        assert_eq!(c.family_min(ModelKind::Llama2_13B), 1);
        assert_eq!(c.family_max(ModelKind::Llama2_13B), 2);
        assert_eq!(c.family_max(ModelKind::Llama3_8B), usize::MAX);
    }

    #[test]
    fn grow_skips_families_at_their_ceiling() {
        let mut c = cfg();
        c.per_group = parse_per_group("llama2-13b=0..1,llama3-8b=1..4").unwrap();
        let mut a = Autoscaler::new(c);
        let mut o = obs(40, 2, 0.0);
        // 13B is the most starved but already at its ceiling: the grow
        // falls to the next-deepest eligible family.
        o.groups = vec![
            gl(ModelKind::Llama3_8B, 5, 1),
            gl(ModelKind::Llama2_13B, 30, 1),
        ];
        assert_eq!(a.observe(&o, 0.0), Some(GROW_8B));
    }

    #[test]
    fn grow_withheld_when_every_family_is_capped() {
        let mut c = cfg();
        c.per_group = parse_per_group("llama3-8b=1..2").unwrap();
        let mut a = Autoscaler::new(c);
        let mut o = obs(40, 2, 0.0);
        // Only the 8B family exists (it is also the template) and it is at
        // its ceiling: no grow, and the cooldown is not burned.
        o.groups = vec![gl(ModelKind::Llama3_8B, 40, 2)];
        assert_eq!(a.observe(&o, 0.0), None);
        assert_eq!(a.grows, 0);
        // Ceiling lifted (an instance drained away): the still-hot streak
        // fires immediately — the cooldown was never consumed.
        o.groups = vec![gl(ModelKind::Llama3_8B, 40, 1)];
        o.active_instances = 1;
        assert_eq!(a.observe(&o, 1.0), Some(GROW_8B));
    }

    #[test]
    fn booting_instances_count_against_ceilings() {
        let mut c = cfg();
        c.per_group = parse_per_group("llama3-8b=1..2").unwrap();
        let mut a = Autoscaler::new(c);
        let mut o = obs(40, 1, 0.0);
        o.pending_instances = 1;
        let mut g = gl(ModelKind::Llama3_8B, 40, 1);
        g.pending_instances = 1;
        o.groups = vec![g];
        assert_eq!(
            a.observe(&o, 0.0),
            None,
            "active + booting at the family ceiling must not grow"
        );
        // Fleet-wide bound honors pending too.
        let mut b = Autoscaler::new(cfg());
        let mut o2 = obs(80, 2, 0.9);
        o2.pending_instances = 2; // 2 active + 2 booting = max 4
        assert_eq!(b.observe(&o2, 0.0), None);
    }

    #[test]
    fn shrink_withheld_when_every_family_sits_at_its_floor() {
        let mut c = cfg();
        c.per_group = parse_per_group("llama3-8b=2..4,llama2-13b=1..2").unwrap();
        let mut a = Autoscaler::new(c);
        let mut o = obs(0, 3, 0.0);
        o.groups = vec![
            gl(ModelKind::Llama3_8B, 0, 2),
            gl(ModelKind::Llama2_13B, 0, 1),
        ];
        assert_eq!(a.observe(&o, 0.0), None);
        assert_eq!(a.observe(&o, 5.0), None, "every family at its floor");
        // One family rises above its floor: the cold streak fires.
        o.groups[0].active_instances = 3;
        o.active_instances = 4;
        assert_eq!(a.observe(&o, 10.0), Some(ScaleAction::Shrink));
    }

    #[test]
    fn cooldown_blocks_back_to_back_actions() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(40, 2, 0.0), 0.0), Some(GROW_8B));
        assert_eq!(a.observe(&obs(40, 3, 0.0), 5.0), None, "inside cooldown");
        assert_eq!(a.observe(&obs(40, 3, 0.0), 10.0), Some(GROW_8B));
    }

    #[test]
    fn shrink_needs_a_cold_streak_and_respects_min() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(0, 3, 0.0), 0.0), None, "one cold tick is not enough");
        assert_eq!(a.observe(&obs(0, 3, 0.0), 5.0), Some(ScaleAction::Shrink));
        // A fleet already at the min bound never shrinks.
        let mut b = Autoscaler::new(cfg());
        assert_eq!(b.observe(&obs(0, 1, 0.0), 0.0), None);
        assert_eq!(b.observe(&obs(0, 1, 0.0), 5.0), None);
        assert_eq!(b.observe(&obs(0, 1, 0.0), 15.0), None);
    }

    #[test]
    fn shrink_waits_for_the_previous_drain_to_finish() {
        let mut a = Autoscaler::new(cfg());
        let mut cold = obs(0, 3, 0.0);
        cold.draining_instances = 1;
        assert_eq!(a.observe(&cold, 0.0), None);
        assert_eq!(a.observe(&cold, 5.0), None, "drain in flight blocks shrink");
        // Drain completed: the (still accumulated) cold streak fires.
        assert_eq!(a.observe(&obs(0, 3, 0.0), 10.0), Some(ScaleAction::Shrink));
    }

    #[test]
    fn factory_less_fleet_never_emits_grow_or_burns_cooldown() {
        let mut a = Autoscaler::new(cfg());
        let hot = FleetObservation { can_grow: false, ..obs(40, 2, 0.9) };
        assert_eq!(a.observe(&hot, 0.0), None);
        assert_eq!(a.observe(&hot, 20.0), None);
        assert_eq!(a.grows, 0, "no phantom grows recorded");
        // The cooldown was never consumed: a later genuine shrink signal
        // fires as soon as its streak completes.
        assert_eq!(a.observe(&obs(0, 3, 0.0), 25.0), None);
        assert_eq!(a.observe(&obs(0, 3, 0.0), 30.0), Some(ScaleAction::Shrink));
    }

    #[test]
    fn hysteresis_band_holds_position() {
        let mut a = Autoscaler::new(cfg());
        // Between queue_low and queue_high: neither streak accumulates.
        for t in 0..10 {
            assert_eq!(a.observe(&obs(4, 2, 0.1), t as f64 * 5.0), None);
        }
        assert_eq!(a.grows + a.shrinks, 0);
    }

    #[test]
    fn mid_band_observation_resets_cold_streak() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&obs(0, 3, 0.0), 0.0), None); // cold 1
        assert_eq!(a.observe(&obs(6, 3, 0.1), 5.0), None); // band: reset
        assert_eq!(a.observe(&obs(0, 3, 0.0), 10.0), None, "cold streak restarted");
        assert_eq!(a.observe(&obs(0, 3, 0.0), 15.0), Some(ScaleAction::Shrink));
    }
}
