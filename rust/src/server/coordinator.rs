//! The clock-agnostic serving runtime.
//!
//! [`Coordinator`] owns the paper's coordination cycle exactly once:
//! central queue → priority scheduler → memory-aware dispatcher → engine
//! fleet → orchestrator feedback. It never reads a clock — every method
//! takes `now` from the caller — so the discrete-event harness
//! ([`super::sim`] over [`crate::simcore`]) and the wall-clock PJRT path
//! ([`super::real`]) are thin *drivers* over the same coordination code.
//! The [`Clock`] trait is the drivers' seam: wall drivers read
//! [`WallClock`], virtual-time drivers advance a [`ManualClock`] (or take
//! times straight off the event queue).
//!
//! The fleet is heterogeneous: a [`FleetSpec`] gives every instance its own
//! [`InstanceSpec`] — model, batch width and KV scale — modeling mixed GPU
//! generations and uneven co-tenant memory pressure. Per-instance capacity
//! flows to the dispatchers through [`InstanceStatus`], so packing decisions
//! are made against each instance's real budget, not a fleet-wide constant.
//!
//! Submission goes through the routing layer
//! ([`crate::orchestrator::router`]): each request's serving group comes
//! from its agent's affinity stamp under [`RoutePolicy::Pinned`], or from
//! the measured per-(agent, family) latency profiles and live group
//! pressures under `Learned` — every decision is appended to
//! [`Coordinator::route_log`], which (with the dispatch, group and scale
//! logs) forms the driver-equivalence seam contract tested in
//! `tests/runtime_seam.rs`.

use std::cell::Cell;
use std::collections::HashMap;

use crate::agents::apps::{App, WorkflowPlan};
use crate::dispatch::{DispatchPolicy, DispatchStats, ScoreScope, Scored};
use crate::engine::core::{
    EngineConfig, EngineCore, ExecBackend, InstanceStatus, SimBackend, StepOutcome,
};
use crate::engine::cost_model::{CostModel, ModelClass, ModelKind};
use crate::engine::request::{Request, RequestId, SeqState};
use crate::lb::policies::SchedulePolicy;
use crate::lb::sharded::{ShardKey, ShardedQueue};
use crate::metrics::{MetricsCollector, RequestRecord, WorkflowRecord};
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::graph::ExecRecord;
use crate::orchestrator::ids::{AgentId, MsgId};
use crate::orchestrator::router::{GroupPressure, RouteDecision, RoutePolicy, Router};
use crate::orchestrator::Orchestrator;
use crate::server::autoscale::{Autoscaler, FleetObservation, GroupLoad, ScaleAction};
use crate::server::pressure::PressureTrace;
use crate::server::pump_pool;
use crate::util::RingLog;
use crate::workload::trace::TraceRecord;
use crate::Time;

// ---------------------------------------------------------------------------
// Clock seam

/// A source of the current time, in seconds. The coordinator itself is
/// clock-agnostic; only drivers hold a clock.
pub trait Clock {
    fn now(&self) -> Time;
}

// The wall-clock implementation lives with the wall-clock driver in
// [`super::real`] — the single module allowed to read real time (lint rule
// D1) — and is re-exported here so existing `coordinator::WallClock`
// imports keep working.
pub use super::real::WallClock;

/// A manually advanced clock for virtual-time drivers and driver tests.
#[derive(Debug, Default)]
pub struct ManualClock {
    now: Cell<Time>,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock { now: Cell::new(0.0) }
    }

    /// Advance to `t`. Time never moves backwards.
    pub fn advance_to(&self, t: Time) {
        if t > self.now.get() {
            self.now.set(t);
        }
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Time {
        self.now.get()
    }
}

// ---------------------------------------------------------------------------
// Fleet specification

/// Configuration of one engine instance — one GPU's worth of serving
/// capacity, with its own model kind, batch width and KV budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceSpec {
    pub model: ModelKind,
    /// KV block size in tokens.
    pub block_size: u32,
    /// vLLM `max_num_seqs` for this instance.
    pub max_batch: usize,
    /// Scale factor on the instance's KV pool (< 1.0 models co-tenant
    /// memory pressure or a smaller GPU; 1.0 = the model's full budget).
    pub kv_scale: f64,
    /// KV block budget of the instance's prefix cache
    /// ([`crate::engine::block_manager::PrefixCache`]); 0 disables the
    /// cache. Autoscaled instances inherit the value through their spec.
    pub cache_blocks: u32,
}

impl InstanceSpec {
    pub fn new(model: ModelKind) -> InstanceSpec {
        InstanceSpec {
            model,
            block_size: 16,
            max_batch: 256,
            kv_scale: 1.0,
            cache_blocks: 0,
        }
    }

    pub fn with_kv_scale(mut self, kv_scale: f64) -> InstanceSpec {
        self.kv_scale = kv_scale;
        self
    }

    /// Set the prefix-cache block budget (0 disables the cache).
    pub fn with_cache_blocks(mut self, cache_blocks: u32) -> InstanceSpec {
        self.cache_blocks = cache_blocks;
        self
    }

    pub fn with_max_batch(mut self, max_batch: usize) -> InstanceSpec {
        self.max_batch = max_batch;
        self
    }

    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.model)
    }

    /// The engine config this spec resolves to: the model's full block pool
    /// scaled by `kv_scale` (never below one block).
    pub fn engine_config(&self) -> EngineConfig {
        let mut cfg = EngineConfig::for_model(self.model, self.block_size);
        cfg.max_batch = self.max_batch;
        cfg.total_blocks = ((cfg.total_blocks as f64) * self.kv_scale).max(1.0) as u32;
        cfg.prefix_cache_blocks = self.cache_blocks;
        cfg
    }
}

/// Per-instance configuration of the whole fleet.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetSpec {
    pub instances: Vec<InstanceSpec>,
}

impl FleetSpec {
    /// `n` identical instances.
    pub fn homogeneous(n: usize, spec: InstanceSpec) -> FleetSpec {
        FleetSpec { instances: vec![spec; n] }
    }

    pub fn push(&mut self, spec: InstanceSpec) -> &mut Self {
        self.instances.push(spec);
        self
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// True when any two instances differ (model, batch or KV budget).
    pub fn is_heterogeneous(&self) -> bool {
        self.instances.windows(2).any(|w| w[0] != w[1])
    }

    /// The reference cost model used for fleet-level annotations (ground
    /// truth isolated latencies, time-slot ramp constants): the first
    /// instance's model.
    pub fn reference_cost(&self) -> CostModel {
        CostModel::new(self.instances.first().map(|s| s.model).unwrap_or(ModelKind::Llama3_8B))
    }

    /// Parse a fleet from a compact CLI string.
    ///
    /// Grammar: comma-separated entries `[COUNT*]MODEL[@KV_SCALE][:MAX_BATCH]`
    /// with models `llama3-8b`, `llama2-13b`, `tiny`. Examples:
    ///
    /// * `4*llama3-8b@0.12` — the paper's homogeneous testbed under
    ///   co-tenant pressure.
    /// * `2*llama3-8b@0.12,2*llama3-8b@0.04:128` — uneven pressure.
    /// * `llama3-8b,llama2-13b@0.5` — mixed models.
    pub fn parse(s: &str) -> Result<FleetSpec, String> {
        if s.trim().is_empty() {
            return Err("empty fleet spec".to_string());
        }
        let mut fleet = FleetSpec::default();
        for raw in s.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                return Err(format!("empty fleet entry in {s:?}"));
            }
            let (count, rest) = match entry.split_once('*') {
                Some((n, rest)) => {
                    let n: usize = n
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad instance count in {entry:?}"))?;
                    if n == 0 {
                        return Err(format!("zero instance count in {entry:?}"));
                    }
                    (n, rest.trim())
                }
                None => (1, entry),
            };
            let (rest, max_batch) = match rest.rsplit_once(':') {
                Some((head, b)) => {
                    let b: usize =
                        b.parse().map_err(|_| format!("bad max_batch in {entry:?}"))?;
                    if b == 0 {
                        return Err(format!("zero max_batch in {entry:?}"));
                    }
                    (head, Some(b))
                }
                None => (rest, None),
            };
            let (model_name, kv_scale) = match rest.split_once('@') {
                Some((m, k)) => {
                    let k: f64 =
                        k.parse().map_err(|_| format!("bad kv_scale in {entry:?}"))?;
                    if !k.is_finite() || k <= 0.0 {
                        return Err(format!(
                            "kv_scale must be a positive finite number in {entry:?}"
                        ));
                    }
                    (m, k)
                }
                None => (rest, 1.0),
            };
            let model_name = model_name.trim();
            // A duplicated separator (e.g. `llama3-8b:64:32` or `2*2*...`)
            // leaves its residue inside the would-be model name; reject it
            // with the clause, not a misleading "unknown model".
            if model_name.contains(['*', '@', ':']) {
                return Err(format!("duplicate or misplaced separator in {entry:?}"));
            }
            let model = ModelKind::parse(model_name)
                .map_err(|e| format!("{e} in fleet entry {entry:?}"))?;
            let mut spec = InstanceSpec::new(model).with_kv_scale(kv_scale);
            if let Some(b) = max_batch {
                spec = spec.with_max_batch(b);
            }
            for _ in 0..count {
                fleet.push(spec);
            }
        }
        Ok(fleet)
    }
}

// ---------------------------------------------------------------------------
// Elastic fleet state

/// Lifecycle state of one instance slot. Slots are stable: retirement
/// never shifts the indices of other instances (dispatcher state, the
/// dispatch log and scale events all key on the index), so a retired slot
/// stays behind as a non-accepting tombstone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Accepting dispatches.
    Active,
    /// No new dispatches; in-flight requests run to completion.
    Draining,
    /// Drained and folded; the slot is a tombstone.
    Retired,
}

/// Sentinel instance index of a [`ScaleEventKind::Provision`] event: the
/// slot is assigned only when the boot completes (a same-family tombstone
/// may be re-used, so the index is unknowable at provision time).
pub const PROVISIONING: usize = usize::MAX;

/// What happened to the fleet, when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScaleEventKind {
    /// Instance requested by the autoscaler; it registers live once the
    /// configured `boot_delay` elapses (the event's `instance` is
    /// [`PROVISIONING`]).
    Provision,
    /// Instance registered live.
    Grow,
    /// Instance stopped accepting dispatches and began draining.
    RetireStart,
    /// Instance fully drained; counters folded into the run metrics.
    RetireDone,
}

/// One fleet-change event, for analyses and the resize contract tests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    pub at: Time,
    pub instance: usize,
    pub kind: ScaleEventKind,
    /// Stream position of the dispatch log (entries ever appended, not
    /// retained — see [`RingLog::total`]) when the event fired: everything
    /// at or after this sequence happened with the fleet in its post-event
    /// shape (e.g. no dispatch past a `RetireStart`'s seq may target its
    /// instance).
    pub dispatch_seq: usize,
}

/// One dispatch decision with its serving-group context: which class the
/// request was pinned to and which model family actually served it. The
/// per-group dispatch logs of the sharded seam contract are views over
/// this; `class.matches(model)` must hold for every entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupDispatch {
    pub req: RequestId,
    pub instance: usize,
    pub class: ModelClass,
    /// Model family of `instance` at dispatch time.
    pub model: ModelKind,
}

/// Retention caps for the coordinator's per-request decision logs
/// ([`Coordinator::dispatch_log`], `group_log`, `route_log`, `trace_log`).
/// `None` retains everything (the default, and what the seam tests and the
/// replay toolchain require); `Some(k)` keeps only the newest `k` entries
/// of that log. Capping changes retention only, never behavior: the same
/// entries are appended in the same order either way (contract pinned in
/// `tests/runtime_seam.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogConfig {
    pub dispatch: Option<usize>,
    pub group: Option<usize>,
    pub route: Option<usize>,
    pub trace: Option<usize>,
}

impl LogConfig {
    /// Unbounded retention on every log (the default).
    pub fn full() -> LogConfig {
        LogConfig { dispatch: None, group: None, route: None, trace: None }
    }

    /// The same cap on every log — million-request runs keep a tail for
    /// spot checks without holding the whole decision history.
    pub fn bounded(cap: usize) -> LogConfig {
        LogConfig {
            dispatch: Some(cap),
            group: Some(cap),
            route: Some(cap),
            trace: Some(cap),
        }
    }
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig::full()
    }
}

/// One model family's slot index, maintained incrementally on every fleet
/// change so the pump's per-head candidate scan and the router's group
/// pressures read `O(family)` state instead of rescanning all instances.
/// Slots are never removed (tombstones keep their index); `active` counts
/// the family's slots currently [`InstanceState::Active`].
#[derive(Debug, Clone)]
struct FamilyIndex {
    model: ModelKind,
    /// This family's slot indices, in fleet (= first-seen) order.
    slots: Vec<usize>,
    /// How many of `slots` are Active right now.
    active: usize,
}

// ---------------------------------------------------------------------------
// Workflow bookkeeping

struct WfState {
    plan: WorkflowPlan,
    next_stage: usize,
    app_start: Time,
    queue_time: f64,
    /// Isolated per-stage latency estimates (suffix sums give the ground
    /// truth remaining latency for Oracle/analysis).
    stage_latency: Vec<f64>,
    /// Prefix-cache session key every stage request carries (the trace's
    /// override, or the workflow's own message id).
    session: u64,
}

struct Pending {
    msg_id: MsgId,
    agent: AgentId,
    stage_arrival: Time,
    output_tokens: u32,
    true_remaining: f64,
    upstream: Option<AgentId>,
}

/// What one absorbed [`StepOutcome`] produced: the completed sequences (for
/// drivers that post-process them, e.g. text extraction in real serving)
/// and whether any workflow advanced or finished.
#[derive(Debug, Default)]
pub struct Absorbed {
    pub completed: Vec<SeqState>,
    pub preempted: u32,
}

/// An instance the autoscaler has provisioned that is still booting: it
/// registers live (becoming a `Grow` scale event) once `ready_at` passes,
/// at the next pump or refresh — deterministic points of the coordination
/// cycle, so both drivers activate it at the same place in the dispatch
/// stream.
#[derive(Debug, Clone, Copy)]
struct PendingBoot {
    ready_at: Time,
    spec: InstanceSpec,
}

// ---------------------------------------------------------------------------
// Parallel pump round plan

/// One shard head offered to a parallel scoring batch: the round plan
/// partitions the queue heads by serving group (one head per shard, each
/// shard a `(group, family)` partition) and scores them concurrently.
struct ScoreJob {
    /// Shard whose head this is.
    shard: usize,
    /// The head itself, cloned so workers need no queue borrow.
    req: Request,
    /// Pinned heads offer only their family's slot set (ascending), the
    /// exact prune the sequential arm feeds `choose_among`; `None` = full
    /// scan (`Any`-class heads).
    candidates: Option<Vec<usize>>,
}

/// A head's cached score, tagged for optimistic conflict detection
/// against the per-slot commit versions.
struct CachedScore {
    /// Request the score was computed for (heads move when shards pop).
    req_id: RequestId,
    /// The pure scoring result, committed later via
    /// [`DispatchPolicy::commit_score`] — or discarded unfolded if a
    /// conflicting commit stales it first.
    scored: Scored,
    /// Commit version the score was computed at.
    epoch: u64,
    /// Instance slots the score read ([`ScoreScope::Slots`] policies with
    /// a pruned candidate set); `None` = the score read every slot, so any
    /// commit invalidates it.
    reads: Option<Vec<usize>>,
}

/// Whether a cached score is still valid: nothing it read was committed to
/// after it was computed. `slot_epoch[j]` is the commit version that last
/// mutated instance `j`; `commit_epoch` is the current version.
fn score_fresh(c: &CachedScore, slot_epoch: &[u64], commit_epoch: u64) -> bool {
    if c.epoch == commit_epoch {
        return true;
    }
    match &c.reads {
        None => false,
        Some(reads) => reads
            .iter()
            .all(|&j| slot_epoch.get(j).copied().unwrap_or(0) <= c.epoch),
    }
}

// ---------------------------------------------------------------------------
// Coordinator

/// The reusable serving runtime: one instance of the coordination cycle,
/// generic over the engine execution backend. Drivers own the clock and the
/// iteration discipline (event queue, polling loop, threads); the
/// coordinator owns every scheduling, dispatching and feedback decision.
pub struct Coordinator<B: ExecBackend> {
    pub fleet: FleetSpec,
    /// The central queue, sharded by serving group: one shard per pinned
    /// model family plus the `Any` shard.
    pub queue: ShardedQueue,
    pub policy: Box<dyn SchedulePolicy>,
    pub dispatcher: Box<dyn DispatchPolicy>,
    pub engines: Vec<EngineCore<B>>,
    pub orch: Orchestrator,
    pub metrics: MetricsCollector,
    workflows: HashMap<MsgId, WfState>,
    pending: HashMap<RequestId, Pending>,
    next_req_id: RequestId,
    next_msg_id: MsgId,
    /// Requests rejected because no instance could ever hold them.
    pub dropped: u64,
    /// Every dispatch decision `(request, instance)` in order — the
    /// driver-equivalence contract (two drivers over the same trace must
    /// produce the same log). Retention is capped by [`LogConfig`];
    /// unbounded by default.
    pub dispatch_log: RingLog<(RequestId, usize)>,
    /// The dispatch log with serving-group context (same order and length
    /// as `dispatch_log`); the sharded seam contract compares this.
    pub group_log: RingLog<GroupDispatch>,
    /// Reusable per-instance status snapshot: refreshed in place, only for
    /// instances whose engine changed since the last pump (no per-pump
    /// allocation — see `benches/bench_overhead.rs`).
    status_buf: Vec<InstanceStatus>,
    status_dirty: Vec<bool>,
    /// Cost model used for fleet-level ground-truth annotations.
    reference_cost: CostModel,
    /// Lifecycle state per instance slot (see [`InstanceState`]).
    instance_state: Vec<InstanceState>,
    /// Every fleet change, in order — grows, drain starts, drain
    /// completions. A [`RingLog`] like the other decision logs (lint rule
    /// D5: no raw `Vec` log fields on long-lived coordinator state);
    /// unbounded by default since fleets change rarely.
    pub scale_log: RingLog<ScaleEvent>,
    /// Physical KV capacity per instance (tokens), before any co-tenant
    /// pressure: the "could this request EVER fit" admission check reads
    /// this, so transient pressure never causes permanent drops.
    base_capacity: Vec<u64>,
    /// Pressure multiplier last applied to each status entry; a moved
    /// multiplier forces a snapshot refresh even for clean engines.
    applied_pressure: Vec<f64>,
    /// Time-varying co-tenant pressure on the per-instance KV budgets.
    pressure: Option<PressureTrace>,
    /// Elastic scaling policy, consulted on every [`Self::refresh`].
    autoscaler: Option<Autoscaler>,
    /// Factory for new instances' backends (None for fleets built from
    /// pre-constructed engines, e.g. PJRT: those cannot autoscale up).
    make_backend: Option<Box<dyn FnMut(&InstanceSpec) -> B>>,
    /// Reusable per-pump shard-blocked flags (no per-pump allocation).
    blocked_buf: Vec<bool>,
    /// Per-model-family slot index, in fleet first-seen order, maintained
    /// incrementally on every fleet change.
    families: Vec<FamilyIndex>,
    /// Cached instance-derived group pressures (queue depths live in
    /// `depth_scratch`, snapshotted per [`ShardedQueue::epoch`] — they
    /// move per enqueue).
    pressure_cache: Vec<GroupPressure>,
    /// Set whenever the status snapshot or an instance's lifecycle state
    /// changes; the next pressure read rebuilds the cache.
    pressure_cache_dirty: bool,
    /// Slots marked stale since the last batched refresh (no duplicates:
    /// guarded by `status_dirty`). Lets [`Self::refresh_statuses`] touch
    /// only changed engines instead of re-checking every slot per pump.
    dirty_slots: Vec<usize>,
    /// Run the pre-index linear candidate scan and per-call pressure
    /// rebuild instead of the incremental structures. Exists so
    /// `kairos bench` can measure a true in-binary baseline-vs-optimized
    /// A/B on one commit, and so the seam tests can pin both paths to
    /// identical decisions.
    legacy_hot_path: bool,
    /// The routing layer: picks each submitted request's serving group
    /// from its affinity stamp and, under the learned policy, the measured
    /// per-family profiles and live group pressures.
    router: Router,
    /// Every routing decision, in submission order — the third leg of the
    /// driver-equivalence contract next to `dispatch_log` and `group_log`.
    pub route_log: RingLog<RouteDecision>,
    /// Autoscaler-provisioned instances still inside their boot delay.
    pending_boots: Vec<PendingBoot>,
    /// The recording path: every submitted plan as a [`TraceRecord`] with
    /// its ground-truth submission time and affinity stamps. Any
    /// plan-driven run — sim or real driver — can be captured here,
    /// written to JSONL ([`crate::workload::Trace`]) and replayed
    /// bit-identically; the record→replay contract rides the same seam as
    /// the dispatch, group, route and scale logs (`tests/runtime_seam.rs`).
    /// Free-standing [`Self::submit_external`] requests are recorded too,
    /// as single-stage [`crate::agents::apps::App::Ext`] records, so a
    /// mixed plan/external run replays in full.
    pub trace_log: RingLog<TraceRecord>,
    /// Per-group queue-depth snapshot (same order as `pressure_cache`),
    /// rebuilt in one shard pass only when [`ShardedQueue::epoch`] moved —
    /// replacing the per-call `group_len` walks of every
    /// [`Self::group_pressures`] read (see `benches/bench_pressure.rs`).
    depth_scratch: Vec<usize>,
    /// The queue epoch `depth_scratch` was computed at (`None` = stale).
    depth_epoch: Option<u64>,
    /// Worker threads for score-in-parallel dispatch rounds (1 = the
    /// sequential loop; see [`Self::set_pump_threads`]).
    pump_threads: usize,
    /// Pin the pump to the sequential loop regardless of `pump_threads` —
    /// the parallel pump's in-binary equivalence baseline, in the same
    /// spirit as `legacy_hot_path` (see [`Self::set_sequential_pump`]).
    sequential_pump: bool,
    /// Parallel pump only: commits that invalidated a fresh sibling score
    /// (the committed slot was in that score's read set).
    par_conflicts: u64,
    /// Parallel pump only: heads scored again after a conflict staled
    /// their previous score.
    par_rescored: u64,
    /// Parallel pump only: scoring batches fanned out to the worker pool.
    par_rounds: u64,
}

impl Coordinator<SimBackend> {
    /// A coordinator whose engines execute under the calibrated cost model
    /// of their own instance spec (virtual-time fleet).
    pub fn sim(
        fleet: FleetSpec,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> Coordinator<SimBackend> {
        Coordinator::new(fleet, policy, dispatcher, |spec| {
            SimBackend::new(spec.cost_model())
        })
    }
}

impl<B: ExecBackend> Coordinator<B> {
    /// Build the fleet: `make_backend` constructs each instance's execution
    /// backend from its spec.
    pub fn new(
        fleet: FleetSpec,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
        mut make_backend: impl FnMut(&InstanceSpec) -> B + 'static,
    ) -> Coordinator<B> {
        let engines: Vec<EngineCore<B>> = fleet
            .instances
            .iter()
            .enumerate()
            .map(|(i, spec)| EngineCore::new(i, spec.engine_config(), make_backend(spec)))
            .collect();
        let mut c = Coordinator::from_engines(fleet, policy, dispatcher, engines);
        // Keep the factory: it is what lets the fleet grow live.
        c.make_backend = Some(Box::new(make_backend));
        c
    }

    /// Build a coordinator over pre-constructed engines (backends whose
    /// engine configs come from elsewhere than the cost model, e.g. the
    /// PJRT tiny-model manifest). `fleet` stays the nominal description.
    pub fn from_engines(
        fleet: FleetSpec,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
        engines: Vec<EngineCore<B>>,
    ) -> Coordinator<B> {
        assert!(!engines.is_empty(), "fleet must have at least one instance");
        assert_eq!(fleet.len(), engines.len(), "fleet spec must match engines");
        let status_buf: Vec<InstanceStatus> = engines.iter().map(|e| e.status()).collect();
        let base_capacity: Vec<u64> = status_buf.iter().map(|s| s.capacity_tokens).collect();
        let n = engines.len();
        let reference_cost = fleet.reference_cost();
        // Family index in fleet first-seen order; every slot starts Active.
        let mut families: Vec<FamilyIndex> = Vec::new();
        for (j, spec) in fleet.instances.iter().enumerate() {
            match families.iter_mut().find(|f| f.model == spec.model) {
                Some(f) => {
                    f.slots.push(j);
                    f.active += 1;
                }
                None => families.push(FamilyIndex {
                    model: spec.model,
                    slots: vec![j],
                    active: 1,
                }),
            }
        }
        Coordinator {
            fleet,
            queue: ShardedQueue::new(),
            policy,
            dispatcher,
            engines,
            orch: Orchestrator::new(),
            metrics: MetricsCollector::new(),
            workflows: HashMap::new(),
            pending: HashMap::new(),
            next_req_id: 1,
            next_msg_id: 1,
            dropped: 0,
            dispatch_log: RingLog::new(),
            group_log: RingLog::new(),
            status_buf,
            status_dirty: vec![false; n],
            reference_cost,
            instance_state: vec![InstanceState::Active; n],
            scale_log: RingLog::new(),
            base_capacity,
            applied_pressure: vec![1.0; n],
            pressure: None,
            autoscaler: None,
            make_backend: None,
            blocked_buf: Vec::new(),
            families,
            pressure_cache: Vec::new(),
            pressure_cache_dirty: true,
            dirty_slots: Vec::new(),
            legacy_hot_path: false,
            router: Router::default(),
            route_log: RingLog::new(),
            pending_boots: Vec::new(),
            trace_log: RingLog::new(),
            depth_scratch: Vec::new(),
            depth_epoch: None,
            pump_threads: 1,
            sequential_pump: false,
            par_conflicts: 0,
            par_rescored: 0,
            par_rounds: 0,
        }
    }

    pub fn n_instances(&self) -> usize {
        self.engines.len()
    }

    /// Instances currently accepting dispatches.
    pub fn active_instances(&self) -> usize {
        self.instance_state.iter().filter(|s| **s == InstanceState::Active).count()
    }

    /// Instances draining toward retirement.
    pub fn draining_instances(&self) -> usize {
        self.instance_state.iter().filter(|s| **s == InstanceState::Draining).count()
    }

    /// Lifecycle state of instance slot `j`.
    pub fn instance_state(&self, j: usize) -> InstanceState {
        self.instance_state[j]
    }

    /// Install a co-tenant pressure trace: from now on the per-instance
    /// status snapshot reports `capacity_tokens` scaled by the trace's
    /// multiplier at the current time.
    pub fn set_pressure(&mut self, trace: PressureTrace) {
        self.pressure = Some(trace);
    }

    /// Install (or replace) the autoscaling policy consulted on
    /// [`Self::refresh`].
    pub fn set_autoscaler(&mut self, autoscaler: Autoscaler) {
        self.autoscaler = Some(autoscaler);
    }

    /// Install agent → model-class affinity annotations: every request an
    /// agent submits from now on carries the agent's class and is routed
    /// through its serving group's queue shard.
    pub fn set_affinity(&mut self, spec: &AffinitySpec) {
        self.orch.apply_affinity(spec);
    }

    /// Install the routing policy (default: [`RoutePolicy::Pinned`], the
    /// static affinity stamp). Resets the router's exploration counters.
    pub fn set_route_policy(&mut self, policy: RoutePolicy) {
        self.router = Router::new(policy);
    }

    /// The active routing policy.
    pub fn route_policy(&self) -> RoutePolicy {
        self.router.policy()
    }

    /// Configure the profiler's per-family half-life (`[policy]
    /// profile_half_life`): with `Some(h)` the learned routing signal
    /// decays, tracking non-stationary agent latencies. Callers validate
    /// `h > 0` and finite.
    pub fn set_profile_half_life(&mut self, half_life: Option<f64>) {
        self.orch.profiler.set_half_life(half_life);
    }

    /// The installed autoscaler, if any (diagnostics).
    pub fn autoscaler(&self) -> Option<&Autoscaler> {
        self.autoscaler.as_ref()
    }

    /// Apply retention caps to the decision logs. Capping changes what is
    /// *kept*, never what is *decided*: entries are appended identically
    /// either way (see `tests/runtime_seam.rs`).
    pub fn set_log_config(&mut self, cfg: LogConfig) {
        self.dispatch_log.set_cap(cfg.dispatch);
        self.group_log.set_cap(cfg.group);
        self.route_log.set_cap(cfg.route);
        self.trace_log.set_cap(cfg.trace);
    }

    /// Switch to the pre-index hot path (linear candidate scans, per-call
    /// pressure rebuilds, unbatched refresh). Decision-for-decision
    /// identical to the indexed path — `kairos bench` uses it as the
    /// in-binary baseline arm.
    pub fn set_legacy_hot_path(&mut self, legacy: bool) {
        self.legacy_hot_path = legacy;
    }

    /// Forward the dispatcher's scoring A/B switch
    /// ([`DispatchPolicy::set_legacy_scoring`]): `true` scores candidates
    /// with the naive reference arm, `false` (default) with the optimized
    /// one. Orthogonal to [`Self::set_legacy_hot_path`] — that one switches
    /// the coordinator's own candidate/pressure structures; this one
    /// switches the packer's per-candidate scoring. Both arms of both
    /// switches must produce identical dispatch decisions.
    pub fn set_legacy_scoring(&mut self, legacy: bool) {
        self.dispatcher.set_legacy_scoring(legacy);
    }

    /// Worker threads for the score-in-parallel dispatch rounds (default 1
    /// = the sequential loop; values are clamped to at least 1). The
    /// parallel path additionally requires a dispatcher that opts in via
    /// [`DispatchPolicy::supports_parallel`] and the indexed hot path.
    /// Thread count must never change a decision, only wall time: the
    /// dispatch/group/route logs are pinned bit-identical across counts by
    /// the property tests below, `tests/runtime_seam.rs`, and the
    /// `kairos bench` par stage's equal-logs assert.
    pub fn set_pump_threads(&mut self, threads: usize) {
        self.pump_threads = threads.max(1);
    }

    /// Force the sequential dispatch loop even when `pump_threads > 1` —
    /// the parallel pump's in-binary baseline arm, mirroring
    /// [`Self::set_legacy_hot_path`]'s role for the indexed structures.
    /// Both arms must produce identical logs; the bench's 1-thread curve
    /// point runs with this set.
    pub fn set_sequential_pump(&mut self, sequential: bool) {
        self.sequential_pump = sequential;
    }

    /// Snapshot of the dispatcher's streaming decision counters
    /// ([`DispatchStats`]) merged with the coordinator-owned parallel-pump
    /// counters (`conflicts`/`rescored`/`par_rounds`); also synced into
    /// [`crate::metrics::StreamingMetrics::packer`] on every refresh.
    pub fn dispatch_stats(&self) -> DispatchStats {
        let mut s = self.dispatcher.stats();
        s.conflicts += self.par_conflicts;
        s.rescored += self.par_rescored;
        s.par_rounds += self.par_rounds;
        s
    }

    /// Resident bytes pinned by the decision logs (buffer capacities plus
    /// the trace records' per-stage heap) — the bench harness's
    /// `peak_log_bytes`.
    pub fn log_state_bytes(&self) -> usize {
        let trace_stage_heap: usize = self
            .trace_log
            .iter()
            .map(|r| {
                r.stages.capacity()
                    * std::mem::size_of::<crate::workload::trace::StageRecord>()
            })
            .sum();
        self.dispatch_log.approx_bytes()
            + self.group_log.approx_bytes()
            + self.route_log.approx_bytes()
            + self.trace_log.approx_bytes()
            + self.scale_log.approx_bytes()
            + trace_stage_heap
    }

    /// Index into [`Self::families`] for `model`, if the fleet has ever
    /// held the family (slots are never removed, so absence is permanent).
    fn family_slot(&self, model: ModelKind) -> Option<usize> {
        self.families.iter().position(|f| f.model == model)
    }

    /// Mark slot `j`'s status snapshot stale, queueing it for the next
    /// batched refresh (deduplicated through `status_dirty`), and
    /// invalidate the cached group pressures.
    fn mark_dirty(&mut self, j: usize) {
        if !self.status_dirty[j] {
            self.status_dirty[j] = true;
            self.dirty_slots.push(j);
        }
        self.pressure_cache_dirty = true;
    }

    /// Register a new instance live, building its backend with the fleet's
    /// factory. Fails for coordinators assembled from pre-constructed
    /// engines (no factory — e.g. the PJRT fleet).
    pub fn add_instance(&mut self, spec: InstanceSpec, now: Time) -> Result<usize, String> {
        let Some(make) = self.make_backend.as_mut() else {
            return Err("no backend factory: this fleet cannot grow live".to_string());
        };
        let backend = make(&spec);
        Ok(self.add_engine(spec, backend, now))
    }

    /// Register a pre-built backend as a new live instance; returns its
    /// index. A retired tombstone slot of the SAME model family is re-used
    /// (same index, fresh engine) instead of growing the instance vector
    /// forever — indices stay stable either way, and the dispatcher's
    /// per-instance state for a re-used slot is reset through
    /// [`DispatchPolicy::on_instance_reset`]. The slot is immediately
    /// eligible for dispatch.
    pub fn add_engine(&mut self, spec: InstanceSpec, backend: B, now: Time) -> usize {
        let reuse = (0..self.engines.len()).find(|&j| {
            self.instance_state[j] == InstanceState::Retired
                && self.fleet.instances[j].model == spec.model
        });
        let j = match reuse {
            Some(j) => {
                self.engines[j] = EngineCore::new(j, spec.engine_config(), backend);
                self.fleet.instances[j] = spec;
                self.instance_state[j] = InstanceState::Active;
                // The slot is already in its family's index (same family by
                // the reuse predicate); it counts as active again. The
                // family is present by construction — `audit_invariants`
                // cross-checks the index, so no panic path here (lint D6).
                if let Some(fi) = self.family_slot(spec.model) {
                    self.families[fi].active += 1;
                }
                self.dispatcher.on_instance_reset(j);
                j
            }
            None => {
                let j = self.engines.len();
                let engine = EngineCore::new(j, spec.engine_config(), backend);
                let status = engine.status();
                self.fleet.instances.push(spec);
                self.base_capacity.push(status.capacity_tokens);
                self.status_buf.push(status);
                self.status_dirty.push(false);
                self.applied_pressure.push(1.0);
                self.instance_state.push(InstanceState::Active);
                self.engines.push(engine);
                match self.family_slot(spec.model) {
                    Some(fi) => {
                        self.families[fi].slots.push(j);
                        self.families[fi].active += 1;
                    }
                    None => self.families.push(FamilyIndex {
                        model: spec.model,
                        slots: vec![j],
                        active: 1,
                    }),
                }
                j
            }
        };
        self.mark_dirty(j);
        self.scale_log.push(ScaleEvent {
            at: now,
            instance: j,
            kind: ScaleEventKind::Grow,
            dispatch_seq: self.dispatch_log.total() as usize,
        });
        self.refresh_statuses(now);
        self.dispatcher.on_fleet_change(&self.status_buf);
        j
    }

    /// Begin retiring instance `j`: it stops accepting dispatches
    /// immediately, its in-flight requests (engine queue + running batch)
    /// run to completion, and once idle its counters fold into the run
    /// metrics and the slot becomes a tombstone.
    pub fn retire_instance(&mut self, j: usize, now: Time) -> Result<(), String> {
        if j >= self.engines.len() {
            return Err(format!("no instance {j} in a fleet of {}", self.engines.len()));
        }
        if self.instance_state[j] != InstanceState::Active {
            return Err(format!("instance {j} is already {:?}", self.instance_state[j]));
        }
        self.instance_state[j] = InstanceState::Draining;
        let model = self.fleet.instances[j].model;
        // Every live slot was indexed at registration, so the lookup
        // cannot miss; `audit_invariants` cross-checks (lint D6: no panic
        // paths in the serving layer).
        if let Some(fi) = self.family_slot(model) {
            self.families[fi].active -= 1;
        }
        self.mark_dirty(j);
        self.scale_log.push(ScaleEvent {
            at: now,
            instance: j,
            kind: ScaleEventKind::RetireStart,
            dispatch_seq: self.dispatch_log.total() as usize,
        });
        self.refresh_statuses(now);
        self.dispatcher.on_fleet_change(&self.status_buf);
        // An idle instance retires on the spot.
        self.finalize_drained(now);
        Ok(())
    }

    /// Complete the retirement of any draining instance that has gone
    /// idle: fold its counters and tombstone the slot. Called after every
    /// absorb/refresh; drivers call it once more at end of run.
    pub fn finalize_drained(&mut self, now: Time) {
        for j in 0..self.engines.len() {
            if self.instance_state[j] != InstanceState::Draining
                || self.engines[j].has_work()
            {
                continue;
            }
            // Fold-and-zero keeps the end-of-run counter sweep idempotent.
            self.fold_instance_counters(j);
            // Draining → Retired: the family's active count already
            // dropped at RetireStart; only the snapshot goes stale here.
            self.instance_state[j] = InstanceState::Retired;
            self.mark_dirty(j);
            self.scale_log.push(ScaleEvent {
                at: now,
                instance: j,
                kind: ScaleEventKind::RetireDone,
                dispatch_seq: self.dispatch_log.total() as usize,
            });
        }
    }

    /// Whether any stage is queued, resident in an engine, or mid-workflow.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty()
            || !self.workflows.is_empty()
            || self.engines.iter().any(|e| e.has_work())
    }

    /// Isolated (uncontended) execution latency of one stage — prefill plus
    /// single-stream decode under the reference cost model. Used for the
    /// ground-truth remaining-latency annotations.
    fn stage_isolated_latency(cost: &CostModel, prompt: u32, output: u32) -> f64 {
        let prefill = cost.step_time(prompt, 0, 0);
        let avg_ctx = prompt as u64 + output as u64 / 2;
        let per_tok = cost.step_time(0, 1, avg_ctx);
        prefill + per_tok * output.saturating_sub(1) as f64
    }

    /// Admit a resolved workflow: registers its state and pushes its first
    /// stage into the central queue. Returns the workflow's message id.
    /// The plan is also captured in [`Self::trace_log`] with its
    /// ground-truth submission time and the agents' current affinity
    /// stamps, so the run can be written out and replayed.
    pub fn submit_plan(&mut self, plan: WorkflowPlan, now: Time) -> MsgId {
        self.submit_plan_with_session(plan, None, now)
    }

    /// [`Self::submit_plan`] with an explicit prefix-cache session key.
    /// `None` keys the workflow's stages by its own message id (the
    /// default); traces carrying a `session` field pass it through here so
    /// replay preserves cross-workflow session grouping.
    pub fn submit_plan_with_session(
        &mut self,
        plan: WorkflowPlan,
        session: Option<u64>,
        now: Time,
    ) -> MsgId {
        let mut rec = TraceRecord::from_plan(&plan, now);
        rec.session = session;
        for s in rec.stages.iter_mut() {
            // Name-based lookup (never interns): recording must not
            // perturb agent-id assignment.
            s.class = match self.orch.class_of_name(s.agent) {
                ModelClass::Any => None,
                c => Some(c),
            };
        }
        self.trace_log.push(rec);
        let stage_latency: Vec<f64> = plan
            .stages
            .iter()
            .map(|s| {
                Self::stage_isolated_latency(
                    &self.reference_cost,
                    s.prompt_tokens,
                    s.output_tokens,
                )
            })
            .collect();
        let msg_id = self.next_msg_id;
        self.next_msg_id += 1;
        self.workflows.insert(
            msg_id,
            WfState {
                plan,
                next_stage: 0,
                app_start: now,
                queue_time: 0.0,
                stage_latency,
                session: session.unwrap_or(msg_id),
            },
        );
        if let Some(req) = self.make_request(msg_id, now) {
            self.route_and_enqueue(req);
        }
        msg_id
    }

    /// Admit a single free-standing request (no workflow plan) — the real
    /// serving frontend's path. `agent` is interned into the orchestrator's
    /// registry so profiles still accumulate. The request is captured in
    /// [`Self::trace_log`] as a single-stage [`App::Ext`] record (same
    /// affinity stamping as plans), so mixed plan/external runs replay.
    pub fn submit_external(
        &mut self,
        agent: &str,
        prompt_tokens: u32,
        output_tokens: u32,
        now: Time,
    ) -> RequestId {
        self.trace_log.push(TraceRecord {
            at: now,
            app: App::Ext,
            dataset: "external",
            stages: vec![crate::workload::trace::StageRecord {
                agent: crate::workload::trace::intern_name(agent),
                prompt_tokens,
                output_tokens,
                class: match self.orch.class_of_name(agent) {
                    ModelClass::Any => None,
                    c => Some(c),
                },
            }],
            session: None,
        });
        let agent = self.orch.registry.intern(agent);
        let id = self.next_req_id;
        self.next_req_id += 1;
        let msg_id = self.next_msg_id;
        self.next_msg_id += 1;
        self.pending.insert(
            id,
            Pending {
                msg_id,
                agent,
                stage_arrival: now,
                output_tokens,
                true_remaining: 0.0,
                upstream: None,
            },
        );
        let req = Request {
            id,
            msg_id,
            agent,
            session: msg_id,
            model_class: self.orch.model_class(agent),
            upstream: None,
            prompt_tokens,
            true_output_tokens: output_tokens,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: now,
            stage_arrival: now,
        };
        self.route_and_enqueue(req);
        id
    }

    /// Route one request through the routing layer and place it in its
    /// shard: the static affinity stamp becomes the routed class (the
    /// learned policy may override a pin), `Any`-class requests balanced
    /// into a group go to that group's routed shard, and the decision is
    /// appended to [`Self::route_log`].
    fn route_and_enqueue(&mut self, mut req: Request) {
        let groups = if self.router.wants_pressure() {
            self.group_pressures()
        } else {
            Vec::new()
        };
        let d = self.router.route(
            req.id,
            req.agent,
            req.model_class,
            &self.orch.profiler,
            &groups,
        );
        req.model_class = d.chosen;
        let key = match d.group {
            Some(m) => ShardKey::AnyIn(m),
            None => ShardKey::Class(d.chosen),
        };
        self.route_log.push(d);
        self.queue.push_routed(req, key, self.policy.as_ref());
    }

    /// Live per-group pressure snapshot for the router, in fleet
    /// first-seen order. Reads only coordinator-owned state (shard depths,
    /// slot lifecycle, the status snapshot as of the last pump/refresh),
    /// so both drivers compute identical pressures at identical submission
    /// points — routing decisions stay inside the driver-equivalence
    /// contract.
    ///
    /// The instance-derived fields (active/inflight/free_tokens) are
    /// cached and rebuilt only after a pump/refresh/fleet change
    /// invalidated them. The queue depths move per enqueue with no
    /// intervening pump, so they are snapshotted separately, keyed on
    /// [`ShardedQueue::epoch`]: a burst of pressure reads between two
    /// depth changes (learned routing probes every submission) reuses one
    /// single-pass snapshot instead of walking all shards per group per
    /// call (measured in `benches/bench_pressure.rs`).
    fn group_pressures(&mut self) -> Vec<GroupPressure> {
        if self.legacy_hot_path {
            return self.group_pressures_legacy();
        }
        if self.pressure_cache_dirty {
            self.rebuild_pressure_cache();
        }
        self.refresh_depth_snapshot();
        let mut out = self.pressure_cache.clone();
        for (g, &d) in out.iter_mut().zip(self.depth_scratch.iter()) {
            g.queued = d;
        }
        out
    }

    /// Rebuild the per-group queue-depth snapshot in one pass over the
    /// shards, unless the queue's depth epoch is unchanged since the last
    /// snapshot (then every depth is unchanged too and the scratch is
    /// reused as-is). Entries parallel `pressure_cache`; shards of
    /// families the fleet has never held are skipped, exactly as the
    /// per-call `group_len` walks skipped them.
    fn refresh_depth_snapshot(&mut self) {
        let epoch = self.queue.epoch();
        if self.depth_epoch == Some(epoch)
            && self.depth_scratch.len() == self.pressure_cache.len()
        {
            return;
        }
        self.depth_scratch.clear();
        self.depth_scratch.resize(self.pressure_cache.len(), 0);
        let cache = &self.pressure_cache;
        let scratch = &mut self.depth_scratch;
        self.queue.for_each_group_depth(|m, d| {
            if let Some(i) = cache.iter().position(|g| g.model == m) {
                scratch[i] += d;
            }
        });
        self.depth_epoch = Some(epoch);
    }

    /// Rebuild the cached instance-derived pressure skeleton from the
    /// family index (same family order and per-family slot order as the
    /// legacy full rescan, so the sums are identical).
    fn rebuild_pressure_cache(&mut self) {
        self.pressure_cache.clear();
        for f in &self.families {
            let mut g = GroupPressure {
                model: f.model,
                queued: 0,
                active: 0,
                inflight: 0,
                free_tokens: 0,
            };
            for &j in &f.slots {
                if self.instance_state[j] != InstanceState::Active {
                    continue;
                }
                let st = &self.status_buf[j];
                g.active += 1;
                g.inflight += st.n_running + st.n_waiting;
                g.free_tokens += st
                    .capacity_tokens
                    .saturating_sub(st.committed_tokens + st.waiting_tokens);
            }
            self.pressure_cache.push(g);
        }
        self.pressure_cache_dirty = false;
        // The family set (and with it the snapshot's row order) may have
        // changed: force the next pressure read to re-derive depths.
        self.depth_epoch = None;
    }

    /// The pre-cache implementation: rescan every instance per call.
    /// Kept callable behind [`Self::set_legacy_hot_path`] for the bench
    /// harness's baseline arm and the hot-path equivalence tests.
    fn group_pressures_legacy(&self) -> Vec<GroupPressure> {
        let mut out: Vec<GroupPressure> = Vec::new();
        for (j, spec) in self.fleet.instances.iter().enumerate() {
            let i = match out.iter().position(|g| g.model == spec.model) {
                Some(i) => i,
                None => {
                    out.push(GroupPressure {
                        model: spec.model,
                        queued: self.queue.group_len(spec.model),
                        active: 0,
                        inflight: 0,
                        free_tokens: 0,
                    });
                    out.len() - 1
                }
            };
            if self.instance_state[j] != InstanceState::Active {
                continue;
            }
            let g = &mut out[i];
            let st = &self.status_buf[j];
            g.active += 1;
            g.inflight += st.n_running + st.n_waiting;
            g.free_tokens += st
                .capacity_tokens
                .saturating_sub(st.committed_tokens + st.waiting_tokens);
        }
        out
    }

    /// Build the next-stage request of workflow `msg_id`, or `None` when
    /// the workflow is unknown (callers only invoke this for registered
    /// workflows; the `Option` keeps the serving layer panic-free, lint D6).
    fn make_request(&mut self, msg_id: MsgId, now: Time) -> Option<Request> {
        let wf = self.workflows.get_mut(&msg_id)?;
        let i = wf.next_stage;
        let stage = &wf.plan.stages[i];
        let agent = self.orch.registry.intern(stage.agent);
        let upstream = if i > 0 {
            Some(self.orch.registry.intern(wf.plan.stages[i - 1].agent))
        } else {
            None
        };
        let true_remaining: f64 = wf.stage_latency[i..].iter().sum();
        let id = self.next_req_id;
        self.next_req_id += 1;
        self.pending.insert(
            id,
            Pending {
                msg_id,
                agent,
                stage_arrival: now,
                output_tokens: stage.output_tokens,
                true_remaining,
                upstream,
            },
        );
        Some(Request {
            id,
            msg_id,
            agent,
            session: wf.session,
            model_class: self.orch.model_class(agent),
            upstream,
            prompt_tokens: stage.prompt_tokens,
            true_output_tokens: stage.output_tokens,
            true_remaining_latency: true_remaining,
            remaining_stages: wf.plan.remaining_stages(i),
            app_start: wf.app_start,
            stage_arrival: now,
        })
    }

    /// Refresh stale entries of the status snapshot in place. An entry is
    /// stale when its engine changed since the last pump OR its co-tenant
    /// pressure multiplier moved; everything else is reused untouched (no
    /// per-pump allocation — see `benches/bench_overhead.rs`).
    ///
    /// Without a pressure trace every multiplier is pinned at 1.0, so only
    /// slots queued in `dirty_slots` can be stale: the batched path drains
    /// that queue instead of re-checking every slot per pump. A pressure
    /// trace makes staleness time-driven (a multiplier can move with no
    /// engine activity), so it falls back to the full scan.
    fn refresh_statuses(&mut self, now: Time) {
        if self.pressure.is_none() && !self.legacy_hot_path {
            while let Some(j) = self.dirty_slots.pop() {
                if self.status_dirty[j] {
                    self.refresh_one(j, 1.0);
                }
            }
            return;
        }
        // Full scan: reconciles every dirty flag, so the queue is moot.
        self.dirty_slots.clear();
        for j in 0..self.engines.len() {
            // Retired tombstones are frozen (idle, non-accepting): skip
            // them entirely so dead slots cost nothing per refresh beyond
            // this state check. A tombstone re-filled by `add_engine` is
            // marked dirty (and Active) there, so it refreshes normally.
            if self.instance_state[j] == InstanceState::Retired && !self.status_dirty[j]
            {
                continue;
            }
            let mult =
                self.pressure.as_ref().map_or(1.0, |p| p.multiplier(j, now));
            if self.status_dirty[j] || mult != self.applied_pressure[j] {
                self.refresh_one(j, mult);
            }
        }
    }

    /// Rebuild one snapshot entry from its engine, applying the given
    /// pressure multiplier and the slot's lifecycle state.
    fn refresh_one(&mut self, j: usize, mult: f64) {
        let mut st = self.engines[j].status();
        self.base_capacity[j] = st.capacity_tokens;
        if mult != 1.0 {
            st.capacity_tokens = ((st.capacity_tokens as f64) * mult).max(1.0) as u64;
        }
        st.accepting = self.instance_state[j] == InstanceState::Active;
        self.status_buf[j] = st;
        self.status_dirty[j] = false;
        self.applied_pressure[j] = mult;
        // The snapshot feeding the cached group pressures moved.
        self.pressure_cache_dirty = true;
    }

    /// The per-instance status snapshot at time `now` (refreshing stale
    /// entries and re-sampling the pressure trace).
    pub fn statuses(&mut self, now: Time) -> &[InstanceStatus] {
        self.refresh_statuses(now);
        &self.status_buf
    }

    /// Whether any accepting instance matches `class` and whether any of
    /// them could EVER hold `need_tokens` (judged against physical pools),
    /// reading only the request's own family from the index. Post-refresh,
    /// `accepting` ≡ `InstanceState::Active`, so a family with
    /// `active > 0` has an accepting instance by construction.
    fn scan_candidates_indexed(
        &self,
        class: ModelClass,
        need_tokens: u64,
    ) -> (bool, bool) {
        let mut any_accepting = false;
        let mut could_ever_fit = false;
        let mut scan_family = |f: &FamilyIndex| {
            if f.active == 0 {
                return false;
            }
            any_accepting = true;
            for &j in &f.slots {
                if self.status_buf[j].accepting && need_tokens <= self.base_capacity[j]
                {
                    could_ever_fit = true;
                    return true;
                }
            }
            false
        };
        match class {
            ModelClass::Model(m) => {
                if let Some(fi) = self.family_slot(m) {
                    scan_family(&self.families[fi]);
                }
            }
            ModelClass::Any => {
                for f in &self.families {
                    if scan_family(f) {
                        break;
                    }
                }
            }
        }
        (any_accepting, could_ever_fit)
    }

    /// The pre-index scan: every instance, every head. Kept callable
    /// behind [`Self::set_legacy_hot_path`] (bench baseline arm, hot-path
    /// equivalence tests).
    fn scan_candidates_legacy(&self, class: ModelClass, need_tokens: u64) -> (bool, bool) {
        let mut any_accepting = false;
        let mut could_ever_fit = false;
        for (j, st) in self.status_buf.iter().enumerate() {
            if !st.accepting || !class.matches(st.model) {
                continue;
            }
            any_accepting = true;
            if need_tokens <= self.base_capacity[j] {
                could_ever_fit = true;
                break;
            }
        }
        (any_accepting, could_ever_fit)
    }

    /// Run the schedule→dispatch half of the cycle: repeatedly pick the
    /// globally highest-priority request among the serving-group shards
    /// and place it on a model-compatible instance, until every shard
    /// drains or defers ("the request remains in the scheduling queue",
    /// paper §6). Head-of-line blocking is per group: a shard whose head
    /// cannot be placed stops only its own group's dispatching this round.
    /// Returns the instances that received at least one request, in
    /// first-dispatch order, so the driver can wake them.
    pub fn pump(&mut self, now: Time) -> Vec<usize> {
        // Booted instances register here (and on refresh) — deterministic
        // points of the cycle, so both drivers reshape the fleet at the
        // same place in the dispatch stream.
        self.activate_booted(now);
        let mut woken: Vec<usize> = Vec::new();
        if self.queue.is_empty() {
            return woken;
        }
        self.refresh_statuses(now);
        self.blocked_buf.clear();
        self.blocked_buf.resize(self.queue.n_shards(), false);
        if self.use_parallel_pump() {
            self.dispatch_round_parallel(now, &mut woken);
        } else {
            self.dispatch_round_sequential(now, &mut woken);
        }
        woken
    }

    /// Whether this pump takes the score-in-parallel path: opted into by
    /// thread count, not pinned sequential, a dispatcher whose scoring can
    /// run as a pure read, and the indexed hot path (the legacy arm stays
    /// all-sequential — it is the bench baseline).
    fn use_parallel_pump(&self) -> bool {
        !self.sequential_pump
            && self.pump_threads >= 2
            && !self.legacy_hot_path
            && self.dispatcher.supports_parallel()
    }

    /// The sequential dispatch round: pick the globally best head, place
    /// or defer it, repeat. This is the reference arm the parallel round
    /// must match log-for-log.
    fn dispatch_round_sequential(&mut self, now: Time, woken: &mut Vec<usize>) {
        loop {
            let Some(s) = self.queue.best_shard(&self.blocked_buf) else {
                return;
            };
            // `best_shard` only returns non-empty shards; a missing head
            // would mean queue-internal drift, so block the shard and move
            // on rather than panic on the serving path (lint D6).
            let Some(best) = self.queue.peek_shard(s) else {
                self.blocked_buf[s] = true;
                continue;
            };
            // The dispatch constraint is the request's own class — the
            // shard is only a queueing partition (a routed `Any` request
            // waits in a group's shard but may still dispatch anywhere).
            let class = best.model_class;
            // A prompt that can never fit any accepting instance OF ITS
            // GROUP — judged against the PHYSICAL pools, so a transient
            // co-tenant squeeze only defers — is rejected outright.
            let need_tokens = best.prompt_tokens as u64 + 1;
            let (any_accepting, could_ever_fit) = if self.legacy_hot_path {
                self.scan_candidates_legacy(class, need_tokens)
            } else {
                self.scan_candidates_indexed(class, need_tokens)
            };
            if !any_accepting {
                // Not one live instance of this family. If the fleet holds
                // no slot of the family at all the request can never be
                // served: drop it (the group analogue of the fit rule).
                // Slots that are merely draining/retired defer instead —
                // scaling can revive the family.
                let family_exists =
                    self.fleet.instances.iter().any(|sp| class.matches(sp.model));
                if family_exists {
                    self.blocked_buf[s] = true;
                } else if let Some(req) = self.queue.pop_shard(s) {
                    self.pending.remove(&req.id);
                    self.workflows.remove(&req.msg_id);
                    self.dropped += 1;
                } else {
                    self.blocked_buf[s] = true;
                }
                continue;
            }
            if !could_ever_fit {
                if let Some(req) = self.queue.pop_shard(s) {
                    self.pending.remove(&req.id);
                    self.workflows.remove(&req.msg_id);
                    self.dropped += 1;
                } else {
                    self.blocked_buf[s] = true;
                }
                continue;
            }
            // The family prune already computed for the fit scan flows into
            // the dispatcher: a pinned request offers only its family's
            // slot set (ascending, so policy tie-breaks are unchanged —
            // the seam tests pin this). `Any` requests and the legacy arm
            // full-scan.
            let chosen = match class {
                ModelClass::Model(m) if !self.legacy_hot_path => {
                    match self.family_slot(m) {
                        Some(fi) => self.dispatcher.choose_among(
                            best,
                            &self.status_buf,
                            &self.families[fi].slots,
                            now,
                        ),
                        None => self.dispatcher.choose(best, &self.status_buf, now),
                    }
                }
                _ => self.dispatcher.choose(best, &self.status_buf, now),
            };
            let Some(j) = chosen else {
                self.blocked_buf[s] = true;
                continue;
            };
            // Safety net over the policies' own filtering: work must never
            // land on an instance that is draining, retired, or serving a
            // model family the request is not pinned to.
            assert!(
                j < self.engines.len()
                    && self.status_buf[j].accepting
                    && class.matches(self.status_buf[j].model),
                "dispatcher chose non-accepting or incompatible instance {j}"
            );
            // The head was just peeked, so the pop cannot miss; if it ever
            // did, deferring the shard is the deterministic fallback.
            let Some(req) = self.queue.pop_shard(s) else {
                self.blocked_buf[s] = true;
                continue;
            };
            self.dispatch_log.push((req.id, j));
            self.group_log.push(GroupDispatch {
                req: req.id,
                instance: j,
                class,
                model: self.status_buf[j].model,
            });
            self.dispatcher.on_dispatch(&req, j, now);
            self.engines[j].submit(req, now);
            // Rebuild through refresh_one so pressure scaling and the
            // accepting flag survive the in-loop snapshot update.
            self.refresh_one(j, self.applied_pressure[j]);
            if !woken.contains(&j) {
                woken.push(j);
            }
        }
    }

    /// The deterministic parallel dispatch round: score-in-parallel,
    /// commit-in-order.
    ///
    /// Each iteration of the outer loop is one **round plan**: every
    /// unblocked shard head that could be placed (its group has a live
    /// instance and the prompt physically fits) and lacks a fresh cached
    /// score becomes a [`ScoreJob`], and the batch is scored concurrently
    /// on the scoped worker pool ([`pump_pool::run_parallel`]) through the
    /// dispatcher's pure [`DispatchPolicy::score`]. The inner loop then
    /// **commits sequentially in exactly the sequential arm's order**
    /// (global head rank, re-picked after every pop): a commit folds the
    /// score's stat delta ([`DispatchPolicy::commit_score`]), pops, logs,
    /// submits — and bumps the committed slot's version so optimistic
    /// conflict detection ([`score_fresh`]) can tell which sibling scores
    /// read state this commit mutated. When the globally best head's score
    /// went stale, the inner loop breaks back out to re-score (counted in
    /// `rescored`; the invalidations in `conflicts`).
    ///
    /// Determinism: scoring is a pure read (enforced by `&self` on
    /// `score`), results land by job index, commits replay the sequential
    /// loop verbatim with `choose` replaced by "fresh cached score" — so
    /// the dispatch/group/route logs are bit-identical at every thread
    /// count. Ring/cursor state also matches: [`DispatchPolicy::begin_round`]
    /// runs lazily before the first batch that actually scores, exactly
    /// the pumps where the sequential arm's first `choose` advances its
    /// rings (advancing is idempotent at fixed `now`).
    fn dispatch_round_parallel(&mut self, now: Time, woken: &mut Vec<usize>) {
        let n_shards = self.queue.n_shards();
        let mut cache: Vec<Option<CachedScore>> = Vec::with_capacity(n_shards);
        cache.resize_with(n_shards, || None);
        // Per-slot commit versions: slot_epoch[j] is the commit number that
        // last mutated instance j's dispatcher/status state.
        let mut slot_epoch: Vec<u64> = vec![0; self.engines.len()];
        let mut commit_epoch: u64 = 0;
        let mut begun = false;
        loop {
            // ---- round plan: batch-score stale unblocked heads ----
            let mut jobs: Vec<ScoreJob> = Vec::new();
            for s in 0..n_shards {
                if self.blocked_buf[s] {
                    continue;
                }
                let Some(head) = self.queue.peek_shard(s) else { continue };
                if let Some(c) = cache[s].as_ref() {
                    if c.req_id == head.id {
                        if score_fresh(c, &slot_epoch, commit_epoch) {
                            continue;
                        }
                        self.par_rescored += 1;
                    }
                }
                // Heads the commit loop will drop or family-defer without
                // consulting the dispatcher are not scored — otherwise a
                // drop-only pump would advance ring state the sequential
                // arm never touches. Both checks read only pump-constant
                // state, so passing now means passing at commit time.
                let class = head.model_class;
                let need_tokens = head.prompt_tokens as u64 + 1;
                let (any_accepting, could_ever_fit) =
                    self.scan_candidates_indexed(class, need_tokens);
                if !any_accepting || !could_ever_fit {
                    continue;
                }
                let candidates = match class {
                    ModelClass::Model(m) => self
                        .family_slot(m)
                        .map(|fi| self.families[fi].slots.clone()),
                    ModelClass::Any => None,
                };
                jobs.push(ScoreJob { shard: s, req: head.clone(), candidates });
            }
            if !jobs.is_empty() {
                if !begun {
                    self.dispatcher.begin_round(&self.status_buf, now);
                    begun = true;
                }
                self.par_rounds += 1;
                let dispatcher: &dyn DispatchPolicy = self.dispatcher.as_ref();
                let statuses: &[InstanceStatus] = &self.status_buf;
                let results = pump_pool::run_parallel(
                    self.pump_threads,
                    &jobs,
                    |_, job: &ScoreJob| {
                        dispatcher.score(&job.req, statuses, job.candidates.as_deref(), now)
                    },
                );
                let slots_scope = dispatcher.score_scope() == ScoreScope::Slots;
                for (job, scored) in jobs.into_iter().zip(results) {
                    // A pruned read set is only a real read set under Slots
                    // scope; global-scope scores are staled by any commit.
                    let reads = if slots_scope { job.candidates } else { None };
                    cache[job.shard] = Some(CachedScore {
                        req_id: job.req.id,
                        scored,
                        epoch: commit_epoch,
                        reads,
                    });
                }
            }
            // ---- commit in order: the sequential loop, reading the cache ----
            loop {
                let Some(s) = self.queue.best_shard(&self.blocked_buf) else {
                    return;
                };
                let Some(best) = self.queue.peek_shard(s) else {
                    self.blocked_buf[s] = true;
                    continue;
                };
                let class = best.model_class;
                let need_tokens = best.prompt_tokens as u64 + 1;
                let (any_accepting, could_ever_fit) =
                    self.scan_candidates_indexed(class, need_tokens);
                if !any_accepting {
                    let family_exists =
                        self.fleet.instances.iter().any(|sp| class.matches(sp.model));
                    if family_exists {
                        self.blocked_buf[s] = true;
                    } else if let Some(req) = self.queue.pop_shard(s) {
                        self.pending.remove(&req.id);
                        self.workflows.remove(&req.msg_id);
                        self.dropped += 1;
                        cache[s] = None;
                    } else {
                        self.blocked_buf[s] = true;
                    }
                    continue;
                }
                if !could_ever_fit {
                    if let Some(req) = self.queue.pop_shard(s) {
                        self.pending.remove(&req.id);
                        self.workflows.remove(&req.msg_id);
                        self.dropped += 1;
                        cache[s] = None;
                    } else {
                        self.blocked_buf[s] = true;
                    }
                    continue;
                }
                let usable = cache[s].as_ref().map_or(false, |c| {
                    c.req_id == best.id && score_fresh(c, &slot_epoch, commit_epoch)
                });
                if !usable {
                    // The globally best head has no fresh score: back out
                    // to the round plan, which re-scores it (and every
                    // other stale head) in one batch.
                    break;
                }
                let Some(entry) = cache[s].take() else {
                    self.blocked_buf[s] = true;
                    continue;
                };
                let Some(j) = entry.scored.pick else {
                    // The policy refused the head: fold the scoring
                    // counters exactly as the sequential arm's refused
                    // `choose` call does, and defer the group.
                    self.dispatcher.commit_score(
                        best,
                        &entry.scored,
                        &self.status_buf,
                        now,
                    );
                    self.blocked_buf[s] = true;
                    continue;
                };
                // Safety net over the policies' own filtering, identical
                // to the sequential arm's.
                assert!(
                    j < self.engines.len()
                        && self.status_buf[j].accepting
                        && class.matches(self.status_buf[j].model),
                    "dispatcher chose non-accepting or incompatible instance {j}"
                );
                self.dispatcher.commit_score(best, &entry.scored, &self.status_buf, now);
                let Some(req) = self.queue.pop_shard(s) else {
                    self.blocked_buf[s] = true;
                    continue;
                };
                self.dispatch_log.push((req.id, j));
                self.group_log.push(GroupDispatch {
                    req: req.id,
                    instance: j,
                    class,
                    model: self.status_buf[j].model,
                });
                self.dispatcher.on_dispatch(&req, j, now);
                self.engines[j].submit(req, now);
                self.refresh_one(j, self.applied_pressure[j]);
                if !woken.contains(&j) {
                    woken.push(j);
                }
                // Conflict accounting BEFORE stamping the new version:
                // fresh sibling scores whose read set covers the committed
                // slot are now invalid (they re-enter the next round plan).
                for (t, slot) in cache.iter().enumerate() {
                    if t == s {
                        continue;
                    }
                    if let Some(c) = slot {
                        if score_fresh(c, &slot_epoch, commit_epoch)
                            && c.reads.as_ref().map_or(true, |r| r.contains(&j))
                        {
                            self.par_conflicts += 1;
                        }
                    }
                }
                commit_epoch += 1;
                if let Some(e) = slot_epoch.get_mut(j) {
                    *e = commit_epoch;
                }
            }
        }
    }

    /// Run one continuous-batching iteration on instance `j`, re-ordering
    /// its waiting queue under the scheduling policy first if it went stale
    /// (vLLM pluggable scheduling). The driver decides when the returned
    /// outcome's duration has elapsed and then calls [`Self::absorb`].
    pub fn step_engine(&mut self, j: usize, now: Time) -> StepOutcome {
        if self.engines[j].waiting_dirty {
            let policy = &self.policy;
            self.engines[j].sort_waiting_by(|r| policy.key(r));
        }
        let out = self.engines[j].step(now);
        self.mark_dirty(j);
        out
    }

    /// Feed one finished engine iteration back into the system: record
    /// preemptions, complete sequences (metrics + orchestrator feedback),
    /// and advance workflows, pushing successor stages into the queue.
    pub fn absorb(&mut self, j: usize, out: StepOutcome, now: Time) -> Absorbed {
        if out.preempted > 0 {
            self.metrics.preemptions += out.preempted as u64;
            self.dispatcher.on_preemption(j, now);
        }
        for seq in &out.completed {
            self.handle_completion(seq, j, now);
        }
        self.mark_dirty(j);
        // A draining instance whose last in-flight request just finished
        // retires here.
        self.finalize_drained(now);
        Absorbed { completed: out.completed, preempted: out.preempted }
    }

    fn handle_completion(&mut self, seq: &SeqState, instance: usize, now: Time) {
        let req = &seq.req;
        let Some(p) = self.pending.remove(&req.id) else { return };
        // Queueing ends at FIRST admission into the running batch (the LLM
        // execution start); everything before is queue time, wherever the
        // request physically waited (LB queue or engine queue).
        let dispatched_at = seq.first_admitted_at.unwrap_or(now);
        self.dispatcher.on_complete(req.id, instance, now);
        if let Some(wf) = self.workflows.get_mut(&req.msg_id) {
            wf.queue_time += dispatched_at - p.stage_arrival;
        }
        self.metrics.record_request(RequestRecord {
            msg_id: p.msg_id,
            agent: p.agent,
            stage_arrival: p.stage_arrival,
            dispatched_at,
            finished_at: now,
            output_tokens: p.output_tokens,
            preempt_count: seq.preempt_count,
            true_remaining: p.true_remaining,
        });
        self.orch.record_execution(ExecRecord {
            msg_id: p.msg_id,
            agent: p.agent,
            upstream: p.upstream,
            start: dispatched_at,
            end: now,
        });
        // Serving-context feedback for the routing layer and the
        // dispatcher's demand prediction: which family actually served the
        // request, how long it ran there, and how much KV it ended up
        // holding.
        self.orch.record_serving_feedback(
            p.agent,
            self.fleet.instances[instance].model,
            now - dispatched_at,
            req.total_tokens() as f64,
            now,
        );
        self.metrics.record_served(p.agent, self.fleet.instances[instance].model);
        // Advance the workflow, if this request belongs to one (external
        // requests are single free-standing stages).
        // Advance while the mutable borrow is live and build the final
        // record in the same pass — no second lookup, no panic path on the
        // serving layer (lint D6).
        let finished = match self.workflows.get_mut(&p.msg_id) {
            Some(wf) => {
                wf.next_stage += 1;
                if wf.next_stage >= wf.plan.stages.len() {
                    Some(WorkflowRecord {
                        msg_id: p.msg_id,
                        app: wf.plan.app,
                        app_start: wf.app_start,
                        finished_at: now,
                        output_tokens: wf.plan.total_output_tokens(),
                        queue_time: wf.queue_time,
                    })
                } else {
                    None
                }
            }
            None => return,
        };
        match finished {
            Some(rec) => {
                self.metrics.record_workflow(rec);
                self.orch.record_workflow_done(p.msg_id, now);
                self.workflows.remove(&p.msg_id);
            }
            None => {
                if let Some(req) = self.make_request(p.msg_id, now) {
                    self.route_and_enqueue(req);
                }
            }
        }
    }

    /// Drop everything queued on an instance that is idle yet cannot admit
    /// its front request (the request alone exceeds the pool). Returns the
    /// number of requests dropped.
    pub fn drain_stuck(&mut self, j: usize) -> usize {
        if self.engines[j].batch_len() != 0 || self.engines[j].waiting_len() == 0 {
            return 0;
        }
        let reqs = self.engines[j].drain();
        let n = reqs.len();
        for req in reqs {
            self.pending.remove(&req.id);
            self.workflows.remove(&req.msg_id);
            self.dropped += 1;
        }
        self.mark_dirty(j);
        n
    }

    /// Periodic priority/profile refresh (paper §7.7: fixed intervals,
    /// asynchronous): recompute policy and dispatcher state from the
    /// orchestrator, re-key the central queue, mark every engine-side
    /// queue stale — and give the elastic-fleet machinery its tick
    /// (completing drains, consulting the autoscaler).
    pub fn refresh(&mut self, now: Time) {
        self.policy.refresh(&self.orch);
        self.dispatcher.refresh(&self.orch);
        self.queue.resort(self.policy.as_ref());
        for e in self.engines.iter_mut() {
            e.waiting_dirty = true;
        }
        self.finalize_drained(now);
        self.activate_booted(now);
        self.autoscale(now);
        // Keep the packer's decision counters visible on the streaming
        // metrics surface (bench summary, `kairos check`).
        self.metrics.stream.packer = self.dispatch_stats();
        // Dynamic counterpart of the static lint pass: in debug builds
        // every refresh re-derives the incremental structures from scratch
        // and asserts they agree (release builds skip this; `kairos check`
        // calls `audit_invariants` explicitly instead).
        #[cfg(debug_assertions)]
        {
            let violations = self.audit_invariants();
            assert!(
                violations.is_empty(),
                "coordinator invariant audit failed:\n{}",
                violations.join("\n")
            );
        }
    }

    /// Cross-check the coordinator's incremental hot-path structures
    /// against from-scratch rebuilds, returning one message per violation
    /// (empty = consistent). The checks:
    ///
    /// 1. [`FamilyIndex`] — the per-family slot sets, first-seen order and
    ///    active counts must match a fresh scan of the fleet.
    /// 2. The dirty-flag [`GroupPressure`] cache — when marked clean it
    ///    must equal a from-scratch rebuild of the instance-derived
    ///    skeleton.
    /// 3. Slot lifecycle — no tombstoned (or draining) slot whose status
    ///    snapshot is up to date may be `accepting`, and every up-to-date
    ///    Active slot must be.
    /// 4. Prefix-cache bookkeeping — every engine's
    ///    [`crate::engine::block_manager::PrefixCache`] must pass its own
    ///    audit: cached blocks within the budget, per-entry block counts
    ///    consistent with the block size.
    ///
    /// Called automatically from [`Self::refresh`] in debug builds, from
    /// the seam tests, and per replayed event by `kairos check`.
    pub fn audit_invariants(&self) -> Vec<String> {
        let mut violations = Vec::new();
        // (1) FamilyIndex vs a fresh first-seen-order scan of the fleet.
        let mut fresh: Vec<FamilyIndex> = Vec::new();
        for (j, spec) in self.fleet.instances.iter().enumerate() {
            let active = (self.instance_state[j] == InstanceState::Active) as usize;
            match fresh.iter_mut().find(|f| f.model == spec.model) {
                Some(f) => {
                    f.slots.push(j);
                    f.active += active;
                }
                None => fresh.push(FamilyIndex {
                    model: spec.model,
                    slots: vec![j],
                    active,
                }),
            }
        }
        if fresh.len() != self.families.len() {
            violations.push(format!(
                "family index holds {} families, fresh scan found {}",
                self.families.len(),
                fresh.len()
            ));
        }
        for (f, g) in self.families.iter().zip(&fresh) {
            if f.model != g.model {
                violations.push(format!(
                    "family order drift: index has {:?} where scan has {:?}",
                    f.model, g.model
                ));
            }
            if f.slots != g.slots {
                violations.push(format!(
                    "family {:?} slot set {:?} != fresh scan {:?}",
                    f.model, f.slots, g.slots
                ));
            }
            if f.active != g.active {
                violations.push(format!(
                    "family {:?} active count {} != fresh scan {}",
                    f.model, f.active, g.active
                ));
            }
        }
        // (2) A clean pressure cache must equal a from-scratch rebuild of
        // the instance-derived skeleton (queue depths are re-read per
        // group_pressures call, so the cached `queued` is always 0).
        if !self.pressure_cache_dirty {
            let mut rebuilt: Vec<GroupPressure> = Vec::new();
            for f in &self.families {
                let mut g = GroupPressure {
                    model: f.model,
                    queued: 0,
                    active: 0,
                    inflight: 0,
                    free_tokens: 0,
                };
                for &j in &f.slots {
                    if self.instance_state[j] != InstanceState::Active {
                        continue;
                    }
                    let st = &self.status_buf[j];
                    g.active += 1;
                    g.inflight += st.n_running + st.n_waiting;
                    g.free_tokens += st
                        .capacity_tokens
                        .saturating_sub(st.committed_tokens + st.waiting_tokens);
                }
                rebuilt.push(g);
            }
            if rebuilt != self.pressure_cache {
                violations.push(format!(
                    "pressure cache marked clean but differs from rebuild: \
                     cached {:?}, rebuilt {:?}",
                    self.pressure_cache, rebuilt
                ));
            }
        }
        // (3) Up-to-date status snapshots must mirror the lifecycle state:
        // accepting ≡ Active. Dirty slots are skipped — their snapshot is
        // legitimately stale until the next batched refresh.
        for (j, st) in self.status_buf.iter().enumerate() {
            if self.status_dirty[j] {
                continue;
            }
            let active = self.instance_state[j] == InstanceState::Active;
            if st.accepting != active {
                violations.push(format!(
                    "slot {j} is {:?} but its snapshot has accepting={}",
                    self.instance_state[j], st.accepting
                ));
            }
        }
        // (4) Prefix-cache bookkeeping: every engine's cache must respect
        // its block budget and internal accounting (cached blocks ≤
        // budget, per-entry block math consistent with the block size).
        for (j, e) in self.engines.iter().enumerate() {
            if let Some(pc) = e.prefix_cache() {
                for v in pc.audit() {
                    violations.push(format!("instance {j} prefix cache: {v}"));
                }
            }
        }
        violations
    }

    /// Deliberately desynchronize the family index (test hook for proving
    /// [`Self::audit_invariants`] detects corruption).
    #[cfg(test)]
    pub(crate) fn corrupt_family_index_for_test(&mut self) {
        if let Some(f) = self.families.first_mut() {
            f.active += 1;
        }
    }

    /// Register every provisioned instance whose boot delay has elapsed,
    /// in provision order. Called from [`Self::pump`] and
    /// [`Self::refresh`] so activation points are deterministic across
    /// drivers.
    fn activate_booted(&mut self, now: Time) {
        if self.pending_boots.is_empty() {
            return;
        }
        let mut i = 0;
        while i < self.pending_boots.len() {
            if self.pending_boots[i].ready_at <= now {
                let pb = self.pending_boots.remove(i);
                // Provisioning only happens on fleets with a factory, so
                // this cannot fail.
                let _ = self.add_instance(pb.spec, now);
            } else {
                i += 1;
            }
        }
    }

    /// Instances provisioned by the autoscaler that are still booting.
    pub fn booting_instances(&self) -> usize {
        self.pending_boots.len()
    }

    /// Mean queuing-time ratio of requests finished since the previous
    /// autoscale observation (the paper's load-calibration metric, here as
    /// the scale-up pressure signal). Accumulated streamingly by the
    /// metrics layer so the window survives lean mode (where
    /// `metrics.requests` retains nothing).
    fn recent_queue_ratio(&mut self) -> f64 {
        self.metrics.take_recent_queue_ratio()
    }

    /// Per-model-family load signals for the autoscaler, in fleet-index
    /// first-seen order (deterministic across drivers): each family's
    /// queue depth (pinned + routed-`Any` shards), its live instance count
    /// and its still-booting provision count.
    fn group_loads(&self) -> Vec<GroupLoad> {
        let mut groups: Vec<GroupLoad> = Vec::new();
        for (j, spec) in self.fleet.instances.iter().enumerate() {
            let active = self.instance_state[j] == InstanceState::Active;
            match groups.iter_mut().find(|g| g.model == spec.model) {
                Some(g) => g.active_instances += active as usize,
                None => groups.push(GroupLoad {
                    model: spec.model,
                    queue_len: self.queue.group_len(spec.model),
                    active_instances: active as usize,
                    pending_instances: 0,
                }),
            }
        }
        // Booting capacity counts against its family's ceiling; a pending
        // family the fleet has never held gets its own row (appended, so
        // fleet first-seen order is preserved).
        for pb in &self.pending_boots {
            match groups.iter_mut().find(|g| g.model == pb.spec.model) {
                Some(g) => g.pending_instances += 1,
                None => groups.push(GroupLoad {
                    model: pb.spec.model,
                    queue_len: self.queue.group_len(pb.spec.model),
                    active_instances: 0,
                    pending_instances: 1,
                }),
            }
        }
        groups
    }

    /// The spec to grow family `model` with: the scaler's template when it
    /// already serves that family, else the first fleet instance of the
    /// family (so a grown 13B co-tenant inherits the 13B group's geometry),
    /// else the template re-pointed at the model.
    fn grow_template(&self, model: ModelKind, template: InstanceSpec) -> InstanceSpec {
        if template.model == model {
            return template;
        }
        self.fleet
            .instances
            .iter()
            .copied()
            .find(|s| s.model == model)
            .unwrap_or(InstanceSpec { model, ..template })
    }

    /// Consult the autoscaling policy and apply its decision: grow the
    /// starved group with the backend factory (provisioning first when a
    /// boot delay is configured), or start draining the highest-index
    /// active instance whose family sits above its per-group floor
    /// (deterministic, so both drivers make identical choices).
    fn autoscale(&mut self, now: Time) {
        let Some(mut scaler) = self.autoscaler.take() else { return };
        let obs = FleetObservation {
            queue_len: self.queue.len(),
            active_instances: self.active_instances(),
            draining_instances: self.draining_instances(),
            pending_instances: self.pending_boots.len(),
            recent_queue_ratio: self.recent_queue_ratio(),
            can_grow: self.make_backend.is_some(),
            groups: self.group_loads(),
        };
        match scaler.observe(&obs, now) {
            Some(ScaleAction::Grow(model)) => {
                let cfg = scaler.config();
                let spec = self.grow_template(model, cfg.template);
                // The grown family's own boot delay (big models provision
                // slower), falling back to the global scalar.
                let delay = cfg.boot_delay_for(model);
                if delay > 0.0 {
                    // The slot is capacity-on-the-way, not capacity: it
                    // registers at the first pump/refresh past ready_at.
                    self.pending_boots
                        .push(PendingBoot { ready_at: now + delay, spec });
                    self.scale_log.push(ScaleEvent {
                        at: now,
                        instance: PROVISIONING,
                        kind: ScaleEventKind::Provision,
                        dispatch_seq: self.dispatch_log.total() as usize,
                    });
                } else {
                    // observe() only emits Grow when `can_grow` held, so
                    // the factory is present and this cannot fail.
                    let _ = self.add_instance(spec, now);
                }
            }
            Some(ScaleAction::Shrink) => {
                // Highest-index active instance whose family can lose a
                // slot without dipping below its per-group floor.
                let cfg = scaler.config();
                let victim = (0..self.instance_state.len()).rev().find(|&j| {
                    if self.instance_state[j] != InstanceState::Active {
                        return false;
                    }
                    let model = self.fleet.instances[j].model;
                    let family_active = (0..self.instance_state.len())
                        .filter(|&i| {
                            self.instance_state[i] == InstanceState::Active
                                && self.fleet.instances[i].model == model
                        })
                        .count();
                    family_active > cfg.family_min(model)
                });
                if let Some(j) = victim {
                    let _ = self.retire_instance(j, now);
                }
            }
            None => {}
        }
        self.autoscaler = Some(scaler);
    }

    /// Fold-and-zero one instance's cumulative counters into the run
    /// metrics: recompute waste, prefix-cache traffic, and KV
    /// allocation failures. Zeroing keeps the fold idempotent — a
    /// drained instance's counters are folded once at retirement and
    /// contribute zeros to the end-of-run sweep.
    fn fold_instance_counters(&mut self, j: usize) {
        let e = &mut self.engines[j];
        self.metrics.recomputed_tokens += e.recomputed_tokens;
        e.recomputed_tokens = 0;
        self.metrics.stream.alloc_failures += e.take_alloc_failures();
        if let Some(pc) = e.prefix_cache_mut() {
            let c = &mut self.metrics.stream.cache;
            c.hits += std::mem::take(&mut pc.hits);
            c.misses += std::mem::take(&mut pc.misses);
            c.saved_prefill_tokens += std::mem::take(&mut pc.saved_prefill_tokens);
            c.insertions += std::mem::take(&mut pc.insertions);
            c.evictions += std::mem::take(&mut pc.evictions);
        }
    }

    /// Sum per-engine counters into the metrics (end of run).
    pub fn fold_engine_counters(&mut self) {
        for j in 0..self.engines.len() {
            self.fold_instance_counters(j);
        }
        // Final sync for runs that end between refreshes.
        self.metrics.stream.packer = self.dispatch_stats();
    }

    /// Number of workflows still in flight.
    pub fn open_workflows(&self) -> usize {
        self.workflows.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use crate::stats::rng::Rng;

    #[test]
    fn fleet_parse_roundtrip() {
        let f = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.5:64,tiny").unwrap();
        assert_eq!(f.len(), 4);
        assert_eq!(f.instances[0].model, ModelKind::Llama3_8B);
        assert!((f.instances[0].kv_scale - 0.12).abs() < 1e-12);
        assert_eq!(f.instances[0].max_batch, 256);
        assert_eq!(f.instances[2].model, ModelKind::Llama2_13B);
        assert_eq!(f.instances[2].max_batch, 64);
        assert!((f.instances[2].kv_scale - 0.5).abs() < 1e-12);
        assert_eq!(f.instances[3].model, ModelKind::Tiny);
        assert!(f.is_heterogeneous());
        assert!(!FleetSpec::homogeneous(4, InstanceSpec::new(ModelKind::Llama3_8B))
            .is_heterogeneous());
    }

    #[test]
    fn fleet_parse_rejects_garbage() {
        assert!(FleetSpec::parse("").is_err());
        assert!(FleetSpec::parse("gpt5").is_err());
        assert!(FleetSpec::parse("0*llama3-8b").is_err());
        assert!(FleetSpec::parse("llama3-8b@-1").is_err());
        assert!(FleetSpec::parse("llama3-8b@nope").is_err());
        assert!(FleetSpec::parse("llama3-8b:0").is_err());
        assert!(FleetSpec::parse("llama3-8b,,tiny").is_err());
    }

    #[test]
    fn fleet_parse_rejects_whitespace_only_spec() {
        let err = FleetSpec::parse("   ").unwrap_err();
        assert!(err.contains("empty fleet spec"), "{err}");
    }

    #[test]
    fn fleet_parse_rejects_non_finite_kv_scale() {
        // `inf > 0.0` holds, so these used to pass straight through into
        // an effectively unbounded KV pool.
        for spec in ["llama3-8b@inf", "llama3-8b@1e999", "llama3-8b@NaN"] {
            let err = FleetSpec::parse(spec).unwrap_err();
            assert!(err.contains("kv_scale"), "{spec}: {err}");
            assert!(err.contains("llama3-8b@"), "error must name the clause: {err}");
        }
    }

    #[test]
    fn fleet_parse_rejects_duplicate_separators_naming_the_clause() {
        for spec in ["llama3-8b:64:32", "2*2*llama3-8b"] {
            let err = FleetSpec::parse(spec).unwrap_err();
            assert!(err.contains("separator"), "{spec}: {err}");
        }
        // Doubled `@`/misplaced `:` fail in the value parse, also naming
        // the offending clause.
        let err = FleetSpec::parse("llama3-8b@0.5@0.3").unwrap_err();
        assert!(err.contains("llama3-8b@0.5@0.3"), "{err}");
        let err = FleetSpec::parse("tiny,llama3-8b@0.5:64:32").unwrap_err();
        assert!(err.contains("llama3-8b@0.5:64:32"), "{err}");
    }

    #[test]
    fn instance_spec_scales_blocks() {
        let full = InstanceSpec::new(ModelKind::Llama3_8B).engine_config();
        let half = InstanceSpec::new(ModelKind::Llama3_8B)
            .with_kv_scale(0.5)
            .engine_config();
        assert!(half.total_blocks < full.total_blocks);
        assert!(half.total_blocks >= full.total_blocks / 2 - 1);
        let tiny = InstanceSpec::new(ModelKind::Llama3_8B)
            .with_kv_scale(1e-9)
            .engine_config();
        assert!(tiny.total_blocks >= 1, "never below one block");
    }

    #[test]
    fn manual_clock_is_monotone() {
        let c = ManualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(3.0);
        c.advance_to(1.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    fn small_fleet(n: usize, kv_scale: f64) -> FleetSpec {
        FleetSpec::homogeneous(
            n,
            InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(kv_scale),
        )
    }

    #[test]
    fn external_requests_complete_without_workflows() {
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let id = c.submit_external("AgentA", 64, 8, 0.0);
        let woken = c.pump(0.0);
        assert_eq!(woken, vec![0]);
        let mut now = 0.0;
        let mut done = Vec::new();
        for _ in 0..100 {
            let out = c.step_engine(0, now);
            if out.duration == 0.0 {
                break;
            }
            now += out.duration;
            let abs = c.absorb(0, out, now);
            done.extend(abs.completed);
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].req.id, id);
        assert_eq!(c.metrics.requests.len(), 1);
        assert_eq!(c.metrics.workflows.len(), 0, "no workflow record for external");
        assert!(!c.has_work());
    }

    #[test]
    fn pump_logs_every_dispatch_and_reuses_snapshot() {
        let mut c = Coordinator::sim(
            small_fleet(2, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let mut rng = Rng::new(1);
        for i in 0..6 {
            let plan = WorkflowPlan::sample(crate::agents::apps::App::Rg, "TQ", &mut rng);
            c.submit_plan(plan, i as f64 * 0.01);
        }
        let woken = c.pump(0.1);
        assert_eq!(c.dispatch_log.len(), 6, "all first stages dispatched");
        // Round-robin alternates, so both instances received work.
        assert_eq!(woken.len(), 2);
        let picks: Vec<usize> = c.dispatch_log.iter().map(|&(_, j)| j).collect();
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn add_instance_registers_live_and_receives_work() {
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let spec = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12);
        let j = c.add_instance(spec, 1.0).unwrap();
        assert_eq!(j, 1);
        assert_eq!(c.n_instances(), 2);
        assert_eq!(c.active_instances(), 2);
        assert_eq!(c.fleet.len(), 2);
        assert_eq!(c.scale_log.len(), 1);
        assert_eq!(c.scale_log[0].kind, ScaleEventKind::Grow);
        // Round-robin immediately alternates across both instances.
        for i in 0..4 {
            c.submit_external("A", 16, 4, 1.0 + i as f64 * 0.001);
        }
        let woken = c.pump(1.1);
        assert_eq!(woken.len(), 2, "new instance takes traffic");
        let picks: Vec<usize> = c.dispatch_log.iter().map(|&(_, j)| j).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn retire_drains_then_folds_with_no_lost_requests() {
        let mut c = Coordinator::sim(
            small_fleet(2, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        for i in 0..4 {
            c.submit_external("A", 32, 6, i as f64 * 0.001);
        }
        c.pump(0.1);
        assert_eq!(c.dispatch_log.len(), 4);
        // Instance 1 has in-flight work: retirement must drain, not drop.
        c.retire_instance(1, 0.2).unwrap();
        assert_eq!(c.instance_state(1), InstanceState::Draining);
        assert!(c.retire_instance(1, 0.2).is_err(), "double retire rejected");
        let before = c.dispatch_log.len();
        // New work only lands on instance 0 while 1 drains.
        for i in 0..3 {
            c.submit_external("B", 16, 4, 0.3 + i as f64 * 0.001);
        }
        let woken = c.pump(0.4);
        assert_eq!(woken, vec![0]);
        assert!(c.dispatch_log.iter().skip(before).all(|&(_, j)| j == 0));
        // Run both engines to completion; the drained instance retires.
        let mut now = 0.4;
        for _ in 0..200 {
            let mut idle = true;
            for j in 0..c.n_instances() {
                if !c.engines[j].has_work() {
                    continue;
                }
                idle = false;
                let out = c.step_engine(j, now);
                now += out.duration.max(1e-6);
                c.absorb(j, out, now);
            }
            c.pump(now);
            if idle {
                break;
            }
        }
        assert_eq!(c.instance_state(1), InstanceState::Retired);
        assert_eq!(c.dropped, 0, "draining must not drop in-flight requests");
        assert_eq!(c.metrics.requests.len(), 7, "every request completed");
        assert!(c
            .scale_log
            .iter()
            .any(|e| e.kind == ScaleEventKind::RetireDone && e.instance == 1));
    }

    #[test]
    fn no_accepting_instances_defers_instead_of_dropping() {
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        c.retire_instance(0, 0.0).unwrap();
        c.submit_external("A", 32, 4, 0.1);
        let woken = c.pump(0.2);
        assert!(woken.is_empty());
        assert_eq!(c.dropped, 0, "deferred, not dropped");
        assert_eq!(c.queue.len(), 1);
    }

    #[test]
    fn pressure_trace_moves_visible_capacity_but_not_drop_rule() {
        use crate::server::pressure::PressureTrace;
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let full = c.statuses(0.0)[0].capacity_tokens;
        c.set_pressure(PressureTrace::parse("*:10=0.5,20=1.0").unwrap());
        assert_eq!(c.statuses(0.0)[0].capacity_tokens, full, "no pressure yet");
        let squeezed = c.statuses(10.0)[0].capacity_tokens;
        assert!(
            squeezed < full && squeezed >= full / 2 - 1,
            "squeezed={squeezed} full={full}"
        );
        assert_eq!(c.statuses(25.0)[0].capacity_tokens, full, "pressure lifted");
        // A request larger than the squeezed budget but within the
        // physical pool is deferred by dispatch, never dropped outright.
        c.set_pressure(PressureTrace::parse("*:0=0.01").unwrap());
        let prompt = (full / 2) as u32;
        c.submit_external("A", prompt, 4, 0.0);
        c.pump(0.0);
        assert_eq!(c.dropped, 0, "transient squeeze must not drop");
    }

    #[test]
    fn add_instance_reuses_compatible_tombstone_slot() {
        let mut c = Coordinator::sim(
            small_fleet(3, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        // Idle instance 1 retires on the spot and becomes a tombstone.
        c.retire_instance(1, 0.0).unwrap();
        assert_eq!(c.instance_state(1), InstanceState::Retired);
        assert_eq!(c.active_instances(), 2);
        // A same-family grow fills the tombstone: same index, fresh
        // engine, no fleet-vector growth.
        let spec = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12);
        let j = c.add_instance(spec, 1.0).unwrap();
        assert_eq!(j, 1, "tombstone slot re-used");
        assert_eq!(c.n_instances(), 3, "instance vector did not grow");
        assert_eq!(c.active_instances(), 3);
        assert_eq!(c.instance_state(1), InstanceState::Active);
        // The revived slot takes traffic again (dispatcher state resized
        // and reset for the slot).
        for i in 0..3 {
            c.submit_external("A", 16, 4, 1.0 + i as f64 * 0.001);
        }
        let woken = c.pump(1.1);
        assert_eq!(woken.len(), 3, "all three slots serve traffic");
    }

    #[test]
    fn cross_family_grow_leaves_tombstone_alone() {
        let mut c = Coordinator::sim(
            small_fleet(2, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        c.retire_instance(1, 0.0).unwrap();
        assert_eq!(c.instance_state(1), InstanceState::Retired);
        // A 13B grow must NOT fill the 8B tombstone: the slot's family is
        // part of its identity (group membership stays stable).
        let j = c.add_instance(InstanceSpec::new(ModelKind::Llama2_13B), 1.0).unwrap();
        assert_eq!(j, 2, "cross-family tombstone left alone");
        assert_eq!(c.instance_state(1), InstanceState::Retired);
        assert_eq!(c.n_instances(), 3);
        // A later same-family grow re-fills it.
        let spec = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12);
        let j2 = c.add_instance(spec, 2.0).unwrap();
        assert_eq!(j2, 1, "same-family grow re-uses the tombstone");
        assert_eq!(c.n_instances(), 3);
        assert_eq!(c.active_instances(), 3);
    }

    #[test]
    fn pinned_requests_route_to_their_group() {
        let mut fleet = FleetSpec::default();
        fleet.push(InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12));
        fleet.push(InstanceSpec::new(ModelKind::Llama2_13B).with_kv_scale(0.12));
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        c.set_affinity(&AffinitySpec::parse("A=llama2-13b,B=llama3-8b").unwrap());
        for i in 0..3 {
            c.submit_external("A", 16, 4, i as f64 * 0.001);
        }
        for i in 0..3 {
            c.submit_external("B", 16, 4, 0.01 + i as f64 * 0.001);
        }
        c.pump(0.1);
        assert_eq!(c.dispatch_log.len(), 6);
        assert_eq!(c.group_log.len(), 6);
        for g in &c.group_log {
            assert!(g.class.matches(g.model), "cross-model dispatch: {g:?}");
        }
        let to_13b = c.group_log.iter().filter(|g| g.instance == 1).count();
        let to_8b = c.group_log.iter().filter(|g| g.instance == 0).count();
        assert_eq!((to_8b, to_13b), (3, 3), "each group served its own pins");
        // The default routing policy logs every decision as a static pin.
        assert_eq!(c.route_log.len(), 6);
        for d in &c.route_log {
            assert_eq!(d.chosen, d.class, "pinned routing never overrides");
            assert_eq!(d.group, None);
            assert_eq!(d.reason, crate::orchestrator::router::RouteReason::Pinned);
        }
    }

    #[test]
    fn learned_routing_balances_any_across_groups() {
        use crate::orchestrator::router::RouteReason;
        let mut fleet = FleetSpec::default();
        fleet.push(InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12));
        fleet.push(InstanceSpec::new(ModelKind::Llama2_13B).with_kv_scale(0.12));
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        // No exploration, unreachable min_samples: pure pressure balancing.
        c.set_route_policy(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 1_000_000 });
        for i in 0..4 {
            c.submit_external("A", 16, 4, i as f64 * 0.001);
        }
        assert_eq!(c.route_log.len(), 4);
        // Every decision balanced into SOME group, class stayed Any.
        let groups: Vec<_> = c.route_log.iter().map(|d| d.group).collect();
        for d in &c.route_log {
            assert_eq!(d.chosen, ModelClass::Any);
            assert_eq!(d.reason, RouteReason::LeastPressured);
        }
        // The queued-depth feedback alternates the assignment: the first
        // request lands on the roomier 8B group, the second sees its
        // backlog and takes the 13B group, and so on.
        assert_eq!(
            groups,
            vec![
                Some(ModelKind::Llama3_8B),
                Some(ModelKind::Llama2_13B),
                Some(ModelKind::Llama3_8B),
                Some(ModelKind::Llama2_13B),
            ]
        );
        // All of them still dispatch (class Any is work-conserving).
        c.pump(0.1);
        assert_eq!(c.dispatch_log.len(), 4);
    }

    #[test]
    fn any_routed_to_a_blocked_group_still_dispatches() {
        let mut fleet = FleetSpec::default();
        fleet.push(InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12));
        fleet.push(InstanceSpec::new(ModelKind::Llama2_13B).with_kv_scale(0.12));
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        c.set_affinity(&AffinitySpec::parse("A=llama2-13b").unwrap());
        // The 13B family drains away: its pinned shard's head defers every
        // round (the family could be revived), blocking that shard only.
        c.retire_instance(1, 0.0).unwrap();
        c.submit_external("A", 16, 4, 0.1);
        // An Any request balanced into the 13B group's routed shard by an
        // earlier pressure snapshot must NOT starve behind the blocked
        // pinned head: it waits in its own AnyIn shard and its class still
        // lets it dispatch to the free 8B instance.
        let req = Request {
            id: 999,
            msg_id: 999,
            agent: AgentId(7),
            session: 999,
            model_class: ModelClass::Any,
            upstream: None,
            prompt_tokens: 16,
            true_output_tokens: 4,
            true_remaining_latency: 0.0,
            remaining_stages: 1,
            app_start: 0.2,
            stage_arrival: 0.2,
        };
        c.queue.push_routed(
            req,
            ShardKey::AnyIn(ModelKind::Llama2_13B),
            c.policy.as_ref(),
        );
        let woken = c.pump(0.3);
        assert_eq!(woken, vec![0], "Any request reached the free group");
        assert!(c.dispatch_log.iter().any(|&(id, j)| id == 999 && j == 0));
        assert_eq!(c.queue.len(), 1, "only the pinned request still waits");
        assert_eq!(c.dropped, 0);
    }

    #[test]
    fn boot_delay_defers_registration_until_elapsed() {
        use crate::server::autoscale::AutoscaleConfig;
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let mut cfg = AutoscaleConfig::for_template(
            InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12),
        );
        cfg.max_instances = 4;
        cfg.queue_high = 0.5;
        cfg.up_after = 1;
        cfg.cooldown = 1000.0;
        cfg.boot_delay = 5.0;
        c.set_autoscaler(Autoscaler::new(cfg));
        for i in 0..8 {
            c.submit_external("A", 16, 4, i as f64 * 0.001);
        }
        c.refresh(0.5);
        assert_eq!(c.n_instances(), 1, "provisioned, not yet registered");
        assert_eq!(c.booting_instances(), 1);
        assert!(c
            .scale_log
            .iter()
            .any(|e| e.kind == ScaleEventKind::Provision && e.instance == PROVISIONING));
        assert!(!c.scale_log.iter().any(|e| e.kind == ScaleEventKind::Grow));
        c.pump(2.0);
        assert_eq!(c.n_instances(), 1, "still inside the boot window");
        c.pump(5.6);
        assert_eq!(c.n_instances(), 2, "registered once the delay elapsed");
        assert_eq!(c.booting_instances(), 0);
        assert!(c
            .scale_log
            .iter()
            .any(|e| e.kind == ScaleEventKind::Grow && e.instance == 1));
    }

    #[test]
    fn shrink_victim_respects_per_group_floor() {
        use crate::server::autoscale::{parse_per_group, AutoscaleConfig};
        // Fleet: 8B, 8B, 13B. The 13B family has a floor of one instance,
        // so a cold-fleet shrink must drain an 8B slot even though the 13B
        // holds the highest index.
        let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        let mut cfg = AutoscaleConfig::for_template(
            InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12),
        );
        cfg.min_instances = 1;
        cfg.down_after = 1;
        cfg.cooldown = 0.0;
        cfg.per_group = parse_per_group("llama2-13b=1..2").unwrap();
        c.set_autoscaler(Autoscaler::new(cfg));
        c.refresh(1.0);
        assert_eq!(c.instance_state(2), InstanceState::Active, "13B floor honored");
        assert_eq!(c.instance_state(1), InstanceState::Retired, "8B drained instead");
    }

    #[test]
    fn starved_group_defers_without_blocking_others() {
        let mut fleet = FleetSpec::default();
        fleet.push(InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12));
        fleet.push(InstanceSpec::new(ModelKind::Llama2_13B).with_kv_scale(0.12));
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        c.set_affinity(&AffinitySpec::parse("A=llama2-13b,B=llama3-8b").unwrap());
        // The 13B family drains away entirely; its shard must defer (the
        // family can be revived) WITHOUT stalling the 8B shard, even
        // though the 13B-pinned request arrived first (FCFS head).
        c.retire_instance(1, 0.0).unwrap();
        c.submit_external("A", 16, 4, 0.1);
        c.submit_external("B", 16, 4, 0.2);
        let woken = c.pump(0.3);
        assert_eq!(woken, vec![0], "8B shard kept dispatching");
        assert_eq!(c.queue.len(), 1, "13B-pinned request still queued");
        assert_eq!(c.dropped, 0, "deferred, not dropped");
    }

    #[test]
    fn class_with_no_family_in_fleet_drops() {
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        c.set_affinity(&AffinitySpec::parse("C=tiny").unwrap());
        c.submit_external("C", 16, 4, 0.0);
        c.submit_external("D", 16, 4, 0.1);
        let woken = c.pump(0.2);
        assert_eq!(c.dropped, 1, "no tiny slot will ever exist: drop");
        assert_eq!(woken, vec![0], "unpinned request unaffected");
        assert!(c.queue.is_empty());
    }

    #[test]
    fn grow_template_follows_fleet_family() {
        let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.5:64").unwrap();
        let c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        let template = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12);
        // Template already serves the family: used as-is.
        assert_eq!(c.grow_template(ModelKind::Llama3_8B, template), template);
        // Another family present in the fleet: inherit its geometry.
        let grown = c.grow_template(ModelKind::Llama2_13B, template);
        assert_eq!(grown.model, ModelKind::Llama2_13B);
        assert_eq!(grown.max_batch, 64);
        assert!((grown.kv_scale - 0.5).abs() < 1e-12);
        // Family absent from the fleet: template re-pointed at the model.
        let tiny = c.grow_template(ModelKind::Tiny, template);
        assert_eq!(tiny.model, ModelKind::Tiny);
        assert!((tiny.kv_scale - 0.12).abs() < 1e-12);
    }

    #[test]
    fn per_family_boot_delay_defers_that_familys_provisioning() {
        use crate::server::autoscale::{parse_boot_delays, AutoscaleConfig};
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let mut cfg = AutoscaleConfig::for_template(
            InstanceSpec::new(ModelKind::Llama2_13B).with_kv_scale(0.12),
        );
        cfg.max_instances = 4;
        cfg.queue_high = 0.5;
        cfg.up_after = 1;
        cfg.cooldown = 1000.0;
        // Global scalar says instant boot; the 13B family overrides it.
        cfg.boot_delay = 0.0;
        cfg.boot_delay_per_group = parse_boot_delays("llama2-13b=5").unwrap();
        c.set_autoscaler(Autoscaler::new(cfg));
        for i in 0..8 {
            c.submit_external("A", 16, 4, i as f64 * 0.001);
        }
        c.refresh(0.5);
        // The grow targets the template's 13B family, whose per-family
        // delay forces a Provision instead of an instant Grow.
        assert_eq!(c.n_instances(), 1, "13B slot provisioned, not registered");
        assert_eq!(c.booting_instances(), 1);
        assert!(c
            .scale_log
            .iter()
            .any(|e| e.kind == ScaleEventKind::Provision));
        c.pump(2.0);
        assert_eq!(c.n_instances(), 1, "still inside the 13B boot window");
        c.pump(5.6);
        assert_eq!(c.n_instances(), 2, "registered once the family delay elapsed");
        assert_eq!(c.fleet.instances[1].model, ModelKind::Llama2_13B);
    }

    #[test]
    fn submit_plan_captures_a_replayable_trace_record() {
        use crate::agents::apps::App;
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        c.set_affinity(&AffinitySpec::parse("ResearchAgent=llama3-8b").unwrap());
        let mut rng = Rng::new(5);
        let plan = WorkflowPlan::sample(App::Rg, "TQ", &mut rng);
        c.submit_plan(plan.clone(), 1.25);
        assert_eq!(c.trace_log.len(), 1);
        let rec = &c.trace_log[0];
        assert_eq!(rec.at, 1.25);
        assert_eq!(rec.plan(), plan, "record resolves back to the exact plan");
        // Stamps reflect the active affinity: pinned agents carry their
        // class, unpinned agents record no stamp.
        assert_eq!(
            rec.stages[0].class,
            Some(ModelClass::Model(ModelKind::Llama3_8B))
        );
        assert_eq!(rec.stages[1].class, None, "WriterAgent is unpinned");
    }

    #[test]
    fn oversized_prompt_dropped_with_workflow() {
        use crate::agents::apps::{App, PlannedStage};
        // One instance with a near-zero pool (one 16-token block): a
        // 1000-token prompt can never fit, so the whole workflow drops.
        let mut c = Coordinator::sim(
            small_fleet(1, 1e-9),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        let plan = WorkflowPlan {
            app: App::Rg,
            dataset: "TQ",
            stages: vec![
                PlannedStage {
                    agent: "ResearchAgent",
                    prompt_tokens: 1000,
                    output_tokens: 5,
                },
                PlannedStage { agent: "WriterAgent", prompt_tokens: 10, output_tokens: 5 },
            ],
        };
        c.submit_plan(plan, 0.0);
        c.pump(0.0);
        assert_eq!(c.dropped, 1);
        assert_eq!(c.open_workflows(), 0, "whole workflow rejected");
        assert!(c.queue.is_empty());
    }

    #[test]
    fn external_submissions_are_recorded_and_replayable() {
        let mut c = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        c.set_affinity(&AffinitySpec::parse("Pinned=llama3-8b").unwrap());
        c.submit_external("Pinned", 64, 8, 0.5);
        c.submit_external("Free", 32, 4, 0.6);
        assert_eq!(c.trace_log.len(), 2);
        let rec = &c.trace_log[0];
        assert_eq!(rec.at, 0.5);
        assert_eq!(rec.app, App::Ext);
        assert_eq!(rec.dataset, "external");
        assert_eq!(rec.stages.len(), 1);
        assert_eq!(rec.stages[0].agent, "Pinned");
        assert_eq!(rec.stages[0].prompt_tokens, 64);
        assert_eq!(rec.stages[0].output_tokens, 8);
        assert_eq!(
            rec.stages[0].class,
            Some(ModelClass::Model(ModelKind::Llama3_8B))
        );
        assert_eq!(c.trace_log[1].stages[0].class, None, "Free is unpinned");
        // The record survives the JSONL round trip and resolves to a
        // single-stage plan a coordinator accepts back.
        let back = TraceRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(&back, rec);
        let plan = back.plan();
        assert_eq!(plan.app, App::Ext);
        assert_eq!(plan.stages.len(), 1);
        let mut replay = Coordinator::sim(
            small_fleet(1, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        replay.submit_plan(plan, back.at);
        let woken = replay.pump(back.at);
        assert_eq!(woken, vec![0], "replayed external dispatches");
    }

    #[test]
    fn bounded_logs_cap_retention_without_changing_decisions() {
        let build = || {
            Coordinator::sim(
                small_fleet(2, 0.12),
                Box::new(Fcfs),
                Box::new(RoundRobin::new()),
            )
        };
        let mut full = build();
        let mut capped = build();
        capped.set_log_config(LogConfig::bounded(2));
        for c in [&mut full, &mut capped] {
            for i in 0..6 {
                c.submit_external("A", 16, 4, i as f64 * 0.01);
            }
            c.pump(0.1);
        }
        assert_eq!(full.dispatch_log.len(), 6);
        assert_eq!(capped.dispatch_log.len(), 2, "only the newest 2 retained");
        assert_eq!(capped.dispatch_log.total(), 6, "every append counted");
        // The retained tail IS the tail of the full log.
        assert_eq!(capped.dispatch_log.to_vec(), full.dispatch_log.to_vec()[4..]);
        assert_eq!(capped.route_log.len(), 2);
        assert_eq!(capped.trace_log.len(), 2);
        assert!(
            capped.log_state_bytes() < full.log_state_bytes(),
            "capping must shrink resident log state"
        );
    }

    #[test]
    fn legacy_and_indexed_hot_paths_make_identical_decisions() {
        let build = |legacy: bool| {
            let spec = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
            let mut c = Coordinator::sim(
                spec,
                Box::new(Fcfs),
                Box::new(RoundRobin::new()),
            );
            c.set_legacy_hot_path(legacy);
            c.set_route_policy(RoutePolicy::learned_default());
            c.set_affinity(
                &AffinitySpec::parse("Pinned=llama2-13b,Other=llama3-8b").unwrap(),
            );
            let mut now = 0.0;
            for i in 0..40 {
                let agent = match i % 3 {
                    0 => "Pinned",
                    1 => "Other",
                    _ => "Free",
                };
                c.submit_external(agent, 48 + (i % 7) * 16, 8, now);
                now += 0.003;
                if i % 5 == 4 {
                    c.pump(now);
                }
            }
            // Drive to idle, absorbing completions (which enqueue nothing
            // here, but exercise refresh/dirty bookkeeping on both paths).
            for _ in 0..500 {
                c.pump(now);
                let mut idle = true;
                for j in 0..c.n_instances() {
                    if !c.engines[j].has_work() {
                        continue;
                    }
                    idle = false;
                    let out = c.step_engine(j, now);
                    now += out.duration.max(1e-6);
                    c.absorb(j, out, now);
                }
                if idle {
                    break;
                }
            }
            assert!(!c.has_work(), "run must drain");
            c
        };
        let mut legacy = build(true);
        let mut indexed = build(false);
        assert!(!indexed.dispatch_log.is_empty());
        assert_eq!(legacy.dispatch_log.take_vec(), indexed.dispatch_log.take_vec());
        assert_eq!(legacy.group_log.take_vec(), indexed.group_log.take_vec());
        assert_eq!(legacy.route_log.take_vec(), indexed.route_log.take_vec());
        assert_eq!(legacy.metrics.requests.len(), indexed.metrics.requests.len());
    }

    #[test]
    fn audit_passes_through_fleet_churn() {
        let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
        let mut c = Coordinator::sim(fleet, Box::new(Fcfs), Box::new(RoundRobin::new()));
        assert_eq!(c.audit_invariants(), Vec::<String>::new());
        for i in 0..6 {
            c.submit_external("A", 32, 4, i as f64 * 0.01);
        }
        c.pump(0.1);
        assert_eq!(c.audit_invariants(), Vec::<String>::new());
        c.retire_instance(2, 0.2).unwrap();
        let spec = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.12);
        c.add_instance(spec, 0.3).unwrap();
        c.refresh(0.4); // debug builds audit here too
        assert_eq!(c.audit_invariants(), Vec::<String>::new());
    }

    #[test]
    fn audit_catches_corrupted_family_index() {
        let mut c = Coordinator::sim(
            small_fleet(2, 0.12),
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        );
        assert!(c.audit_invariants().is_empty(), "fresh fleet audits clean");
        c.corrupt_family_index_for_test();
        let violations = c.audit_invariants();
        assert!(
            violations.iter().any(|v| v.contains("active count")),
            "corrupted active count must be reported, got: {violations:?}"
        );
    }

    // ---- parallel pump -------------------------------------------------

    /// Everything the parallel pump must reproduce bit-for-bit: the
    /// decision logs, the drop count, the dispatcher's mutable state
    /// digest, and the non-parallel stat counters.
    #[derive(Debug, PartialEq)]
    struct PumpTrace {
        dispatches: Vec<(RequestId, usize)>,
        groups: Vec<GroupDispatch>,
        routes: Vec<RouteDecision>,
        dropped: u64,
        fingerprint: u64,
        completed: usize,
        decisions: u64,
        candidates: u64,
        evaluated: u64,
        fast_accepted: u64,
        fast_rejected: u64,
        rejected_rounds: u64,
        sticky: (u64, u64),
    }

    /// Drive a mixed stream (pinned + free agents, interleaved engine
    /// stepping, optional mid-stream fleet growth) and summarize every
    /// decision artifact the equivalence property compares. `sequential`
    /// pins the reference arm; `threads >= 2` with `sequential = false`
    /// takes the score-in-parallel path for parallel-capable dispatchers.
    fn drive_pump_scenario(
        fleet: &str,
        dispatcher: &str,
        n_reqs: usize,
        churn: bool,
        seed: u64,
        threads: usize,
        sequential: bool,
    ) -> PumpTrace {
        let spec = FleetSpec::parse(fleet).unwrap();
        let disp = crate::server::sim::make_dispatcher_tuned(dispatcher, &spec, None, None);
        let mut c = Coordinator::sim(spec, Box::new(Fcfs), disp);
        c.set_pump_threads(threads);
        c.set_sequential_pump(sequential);
        // Pinning an agent to a family some fleets lack exercises the
        // drop path (never served) alongside ordinary placements.
        c.set_affinity(
            &AffinitySpec::parse("Pinned=llama2-13b,Other=llama3-8b").unwrap(),
        );
        let mut rng = Rng::new(seed);
        let mut now = 0.0;
        for i in 0..n_reqs {
            let agent = match rng.below(3) {
                0 => "Pinned",
                1 => "Other",
                _ => "Free",
            };
            let prompt = (16 + rng.below(200) * 3) as u32;
            let output = (4 + rng.below(24)) as u32;
            c.submit_external(agent, prompt, output, now);
            now += 0.002;
            if rng.chance(0.3) {
                c.pump(now);
            }
            if churn && i == n_reqs / 2 {
                let grown = InstanceSpec::new(ModelKind::Llama3_8B).with_kv_scale(0.1);
                let _ = c.add_instance(grown, now);
            }
            if rng.chance(0.2) {
                for j in 0..c.n_instances() {
                    if c.engines[j].has_work() {
                        let out = c.step_engine(j, now);
                        now += out.duration.max(1e-6);
                        c.absorb(j, out, now);
                    }
                }
            }
        }
        for _ in 0..800 {
            c.pump(now);
            let mut idle = true;
            for j in 0..c.n_instances() {
                if !c.engines[j].has_work() {
                    continue;
                }
                idle = false;
                let out = c.step_engine(j, now);
                now += out.duration.max(1e-6);
                c.absorb(j, out, now);
            }
            if idle {
                break;
            }
        }
        assert_eq!(c.audit_invariants(), Vec::<String>::new());
        let stats = c.dispatch_stats();
        PumpTrace {
            dispatches: c.dispatch_log.take_vec(),
            groups: c.group_log.take_vec(),
            routes: c.route_log.take_vec(),
            dropped: c.dropped,
            fingerprint: c.dispatcher.state_fingerprint(),
            completed: c.metrics.requests.len(),
            decisions: stats.decisions,
            candidates: stats.candidates,
            evaluated: stats.evaluated,
            fast_accepted: stats.fast_accepted,
            fast_rejected: stats.fast_rejected,
            rejected_rounds: stats.rejected_rounds,
            sticky: (stats.sticky_hits, stats.sticky_fallbacks),
        }
    }

    #[test]
    fn parallel_pump_matches_sequential_bit_for_bit() {
        const FLEETS: [&str; 3] = [
            "3*llama3-8b@0.12",
            "2*llama3-8b@0.12,2*llama2-13b@0.12",
            "4*llama3-8b@0.08,llama2-13b@0.2",
        ];
        const DISPATCHERS: [&str; 4] = ["kairos", "oracle", "rr", "cache-affine"];
        crate::testing::forall(
            "parallel-pump-equivalence",
            10,
            0xD15F_A7C4,
            |rng| {
                (
                    FLEETS[rng.below(FLEETS.len())],
                    DISPATCHERS[rng.below(DISPATCHERS.len())],
                    24 + rng.below(32),
                    rng.chance(0.5),
                    rng.next_u64(),
                )
            },
            |&(fleet, disp, n, churn, seed)| {
                let base = drive_pump_scenario(fleet, disp, n, churn, seed, 1, true);
                if base.dispatches.is_empty() {
                    return Err("scenario dispatched nothing".into());
                }
                for threads in [1usize, 2, 4, 8] {
                    let par =
                        drive_pump_scenario(fleet, disp, n, churn, seed, threads, false);
                    if par != base {
                        return Err(format!(
                            "diverged at {threads} threads:\n  sequential: {base:?}\n  \
                             parallel:   {par:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parallel_pump_reports_rounds_conflicts_and_rescores() {
        // Two shards (a pinned family and Any) under a Global-scope policy:
        // every commit invalidates the sibling shard's cached score, so the
        // pump must log conflicts, re-scores, and multiple scoring rounds —
        // while the dispatch log stays identical to the sequential arm's.
        let build = |sequential: bool| {
            let spec = FleetSpec::parse("2*llama3-8b@0.12,2*llama2-13b@0.12").unwrap();
            let mut c =
                Coordinator::sim(spec, Box::new(Fcfs), Box::new(RoundRobin::new()));
            c.set_pump_threads(4);
            c.set_sequential_pump(sequential);
            c.set_affinity(&AffinitySpec::parse("Pinned=llama2-13b").unwrap());
            for i in 0..8 {
                let agent = if i % 2 == 0 { "Pinned" } else { "Free" };
                c.submit_external(agent, 32, 4, i as f64 * 0.001);
            }
            let woken = c.pump(0.05);
            assert!(!woken.is_empty());
            c
        };
        let mut par = build(false);
        let stats = par.dispatch_stats();
        assert!(stats.par_rounds >= 2, "expected re-score rounds, got {stats:?}");
        assert!(stats.conflicts >= 1, "expected conflicts, got {stats:?}");
        assert!(stats.rescored >= 1, "expected rescored heads, got {stats:?}");
        let mut seq = build(true);
        let s = seq.dispatch_stats();
        assert_eq!(
            (s.conflicts, s.rescored, s.par_rounds),
            (0, 0, 0),
            "sequential arm must report no parallel-pump activity"
        );
        assert_eq!(par.dispatch_log.take_vec(), seq.dispatch_log.take_vec());
        assert_eq!(par.group_log.take_vec(), seq.group_log.take_vec());
    }

    #[test]
    fn single_thread_or_unsupported_policy_stays_sequential() {
        // pump_threads == 1 (the default) and sequential pinning both keep
        // the reference arm: no scoring rounds are ever fanned out.
        let spec = FleetSpec::parse("2*llama3-8b@0.12").unwrap();
        let mut c = Coordinator::sim(spec, Box::new(Fcfs), Box::new(RoundRobin::new()));
        for i in 0..4 {
            c.submit_external("A", 16, 4, i as f64 * 0.001);
        }
        c.pump(0.01);
        assert_eq!(c.dispatch_stats().par_rounds, 0);
        assert_eq!(c.dispatch_log.len(), 4);
    }

    #[test]
    fn fold_engine_counters_is_idempotent_across_pump_threads() {
        // Satellite regression: the parallel pump must not change when or
        // how often per-engine counters fold into the run metrics — the
        // folded totals are identical at every thread count, and a second
        // fold adds exactly zero.
        let run = |threads: usize| {
            let spec = FleetSpec::parse("2*llama3-8b@0.08,llama2-13b@0.08").unwrap();
            let disp =
                crate::server::sim::make_dispatcher_tuned("kairos", &spec, None, None);
            let mut c = Coordinator::sim(spec, Box::new(Fcfs), disp);
            c.set_pump_threads(threads);
            c.set_affinity(&AffinitySpec::parse("Pinned=llama2-13b").unwrap());
            let mut now = 0.0;
            for i in 0..24 {
                let agent = if i % 3 == 0 { "Pinned" } else { "Free" };
                c.submit_external(agent, 48 + (i % 5) * 64, 12, now);
                now += 0.002;
                if i % 4 == 3 {
                    c.pump(now);
                }
            }
            for _ in 0..800 {
                c.pump(now);
                let mut idle = true;
                for j in 0..c.n_instances() {
                    if !c.engines[j].has_work() {
                        continue;
                    }
                    idle = false;
                    let out = c.step_engine(j, now);
                    now += out.duration.max(1e-6);
                    c.absorb(j, out, now);
                }
                if idle {
                    break;
                }
            }
            let snapshot = |c: &Coordinator<SimBackend>| {
                (
                    c.metrics.recomputed_tokens,
                    c.metrics.stream.alloc_failures,
                    c.metrics.stream.cache.hits,
                    c.metrics.stream.cache.misses,
                    c.metrics.stream.cache.saved_prefill_tokens,
                    c.metrics.stream.cache.insertions,
                    c.metrics.stream.cache.evictions,
                    c.metrics.requests.len(),
                )
            };
            c.fold_engine_counters();
            let first = snapshot(&c);
            c.fold_engine_counters();
            assert_eq!(first, snapshot(&c), "second fold must add zero");
            first
        };
        let base = run(1);
        assert_eq!(base, run(2), "folded metrics diverged at 2 threads");
        assert_eq!(base, run(4), "folded metrics diverged at 4 threads");
    }
}
