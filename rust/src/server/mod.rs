//! The full serving system.
//!
//! * [`sim`] — the virtual-time system: workload arrivals → frontend →
//!   central queue → priority scheduler → dispatcher → vLLM-like engine
//!   instances → orchestrator feedback loop. Every figure/bench harness
//!   runs through this driver.
//! * [`real`] — the wall-clock system: the same coordination stack driving
//!   real PJRT compute (the AOT-compiled tiny model) for the end-to-end
//!   quickstart.

pub mod real;
pub mod sim;

pub use sim::{SimConfig, SimResult, SimServer};
