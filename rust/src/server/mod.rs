//! The full serving system.
//!
//! * [`coordinator`] — the clock-agnostic runtime: the
//!   queue→schedule→dispatch→engine→orchestrator-feedback cycle, generic
//!   over the engine backend, plus the heterogeneous [`FleetSpec`] and the
//!   [`Clock`] seam. All coordination decisions live here, exactly once.
//! * [`sim`] — the virtual-time driver: a discrete-event loop (workload
//!   arrivals, engine iterations, periodic refreshes) over the coordinator.
//!   Every figure/bench harness runs through this driver.
//! * [`real`] — the wall-clock driver: the same coordinator driving real
//!   PJRT compute (the AOT-compiled tiny model) for the end-to-end
//!   quickstart.

pub mod coordinator;
pub mod real;
pub mod sim;

pub use coordinator::{Clock, Coordinator, FleetSpec, InstanceSpec, ManualClock, WallClock};
pub use sim::{FleetConfig, SimConfig, SimResult, SimServer};
