//! The full serving system.
//!
//! * [`coordinator`] — the clock-agnostic runtime: the
//!   queue→schedule→dispatch→engine→orchestrator-feedback cycle, generic
//!   over the engine backend, plus the heterogeneous [`FleetSpec`] and the
//!   [`Clock`] seam. All coordination decisions live here, exactly once.
//! * [`sim`] — the virtual-time driver: a discrete-event loop (workload
//!   arrivals, engine iterations, periodic refreshes) over the coordinator.
//!   Every figure/bench harness runs through this driver.
//! * [`real`] — the wall-clock driver: the same coordinator driving real
//!   PJRT compute (the AOT-compiled tiny model) for the end-to-end
//!   quickstart.
//! * [`autoscale`] — the elastic-fleet policy: queue-depth / queuing-ratio
//!   thresholds with hysteresis deciding when the coordinator grows the
//!   fleet or drains an instance back out.
//! * [`pressure`] — co-tenant memory-pressure traces: piecewise
//!   `kv_scale` multipliers that vary each instance's visible KV budget
//!   over time.
//! * [`pump_pool`] — the parallel pump's scoped worker pool: the ONLY
//!   module allowed to spawn threads outside tests (kairos-lint rule
//!   `thread-spawn`), so every concurrency decision stays order-free.

pub mod autoscale;
pub mod coordinator;
pub mod pressure;
pub mod pump_pool;
pub mod real;
pub mod sim;

pub use autoscale::{
    parse_per_group, AutoscaleConfig, Autoscaler, FleetObservation, GroupBounds, GroupLoad,
    ScaleAction,
};
pub use coordinator::{
    Clock, Coordinator, FleetSpec, GroupDispatch, InstanceSpec, InstanceState, ManualClock,
    ScaleEvent, ScaleEventKind, WallClock, PROVISIONING,
};
pub use pressure::PressureTrace;
pub use sim::{CacheTuning, FleetConfig, SimConfig, SimResult, SimServer};
