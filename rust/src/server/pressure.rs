//! Co-tenant memory-pressure traces.
//!
//! In the paper's public cloud, each serving instance shares its GPU with
//! co-tenant jobs whose memory footprint moves over time — the KV budget a
//! dispatcher can actually use is not a constant. A [`PressureTrace`] is a
//! piecewise-constant multiplier on each instance's KV capacity over time:
//! the coordinator samples it whenever it refreshes the per-instance
//! status snapshot and scales [`InstanceStatus::capacity_tokens`]
//! accordingly, so the memory-aware dispatchers pack against the *moving*
//! budgets instead of the construction-time ones.
//!
//! [`InstanceStatus::capacity_tokens`]: crate::engine::core::InstanceStatus::capacity_tokens
//!
//! Trace grammar (CLI `--pressure`, config `[pressure] trace = "..."`):
//! `;`-separated entries of `TARGET:TIME=MULT,TIME=MULT,...` where TARGET
//! is an instance index or `*` (every instance without its own entry), the
//! times ascend, and each multiplier (> 0) applies from its time until the
//! next step. Example — all instances squeezed to 50% between t=30 s and
//! t=90 s while instance 2 is permanently down to 80%:
//!
//! ```text
//! *:0=1.0,30=0.5,90=1.0;2:0=0.8
//! ```

use std::collections::HashMap;

use crate::Time;

/// Piecewise-constant per-instance `kv_scale` multipliers over time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PressureTrace {
    /// Steps applying to every instance without a per-instance override.
    global: Vec<(Time, f64)>,
    /// Per-instance overrides (instance index → steps).
    per: HashMap<usize, Vec<(Time, f64)>>,
}

fn step_at(steps: &[(Time, f64)], t: Time) -> f64 {
    let mut m = 1.0;
    for &(at, v) in steps {
        if t >= at {
            m = v;
        } else {
            break;
        }
    }
    m
}

fn parse_steps(s: &str, entry: &str) -> Result<Vec<(Time, f64)>, String> {
    let mut steps: Vec<(Time, f64)> = Vec::new();
    for raw in s.split(',') {
        let part = raw.trim();
        let (t, m) = part
            .split_once('=')
            .ok_or_else(|| format!("expected TIME=MULT, got {part:?} in {entry:?}"))?;
        let t: Time = t
            .trim()
            .parse()
            .map_err(|_| format!("bad time {t:?} in {entry:?}"))?;
        let m: f64 = m
            .trim()
            .parse()
            .map_err(|_| format!("bad multiplier {m:?} in {entry:?}"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("bad time {t} in {entry:?}"));
        }
        if !m.is_finite() || m <= 0.0 || m > 1.0 {
            // A co-tenant can only take capacity away: multipliers above
            // 1.0 would report more KV than the engine physically has and
            // drive the memory-aware dispatchers into preemption storms.
            return Err(format!("multiplier must be in (0, 1], got {m} in {entry:?}"));
        }
        if let Some(&(prev, _)) = steps.last() {
            if t <= prev {
                return Err(format!("times must ascend in {entry:?}"));
            }
        }
        steps.push((t, m));
    }
    Ok(steps)
}

impl PressureTrace {
    /// Parse the compact trace grammar (see module docs).
    pub fn parse(s: &str) -> Result<PressureTrace, String> {
        let mut trace = PressureTrace::default();
        for raw in s.split(';') {
            let entry = raw.trim();
            if entry.is_empty() {
                return Err(format!("empty pressure entry in {s:?}"));
            }
            let (target, steps) = entry
                .split_once(':')
                .ok_or_else(|| format!("expected TARGET:STEPS, got {entry:?}"))?;
            let steps = parse_steps(steps, entry)?;
            if steps.is_empty() {
                return Err(format!("no steps in {entry:?}"));
            }
            match target.trim() {
                "*" => {
                    if !trace.global.is_empty() {
                        return Err(format!("duplicate `*` entry in {s:?}"));
                    }
                    trace.global = steps;
                }
                idx => {
                    let j: usize = idx
                        .parse()
                        .map_err(|_| format!("bad instance index {idx:?} in {entry:?}"))?;
                    if trace.per.insert(j, steps).is_some() {
                        return Err(format!("duplicate entry for instance {j} in {s:?}"));
                    }
                }
            }
        }
        Ok(trace)
    }

    /// A trace applying the same steps to every instance.
    pub fn uniform(steps: Vec<(Time, f64)>) -> PressureTrace {
        PressureTrace { global: steps, per: HashMap::new() }
    }

    /// Override the steps of one instance (builder style).
    pub fn with_instance(mut self, instance: usize, steps: Vec<(Time, f64)>) -> Self {
        self.per.insert(instance, steps);
        self
    }

    /// Capacity multiplier of `instance` at time `t`. A per-instance entry
    /// overrides the `*` steps; the `*` steps apply to every other
    /// instance, including ones the autoscaler adds later. 1.0 before the
    /// first applicable step and for instances no entry covers.
    pub fn multiplier(&self, instance: usize, t: Time) -> f64 {
        match self.per.get(&instance) {
            Some(steps) => step_at(steps, t),
            None => step_at(&self.global, t),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.global.is_empty() && self.per.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_global_and_overrides() {
        let p = PressureTrace::parse("*:0=1.0,30=0.5,90=1.0;2:0=0.8").unwrap();
        assert_eq!(p.multiplier(0, 0.0), 1.0);
        assert_eq!(p.multiplier(0, 30.0), 0.5);
        assert_eq!(p.multiplier(0, 89.9), 0.5);
        assert_eq!(p.multiplier(0, 90.0), 1.0);
        // Instance 2 is fully overridden — the global squeeze ignores it.
        assert_eq!(p.multiplier(2, 45.0), 0.8);
        // `*` covers instances beyond the overrides too — including ones
        // the autoscaler registers later.
        assert_eq!(p.multiplier(7, 45.0), 0.5);
        // Without a `*` entry, untraced instances see no pressure.
        let q = PressureTrace::parse("0:0=0.5").unwrap();
        assert_eq!(q.multiplier(7, 45.0), 1.0);
    }

    #[test]
    fn before_first_step_is_unpressured() {
        let p = PressureTrace::parse("0:10=0.5").unwrap();
        assert_eq!(p.multiplier(0, 5.0), 1.0);
        assert_eq!(p.multiplier(0, 10.0), 0.5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(PressureTrace::parse("").is_err());
        assert!(PressureTrace::parse("*:").is_err());
        assert!(PressureTrace::parse("*:0=0").is_err(), "zero multiplier");
        assert!(PressureTrace::parse("*:0=-0.5").is_err());
        assert!(
            PressureTrace::parse("*:0=1.5").is_err(),
            "co-tenants cannot add capacity"
        );
        assert!(PressureTrace::parse("*:5=0.5,5=0.6").is_err(), "non-ascending");
        assert!(PressureTrace::parse("*:0=1;*:0=0.5").is_err(), "duplicate *");
        assert!(PressureTrace::parse("x:0=1").is_err(), "bad index");
        assert!(PressureTrace::parse("0:0=1;0:1=0.5").is_err(), "duplicate index");
        assert!(PressureTrace::parse("*:nope").is_err());
    }

    #[test]
    fn uniform_builder_matches_parse() {
        let a = PressureTrace::uniform(vec![(0.0, 1.0), (30.0, 0.5)]);
        let b = PressureTrace::parse("*:0=1.0,30=0.5").unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(PressureTrace::default().is_empty());
    }
}
