//! The parallel pump's scoped worker pool — the ONLY module allowed to
//! spawn threads in non-test code (kairos-lint rule `thread-spawn`).
//!
//! Rationale: the repo's determinism guarantees (driver equivalence,
//! record→replay bit-identity, the bench A/B equal-decision asserts) all
//! assume that concurrency never reaches an ordering decision. Confining
//! every spawn to this one module keeps that machine-checkable: the pool
//! below runs a *pure* function over an indexed job list and slots results
//! by job index, so the output is a deterministic function of the input no
//! matter how the OS schedules the workers. Work distribution uses an
//! atomic work-stealing counter (fast, order-free); result placement is
//! by index (order restored).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Evaluate `f` over `jobs`, fanning out across up to `threads` scoped
/// worker threads (`std::thread::scope` — no detached threads, no new
/// dependencies), and return the results in job order.
///
/// Determinism contract: `f` must be a pure function of `(index, job)` and
/// whatever shared state it captures by `&` — the pool adds no ordering of
/// its own because every result lands in its job's slot. With `threads <=
/// 1` (or fewer than two jobs) the pool degenerates to an inline loop, so
/// thread count can never change a result, only wall time.
pub fn run_parallel<J, R, F>(threads: usize, jobs: &[J], f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(usize, &J) -> R + Sync,
{
    if threads <= 1 || jobs.len() <= 1 {
        return jobs.iter().enumerate().map(|(i, j)| f(i, j)).collect();
    }
    let n_workers = threads.min(jobs.len());
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(jobs.len());
    slots.resize_with(jobs.len(), || None);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            handles.push(scope.spawn(|| {
                // Claim jobs by atomic counter: whichever worker takes job
                // i computes exactly f(i, &jobs[i]); the pairs carry the
                // index home so placement is order-free.
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    local.push((i, f(i, &jobs[i])));
                }
                local
            }));
        }
        for h in handles {
            match h.join() {
                Ok(pairs) => {
                    for (i, r) in pairs {
                        slots[i] = Some(r);
                    }
                }
                // A worker panicked (f itself failed): surface the original
                // panic on the caller's thread instead of a poisoned
                // placeholder result.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots
        .into_iter()
        .map(|s| match s {
            Some(r) => r,
            // Unreachable by construction: the counter hands out every
            // index in [0, jobs.len()) exactly once and each worker's
            // results were drained above.
            None => unreachable!("pump pool worker skipped a job slot"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_job_order_at_every_thread_count() {
        let jobs: Vec<u64> = (0..137).collect();
        let expect: Vec<u64> = jobs.iter().map(|j| j * j + 1).collect();
        for threads in [1, 2, 3, 4, 8, 64] {
            let got = run_parallel(threads, &jobs, |_, j| j * j + 1);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn index_reaches_the_job_function() {
        let jobs = vec!["a", "b", "c"];
        let got = run_parallel(2, &jobs, |i, j| format!("{i}:{j}"));
        assert_eq!(got, vec!["0:a", "1:b", "2:c"]);
    }

    #[test]
    fn empty_and_single_job_lists_run_inline() {
        let none: Vec<u32> = Vec::new();
        assert!(run_parallel(8, &none, |_, j| *j).is_empty());
        assert_eq!(run_parallel(8, &[7u32], |_, j| *j + 1), vec![8]);
    }

    #[test]
    fn worker_panic_propagates() {
        let jobs: Vec<u32> = (0..8).collect();
        let r = std::panic::catch_unwind(|| {
            run_parallel(4, &jobs, |_, j| {
                assert!(*j != 5, "boom on 5");
                *j
            })
        });
        assert!(r.is_err());
    }
}
