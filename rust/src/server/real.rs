//! Wall-clock driver over the shared serving runtime, on real PJRT compute.
//!
//! The same [`Coordinator`](super::coordinator::Coordinator) as the
//! virtual-time driver — central queue, priority scheduler, dispatcher,
//! continuous-batching engines — but the engines run the AOT-compiled tiny
//! model through [`PjrtExecBackend`] and the clock is a [`WallClock`]. This
//! is what `examples/quickstart.rs` drives: a real small model serving
//! batched requests end to end with Python nowhere on the request path.

use std::path::Path;

use crate::dispatch::DispatchPolicy;
use crate::engine::core::{EngineConfig, EngineCore};
use crate::engine::pjrt_backend::PjrtExecBackend;
use crate::engine::request::RequestId;
use crate::lb::policies::SchedulePolicy;
use crate::runtime::{ByteTokenizer, TinyModel};
use crate::server::coordinator::{Clock, Coordinator, FleetSpec, InstanceSpec};
use crate::Time;

// ---------------------------------------------------------------------------
// Wall clock
//
// This module is the single place allowed to read real time (lint rule D1):
// every other component takes `now` from its caller, so the virtual-time
// driver and this one run the same coordination code.

/// Wall-clock time since construction (the real-serving driver's clock).
pub struct WallClock {
    origin: std::time::Instant,
}

impl WallClock {
    /// Anchor the clock at the current instant; [`Clock::now`] reports
    /// seconds elapsed since then.
    #[allow(clippy::disallowed_methods)] // the one sanctioned wall-time read
    pub fn new() -> WallClock {
        WallClock { origin: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Time {
        self.origin.elapsed().as_secs_f64()
    }
}

/// One serving response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub agent: String,
    pub prompt: String,
    pub completion: String,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Queue wait + execution, wall seconds.
    pub e2e_seconds: f64,
    pub queue_seconds: f64,
}

/// Aggregate stats of a real serving run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub n_requests: usize,
    pub total_tokens: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    pub mean_e2e: f64,
    pub p90_e2e: f64,
    pub compute_seconds: f64,
}

/// A request waiting to be served (text level).
pub struct ServeRequest {
    pub agent: String,
    pub prompt: String,
    pub max_tokens: usize,
}

/// The real-mode server: N PJRT engine instances behind one coordinator.
pub struct RealServer {
    coord: Coordinator<PjrtExecBackend>,
    tokenizer: ByteTokenizer,
}

impl RealServer {
    /// Load `n_instances` copies of the AOT artifact `model_name`.
    pub fn new(
        artifacts: &Path,
        model_name: &str,
        n_instances: usize,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> crate::Result<RealServer> {
        anyhow::ensure!(n_instances > 0);
        let mut engines = Vec::new();
        let mut vocab = 256;
        let mut fleet = FleetSpec::default();
        for i in 0..n_instances {
            let model = TinyModel::load(artifacts, model_name)?;
            vocab = model.manifest.vocab_size;
            let max_seq = model.manifest.max_seq as u32;
            let batch = model.manifest.batch;
            let backend = PjrtExecBackend::new(model);
            // Engine geometry comes from the compiled model's manifest, not
            // the cost model; the fleet spec stays the nominal description.
            let cfg = EngineConfig {
                model: crate::engine::cost_model::ModelKind::Tiny,
                block_size: 4,
                total_blocks: batch as u32 * max_seq / 4,
                max_batch: batch,
                max_prefill_tokens: 1 << 20,
                prefix_cache_blocks: 0,
            };
            fleet.push(
                InstanceSpec::new(crate::engine::cost_model::ModelKind::Tiny)
                    .with_max_batch(batch),
            );
            engines.push(EngineCore::new(i, cfg, backend));
        }
        let coord = Coordinator::from_engines(fleet, policy, dispatcher, engines);
        Ok(RealServer { coord, tokenizer: ByteTokenizer::new(vocab) })
    }

    /// The underlying runtime (inspection in tests).
    pub fn coordinator(&self) -> &Coordinator<PjrtExecBackend> {
        &self.coord
    }

    /// Serve a batch of requests to completion; returns responses in
    /// completion order plus run statistics.
    pub fn serve(
        &mut self,
        requests: Vec<ServeRequest>,
    ) -> crate::Result<(Vec<Response>, ServeStats)> {
        let clock = WallClock::new();

        let mut meta: std::collections::HashMap<RequestId, (String, String, Time)> =
            std::collections::HashMap::new();
        let max_tokens_cap = self
            .coord
            .engines
            .first()
            .map(|e| e.backend.max_tokens())
            .unwrap_or(16);
        for r in requests {
            let tokens = self.tokenizer.encode(&r.prompt);
            let prompt_len = tokens.len().clamp(1, max_tokens_cap / 2);
            let tokens = tokens[..prompt_len].to_vec();
            let output = r.max_tokens.clamp(1, max_tokens_cap - prompt_len);
            let t = clock.now();
            let id = self.coord.submit_external(&r.agent, prompt_len as u32, output as u32, t);
            // Every instance could host the request: register its prompt
            // with each backend (registration is cheap).
            for e in self.coord.engines.iter_mut() {
                e.backend.set_prompt(id, tokens.clone());
            }
            meta.insert(id, (r.agent, r.prompt, t));
        }

        let mut responses = Vec::new();
        let mut guard = 0usize;
        loop {
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "serve loop guard tripped");
            // Dispatch as much as possible, then step every engine with
            // work — the coordination decisions all live in the runtime.
            self.coord.pump(clock.now());
            let mut any = false;
            for j in 0..self.coord.n_instances() {
                if !self.coord.engines[j].has_work() {
                    continue;
                }
                any = true;
                let out = self.coord.step_engine(j, clock.now());
                let t_done = clock.now();
                if out.prefill_tokens == 0 && out.n_decode == 0 {
                    // The iteration did nothing (the wall-clock backend
                    // still reports a tiny positive duration): the engine
                    // is idle with unadmittable work — shed it instead of
                    // spinning.
                    self.coord.drain_stuck(j);
                    continue;
                }
                let absorbed = self.coord.absorb(j, out, t_done);
                for seq in absorbed.completed {
                    let id = seq.req.id;
                    // `serve` returns `Result`, so a missing generation or
                    // meta entry becomes an error instead of a panic on the
                    // serving path (lint D6).
                    let gen = self.coord.engines[j]
                        .backend
                        .take_generation(id)
                        .ok_or_else(|| {
                            anyhow::anyhow!("no generation state for request {id}")
                        })?;
                    let (agent, prompt, arrived) = meta.remove(&id).ok_or_else(|| {
                        anyhow::anyhow!("no submission meta for request {id}")
                    })?;
                    responses.push(Response {
                        id,
                        agent,
                        prompt,
                        completion: self.tokenizer.decode(&gen.generated),
                        prompt_tokens: gen.prompt.len(),
                        output_tokens: gen.generated.len(),
                        e2e_seconds: t_done - arrived,
                        queue_seconds: seq.first_admitted_at.unwrap_or(t_done) - arrived,
                    });
                }
            }
            if !any && self.coord.queue.is_empty() {
                break;
            }
        }

        let wall = clock.now();
        let total_tokens: usize = responses.iter().map(|r| r.output_tokens).sum();
        let e2es: Vec<f64> = responses.iter().map(|r| r.e2e_seconds).collect();
        let summary = crate::stats::summary::Summary::from_samples(&e2es);
        let compute: f64 = self
            .coord
            .engines
            .iter()
            .map(|e| e.backend.compute_seconds)
            .sum();
        let stats = ServeStats {
            n_requests: responses.len(),
            total_tokens,
            wall_seconds: wall,
            tokens_per_second: total_tokens as f64 / wall.max(1e-9),
            mean_e2e: summary.as_ref().map(|s| s.mean()).unwrap_or(0.0),
            p90_e2e: summary.as_ref().map(|s| s.p90()).unwrap_or(0.0),
            compute_seconds: compute,
        };
        Ok((responses, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn serves_real_requests_end_to_end() {
        if !artifacts_dir().join("micro_manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let mut server = RealServer::new(
            &artifacts_dir(),
            "micro",
            1,
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        )
        .unwrap();
        let reqs = (0..5)
            .map(|i| ServeRequest {
                agent: format!("agent{i}"),
                prompt: format!("task number {i}"),
                max_tokens: 6,
            })
            .collect();
        let (responses, stats) = server.serve(reqs).unwrap();
        assert_eq!(responses.len(), 5);
        assert_eq!(stats.n_requests, 5);
        assert!(stats.total_tokens >= 5);
        assert!(stats.tokens_per_second > 0.0);
        assert!(stats.compute_seconds > 0.0);
        for r in &responses {
            assert!(r.output_tokens > 0);
            assert!(!r.completion.is_empty());
        }
        // The coordination stack recorded every request through the same
        // metrics path as the virtual-time driver.
        assert_eq!(server.coordinator().metrics.requests.len(), 5);
        assert_eq!(server.coordinator().dispatch_log.len(), 5);
    }
}
