//! Wall-clock serving over real PJRT compute.
//!
//! The same coordination stack as [`super::sim`] — central queue, priority
//! scheduler, dispatcher, continuous-batching engines — but the engines run
//! the AOT-compiled tiny model through [`PjrtExecBackend`] and the clock is
//! `std::time::Instant`. This is what `examples/quickstart.rs` drives: a
//! real small model serving batched requests end to end with Python nowhere
//! on the request path.

use std::path::Path;
use std::time::Instant;

use crate::dispatch::DispatchPolicy;
use crate::engine::core::{EngineConfig, EngineCore};
use crate::engine::pjrt_backend::PjrtExecBackend;
use crate::engine::request::Request;
use crate::lb::policies::SchedulePolicy;
use crate::lb::queue::RequestQueue;
use crate::runtime::{ByteTokenizer, TinyModel};
use crate::Time;

/// One serving response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub agent: String,
    pub prompt: String,
    pub completion: String,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Queue wait + execution, wall seconds.
    pub e2e_seconds: f64,
    pub queue_seconds: f64,
}

/// Aggregate stats of a real serving run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub n_requests: usize,
    pub total_tokens: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    pub mean_e2e: f64,
    pub p90_e2e: f64,
    pub compute_seconds: f64,
}

/// A request waiting to be served (text level).
pub struct ServeRequest {
    pub agent: String,
    pub prompt: String,
    pub max_tokens: usize,
}

/// The real-mode server: N PJRT engine instances behind one queue.
pub struct RealServer {
    engines: Vec<EngineCore<PjrtExecBackend>>,
    tokenizer: ByteTokenizer,
    policy: Box<dyn SchedulePolicy>,
    dispatcher: Box<dyn DispatchPolicy>,
}

impl RealServer {
    /// Load `n_instances` copies of the AOT artifact `model_name`.
    pub fn new(
        artifacts: &Path,
        model_name: &str,
        n_instances: usize,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> crate::Result<RealServer> {
        anyhow::ensure!(n_instances > 0);
        let mut engines = Vec::new();
        let mut vocab = 256;
        for i in 0..n_instances {
            let model = TinyModel::load(artifacts, model_name)?;
            vocab = model.manifest.vocab_size;
            let max_seq = model.manifest.max_seq as u32;
            let batch = model.manifest.batch;
            let backend = PjrtExecBackend::new(model);
            let cfg = EngineConfig {
                block_size: 4,
                total_blocks: batch as u32 * max_seq / 4,
                max_batch: batch,
                max_prefill_tokens: 1 << 20,
            };
            engines.push(EngineCore::new(i, cfg, backend));
        }
        Ok(RealServer {
            engines,
            tokenizer: ByteTokenizer::new(vocab),
            policy,
            dispatcher,
        })
    }

    /// Serve a batch of requests to completion; returns responses in
    /// completion order plus run statistics.
    pub fn serve(
        &mut self,
        requests: Vec<ServeRequest>,
    ) -> crate::Result<(Vec<Response>, ServeStats)> {
        let t0 = Instant::now();
        let now = |t0: Instant| -> Time { t0.elapsed().as_secs_f64() };

        let mut queue = RequestQueue::new();
        let mut meta: std::collections::HashMap<u64, (String, String, Time)> =
            std::collections::HashMap::new();
        let max_tokens_cap = self
            .engines
            .first()
            .map(|e| e.backend.max_tokens())
            .unwrap_or(16);
        for (i, r) in requests.into_iter().enumerate() {
            let id = i as u64 + 1;
            let tokens = self.tokenizer.encode(&r.prompt);
            let prompt_len = tokens.len().clamp(1, max_tokens_cap / 2);
            let tokens = tokens[..prompt_len].to_vec();
            let output = r.max_tokens.clamp(1, max_tokens_cap - prompt_len);
            for e in self.engines.iter_mut() {
                // every instance could host it; register prompt lazily at
                // dispatch instead — but registration is cheap, do it now.
                e.backend.set_prompt(id, tokens.clone());
            }
            let t = now(t0);
            meta.insert(id, (r.agent.clone(), r.prompt.clone(), t));
            let request = Request {
                id,
                msg_id: id,
                agent: crate::orchestrator::ids::AgentId(0),
                upstream: None,
                prompt_tokens: prompt_len as u32,
                true_output_tokens: output as u32,
                true_remaining_latency: 0.0,
                remaining_stages: 1,
                app_start: t,
                stage_arrival: t,
            };
            queue.push(request, self.policy.as_ref());
        }

        let mut responses = Vec::new();
        let mut guard = 0usize;
        loop {
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "serve loop guard tripped");
            // Dispatch as much as possible.
            loop {
                if queue.is_empty() {
                    break;
                }
                let statuses: Vec<_> = self.engines.iter().map(|e| e.status()).collect();
                let t = now(t0);
                let Some(best) = queue.peek_best() else { break };
                // Instances are slot-limited: skip dispatch when full.
                let Some(j) = self
                    .dispatcher
                    .choose(best, &statuses, t)
                    .filter(|&j| statuses[j].n_running + statuses[j].n_waiting
                        < self.engines[j].backend.max_batch())
                else {
                    break;
                };
                let req = queue.pop_best().unwrap();
                self.dispatcher.on_dispatch(&req, j, t);
                self.engines[j].submit(req, t);
            }
            // Step every engine with work.
            let mut any = false;
            for j in 0..self.engines.len() {
                if !self.engines[j].has_work() {
                    continue;
                }
                any = true;
                let t = now(t0);
                let out = self.engines[j].step(t);
                let t_done = now(t0);
                for seq in out.completed {
                    let id = seq.req.id;
                    self.dispatcher.on_complete(id, j, t_done);
                    let gen = self.engines[j]
                        .backend
                        .take_generation(id)
                        .expect("generation state");
                    let (agent, prompt, arrived) =
                        meta.remove(&id).expect("request meta");
                    responses.push(Response {
                        id,
                        agent,
                        prompt,
                        completion: self.tokenizer.decode(&gen.generated),
                        prompt_tokens: gen.prompt.len(),
                        output_tokens: gen.generated.len(),
                        e2e_seconds: t_done - arrived,
                        queue_seconds: seq.admitted_at - arrived,
                    });
                }
            }
            if !any && queue.is_empty() {
                break;
            }
        }

        let wall = now(t0);
        let total_tokens: usize = responses.iter().map(|r| r.output_tokens).sum();
        let e2es: Vec<f64> = responses.iter().map(|r| r.e2e_seconds).collect();
        let summary = crate::stats::summary::Summary::from_samples(&e2es);
        let compute: f64 = self.engines.iter().map(|e| e.backend.compute_seconds).sum();
        let stats = ServeStats {
            n_requests: responses.len(),
            total_tokens,
            wall_seconds: wall,
            tokens_per_second: total_tokens as f64 / wall.max(1e-9),
            mean_e2e: summary.as_ref().map(|s| s.mean()).unwrap_or(0.0),
            p90_e2e: summary.as_ref().map(|s| s.p90()).unwrap_or(0.0),
            compute_seconds: compute,
        };
        Ok((responses, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::RoundRobin;
    use crate::lb::policies::Fcfs;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn serves_real_requests_end_to_end() {
        if !artifacts_dir().join("micro_manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let mut server = RealServer::new(
            &artifacts_dir(),
            "micro",
            1,
            Box::new(Fcfs),
            Box::new(RoundRobin::new()),
        )
        .unwrap();
        let reqs = (0..5)
            .map(|i| ServeRequest {
                agent: format!("agent{i}"),
                prompt: format!("task number {i}"),
                max_tokens: 6,
            })
            .collect();
        let (responses, stats) = server.serve(reqs).unwrap();
        assert_eq!(responses.len(), 5);
        assert_eq!(stats.n_requests, 5);
        assert!(stats.total_tokens >= 5);
        assert!(stats.tokens_per_second > 0.0);
        assert!(stats.compute_seconds > 0.0);
        for r in &responses {
            assert!(r.output_tokens > 0);
            assert!(!r.completion.is_empty());
        }
    }
}
